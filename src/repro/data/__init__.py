from repro.data import synthetic, tokens  # noqa: F401
