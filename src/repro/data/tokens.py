"""Synthetic token streams for LM-architecture FFT experiments and for the
training/serving drivers: a class-conditioned bigram process so that (a) a
model can actually reduce loss, and (b) each FL client's "domain" (= label
class in the paper's histogram machinery) induces a distinct token
distribution — letting the FedAuto class-histogram weights act on LM clients
via hashed token-class buckets (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def make_bigram_stream(n_tokens: int, vocab: int, domain: int,
                       n_domains: int, seed: int = 0) -> np.ndarray:
    """Markov token stream whose transition structure depends on `domain`."""
    rng = np.random.default_rng(seed * 1000 + domain)
    out = np.empty(n_tokens, dtype=np.int32)
    t = rng.integers(0, vocab)
    stride = (domain * 2 + 3) % max(vocab - 1, 1) + 1
    for i in range(n_tokens):
        out[i] = t
        if rng.uniform() < 0.8:
            t = (t * 7 + stride) % vocab       # domain-specific deterministic hop
        else:
            t = rng.integers(0, vocab)
    return out


def batches_from_stream(stream: np.ndarray, batch: int, seq: int,
                        seed: int = 0):
    rng = np.random.default_rng(seed)
    n = len(stream) - seq - 1
    while True:
        starts = rng.integers(0, n, batch)
        toks = np.stack([stream[s:s + seq] for s in starts])
        labels = np.stack([stream[s + 1:s + seq + 1] for s in starts])
        yield toks.astype(np.int32), labels.astype(np.int32)


def token_class_histogram(tokens: np.ndarray, n_buckets: int) -> np.ndarray:
    """Hashed token histogram — the LM generalization of label histograms."""
    t = tokens.reshape(-1).astype(np.int64)
    return np.bincount((t * 2654435761 % (2 ** 31)) % n_buckets,
                       minlength=n_buckets).astype(np.int64)
