"""Synthetic class-structured image datasets (offline stand-ins for
MNIST/CIFAR — see DESIGN.md §2).

Each class is a random smooth prototype image; samples are prototype +
per-sample Gaussian noise + random shift. Linearly separable enough for the
paper's small CNN/ResNet to reach high accuracy in a few hundred steps, with
genuine cross-class confusability (shared low-frequency structure) so
non-iid bias effects reproduce qualitatively.

The FFT split mirrors the paper: a *public* server set with broad class
coverage but few samples per class, and client *private* sets partitioned by
``repro.fl.partition``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass
class Dataset:
    x: np.ndarray          # (N, H, W, C) float32
    y: np.ndarray          # (N,) int32
    n_classes: int


def _prototypes(rng, n_classes, image_size, channels):
    base = rng.normal(0.0, 1.0, (image_size // 4, image_size // 4, channels))
    protos = []
    for c in range(n_classes):
        p = 0.35 * base + rng.normal(0.0, 1.0, base.shape)
        p = np.kron(p, np.ones((4, 4, 1)))            # smooth upsample
        protos.append(p)
    return np.stack(protos).astype(np.float32)


def make_dataset(n_samples: int, n_classes: int = 10, image_size: int = 32,
                 channels: int = 3, noise: float = 0.9,
                 seed: int = 0) -> Dataset:
    rng = np.random.default_rng(seed)
    protos = _prototypes(rng, n_classes, image_size, channels)
    y = rng.integers(0, n_classes, n_samples).astype(np.int32)
    x = protos[y] + noise * rng.normal(0.0, 1.0, (n_samples, image_size,
                                                  image_size, channels))
    shift = rng.integers(-2, 3, (n_samples, 2))
    for i in range(n_samples):                        # small translations
        x[i] = np.roll(x[i], tuple(shift[i]), axis=(0, 1))
    return Dataset(x=x.astype(np.float32), y=y, n_classes=n_classes)


def train_test_split(dataset: Dataset, n_test: int,
                     seed: int = 0) -> Tuple[Dataset, Dataset]:
    """Split one generated dataset (same class prototypes!) into train/test."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(dataset.y))
    te, tr = perm[:n_test], perm[n_test:]
    return (Dataset(dataset.x[tr], dataset.y[tr], dataset.n_classes),
            Dataset(dataset.x[te], dataset.y[te], dataset.n_classes))


def fft_split(dataset: Dataset, *, public_per_class: int,
              seed: int = 0) -> Tuple[Dataset, Dataset]:
    """Split into (public server set with ≤ public_per_class samples/class,
    private pool for the clients) — the paper's data regime (§II-A)."""
    rng = np.random.default_rng(seed)
    pub_idx = []
    for c in range(dataset.n_classes):
        pool = np.where(dataset.y == c)[0]
        pub_idx.extend(rng.permutation(pool)[:public_per_class].tolist())
    pub_idx = np.array(sorted(pub_idx))
    priv_mask = np.ones(len(dataset.y), dtype=bool)
    priv_mask[pub_idx] = False
    priv_idx = np.where(priv_mask)[0]
    pub = Dataset(dataset.x[pub_idx], dataset.y[pub_idx], dataset.n_classes)
    priv = Dataset(dataset.x[priv_idx], dataset.y[priv_idx], dataset.n_classes)
    return pub, priv
