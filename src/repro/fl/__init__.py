from repro.fl import failures, lora, network, parallel, partition, runtime  # noqa: F401
