from repro.fl import (failures, lora, network, parallel, partition,  # noqa: F401
                      runtime, scenarios)
