"""Fused server-side aggregation of quantized payloads.

The generic path decodes every payload to float32 and then β-reduces
(``aggregate_pytrees``) — M·4 bytes/param of HBM traffic.  When every
upload is an int8-family payload (``int8``, ``qsgd:<bits>``, ``sign1``),
the dequantize and the β-reduction fuse into one pass over the 1-byte
payloads (``kernels.ops.dequant_fedagg``; Pallas on TPU):

    Σ_m β_m · decode(p_m)  =  Σ_m (β_m s_m^{(leaf)}) · q_m^{(leaf)}

``aggregate_quantized`` returns that β-weighted *decoded-delta* sum.  With β
on the simplex the full FedAvg-style model aggregate follows as
``t_global + aggregate_quantized(...)`` since Σ β_m t_global = t_global —
see ``bench_comm.py`` for the fused-vs-unfused comparison and
``tests/test_comm.py`` for the fp32-tolerance equivalence.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

from repro.fl.comm.codecs import Payload
from repro.kernels import ops as kops

_QUANT_KEYS = {"q", "scale"}


def is_quantized(payload: Payload) -> bool:
    """True iff every leaf is an int8-family (q, scale) payload."""
    return all(set(el.data) == _QUANT_KEYS and el.data["q"].dtype == jnp.int8
               for el in payload.leaves)


def aggregate_quantized(payloads: Sequence[Payload], betas) -> object:
    """β-weighted sum of decoded payload pytrees, dequantized in-kernel.

    payloads: M same-structure int8-family payloads; betas: (M,).
    Returns the pytree Σ_m β_m · decode(payloads[m]) in float32.
    """
    if not payloads:
        raise ValueError("aggregate_quantized needs at least one payload")
    if not all(is_quantized(p) for p in payloads):
        raise ValueError("aggregate_quantized only takes int8-family "
                         "payloads (int8 / qsgd:<bits> / sign1)")
    betas = jnp.asarray(betas, jnp.float32)
    n_leaves = len(payloads[0].leaves)
    out_leaves: List[jnp.ndarray] = []
    for li in range(n_leaves):
        els = [p.leaves[li] for p in payloads]
        q = jnp.stack([e.data["q"].reshape(-1) for e in els])       # (M, P)
        scales = jnp.stack([jnp.asarray(e.data["scale"], jnp.float32)
                            for e in els])                          # (M,)
        flat = kops.dequant_fedagg(q, scales, betas)                # (P,)
        out_leaves.append(flat.reshape(els[0].shape))
    return jax.tree.unflatten(payloads[0].treedef, out_leaves)
