"""Adaptive per-client codec assignment from *observed* round outcomes.

FedAuto's promise is robustness without prior knowledge of network
conditions; a deployment that statically picks one codec for every client
either wastes capacity on fast links (everyone pays sign1's fidelity loss)
or keeps losing slow ones (everyone ships fp32 into a deadline they cannot
make).  The ``AdaptiveCommController`` closes that gap with the only
information a real server has: which selected clients' uploads landed, and
when.  It never reads ``LinkState`` — capacity is *estimated*, not leaked.

``FFTConfig.codec = "adaptive:<lo>-<hi>"`` (e.g. ``adaptive:sign1-fp16``)
selects a contiguous slice of the rung ladder

    sign1 → qsgd:2 → … → qsgd:8 → int8 → fp16 → fp32

ordered by fidelity (and, because every rung's byte count is
value-independent, by non-decreasing bytes-on-wire).  Each round, each
client is assigned the *richest* rung whose predicted landing time fits
inside a safety fraction of the deadline:

    t_pred(i, rung) = compute_prior + wire_bits(rung) / ĉ_i

where ĉ_i is the client's estimated effective capacity (bits/s) and
``wire_bits`` counts the uplink payload plus the broadcast at the assumed
downlink asymmetry.  The estimate is AIMD-flavored and needs no oracle:

* a landed upload updates ĉ_i by EWMA toward the implied throughput
  ``wire_bits / (finish_s − compute_prior)`` — *asymmetrically*: upward
  moves use the faster ``ewma_up`` (an arrival is direct evidence the link
  sustained that rate; climbing fast keeps a recovered client from lingering
  on coarse rungs, whose isolated one-shot updates are far noisier than the
  repeated ones error feedback is built for), downward moves the slower
  ``ewma_down``;
* a missed deadline (indistinguishable from a dead link, exactly as for a
  real server) multiplies ĉ_i by ``backoff`` — the client slides down the
  ladder until its uploads land again.

The controller starts optimistic (round 1 assigns ``hi`` to everyone), is
fully deterministic given the observed event stream, and therefore replays
bit-exactly from a recorded trace: the same events re-derive the same
assignments, and the v3 trace's per-round byte vectors cross-check that
nothing drifted.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

import numpy as np

# Fidelity-ordered rung ladder; byte counts are non-decreasing left→right
# (qsgd:8 and int8 tie at 1 B/param + 4 B scale).
RUNG_LADDER: Tuple[str, ...] = (
    "sign1", "qsgd:2", "qsgd:3", "qsgd:4", "qsgd:5", "qsgd:6", "qsgd:7",
    "qsgd:8", "int8", "fp16", "fp32")


def is_adaptive_spec(spec: str) -> bool:
    return spec == "adaptive" or spec.startswith("adaptive:")


def parse_adaptive_spec(spec: str) -> Tuple[str, str]:
    """``"adaptive:<lo>-<hi>"`` → ``(lo, hi)`` rung names; bare
    ``"adaptive"`` spans the full ladder."""
    if spec == "adaptive":
        return RUNG_LADDER[0], RUNG_LADDER[-1]
    if not spec.startswith("adaptive:"):
        raise ValueError(f"not an adaptive codec spec: {spec!r}")
    body = spec.split(":", 1)[1]
    parts = body.split("-")
    if len(parts) != 2:
        raise ValueError(
            f"bad adaptive spec {spec!r}: want adaptive:<lo>-<hi> with "
            f"rungs from {RUNG_LADDER}")
    lo, hi = parts
    for name in (lo, hi):
        if name not in RUNG_LADDER:
            raise ValueError(f"bad adaptive spec {spec!r}: {name!r} is not "
                             f"a ladder rung {RUNG_LADDER}")
    if RUNG_LADDER.index(lo) > RUNG_LADDER.index(hi):
        raise ValueError(f"bad adaptive spec {spec!r}: lo rung {lo!r} is "
                         f"richer than hi rung {hi!r}")
    return lo, hi


def ladder_between(lo: str, hi: str) -> Tuple[str, ...]:
    return RUNG_LADDER[RUNG_LADDER.index(lo):RUNG_LADDER.index(hi) + 1]


@dataclasses.dataclass
class RoundAssignment:
    """One round's per-client codec decision (what the v3+ trace records).

    ``rung_idx``/``upload_bytes`` cover all N clients (the policy is a
    deterministic function of the estimates, and the simulator prices every
    link), but only the entries where ``selected`` is True describe rungs
    the server actually handed out — histograms and trace rows mask by it.
    The decision is stored array-backed (``rung_idx`` into ``rungs``);
    ``codecs`` materializes the historical per-client name list on demand.
    """
    rnd: int
    rung_idx: np.ndarray         # (N,) int index into ``rungs``
    rungs: Tuple[str, ...]       # ladder slice the indices refer to
    upload_bytes: np.ndarray     # (N,) simulated uplink wire bytes
    download_bytes: float        # broadcast bytes each client receives
    selected: Optional[np.ndarray] = None  # (N,) bool; None = all selected

    @property
    def codecs(self) -> List[str]:
        """Per-client rung names (derived view over ``rung_idx``)."""
        return [self.rungs[k] for k in self.rung_idx]


class AdaptiveCommController:
    """Online per-client bit-width policy over a rung ladder.

    ``assign(r)`` must be called once per round in order, ``observe(r, …)``
    after the round's events are known; both are deterministic functions of
    the observation history, which is what makes adaptive runs replayable.
    """

    def __init__(self, n_clients: int, comm, *, lo: str, hi: str,
                 deadline_s: float, compute_s: float = 2.0,
                 safety: float = 0.9, ewma_up: float = 0.7,
                 ewma_down: float = 0.35, backoff: float = 0.5,
                 dl_ratio: float = 8.0):
        self.n_clients = n_clients
        self.rungs = ladder_between(lo, hi)
        self.rung_bytes = np.array([comm.nbytes_for(name)
                                    for name in self.rungs], dtype=float)
        self.download_bytes = float(comm.download_bytes)
        self.deadline_s = float(deadline_s)
        self.fixed_s = float(compute_s)      # compute prior (config, no oracle)
        self.safety = float(safety)
        self.ewma_up = float(ewma_up)
        self.ewma_down = float(ewma_down)
        self.backoff = float(backoff)
        self.dl_ratio = float(dl_ratio)
        # bits each rung moves end-to-end: uplink payload + the broadcast
        # crossing the (assumed) dl_ratio-times-faster downlink
        self.wire_bits = (self.rung_bytes +
                          self.download_bytes / self.dl_ratio) * 8.0
        self.budget_s = self.safety * self.deadline_s
        # clamped into (0, 1e9]: an infinite (or sub-compute) deadline must
        # not poison cap_init with 0 or inf — 0 * inf = NaN would demote
        # everyone to the coarsest rung instead of the optimistic hi probe
        self.transfer_budget_s = max(min(self.budget_s - self.fixed_s, 1e9),
                                     1e-6)
        # optimistic start: exactly the capacity at which hi fits the budget,
        # so round 1 probes the richest rung and misses back off from there
        self.cap_init = float(self.wire_bits[-1] / self.transfer_budget_s)
        self.cap_min = float(self.wire_bits[0] / self.transfer_budget_s) * 1e-3
        self.cap_max = 1e18
        # telemetry hub (repro.obs); the runner swaps in a live one per
        # instrumented run
        from repro.obs.telemetry import NULL_TELEMETRY
        self.telemetry = NULL_TELEMETRY
        self.reset()

    def reset(self) -> None:
        """Back to the optimistic prior (start of a run): estimates are
        per-run state, like error-feedback residuals."""
        self.cap_hat = np.full(self.n_clients, self.cap_init)
        self.assignments: Dict[int, RoundAssignment] = {}
        self.n_success = 0
        self.n_miss = 0
        self._last_idx: Optional[np.ndarray] = None  # previous rung indices

    # ------------------------------------------------------------- policy
    def rung_index_for(self, cap_bps: float) -> int:
        """Richest feasible rung index at estimated capacity ``cap_bps``
        (monotone non-decreasing in capacity; 0 when nothing fits)."""
        feasible = self.wire_bits <= cap_bps * self.transfer_budget_s
        if not feasible.any():
            return 0
        # wire_bits is non-decreasing, so the feasible set is a prefix
        return int(np.nonzero(feasible)[0][-1])

    def rung_for(self, cap_bps: float) -> str:
        return self.rungs[self.rung_index_for(cap_bps)]

    def rung_indices(self, cap_bps: np.ndarray) -> np.ndarray:
        """Vectorized ``rung_index_for`` over a capacity array.

        ``wire_bits`` is non-decreasing, so the feasible set at any capacity
        is a prefix of the ladder and the richest feasible rung is simply
        ``count(feasible) − 1`` (0 when nothing fits) — one broadcasted
        comparison instead of N python loops."""
        cap_bps = np.asarray(cap_bps, dtype=float)
        feasible = (self.wire_bits[None, :]
                    <= cap_bps[:, None] * self.transfer_budget_s)
        return np.maximum(feasible.sum(axis=1) - 1, 0)

    def landable_mask(self) -> np.ndarray:
        """(N,) bool: True where the current capacity estimate can land at
        least the *lowest* rung inside the transfer budget — the
        straggler-skip predicate (``FFTConfig.skip_stragglers``).  A False
        entry means even the coarsest upload is predicted to miss the
        deadline, so selecting that client buys nothing this round."""
        return self.wire_bits[0] <= self.cap_hat * self.transfer_budget_s

    def assign(self, rnd: int, selected: Optional[np.ndarray] = None,
               download_bytes: Optional[float] = None) -> RoundAssignment:
        """Assign this round's rungs.  ``selected`` masks the clients the
        server actually contacts this round: assignments are still computed
        for everyone (the policy is deterministic and the simulator prices
        every link), but stats and trace rows only count selected clients —
        a rung the server never handed out is not an assignment.
        ``download_bytes`` overrides the steady-state broadcast size for
        this round (the round-1 full-model enrollment transfer) so
        ``observe`` later divides the wire bits that actually traveled by
        the observed time."""
        tel = self.telemetry
        with tel.timer("phase.controller"):
            idx_arr = self.rung_indices(self.cap_hat)
            a = RoundAssignment(
                rnd=rnd,
                rung_idx=idx_arr,
                rungs=self.rungs,
                upload_bytes=self.rung_bytes[idx_arr].copy(),
                download_bytes=(self.download_bytes if download_bytes is None
                                else float(download_bytes)),
                selected=(None if selected is None
                          else np.asarray(selected, dtype=bool).copy()))
            self.assignments[rnd] = a
            if tel:
                if self._last_idx is not None:
                    # fraction of clients whose assigned rung changed since
                    # the previous assignment — the health monitors' rung-
                    # thrash signal (policy instability, not selection noise,
                    # so it is measured over all clients)
                    churn = float((idx_arr != self._last_idx).mean())
                    tel.gauge(rnd, "rung_churn", churn)
                # per-client capacity estimates as a distribution (folded
                # into a quantile sketch in sketch mode, dropped in full
                # mode where cap_hat_mean_bps already summarizes them)
                tel.distribution(rnd, "cap_hat_bps", self.cap_hat)
            self._last_idx = idx_arr
        return a

    # ---------------------------------------------------------- learning
    def observe(self, rnd: int, events, selected: np.ndarray) -> None:
        """Update capacity estimates from one round's resolved events.

        Only *selected* clients are observed (the server sent nothing to the
        rest), and only through what a server sees: landed uploads carry an
        arrival instant; everything else — outage or straggler alike — is
        one undifferentiated miss.
        """
        a = self.assignments.get(rnd)
        if a is None:
            return
        tel = self.telemetry
        with tel.timer("phase.controller"):
            sel = np.asarray(selected, dtype=bool)
            finish = events.finish_array()
            met = events.deadline_mask()
            landed = sel & met & np.isfinite(finish)
            missed = sel & ~(met & np.isfinite(finish))
            wire_bits = (a.upload_bytes +
                         a.download_bytes / self.dl_ratio) * 8.0
            with np.errstate(divide="ignore", invalid="ignore"):
                obs = wire_bits / np.maximum(finish - self.fixed_s, 1e-3)
            w = np.where(obs > self.cap_hat, self.ewma_up, self.ewma_down)
            ewma = (1.0 - w) * self.cap_hat + w * obs
            cap = np.where(landed, ewma,
                           np.where(missed, self.cap_hat * self.backoff,
                                    self.cap_hat))
            # clip only the clients observed this round (the rest keep
            # their estimate verbatim, clipped or not)
            self.cap_hat = np.where(
                sel, np.minimum(np.maximum(cap, self.cap_min), self.cap_max),
                cap)
            n_landed = int(landed.sum())
            n_sel = int(sel.sum())
            self.n_success += n_landed
            self.n_miss += n_sel - n_landed
            if tel:
                tel.counter("adaptive.landed", n_landed)
                tel.counter("adaptive.missed", n_sel - n_landed)
                tel.gauge(rnd, "cap_hat_mean_bps",
                          float(self.cap_hat.mean()))

    # ------------------------------------------------------- persistence
    def save_state(self, path: str) -> None:
        """Persist the learned capacity estimates as JSON.

        The estimates are the controller's only cross-round state: a later
        run that loads them skips the optimistic-probe warm-up and opens on
        each client's converged rung (``FFTConfig.controller_state_in``)."""
        state = {
            "version": 1,
            "n_clients": self.n_clients,
            "rungs": list(self.rungs),
            "cap_hat_bps": [float(c) for c in self.cap_hat],
            "n_success": int(self.n_success),
            "n_miss": int(self.n_miss),
        }
        with open(path, "w") as f:
            json.dump(state, f)

    def load_state(self, path: str) -> None:
        """Warm-start capacity estimates from ``save_state`` output.

        The ladder slice may differ between runs (estimates are in bps,
        rung-independent), but the population size must match — estimates
        are indexed by client id."""
        with open(path) as f:
            state = json.load(f)
        n = int(state["n_clients"])
        if n != self.n_clients:
            raise ValueError(
                f"controller state {path} was saved for {n} clients but "
                f"this run has {self.n_clients}; capacity estimates are "
                "indexed by client id and cannot be remapped")
        cap = np.asarray(state["cap_hat_bps"], dtype=float)
        self.cap_hat = np.minimum(np.maximum(cap, self.cap_min), self.cap_max)
        self.n_success = int(state.get("n_success", 0))
        self.n_miss = int(state.get("n_miss", 0))

    # ------------------------------------------------------------- stats
    def rung_histogram(self) -> Dict[str, int]:
        """Total per-rung assignment counts across all rounds so far —
        *selected* clients only: a rung computed for a client the server
        never contacted that round is policy state, not an assignment."""
        totals = np.zeros(len(self.rungs), dtype=np.int64)
        for a in self.assignments.values():
            idx = (a.rung_idx if a.selected is None
                   else a.rung_idx[a.selected])
            totals += np.bincount(idx, minlength=len(self.rungs))
        return {name: int(totals[k]) for k, name in enumerate(self.rungs)}
