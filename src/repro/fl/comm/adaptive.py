"""Adaptive per-client codec assignment from *observed* round outcomes.

FedAuto's promise is robustness without prior knowledge of network
conditions; a deployment that statically picks one codec for every client
either wastes capacity on fast links (everyone pays sign1's fidelity loss)
or keeps losing slow ones (everyone ships fp32 into a deadline they cannot
make).  The ``AdaptiveCommController`` closes that gap with the only
information a real server has: which selected clients' uploads landed, and
when.  It never reads ``LinkState`` — capacity is *estimated*, not leaked.

``FFTConfig.codec = "adaptive:<lo>-<hi>"`` (e.g. ``adaptive:sign1-fp16``)
selects a contiguous slice of the rung ladder

    sign1 → qsgd:2 → … → qsgd:8 → int8 → fp16 → fp32

ordered by fidelity (and, because every rung's byte count is
value-independent, by non-decreasing bytes-on-wire).  Each round, each
client is assigned the *richest* rung whose predicted landing time fits
inside a safety fraction of the deadline:

    t_pred(i, rung) = compute_prior + wire_bits(rung) / ĉ_i

where ĉ_i is the client's estimated effective capacity (bits/s) and
``wire_bits`` counts the uplink payload plus the broadcast at the assumed
downlink asymmetry.  The estimate is AIMD-flavored and needs no oracle:

* a landed upload updates ĉ_i by EWMA toward the implied throughput
  ``wire_bits / (finish_s − compute_prior)`` — *asymmetrically*: upward
  moves use the faster ``ewma_up`` (an arrival is direct evidence the link
  sustained that rate; climbing fast keeps a recovered client from lingering
  on coarse rungs, whose isolated one-shot updates are far noisier than the
  repeated ones error feedback is built for), downward moves the slower
  ``ewma_down``;
* a missed deadline (indistinguishable from a dead link, exactly as for a
  real server) multiplies ĉ_i by ``backoff`` — the client slides down the
  ladder until its uploads land again.

The controller starts optimistic (round 1 assigns ``hi`` to everyone), is
fully deterministic given the observed event stream, and therefore replays
bit-exactly from a recorded trace: the same events re-derive the same
assignments, and the v3 trace's per-round byte vectors cross-check that
nothing drifted.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

# Fidelity-ordered rung ladder; byte counts are non-decreasing left→right
# (qsgd:8 and int8 tie at 1 B/param + 4 B scale).
RUNG_LADDER: Tuple[str, ...] = (
    "sign1", "qsgd:2", "qsgd:3", "qsgd:4", "qsgd:5", "qsgd:6", "qsgd:7",
    "qsgd:8", "int8", "fp16", "fp32")


def is_adaptive_spec(spec: str) -> bool:
    return spec == "adaptive" or spec.startswith("adaptive:")


def parse_adaptive_spec(spec: str) -> Tuple[str, str]:
    """``"adaptive:<lo>-<hi>"`` → ``(lo, hi)`` rung names; bare
    ``"adaptive"`` spans the full ladder."""
    if spec == "adaptive":
        return RUNG_LADDER[0], RUNG_LADDER[-1]
    if not spec.startswith("adaptive:"):
        raise ValueError(f"not an adaptive codec spec: {spec!r}")
    body = spec.split(":", 1)[1]
    parts = body.split("-")
    if len(parts) != 2:
        raise ValueError(
            f"bad adaptive spec {spec!r}: want adaptive:<lo>-<hi> with "
            f"rungs from {RUNG_LADDER}")
    lo, hi = parts
    for name in (lo, hi):
        if name not in RUNG_LADDER:
            raise ValueError(f"bad adaptive spec {spec!r}: {name!r} is not "
                             f"a ladder rung {RUNG_LADDER}")
    if RUNG_LADDER.index(lo) > RUNG_LADDER.index(hi):
        raise ValueError(f"bad adaptive spec {spec!r}: lo rung {lo!r} is "
                         f"richer than hi rung {hi!r}")
    return lo, hi


def ladder_between(lo: str, hi: str) -> Tuple[str, ...]:
    return RUNG_LADDER[RUNG_LADDER.index(lo):RUNG_LADDER.index(hi) + 1]


@dataclasses.dataclass
class RoundAssignment:
    """One round's per-client codec decision (what the v3+ trace records).

    ``codecs``/``upload_bytes`` cover all N clients (the policy is a
    deterministic function of the estimates, and the simulator prices every
    link), but only the entries where ``selected`` is True describe rungs
    the server actually handed out — histograms and trace rows mask by it.
    """
    rnd: int
    codecs: List[str]            # per-client rung name
    upload_bytes: np.ndarray     # (N,) simulated uplink wire bytes
    download_bytes: float        # broadcast bytes each client receives
    selected: Optional[np.ndarray] = None  # (N,) bool; None = all selected


class AdaptiveCommController:
    """Online per-client bit-width policy over a rung ladder.

    ``assign(r)`` must be called once per round in order, ``observe(r, …)``
    after the round's events are known; both are deterministic functions of
    the observation history, which is what makes adaptive runs replayable.
    """

    def __init__(self, n_clients: int, comm, *, lo: str, hi: str,
                 deadline_s: float, compute_s: float = 2.0,
                 safety: float = 0.9, ewma_up: float = 0.7,
                 ewma_down: float = 0.35, backoff: float = 0.5,
                 dl_ratio: float = 8.0):
        self.n_clients = n_clients
        self.rungs = ladder_between(lo, hi)
        self.rung_bytes = np.array([comm.nbytes_for(name)
                                    for name in self.rungs], dtype=float)
        self.download_bytes = float(comm.download_bytes)
        self.deadline_s = float(deadline_s)
        self.fixed_s = float(compute_s)      # compute prior (config, no oracle)
        self.safety = float(safety)
        self.ewma_up = float(ewma_up)
        self.ewma_down = float(ewma_down)
        self.backoff = float(backoff)
        self.dl_ratio = float(dl_ratio)
        # bits each rung moves end-to-end: uplink payload + the broadcast
        # crossing the (assumed) dl_ratio-times-faster downlink
        self.wire_bits = (self.rung_bytes +
                          self.download_bytes / self.dl_ratio) * 8.0
        self.budget_s = self.safety * self.deadline_s
        # clamped into (0, 1e9]: an infinite (or sub-compute) deadline must
        # not poison cap_init with 0 or inf — 0 * inf = NaN would demote
        # everyone to the coarsest rung instead of the optimistic hi probe
        self.transfer_budget_s = max(min(self.budget_s - self.fixed_s, 1e9),
                                     1e-6)
        # optimistic start: exactly the capacity at which hi fits the budget,
        # so round 1 probes the richest rung and misses back off from there
        self.cap_init = float(self.wire_bits[-1] / self.transfer_budget_s)
        self.cap_min = float(self.wire_bits[0] / self.transfer_budget_s) * 1e-3
        self.cap_max = 1e18
        # telemetry hub (repro.obs); the runner swaps in a live one per
        # instrumented run
        from repro.obs.telemetry import NULL_TELEMETRY
        self.telemetry = NULL_TELEMETRY
        self.reset()

    def reset(self) -> None:
        """Back to the optimistic prior (start of a run): estimates are
        per-run state, like error-feedback residuals."""
        self.cap_hat = np.full(self.n_clients, self.cap_init)
        self.assignments: Dict[int, RoundAssignment] = {}
        self.n_success = 0
        self.n_miss = 0
        self._last_idx: Optional[np.ndarray] = None  # previous rung indices

    # ------------------------------------------------------------- policy
    def rung_index_for(self, cap_bps: float) -> int:
        """Richest feasible rung index at estimated capacity ``cap_bps``
        (monotone non-decreasing in capacity; 0 when nothing fits)."""
        feasible = self.wire_bits <= cap_bps * self.transfer_budget_s
        if not feasible.any():
            return 0
        # wire_bits is non-decreasing, so the feasible set is a prefix
        return int(np.nonzero(feasible)[0][-1])

    def rung_for(self, cap_bps: float) -> str:
        return self.rungs[self.rung_index_for(cap_bps)]

    def assign(self, rnd: int, selected: Optional[np.ndarray] = None,
               download_bytes: Optional[float] = None) -> RoundAssignment:
        """Assign this round's rungs.  ``selected`` masks the clients the
        server actually contacts this round: assignments are still computed
        for everyone (the policy is deterministic and the simulator prices
        every link), but stats and trace rows only count selected clients —
        a rung the server never handed out is not an assignment.
        ``download_bytes`` overrides the steady-state broadcast size for
        this round (the round-1 full-model enrollment transfer) so
        ``observe`` later divides the wire bits that actually traveled by
        the observed time."""
        tel = self.telemetry
        with tel.timer("phase.controller"):
            idx = [self.rung_index_for(c) for c in self.cap_hat]
            a = RoundAssignment(
                rnd=rnd,
                codecs=[self.rungs[k] for k in idx],
                upload_bytes=self.rung_bytes[idx].copy(),
                download_bytes=(self.download_bytes if download_bytes is None
                                else float(download_bytes)),
                selected=(None if selected is None
                          else np.asarray(selected, dtype=bool).copy()))
            self.assignments[rnd] = a
            idx_arr = np.asarray(idx)
            if tel:
                if self._last_idx is not None:
                    # fraction of clients whose assigned rung changed since
                    # the previous assignment — the health monitors' rung-
                    # thrash signal (policy instability, not selection noise,
                    # so it is measured over all clients)
                    churn = float((idx_arr != self._last_idx).mean())
                    tel.gauge(rnd, "rung_churn", churn)
                # per-client capacity estimates as a distribution (folded
                # into a quantile sketch in sketch mode, dropped in full
                # mode where cap_hat_mean_bps already summarizes them)
                tel.distribution(rnd, "cap_hat_bps", self.cap_hat)
            self._last_idx = idx_arr
        return a

    # ---------------------------------------------------------- learning
    def observe(self, rnd: int, events, selected: np.ndarray) -> None:
        """Update capacity estimates from one round's resolved events.

        Only *selected* clients are observed (the server sent nothing to the
        rest), and only through what a server sees: landed uploads carry an
        arrival instant; everything else — outage or straggler alike — is
        one undifferentiated miss.
        """
        a = self.assignments.get(rnd)
        if a is None:
            return
        tel = self.telemetry
        with tel.timer("phase.controller"):
            for i in range(self.n_clients):
                if not bool(selected[i]):
                    continue
                e = events.events[i]
                wire_bits = (a.upload_bytes[i] +
                             a.download_bytes / self.dl_ratio) * 8.0
                if e.met_deadline and math.isfinite(e.finish_s):
                    obs = wire_bits / max(e.finish_s - self.fixed_s, 1e-3)
                    w = (self.ewma_up if obs > self.cap_hat[i]
                         else self.ewma_down)
                    self.cap_hat[i] = (1.0 - w) * self.cap_hat[i] + w * obs
                    self.n_success += 1
                else:
                    self.cap_hat[i] *= self.backoff
                    self.n_miss += 1
                self.cap_hat[i] = min(max(self.cap_hat[i], self.cap_min),
                                      self.cap_max)
            if tel:
                n_sel = int(np.asarray(selected, dtype=bool).sum())
                n_landed = sum(
                    1 for i in range(self.n_clients) if bool(selected[i])
                    and events.events[i].met_deadline
                    and math.isfinite(events.events[i].finish_s))
                tel.counter("adaptive.landed", n_landed)
                tel.counter("adaptive.missed", n_sel - n_landed)
                tel.gauge(rnd, "cap_hat_mean_bps",
                          float(self.cap_hat.mean()))

    # ------------------------------------------------------------- stats
    def rung_histogram(self) -> Dict[str, int]:
        """Total per-rung assignment counts across all rounds so far —
        *selected* clients only: a rung computed for a client the server
        never contacted that round is policy state, not an assignment."""
        hist = {name: 0 for name in self.rungs}
        for a in self.assignments.values():
            for i, name in enumerate(a.codecs):
                if a.selected is None or a.selected[i]:
                    hist[name] += 1
        return hist
