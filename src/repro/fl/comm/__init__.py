"""Communication codec subsystem (compressed uploads, bytes-on-wire).

Three layers:

* ``codecs`` — registry of update codecs (``FFTConfig.codec = "fp32" |
  "fp16" | "int8" | "qsgd:<bits>" | "topk:<frac>" | "sign1" | "lora_only"``)
  mapping update pytrees to payloads with exact, value-independent byte
  counts.
* ``state``  — per-run ``CommState``: client-side encode / server-side
  decode with per-client error-feedback residuals, the downlink broadcast
  codec with server-side error feedback, plus the upload/download byte
  accounting the deadline simulator prices rounds with.
* ``adaptive`` — the per-client, per-round bit-width controller behind
  ``FFTConfig.codec = "adaptive:<lo>-<hi>"``: estimates each client's
  effective capacity online from observed arrivals/misses (no oracle) and
  assigns the richest rung of the ladder predicted to land in time.
* ``stream`` — the streaming server side: ``StreamAccumulator`` consumes
  packed ``(payload, β)`` pairs incrementally through the batched
  decode-and-accumulate kernels, so K arrivals never materialize K fp32
  delta pytrees (see ``CommState.encode_upload`` / ``decode_upload`` for
  the client/server halves of the old ``roundtrip``).
* the batched decode-and-accumulate Pallas kernels live with the other
  kernels (``repro.kernels.dequant_agg``; dispatch via ``kernels.ops``).
"""
from repro.fl.comm.adaptive import (RUNG_LADDER, AdaptiveCommController,
                                    RoundAssignment, is_adaptive_spec,
                                    ladder_between, parse_adaptive_spec)
from repro.fl.comm.codecs import (CODECS, Codec, EncodedLeaf, Payload,
                                  available_codecs, make_codec)
from repro.fl.comm.fused import aggregate_quantized, is_quantized
from repro.fl.comm.state import CommState, fp32_nbytes
from repro.fl.comm.stream import (PackedUpdate, StreamAccumulator,
                                  payload_family, weighted_model_sum,
                                  weighted_tree_sum)

__all__ = [
    "CODECS", "Codec", "EncodedLeaf", "Payload", "available_codecs",
    "make_codec", "CommState", "fp32_nbytes",
    "aggregate_quantized", "is_quantized",
    "PackedUpdate", "StreamAccumulator", "payload_family",
    "weighted_model_sum", "weighted_tree_sum",
    "RUNG_LADDER", "AdaptiveCommController", "RoundAssignment",
    "is_adaptive_spec", "ladder_between", "parse_adaptive_spec",
]
