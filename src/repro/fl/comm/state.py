"""Per-run communication state: error feedback + bytes-on-wire accounting.

``CommState`` sits between a client's local update and the server's
aggregation: the client encodes its *delta* from the round's global model
(plus its carried error-feedback residual), the link carries exactly
``payload.nbytes`` bytes, and the server decodes back to a model pytree, so
every strategy aggregates reconstructed models unchanged.

Error feedback (EF / EF21 family): for client i with residual e_i,

    c   = (w_i − w̄) + e_i          # compress the residual-corrected delta
    p   = encode(c);  d = decode(p)
    e_i ← c − d                     # what the wire dropped, retried next time
    ŵ_i = w̄ + d                    # what the server reconstructs

For lossless codecs e_i stays exactly zero and ŵ_i ≡ w_i (up to fp32 cast).
The residual carry is what keeps biased compressors (deterministic
quantizers, top-k, sign) convergent: the compression error is not lost, it
is re-sent, so the *cumulative* decoded mass tracks the cumulative true
delta with bounded lag (tested as residual contraction in
``tests/test_comm.py``).

Byte accounting: every codec's payload size is value-independent, so
``upload_nbytes`` is known before local training — the deadline simulator
prices uploads with it.  When ``FFTConfig.model_bytes`` overrides the
derived fp32 size (simulating a larger model over the same toy problem),
upload bytes scale by the codec's exact compression ratio on the real
template, keeping the override and the codec composable.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.fl.comm.codecs import Codec, Payload


def fp32_nbytes(template) -> int:
    """Bytes of the baseline uncompressed fp32 upload of ``template``."""
    return sum(4 * l.size for l in jax.tree.leaves(template))


class CommState:
    """Codec + per-client error-feedback residuals for one runner."""

    def __init__(self, codec: Codec, template, *,
                 model_bytes_override: Optional[float] = None,
                 lora_cfg=None):
        codec.validate_template(template, lora_cfg=lora_cfg)
        self.codec = codec
        self.fp32_nbytes = fp32_nbytes(template)
        self.wire_nbytes = codec.nbytes(template)
        self.compression_ratio = self.wire_nbytes / max(self.fp32_nbytes, 1)
        # Simulated sizes: exact codec bytes by default; scaled by the
        # codec's measured ratio under an explicit model_bytes override.
        if model_bytes_override is None:
            self.download_bytes = float(self.fp32_nbytes)
            self.upload_bytes = float(self.wire_nbytes)
        else:
            self.download_bytes = float(model_bytes_override)
            self.upload_bytes = float(model_bytes_override *
                                      self.compression_ratio)
        self._residuals: Dict[int, Any] = {}
        self.total_uplink_bytes = 0.0          # cumulative, all clients
        self.n_encoded = 0

    # ---------------------------------------------------------------- wire
    def reset(self) -> None:
        self._residuals.clear()
        self.total_uplink_bytes = 0.0
        self.n_encoded = 0

    def residual(self, client: int):
        return self._residuals.get(client)

    def roundtrip(self, client: int, model, global_params
                  ) -> Tuple[Any, Payload]:
        """Client-encode then server-decode one upload.

        Returns ``(reconstructed_model, payload)`` where the reconstruction
        has ``model``'s dtypes and the payload carries the exact wire bytes.
        Mutates the client's error-feedback residual (lossy codecs only).
        """
        delta = jax.tree.map(
            lambda w, g: w.astype(jnp.float32) - g.astype(jnp.float32),
            model, global_params)
        if self.codec.lossless:
            payload = self.codec.encode(delta)
            decoded = self.codec.decode(payload)
        else:
            resid = self._residuals.get(client)
            carry = (delta if resid is None else
                     jax.tree.map(jnp.add, delta, resid))
            payload = self.codec.encode(carry)
            decoded = self.codec.decode(payload)
            self._residuals[client] = jax.tree.map(jnp.subtract, carry,
                                                   decoded)
        recon = jax.tree.map(
            lambda g, d: (g.astype(jnp.float32) + d).astype(g.dtype),
            global_params, decoded)
        self.total_uplink_bytes += payload.nbytes
        self.n_encoded += 1
        return recon, payload
