"""Per-run communication state: error feedback + bytes-on-wire accounting.

``CommState`` sits between a client's local update and the server's
aggregation: the client encodes its *delta* from the round's global model
(plus its carried error-feedback residual), the link carries exactly
``payload.nbytes`` bytes, and the server decodes back to a model pytree, so
every strategy aggregates reconstructed models unchanged.

Error feedback (EF / EF21 family): for client i with residual e_i,

    c   = (w_i − w̄) + e_i          # compress the residual-corrected delta
    p   = encode(c);  d = decode(p)
    e_i ← c − d                     # what the wire dropped, retried next time
    ŵ_i = w̄ + d                    # what the server reconstructs

For lossless codecs e_i stays exactly zero and ŵ_i ≡ w_i (up to fp32 cast).
The residual carry is what keeps biased compressors (deterministic
quantizers, top-k, sign) convergent: the compression error is not lost, it
is re-sent, so the *cumulative* decoded mass tracks the cumulative true
delta with bounded lag (tested as residual contraction in
``tests/test_comm.py``).  The residual is per-*client* and codec-agnostic
— the adaptive controller may hand a client a different rung every round
and the carry still conserves mass (a lossless rung flushes it to zero).

Downlink: the server's broadcast travels through ``downlink_codec`` with a
*server-side* error-feedback residual of the same shape: the server tracks
``_dl_ref``, the decoded global replica every client holds, encodes the
delta (new global − replica) + residual each round, and clients apply the
decoded delta to their replica.  ``broadcast`` returns that replica — the
parameters clients actually start local training from — so the accuracy
cost of compressing the downlink is borne honestly, not just the byte
count.  ``downlink_codec=None`` keeps the exact fp32 broadcast (and the
fp32 byte accounting) of earlier revisions.

Byte accounting: every codec's payload size is value-independent, so
``upload_nbytes`` is known before local training — the deadline simulator
prices uploads with it.  When ``FFTConfig.model_bytes`` overrides the
derived fp32 size (simulating a larger model over the same toy problem),
wire bytes scale by each codec's exact compression ratio on the real
template, keeping the override and every codec (static, downlink, or
adaptive rung) composable.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.comm.codecs import Codec, Payload, make_codec
from repro.obs.telemetry import NULL_TELEMETRY


def fp32_nbytes(template) -> int:
    """Bytes of the baseline uncompressed fp32 upload of ``template``."""
    return sum(4 * l.size for l in jax.tree.leaves(template))


class _ResidualStore:
    """Error-feedback residuals for all clients, leaf-major.

    Dense mode (``n`` known): one ``(N, *leaf.shape)`` float32 array per
    template leaf, allocated lazily on the first lossy store, plus an
    ``(N,)`` presence mask — O(1) per-client access with no dict churn at
    population scale, and the whole store is two allocations instead of N
    pytrees.  Sparse mode (``n`` is None): a plain per-client dict, for
    direct ``CommState`` constructions that never declare a population
    size.  ``get`` always returns a fresh pytree (device copies of the
    rows), so a caller-held residual is never aliased by a later store.
    """

    def __init__(self, template, n: Optional[int]):
        self.n = n
        self._treedef = jax.tree.structure(template)
        self._shapes = [tuple(l.shape) for l in jax.tree.leaves(template)]
        self._dict: Optional[Dict[int, Any]] = {} if n is None else None
        self._stacks: Optional[list] = None
        self._present = None if n is None else np.zeros(n, dtype=bool)

    def __len__(self) -> int:
        if self._dict is not None:
            return len(self._dict)
        return int(self._present.sum())

    def clear(self) -> None:
        if self._dict is not None:
            self._dict.clear()
        else:
            self._stacks = None
            self._present[:] = False

    def get(self, client: int):
        if self._dict is not None:
            return self._dict.get(client)
        if self._stacks is None or not self._present[client]:
            return None
        return jax.tree.unflatten(
            self._treedef, [jnp.asarray(s[client]) for s in self._stacks])

    def set(self, client: int, tree) -> None:
        if self._dict is not None:
            self._dict[client] = tree
            return
        leaves = jax.tree.leaves(tree)
        if self._stacks is None:
            self._stacks = [np.zeros((self.n,) + shp, dtype=np.float32)
                            for shp in self._shapes]
        for s, leaf in zip(self._stacks, leaves):
            s[client] = np.asarray(leaf, dtype=np.float32)
        self._present[client] = True

    def pop(self, client: int) -> None:
        if self._dict is not None:
            self._dict.pop(client, None)
        elif self._present is not None:
            self._present[client] = False


class _DenseFloatMap:
    """Dict-shaped view over a dense ``(N,)`` float array + presence mask.

    Drop-in for the per-client ``last_distortions`` dict when the
    population size is known: ``m[i]`` / ``m[i] = x`` / ``m.get(i)`` /
    ``i in m`` / ``len(m)`` all work, backed by two fixed arrays instead
    of a hash map that churns at population scale."""

    def __init__(self, n: int):
        self._vals = np.zeros(n, dtype=np.float64)
        self._present = np.zeros(n, dtype=bool)

    def __getitem__(self, client: int) -> float:
        if not self._present[client]:
            raise KeyError(client)
        return float(self._vals[client])

    def __setitem__(self, client: int, value: float) -> None:
        self._vals[client] = value
        self._present[client] = True

    def __contains__(self, client) -> bool:
        c = int(client)
        return 0 <= c < len(self._vals) and bool(self._present[c])

    def __len__(self) -> int:
        return int(self._present.sum())

    def get(self, client: int, default: float = None):
        c = int(client)
        if 0 <= c < len(self._vals) and self._present[c]:
            return float(self._vals[c])
        return default

    def clear(self) -> None:
        self._present[:] = False
        self._vals[:] = 0.0

    def keys(self):
        return (int(i) for i in np.nonzero(self._present)[0])

    def items(self):
        return ((int(i), float(self._vals[i]))
                for i in np.nonzero(self._present)[0])


def _l2(tree) -> float:
    """Global L2 norm across all leaves of a pytree (fp32 accumulate)."""
    return float(jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                              for l in jax.tree.leaves(tree))))


class CommState:
    """Codec + per-client error-feedback residuals for one runner."""

    def __init__(self, codec: Codec, template, *,
                 model_bytes_override: Optional[float] = None,
                 lora_cfg=None, downlink_codec: Optional[Codec] = None,
                 n_clients: Optional[int] = None):
        codec.validate_template(template, lora_cfg=lora_cfg)
        if downlink_codec is not None:
            downlink_codec.validate_template(template, lora_cfg=lora_cfg)
        self.codec = codec
        self.downlink_codec = downlink_codec
        self._template = template
        self._lora_cfg = lora_cfg
        self._model_bytes_override = model_bytes_override
        self.fp32_nbytes = fp32_nbytes(template)
        self.wire_nbytes = codec.nbytes(template)
        self.compression_ratio = self.wire_nbytes / max(self.fp32_nbytes, 1)
        self._codec_cache: Dict[str, Codec] = {codec.name: codec}
        self._nbytes_cache: Dict[str, float] = {}
        # Simulated sizes: exact codec bytes by default; scaled by the
        # codec's measured ratio under an explicit model_bytes override.
        # ``ref_bytes`` is the uncompressed fp32 reference everything scales
        # against (the historical ``model_bytes``).
        self.ref_bytes = (float(model_bytes_override)
                          if model_bytes_override is not None
                          else float(self.fp32_nbytes))
        self.upload_bytes = self.nbytes_for(codec)
        self.download_bytes = (self.ref_bytes if downlink_codec is None
                               else self.nbytes_for(downlink_codec))
        # per-client state: dense arrays indexed by client id when the
        # population size is declared, dicts otherwise (see _ResidualStore)
        self.n_clients = n_clients
        self._residuals = _ResidualStore(template, n_clients)
        self._dl_ref = None                    # clients' decoded global replica
        self._dl_residual = None               # server-side EF residual
        self.total_uplink_bytes = 0.0          # cumulative, all clients
        self.total_downlink_bytes = 0.0        # cumulative broadcast bytes
        self.n_encoded = 0
        # last measured normalized compression distortion per client
        # (‖carry − decoded‖/‖carry‖ of the most recent roundtrip; exactly
        # 0.0 for lossless uploads)
        self.last_distortions = (_DenseFloatMap(n_clients)
                                 if n_clients is not None else {})
        # telemetry hub (repro.obs); the runner swaps in a live one per
        # instrumented run — the comm counters are a third, independent
        # accounting the reconcile cross-check compares against
        self.telemetry = NULL_TELEMETRY

    # -------------------------------------------------------------- sizing
    def codec_named(self, name: str) -> Codec:
        """Resolve (and cache) a codec by spec, validated on the template."""
        if name not in self._codec_cache:
            c = make_codec(name)
            c.validate_template(self._template, lora_cfg=self._lora_cfg)
            self._codec_cache[name] = c
        return self._codec_cache[name]

    def nbytes_for(self, codec) -> float:
        """Simulated wire bytes of one upload under ``codec`` (a ``Codec``
        or a spec string): exact template bytes, scaled by the codec's
        measured compression ratio when ``model_bytes`` is overridden.
        Cached per codec name — the result is constant and this sits on the
        per-client per-round upload path."""
        if isinstance(codec, str):
            codec = self.codec_named(codec)
        if codec.name not in self._nbytes_cache:
            exact = codec.nbytes(self._template)
            self._nbytes_cache[codec.name] = (
                float(exact) if self._model_bytes_override is None
                else float(self._model_bytes_override * exact /
                           max(self.fp32_nbytes, 1)))
        return self._nbytes_cache[codec.name]

    # ---------------------------------------------------------------- wire
    def reset(self) -> None:
        self._residuals.clear()
        self._dl_ref = None
        self._dl_residual = None
        self.total_uplink_bytes = 0.0
        self.total_downlink_bytes = 0.0
        self.n_encoded = 0
        self.last_distortions.clear()

    def residual(self, client: int):
        return self._residuals.get(client)

    def _encode(self, client: int, model, global_params,
                codec: Optional[Codec]):
        """Client-side half of one upload: delta, EF carry, encode, residual
        update, byte charging.  Returns ``(payload, decoded, distortion)``.
        The transient ``decoded`` pytree exists because error feedback needs
        the client to know exactly what the server will reconstruct (and the
        distortion measurement rides on it); callers that stream drop it
        immediately, ``roundtrip`` reuses it so the materializing path never
        decodes twice."""
        codec = self.codec if codec is None else codec
        delta = jax.tree.map(
            lambda w, g: w.astype(jnp.float32) - g.astype(jnp.float32),
            model, global_params)
        resid = self._residuals.get(client)
        distortion = 0.0
        if codec.lossless and resid is None:
            payload = codec.encode(delta)
            decoded = codec.decode(payload)
        else:
            carry = (delta if resid is None else
                     jax.tree.map(jnp.add, delta, resid))
            payload = codec.encode(carry)
            decoded = codec.decode(payload)
            if codec.lossless:
                # wire carried the full corrected delta: residual flushed
                self._residuals.pop(client)
            else:
                new_resid = jax.tree.map(jnp.subtract, carry, decoded)
                self._residuals.set(client, new_resid)
                carry_norm = _l2(carry)
                if carry_norm > 0.0:
                    distortion = _l2(new_resid) / carry_norm
        # accumulate *simulated* wire bytes (override-scaled), the same
        # unit the deadline simulator, traces, and total_downlink_bytes
        # use
        nbytes = self.nbytes_for(codec)
        self.total_uplink_bytes += nbytes
        self.n_encoded += 1
        self.last_distortions[client] = distortion
        tel = self.telemetry
        if tel:
            tel.counter("comm.uploads")
            tel.counter("comm.upload_bytes", nbytes)
        return payload, decoded, distortion

    def encode_upload(self, client: int, model, global_params, *,
                      codec: Optional[Codec] = None) -> Tuple[Payload, float]:
        """Client-side encode of one upload, for the streaming server path.

        Returns ``(payload, distortion)`` — the server receives the *packed*
        payload plus wire metadata and feeds it to a
        ``repro.fl.comm.stream.StreamAccumulator`` without ever
        materializing the fp32 delta.  Error-feedback residual mutation,
        distortion bookkeeping, and byte accounting are identical to
        ``roundtrip`` (they are the same code); only the server-side
        reconstruction is omitted."""
        tel = self.telemetry
        with tel.timer("phase.uplink"):
            payload, decoded, distortion = self._encode(
                client, model, global_params, codec)
            if tel:
                # device time is honest only once the encode finished
                jax.block_until_ready([el.data for el in payload.leaves])
        return payload, distortion

    def decode_upload(self, payload: Payload, global_params,
                      codec: Optional[Codec] = None):
        """Server-side decode of one packed upload back to a full model
        pytree — the *materializing* path, for strategies that genuinely
        need per-client models/deltas (Scaffold's control variates, FedLAW's
        proxy optimization, FedExLoRA's adapter products).  Counts itself as
        a fallback in the ``uplink_decode`` attribution so the profiler
        shows when the fused path was not taken."""
        tel = self.telemetry
        with tel.timer("phase.uplink_decode"):
            codec = (self.codec if codec is None else
                     self.codec_named(codec) if isinstance(codec, str)
                     else codec)
            decoded = codec.decode(payload)
            recon = jax.tree.map(
                lambda g, d: (g.astype(jnp.float32) + d).astype(g.dtype),
                global_params, decoded)
            if tel:
                jax.block_until_ready(recon)
                tel.counter("uplink.fallback_payloads")
                tel.counter("uplink.decoded_bytes", self.fp32_nbytes)
        return recon

    def roundtrip(self, client: int, model, global_params, *,
                  codec: Optional[Codec] = None) -> Tuple[Any, Payload, float]:
        """Client-encode then server-decode one upload.

        Returns ``(reconstructed_model, payload, distortion)`` where the
        reconstruction has ``model``'s dtypes, the payload carries the exact
        wire bytes, and ``distortion`` is the upload's normalized
        compression distortion ``‖carry − decoded‖/‖carry‖`` (essentially
        free to measure — both pytrees are already in hand; exactly 0.0 for
        lossless uploads).  Mutates the client's error-feedback residual and
        records the distortion in ``last_distortions[client]``.  ``codec``
        overrides the run's static codec for this one upload (the adaptive
        controller's per-client rung); the residual carries across rung
        changes unchanged — EF is codec-agnostic.

        This is the composition ``encode_upload`` + reconstruction with the
        encode-side transient decode reused (one decode total) — the
        materializing server path.  Streaming strategies take
        ``encode_upload`` alone and never build ``recon``.
        """
        tel = self.telemetry
        with tel.timer("phase.uplink"):
            payload, decoded, distortion = self._encode(
                client, model, global_params, codec)
            recon = jax.tree.map(
                lambda g, d: (g.astype(jnp.float32) + d).astype(g.dtype),
                global_params, decoded)
            if tel:
                # device time is honest only once the reconstruction exists
                jax.block_until_ready(recon)
        return recon, payload, distortion

    # ----------------------------------------------------------- downlink
    def next_broadcast_nbytes(self) -> float:
        """Wire bytes the *next* ``broadcast`` call will charge: the full
        ``ref_bytes`` enrollment transfer for a downlink codec's first
        broadcast, the steady-state ``download_bytes`` otherwise.  The round
        loops query this before the network draw so the deadline simulator,
        the trace, and ``total_downlink_bytes`` all price the same round in
        the same unit."""
        if self.downlink_codec is not None and self._dl_ref is None:
            return float(self.ref_bytes)
        return float(self.download_bytes)

    def broadcast(self, global_params) -> Tuple[Any, float]:
        """Server-encode the round's broadcast; returns ``(params clients
        start from, simulated broadcast bytes)``.

        With no downlink codec the broadcast is the exact global model at
        fp32 size.  With one, the server encodes the delta from the clients'
        decoded replica (plus its error-feedback residual) and the replica
        advances by the decoded delta — every client then trains from the
        replica, never from state it could not have received.  The first
        broadcast initializes the replica to the current global — that
        enrollment transfer ships the *full* model, so it is charged at
        ``ref_bytes`` (the uncompressed fp32 reference), not the compressed
        per-round rate: a 100×-compressed downlink run must still account
        for how clients got the model in the first place.
        """
        tel = self.telemetry
        with tel.timer("phase.downlink"):
            if self.downlink_codec is None:
                self.total_downlink_bytes += self.download_bytes
                if tel:
                    tel.counter("comm.broadcasts")
                    tel.counter("comm.download_bytes", self.download_bytes)
                return global_params, self.download_bytes
            nbytes = self.download_bytes
            if self._dl_ref is None:
                self._dl_ref = jax.tree.map(
                    lambda g: g.astype(jnp.float32), global_params)
                nbytes = self.ref_bytes      # enrollment: full-model transfer
            else:
                delta = jax.tree.map(
                    lambda g, ref: g.astype(jnp.float32) - ref,
                    global_params, self._dl_ref)
                if self._dl_residual is not None:
                    delta = jax.tree.map(jnp.add, delta, self._dl_residual)
                payload = self.downlink_codec.encode(delta)
                decoded = self.downlink_codec.decode(payload)
                if not self.downlink_codec.lossless:
                    self._dl_residual = jax.tree.map(
                        jnp.subtract, delta, decoded)
                self._dl_ref = jax.tree.map(jnp.add, self._dl_ref, decoded)
            self.total_downlink_bytes += nbytes
            out = jax.tree.map(lambda ref, g: ref.astype(g.dtype),
                               self._dl_ref, global_params)
            if tel:
                jax.block_until_ready(out)
                tel.counter("comm.broadcasts")
                tel.counter("comm.download_bytes", nbytes)
        return out, nbytes
