"""Update codecs: client update pytree ⇄ wire payload with exact byte counts.

The paper's failure mechanism is uploads that don't survive the link; the
one lever a deployment has against deadline drops is *sending fewer bytes*.
Each codec here encodes a client's update (the float32 delta from the round's
global model, plus any error-feedback residual) into a ``Payload`` whose
``nbytes`` is the exact bytes-on-wire count, and decodes it server-side.

Crucially, every codec's byte count is a function of the pytree *structure*
only, never of the values (``nbytes(template)``) — so the deadline simulator
can price the upload before local training runs, exactly as a real client
knows its payload size from the model architecture alone.

Registry specs (``FFTConfig.codec``):

  fp32        identity float32 (4 B/param) — the lossless baseline
  fp16        half-precision cast (2 B/param)
  int8        per-leaf absmax linear quantization (1 B/param + 4 B scale)
  qsgd:<b>    b-bit (2..8) absmax quantization, deterministic nearest
              rounding (⌈b·n/8⌉ B + 4 B scale per leaf); the 1-bit
              FeedSign-style case is ``sign1``
  topk:<f>    top-⌈f·n⌉ magnitudes per leaf as (int32 index, fp32 value)
  sign1       1 bit/param sign + per-leaf mean-|x| scale (signSGD/FeedSign)
  lora_only   identity fp32 over a LoRA adapter pytree; *refuses* full-param
              trees, making "adapters only travel" an enforced invariant

All codecs are deterministic (no RNG), so record/replay of a compressed run
is bit-exact; lossy ones stay convergent through the per-client
error-feedback residuals kept by ``CommState`` (see ``state.py``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class EncodedLeaf:
    """One pytree leaf on the wire."""
    shape: Tuple[int, ...]
    data: Dict[str, Any]          # codec-specific arrays/scalars
    nbytes: int                   # exact wire bytes for this leaf


@dataclasses.dataclass
class Payload:
    """One client upload: encoded leaves in ``jax.tree.leaves`` order."""
    codec: str
    leaves: List[EncodedLeaf]
    treedef: Any
    nbytes: int                   # Σ leaf nbytes (what the link carries)


class Codec:
    """Leaf-wise update codec.  ``encode_leaf``/``decode_leaf`` operate on
    float32 arrays; ``leaf_nbytes`` must be value-independent."""

    name = "base"
    lossless = False              # lossless ⇒ no error-feedback residual kept

    def encode_leaf(self, x: jnp.ndarray) -> EncodedLeaf:
        raise NotImplementedError

    def decode_leaf(self, el: EncodedLeaf) -> jnp.ndarray:
        raise NotImplementedError

    def leaf_nbytes(self, shape: Tuple[int, ...]) -> int:
        raise NotImplementedError

    # ------------------------------------------------------------- pytrees
    def encode(self, tree) -> Payload:
        leaves, treedef = jax.tree.flatten(tree)
        enc = [self.encode_leaf(jnp.asarray(l, jnp.float32)) for l in leaves]
        return Payload(codec=self.name, leaves=enc, treedef=treedef,
                       nbytes=sum(e.nbytes for e in enc))

    def decode(self, payload: Payload):
        dec = [self.decode_leaf(e) for e in payload.leaves]
        return jax.tree.unflatten(payload.treedef, dec)

    def nbytes(self, template) -> int:
        """Exact wire bytes for any value with ``template``'s structure."""
        return sum(self.leaf_nbytes(tuple(l.shape))
                   for l in jax.tree.leaves(template))

    def validate_template(self, template, lora_cfg=None) -> None:
        """Hook: codecs with structural requirements raise here."""


def _size(shape: Tuple[int, ...]) -> int:
    return int(np.prod(shape)) if shape else 1


# ---------------------------------------------------------------------------
# lossless float codecs
# ---------------------------------------------------------------------------
class Fp32Codec(Codec):
    name = "fp32"
    lossless = True

    def encode_leaf(self, x):
        return EncodedLeaf(tuple(x.shape), {"v": x},
                           self.leaf_nbytes(tuple(x.shape)))

    def decode_leaf(self, el):
        return el.data["v"]

    def leaf_nbytes(self, shape):
        return 4 * _size(shape)


class Fp16Codec(Codec):
    """Half-precision cast.  Lossy in general (hence error feedback), exact
    on fp16-representable values."""
    name = "fp16"

    def encode_leaf(self, x):
        return EncodedLeaf(tuple(x.shape), {"v": x.astype(jnp.float16)},
                           self.leaf_nbytes(tuple(x.shape)))

    def decode_leaf(self, el):
        return el.data["v"].astype(jnp.float32)

    def leaf_nbytes(self, shape):
        return 2 * _size(shape)


class LoRAOnlyCodec(Fp32Codec):
    """fp32 over adapter factors only.  The runner's trainable pytree *is*
    the adapter dict in LoRA mode, so numerically this is the identity — the
    codec's job is to refuse full-parameter trees, turning "only adapters
    travel" from a convention into an enforced invariant, and to make the
    byte accounting reflect adapter-sized uploads."""
    name = "lora_only"

    def validate_template(self, template, lora_cfg=None) -> None:
        if lora_cfg is None:
            raise ValueError(
                "codec 'lora_only' needs a LoRA run (lora_cfg set): the "
                "trainable pytree must be the adapter dict, not full params")
        ok = (isinstance(template, dict) and template and all(
            isinstance(v, dict) and set(v) == {"a", "b"}
            for v in template.values()))
        if not ok:
            raise ValueError(
                "codec 'lora_only': trainable pytree is not an adapter dict "
                "({path: {'a','b'}}); refusing full-parameter upload")


# ---------------------------------------------------------------------------
# quantizers (deterministic nearest rounding; EF makes them convergent)
# ---------------------------------------------------------------------------
class Int8Codec(Codec):
    """Per-leaf absmax linear quantization to int8: q = round(127·x/‖x‖∞).
    Wire: 1 B/param + one fp32 scale per leaf.  |x − x̂| ≤ scale/2."""
    name = "int8"

    def encode_leaf(self, x):
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return EncodedLeaf(tuple(x.shape), {"q": q, "scale": scale},
                           self.leaf_nbytes(tuple(x.shape)))

    def decode_leaf(self, el):
        return el.data["q"].astype(jnp.float32) * el.data["scale"]

    def leaf_nbytes(self, shape):
        return _size(shape) + 4


class QSGDCodec(Codec):
    """b-bit absmax quantization (levels = 2^{b−1} − 1 signed).
    Deterministic nearest rounding instead of QSGD's
    stochastic rounding — the bias is absorbed by error feedback, and
    determinism is what keeps record/replay and sync-vs-async comparisons
    bit-exact.  Wire: ⌈b·n/8⌉ B + 4 B scale per leaf."""

    def __init__(self, bits: int):
        # 2^b − 1 symmetric values fit b bits; the 1-bit case is ``sign1``
        if not 2 <= bits <= 8:
            raise ValueError(f"qsgd bits must be in 2..8 (1-bit = sign1), "
                             f"got {bits}")
        self.bits = bits
        self.name = f"qsgd:{bits}"
        self.levels = (1 << (bits - 1)) - 1           # signed levels

    def encode_leaf(self, x):
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / self.levels
        q = jnp.clip(jnp.round(x / scale),
                     -self.levels, self.levels).astype(jnp.int8)
        return EncodedLeaf(tuple(x.shape), {"q": q, "scale": scale},
                           self.leaf_nbytes(tuple(x.shape)))

    def decode_leaf(self, el):
        return el.data["q"].astype(jnp.float32) * el.data["scale"]

    def leaf_nbytes(self, shape):
        return math.ceil(self.bits * _size(shape) / 8) + 4


class Sign1Codec(Codec):
    """signSGD / FeedSign-style 1-bit codec: sign(x) at 1 bit/param, scaled
    by the leaf's mean |x| (the L1 scaling that makes signSGD a descent
    direction in expectation).  Wire: ⌈n/8⌉ B + 4 B scale per leaf."""
    name = "sign1"

    def encode_leaf(self, x):
        scale = jnp.mean(jnp.abs(x))
        s = jnp.where(x < 0, jnp.int8(-1), jnp.int8(1))
        return EncodedLeaf(tuple(x.shape), {"q": s, "scale": scale},
                           self.leaf_nbytes(tuple(x.shape)))

    def decode_leaf(self, el):
        return el.data["q"].astype(jnp.float32) * el.data["scale"]

    def leaf_nbytes(self, shape):
        return math.ceil(_size(shape) / 8) + 4


class TopKCodec(Codec):
    """Per-leaf magnitude sparsification: keep the ⌈f·n⌉ largest-|x| entries
    as (int32 index, fp32 value) pairs; everything else is zero server-side
    and carried forward by the error-feedback residual."""

    def __init__(self, frac: float):
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"topk fraction must be in (0, 1], got {frac}")
        self.frac = frac
        self.name = f"topk:{frac:g}"

    def _k(self, shape) -> int:
        return max(1, math.ceil(self.frac * _size(shape)))

    def encode_leaf(self, x):
        flat = x.reshape(-1)
        k = self._k(tuple(x.shape))
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        idx = jnp.sort(idx)                      # deterministic order on wire
        return EncodedLeaf(tuple(x.shape),
                           {"idx": idx.astype(jnp.int32), "val": flat[idx]},
                           self.leaf_nbytes(tuple(x.shape)))

    def decode_leaf(self, el):
        n = _size(el.shape)
        flat = jnp.zeros((n,), jnp.float32).at[el.data["idx"]].set(
            el.data["val"])
        return flat.reshape(el.shape)

    def leaf_nbytes(self, shape):
        return 8 * self._k(shape)                # 4 B index + 4 B value


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
CODECS: Dict[str, Type[Codec]] = {
    "fp32": Fp32Codec,
    "fp16": Fp16Codec,
    "int8": Int8Codec,
    "sign1": Sign1Codec,
    "lora_only": LoRAOnlyCodec,
}

PARAMETRIC_CODECS = ("qsgd", "topk")


def available_codecs() -> List[str]:
    return sorted(CODECS) + [f"{p}:<arg>" for p in PARAMETRIC_CODECS]


def make_codec(spec: str) -> Codec:
    """Parse a codec spec ("fp32", "qsgd:4", "topk:0.1", ...) and build it."""
    spec = spec.strip()
    if spec in CODECS:
        return CODECS[spec]()
    if ":" in spec:
        family, arg = spec.split(":", 1)
        if family == "qsgd":
            try:
                return QSGDCodec(int(arg))
            except ValueError as e:
                raise ValueError(f"bad codec spec {spec!r}: {e}") from None
        if family == "topk":
            try:
                return TopKCodec(float(arg))
            except ValueError as e:
                raise ValueError(f"bad codec spec {spec!r}: {e}") from None
    raise ValueError(f"unknown codec {spec!r}; "
                     f"available: {available_codecs()}")
