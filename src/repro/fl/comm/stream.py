"""Streaming server-side aggregation: K arrivals, one fp32 accumulator.

The materializing server path decodes every upload to a full fp32 model
pytree and hands strategies a ``client_models`` dict — K arrivals cost K
fp32 pytrees of HBM before the β-reduction even starts.  This module is the
other half of the ``CommState.roundtrip`` split: uploads arrive as *packed*
payloads (``CommState.encode_upload``) and a ``StreamAccumulator`` consumes
``(payload, β)`` pairs incrementally, batching per rung family through the
batched decode-and-accumulate kernels (``kernels.ops.dequant_fedagg`` /
``float_fedagg`` / ``topk_fedagg``) into ONE shared fp32 accumulator:

    acc[p] += Σ_{batch} β_m · decode(p_m)[p]        one kernel pass per batch

Peak *decoded* memory is O(1) in K — the accumulator (one fp32 template)
plus one batch's in-flight tile — instead of O(K).  The packed payloads
themselves are wire-sized (the server had to receive those bytes anyway)
and are dropped as soon as their batch flushes.

Mixed-rung cohorts work out of the box: payloads bucket by rung *family*
(``quant`` = int8/qsgd/sign1, ``fp16``, ``fp32``, ``topk:<spec>``) and every
family's partial sums land in the same accumulator.  A payload whose family
is unknown falls back to per-payload decode into the accumulator — counted
in the ``uplink_decode`` attribution so the profiler shows when and why the
fused path was not taken.

``weighted_model_sum`` builds the full strategy-facing aggregate

    Σ_j β_j · (origin_global_j + decode(p_j))  +  Σ_t w_t · tree_t

without materializing any per-client model: the origin-global coefficients
group per *distinct* origin pytree (at most staleness-bound-many under the
async server, exactly one under the sync server), so the dense part of the
sum is O(τ_max) pytrees, never O(K).

Distortion bookkeeping is untouched by streaming: the normalized
compression distortion is measured client-side in ``encode_upload`` (error
feedback already needs the transient decode there) and travels as wire
metadata on the ``PackedUpdate``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.comm.codecs import Payload, make_codec
from repro.kernels import ops as kops
from repro.obs.telemetry import NULL_TELEMETRY

#: rung families a batched kernel exists for (bucket keys of the accumulator)
FUSED_FAMILIES = ("quant", "fp16", "fp32", "topk")


# Jitted flush reductions: a whole batch reduces inside ONE compiled call,
# which is what makes the fused path beat K eager per-payload decodes.  In
# "off" (reference) dispatch the weighted sum is left UNROLLED — XLA fuses
# it into a single pass that reads each packed payload once, which on CPU
# beats stacking into an (M, P) batch by an order of magnitude (the
# many-operand concatenate alone costs more than the reduction).  The
# Pallas modes stack, because the tiled kernels take the (M, P) batch and
# on TPU the stack is a cheap contiguous HBM layout.  ``mode`` is a static
# cache key as well as the dispatch switch, so a kernel-mode change
# (kernels.ops.set_mode) can never hit a trace cached under the old mode.
@functools.partial(jax.jit, static_argnames=("mode",))
def _quant_reduce(qs, scales, betas, *, mode):
    if mode == "off":
        out = None
        for i, (q, s) in enumerate(zip(qs, scales)):
            term = ((betas[i] * jnp.asarray(s, jnp.float32))
                    * q.astype(jnp.float32).reshape(-1))
            out = term if out is None else out + term
        return out
    q = jnp.stack([x.reshape(-1) for x in qs])
    s = jnp.stack([jnp.asarray(x, jnp.float32).reshape(()) for x in scales])
    return kops.dequant_fedagg(q, s, betas)


@functools.partial(jax.jit, static_argnames=("mode",))
def _float_reduce(xs, betas, *, mode):
    if mode == "off":
        out = None
        for i, x in enumerate(xs):
            term = betas[i] * x.astype(jnp.float32).reshape(-1)
            out = term if out is None else out + term
        return out
    return kops.float_fedagg(jnp.stack([x.reshape(-1) for x in xs]), betas)


@functools.partial(jax.jit, static_argnames=("mode", "n"))
def _topk_reduce(idx, vals, betas, *, n, mode):
    # top-k index/value vectors are k-sized, so the stack is cheap in every
    # mode; the scatter fold itself is shared across modes (kernels.ops)
    del mode
    return kops.topk_fedagg(jnp.stack(idx), jnp.stack(vals), betas, n)


@dataclasses.dataclass
class PackedUpdate:
    """One upload exactly as the server receives it on the wire: the packed
    payload plus wire metadata.  ``origin_global`` is the global pytree the
    payload's delta is relative to (the round-r broadcast for a round-r
    upload) — shared by reference across a cohort, never copied."""
    client: int
    payload: Payload
    origin_global: Any
    codec: str
    nbytes: float
    distortion: float
    origin_round: int = 0


def _size(shape) -> int:
    return int(np.prod(shape)) if shape else 1


def payload_family(payload: Payload) -> Optional[str]:
    """The batched-kernel bucket a payload belongs to, or ``None`` when no
    batched kernel covers it (→ per-payload decode fallback).  Top-k buckets
    carry the codec spec — two top-k payloads only stack when their per-leaf
    k agree, which the shared spec guarantees."""
    fams = set()
    for el in payload.leaves:
        keys = set(el.data)
        if keys == {"q", "scale"} and el.data["q"].dtype == jnp.int8:
            fams.add("quant")
        elif keys == {"v"}:
            fams.add("fp16" if el.data["v"].dtype == jnp.float16 else "fp32")
        elif keys == {"idx", "val"}:
            fams.add(payload.codec)              # "topk:<frac>" — k must agree
        else:
            return None
    return fams.pop() if len(fams) == 1 else None


class StreamAccumulator:
    """Incremental β-weighted decode-and-accumulate over packed payloads.

    ``add(payload, β)`` buckets the payload by rung family; every
    ``batch_k`` payloads of a family flush through that family's batched
    kernel into the shared per-leaf fp32 accumulator.  ``total()`` flushes
    the stragglers and returns the accumulated pytree
    ``Σ β_m · decode(p_m)`` in fp32.

    ``peak_decoded_bytes`` tracks the high-water mark of *decoded* fp32
    bytes ever live at once: the accumulator itself plus either one batched
    partial leaf (fused flush) or one template (fallback decode) — O(1) in
    the number of payloads, which is the whole point.  The telemetry
    counters ``uplink.fused_payloads`` / ``uplink.fallback_payloads`` feed
    the profiler's ``uplink_decode`` attribution.
    """

    def __init__(self, template, *, batch_k: int = 64,
                 telemetry=NULL_TELEMETRY):
        leaves, treedef = jax.tree.flatten(template)
        self._treedef = treedef
        self._shapes = [tuple(l.shape) for l in leaves]
        self._acc: Optional[List[jnp.ndarray]] = None
        self._buckets: Dict[str, List[Tuple[Payload, float]]] = {}
        self.batch_k = int(batch_k)
        self.telemetry = telemetry
        self.n_added = 0
        self.n_fused = 0
        self.n_fallback = 0
        self.n_flushes = 0
        self._acc_bytes = sum(4 * _size(s) for s in self._shapes)
        self.peak_decoded_bytes = 0

    # ------------------------------------------------------------- feeding
    def add(self, payload: Payload, beta: float) -> None:
        """Consume one ``(payload, β)`` pair; may trigger a batch flush."""
        self.n_added += 1
        fam = payload_family(payload)
        if fam is None:
            self._fallback(payload, beta)
            return
        bucket = self._buckets.setdefault(fam, [])
        bucket.append((payload, float(beta)))
        if len(bucket) >= self.batch_k:
            self._flush(fam)

    def add_tree(self, tree, weight: float) -> None:
        """Accumulate ``weight · tree`` directly (already-dense terms, e.g.
        a strategy's server-model anchor)."""
        self._ensure_acc()
        w = jnp.float32(weight)
        for li, leaf in enumerate(jax.tree.leaves(tree)):
            self._acc[li] = self._acc[li] + w * (
                leaf.astype(jnp.float32).reshape(-1))

    # ------------------------------------------------------------ flushing
    def _ensure_acc(self) -> None:
        if self._acc is None:
            self._acc = [jnp.zeros((_size(s),), jnp.float32)
                         for s in self._shapes]
            self._note_peak(0)

    def _note_peak(self, transient_bytes: int) -> None:
        live = self._acc_bytes + transient_bytes
        if live > self.peak_decoded_bytes:
            self.peak_decoded_bytes = live

    def _fallback(self, payload: Payload, beta: float) -> None:
        # no batched kernel for this payload: decode it alone and fold it
        # in — one transient fp32 template, immediately released
        codec = make_codec(payload.codec)
        self.add_tree(codec.decode(payload), beta)
        self.n_fallback += 1
        self._note_peak(self._acc_bytes)
        if self.telemetry:
            self.telemetry.counter("uplink.fallback_payloads")
            self.telemetry.counter("uplink.decoded_bytes", self._acc_bytes)

    def _flush(self, fam: str) -> None:
        entries = self._buckets.pop(fam, [])
        if not entries:
            return
        self._ensure_acc()
        betas = jnp.asarray([b for _, b in entries], jnp.float32)
        payloads = [p for p, _ in entries]
        mode = kops.get_mode()
        for li, shape in enumerate(self._shapes):
            els = [p.leaves[li] for p in payloads]
            n = _size(shape)
            if fam == "quant":
                part = _quant_reduce([e.data["q"] for e in els],
                                     [e.data["scale"] for e in els],
                                     betas, mode=mode)
            elif fam in ("fp16", "fp32"):
                part = _float_reduce([e.data["v"] for e in els], betas,
                                     mode=mode)
            else:                                   # topk:<spec>
                part = _topk_reduce([e.data["idx"] for e in els],
                                    [e.data["val"] for e in els],
                                    betas, n=n, mode=mode)
            self._acc[li] = self._acc[li] + part
            self._note_peak(4 * n)          # one batched partial leaf live
        self.n_fused += len(entries)
        self.n_flushes += 1
        if self.telemetry:
            self.telemetry.counter("uplink.fused_payloads", len(entries))

    def total(self):
        """Flush every bucket and return ``Σ β_m·decode(p_m)`` (+ any
        ``add_tree`` terms) as an fp32 pytree of the template's structure.
        An empty accumulator (empty cohort) returns exact zeros."""
        for fam in list(self._buckets):
            self._flush(fam)
        self._ensure_acc()
        return jax.tree.unflatten(
            self._treedef,
            [a.reshape(s) for a, s in zip(self._acc, self._shapes)])

    @property
    def stats(self) -> Dict[str, float]:
        return {"added": self.n_added, "fused": self.n_fused,
                "fallback": self.n_fallback, "flushes": self.n_flushes,
                "peak_decoded_bytes": float(self.peak_decoded_bytes)}


def weighted_tree_sum(trees: Sequence[Any], weights: Sequence[float]):
    """Σ_t w_t · tree_t with fp32 leaves, through the batched float kernel.
    Small-M companion of the accumulator for the dense terms of a streaming
    aggregate (server anchor + distinct origin globals)."""
    if not trees:
        raise ValueError("weighted_tree_sum needs at least one tree")
    w = jnp.asarray(list(weights), jnp.float32)
    leaves0, treedef = jax.tree.flatten(trees[0])
    flats = [jax.tree.leaves(t) for t in trees]
    mode = kops.get_mode()
    out = [_float_reduce([f[li] for f in flats], w, mode=mode)
           .reshape(leaves0[li].shape) for li in range(len(leaves0))]
    return jax.tree.unflatten(treedef, out)


def weighted_model_sum(packed_terms: Sequence[Tuple[float, PackedUpdate]],
                       dense_terms: Sequence[Tuple[float, Any]] = (), *,
                       template, batch_k: int = 64,
                       telemetry=NULL_TELEMETRY, rnd: Optional[int] = None):
    """The streaming form of a strategy's β-weighted model aggregate:

        Σ_j β_j·(origin_global_j + decode(payload_j)) + Σ_t w_t·tree_t

    computed as one StreamAccumulator pass over the packed payloads plus an
    O(#distinct origin globals + #dense terms) dense sum — identical in
    exact arithmetic to materializing every ``origin_global_j +
    decode(payload_j)`` model and β-reducing, without ever building one.
    Returns fp32 leaves (callers cast to their model dtype).  When ``rnd``
    is given, emits the per-round ``uplink_decode`` attribution gauges.
    """
    acc = StreamAccumulator(template, batch_k=batch_k, telemetry=telemetry)
    origin: Dict[int, List[Any]] = {}        # id(tree) -> [tree, coef]
    for beta, pu in packed_terms:
        acc.add(pu.payload, beta)
        ent = origin.setdefault(id(pu.origin_global), [pu.origin_global, 0.0])
        ent[1] += float(beta)
    trees = [t for _, t in dense_terms] + [t for t, _ in origin.values()]
    weights = [w for w, _ in dense_terms] + [c for _, c in origin.values()]
    delta = acc.total()
    if trees:
        base = weighted_tree_sum(trees, weights)
        out = jax.tree.map(jnp.add, base, delta)
    else:
        out = delta
    if telemetry and rnd is not None:
        telemetry.gauge(rnd, "uplink_fused_payloads", acc.n_fused)
        telemetry.gauge(rnd, "uplink_fallback_payloads", acc.n_fallback)
        telemetry.gauge(rnd, "uplink_peak_decoded_bytes",
                        acc.peak_decoded_bytes)
    return out
