"""Connection-failure processes (paper §V-A2 and Appendix III-B).

* Transient  — per-round outage draws from the path-loss channel (Eq. 40).
* Intermittent — renewal process: failure triggers with probability
  1 − exp(−λ_i (r − r_0)) (Eq. 42); once triggered the disconnection lasts
  Uniform[1, duration_max] rounds (paper: [1, 100/α]).
* Mixed — union of both.
* scenario:<name> / replay:<path> — deadline-based scenario worlds and
  bit-exact trace replay from ``repro.fl.scenarios``.

All models expose ``draw(round) -> np.ndarray[bool]`` (True = CONNECTED),
require no prior-knowledge hooks (FedAuto never reads their internals), and
are seeded for reproducibility.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.fl.network import ClientChannel

# Table 8 — intermittent failure rate per client (1-based groups of 4)
def intermittent_rate(i: int) -> float:
    return float(10.0 ** -(5 - min((i) // 4, 4)))   # 1e-5,1e-4,1e-3,1e-2,1e-1


class FailureModel:
    def draw(self, r: int) -> np.ndarray:           # True = connected
        raise NotImplementedError

    def reset(self) -> None:
        pass

    def set_payload_bytes(self, upload_bytes=None, download_bytes=None
                          ) -> None:
        """Per-client, per-direction wire sizes (repro.fl.comm codecs).
        Boolean models have no time dimension, so the default is a no-op;
        timing-aware models forward to their ``DeadlineSimulator``."""


class NoFailures(FailureModel):
    def __init__(self, n: int):
        self.n = n

    def draw(self, r: int) -> np.ndarray:
        return np.ones(self.n, dtype=bool)


class TransientFailures(FailureModel):
    """Outage-driven: client i fails in round r iff C_i^r <= R_i (Eq. 40)."""

    def __init__(self, channels: List[ClientChannel], rate_bps: float,
                 seed: int = 0):
        self.channels = channels
        self.rate = rate_bps
        self.seed = seed
        self.reset()

    def reset(self) -> None:
        self.rng = np.random.default_rng(self.seed)

    def draw(self, r: int) -> np.ndarray:
        return np.array([c.capacity(self.rng) > self.rate for c in self.channels])


class IntermittentFailures(FailureModel):
    """Exponential trigger (Eq. 42) + uniform disconnection duration."""

    def __init__(self, n: int, duration_max: int = 10, seed: int = 0,
                 rates: Optional[np.ndarray] = None):
        self.n = n
        self.duration_max = duration_max
        self.rates = rates if rates is not None else np.array(
            [intermittent_rate(i) for i in range(n)])
        self.seed = seed
        self.reset()

    def reset(self) -> None:
        # reseed so reset() restores the full realization, matching the
        # scenario models' reproducibility contract
        self.rng = np.random.default_rng(self.seed)
        self.last_recovery = np.zeros(self.n, dtype=int)
        self.down_until = -np.ones(self.n, dtype=int)

    def draw(self, r: int) -> np.ndarray:
        up = np.ones(self.n, dtype=bool)
        for i in range(self.n):
            if r < self.down_until[i]:
                up[i] = False
                continue
            if self.down_until[i] >= 0 and r >= self.down_until[i]:
                self.last_recovery[i] = self.down_until[i]
                self.down_until[i] = -1
            p_fail = 1.0 - np.exp(-self.rates[i] * (r - self.last_recovery[i]))
            if self.rng.uniform() < p_fail:
                dur = self.rng.integers(1, self.duration_max + 1)
                self.down_until[i] = r + dur
                up[i] = False
        return up


class MixedFailures(FailureModel):
    def __init__(self, transient: TransientFailures,
                 intermittent: IntermittentFailures):
        self.t = transient
        self.i = intermittent

    def draw(self, r: int) -> np.ndarray:
        return self.t.draw(r) & self.i.draw(r)

    def reset(self) -> None:
        self.t.reset()
        self.i.reset()


def make_failure_model(mode: str, channels: List[ClientChannel],
                       rate_bps: float, *, duration_max: int = 10,
                       seed: int = 0, model_bytes: Optional[float] = None,
                       deadline_s: Optional[float] = None,
                       compute_s: float = 2.0,
                       engine: str = "vectorized") -> FailureModel:
    n = len(channels)
    if mode.startswith("scenario:"):
        # Deadline-based scenario worlds (repro.fl.scenarios). Imported here
        # to keep failures.py import-light and cycle-free.
        from repro.fl import scenarios as scen
        if model_bytes is None or deadline_s is None:
            raise ValueError("scenario:* failure modes need model_bytes "
                             "and deadline_s")
        return scen.make_scenario_model(
            mode.split(":", 1)[1], n, model_bytes=model_bytes,
            deadline_s=deadline_s, compute_s=compute_s, seed=seed,
            channels=channels, engine=engine)
    if mode.startswith("replay:"):
        from repro.fl.scenarios import ReplayFailureModel
        return ReplayFailureModel(mode.split(":", 1)[1], n_clients=n)
    if mode == "none":
        return NoFailures(n)
    if mode == "transient":
        return TransientFailures(channels, rate_bps, seed=seed)
    if mode == "intermittent":
        return IntermittentFailures(n, duration_max=duration_max, seed=seed)
    if mode == "mixed":
        return MixedFailures(TransientFailures(channels, rate_bps, seed=seed),
                             IntermittentFailures(n, duration_max=duration_max,
                                                  seed=seed + 1))
    raise ValueError(mode)
