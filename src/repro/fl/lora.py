"""LoRA substrate (paper §V-C: partial-parameter fine-tuning, rank 8 on the
attention projections).

Generic over any parameter pytree: 2-D weight leaves selected by a path
predicate get (A, B) factors; ``apply_lora`` produces effective params
``W + (α/r)·A@B`` for the forward pass (via the fused Pallas kernel when
enabled), and only the adapters travel between server and clients — which is
what makes FedEx-LoRA's residual (Eq. 52-53) meaningful.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class LoRAConfig:
    rank: int = 8
    alpha: float = 16.0
    match: Callable[[str], bool] = lambda path: path.endswith("qkv/w")

    @property
    def scaling(self) -> float:
        return self.alpha / self.rank


def _iter_paths(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _iter_paths(v, f"{prefix}{k}/")
    else:
        yield prefix[:-1], tree


def lora_paths(params, cfg: LoRAConfig):
    """2-D weights and 3-D scanned layer stacks (leading layer dim)."""
    return [p for p, leaf in _iter_paths(params)
            if hasattr(leaf, "ndim") and leaf.ndim in (2, 3) and cfg.match(p)]


def lora_init(key, params, cfg: LoRAConfig) -> Dict[str, Any]:
    """Returns {path: {"a": (…, d_in, r), "b": (…, r, d_out)}} (b zero-init).
    Stacked (L, d_in, d_out) weights get per-layer (L, …) factors."""
    adapters = {}
    for i, path in enumerate(lora_paths(params, cfg)):
        leaf = _get(params, path)
        k = jax.random.fold_in(key, i)
        d_in, d_out = leaf.shape[-2], leaf.shape[-1]
        lead = leaf.shape[:-2]
        a = (jax.random.normal(k, lead + (d_in, cfg.rank)) /
             jnp.sqrt(d_in)).astype(jnp.float32)
        b = jnp.zeros(lead + (cfg.rank, d_out), jnp.float32)
        adapters[path] = {"a": a, "b": b}
    return adapters


def _get(tree, path):
    node = tree
    for k in path.split("/"):
        node = node[k]
    return node


def _set(tree, path, value):
    keys = path.split("/")
    node = tree
    for k in keys[:-1]:
        node = node[k]
    node[keys[-1]] = value


def apply_lora(params, adapters: Dict[str, Any], cfg: LoRAConfig):
    """Effective params: W_eff = W + scaling · A @ B (copy-on-write)."""
    out = jax.tree.map(lambda x: x, params)        # shallow-structure copy

    def deep(d):
        return {k: deep(v) if isinstance(v, dict) else v for k, v in d.items()}

    out = deep(params)
    for path, ab in adapters.items():
        w = _get(params, path)
        delta = jnp.matmul(ab["a"], ab["b"]) * cfg.scaling   # batched for 3-D
        _set(out, path, (w.astype(jnp.float32) + delta).astype(w.dtype))
    return out


def lora_matmul(x, w, ab, cfg: LoRAConfig):
    """Fused-path forward for a single LoRA layer (kernels.ops dispatch)."""
    return kops.lora_matmul(x, w, ab["a"], ab["b"], cfg.scaling)


def merge_lora(params, adapters, cfg: LoRAConfig):
    return apply_lora(params, adapters, cfg)
