"""FFT round engine (Algorithm 1 + Algorithm 2).

Drives: client selection → failure draw → parallel local SGD (clients +
server, Eq. 2–3) → strategy aggregation (Eq. 5/7). Supports full- and
partial-parameter (LoRA) fine-tuning, all strategies in
``repro.core.strategies``, and the ResourceOpt network interventions.
The round loop itself is pluggable (``repro.fl.server``):
``FFTConfig.server_mode`` picks the synchronous driver or the
staleness-buffered asynchronous/buffered ones.  Client uploads travel
through the communication codec (``FFTConfig.codec``, ``repro.fl.comm``):
encoded client-side after the local update, decoded server-side before
strategy aggregation, with the codec's exact byte count pricing the upload
in the deadline simulator.

Local updates are one jitted ``lax.scan`` of E minibatch-SGD steps; client
datasets are resampled to a common static shape so a single compiled update
serves every participant.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategies import Strategy
from repro.data.synthetic import Dataset
from repro.fl import failures as fail_mod
from repro.fl import network as net_mod
from repro.fl.lora import LoRAConfig, apply_lora, lora_init
from repro.fl.partition import class_histogram


@dataclasses.dataclass
class FFTConfig:
    n_clients: int = 20
    k_selected: int = 20                  # K (20 = full participation)
    local_steps: int = 5                  # E
    batch_size: int = 32
    lr: float = 0.05
    lr_boundary: Optional[int] = None     # step decay at this round
    failure_mode: str = "mixed"           # none | transient | intermittent |
    #                                       mixed | scenario:<name> | replay:<path>
    duration_max: int = 10
    model_bytes: Optional[float] = None   # fp32 upload bytes; None = derive
    #                                       from the actual trainable pytree
    tx_delay_s: float = 0.8
    resource_opt: Optional[str] = None    # None | "joint" | "per_standard"
    seed: int = 0
    eval_every: int = 10
    eval_batch: int = 256
    # --- scenario engine (repro.fl.scenarios) ---------------------------------
    deadline_s: float = 30.0              # server round timeout (scenario modes)
    compute_s: float = 2.0                # mean local-compute wall-clock per round
    engine: str = "vectorized"            # timing engine: "vectorized" batch
    #                                       closed-form | "heap" reference
    #                                       event loop (bit-identical)
    cohort_size: int = 0                  # stream clients through the round in
    #                                       fixed-size cohorts (0 = whole
    #                                       population at once); bounds peak
    #                                       memory at O(cohort) for the
    #                                       timing arrays and local updates
    trace_record: Optional[str] = None    # NDJSON path: record realized rounds
    trace_replay: Optional[str] = None    # NDJSON path: replay (overrides
    #                                       failure_mode)
    trace_mode: str = "auto"              # "full": per-client rows every round
    #                                       (v1–v4 behavior); "sketch": v5
    #                                       bounded rows — per-round counts,
    #                                       cause histogram + GK sketches,
    #                                       regenerable from the seed;
    #                                       "auto": full below
    #                                       TRACE_SKETCH_THRESHOLD clients,
    #                                       sketch at or above it
    # --- asynchronous server (repro.fl.server) --------------------------------
    server_mode: str = "sync"             # sync | async | buffered
    tau_max: int = 5                      # max staleness (rounds) accepted async
    buffer_k: int = 4                     # buffered mode: arrivals per agg step
    streaming_agg: str = "auto"           # "auto": streaming-capable strategies
    #                                       aggregate packed uploads through the
    #                                       StreamAccumulator (K arrivals never
    #                                       materialize K fp32 models); "off":
    #                                       force the materializing path
    #                                       (per-client decoded models) — the
    #                                       benchmark's control arm
    # --- communication codec (repro.fl.comm) ----------------------------------
    codec: str = "fp32"                   # fp32 | fp16 | int8 | qsgd:<bits> |
    #                                       topk:<frac> | sign1 | lora_only |
    #                                       adaptive:<lo>-<hi>
    skip_stragglers: bool = False         # adaptive runs: exclude clients whose
    #                                       capacity estimate cannot land even
    #                                       the lowest rung from selection
    #                                       (telemetry outcome
    #                                       "skipped_straggler")
    controller_state_in: Optional[str] = None   # JSON path: warm-start the
    #                                       adaptive controller's capacity
    #                                       estimates from a previous run
    controller_state_out: Optional[str] = None  # JSON path: persist the
    #                                       controller's converged estimates
    #                                       at run end
    downlink_codec: Optional[str] = None  # broadcast codec; None = fp32 for
    #                                       static runs, the hi rung for
    #                                       adaptive ones ("fp32" forces the
    #                                       uncompressed broadcast)
    fidelity_discount_b: float = 0.0      # exponent b of the (1−d)^b post-QP
    #                                       fidelity discount applied by the
    #                                       fedauto/fedauto_async strategies
    #                                       to each upload's measured
    #                                       compression distortion d (0 = no
    #                                       discount, today's behavior; a
    #                                       strategy's own fidelity_discount
    #                                       knob overrides this)
    # --- run telemetry (repro.obs) --------------------------------------------
    telemetry: Any = False                # per-round flight recorder; off =
    #                                       shared no-op hub, bit-identical
    #                                       to an uninstrumented run.
    #                                       True/"full": per-client rows;
    #                                       "sketch": bounded-memory mode —
    #                                       exact counters/byte totals +
    #                                       streaming quantile sketches,
    #                                       state O(rounds + K) instead of
    #                                       O(n_clients × rounds)
    telemetry_log: Optional[str] = None   # NDJSON event-log path (implies
    #                                       telemetry; observational only —
    #                                       replay never reads it)
    telemetry_console: bool = False       # per-round terminal summary line
    #                                       (implies telemetry)
    telemetry_sketch_k: int = 64          # sketch mode: reservoir-sample rows
    telemetry_health: bool = True         # online run-health monitors (when
    #                                       telemetry is on): alarm records +
    #                                       run-end verdict; observational
    telemetry_trace: Optional[str] = None  # Chrome trace-event JSON path
    #                                       (implies telemetry; open the file
    #                                       in Perfetto for a flamegraph of
    #                                       the phase timers)
    telemetry_dashboard: bool = False     # in-place live console dashboard
    #                                       (implies telemetry)


class FFTRunner:
    """One experiment: (model, data split, network, strategy) → accuracy curve."""

    def __init__(self, cfg: FFTConfig, init_fn: Callable, apply_fn: Callable,
                 public: Dataset, client_indices: Sequence[np.ndarray],
                 private: Dataset, test: Dataset,
                 lora_cfg: Optional[LoRAConfig] = None,
                 pretrain_steps: int = 0):
        self.cfg = cfg
        self.apply_fn = apply_fn
        self.n_clients = cfg.n_clients
        self.k_selected = cfg.k_selected
        self.local_steps = cfg.local_steps
        self.lora_cfg = lora_cfg
        self.rng = np.random.default_rng(cfg.seed)
        key = jax.random.PRNGKey(cfg.seed)

        self.public = public
        self.test = test
        self.n_classes = public.n_classes

        # --- per-client data, resampled to a common static size ------------
        sizes = [max(len(ix), 1) for ix in client_indices]
        self.data_size = max(max(sizes), cfg.batch_size)
        self.client_x, self.client_y = [], []
        for ix in client_indices:
            ix = np.asarray(ix)
            if len(ix) == 0:
                ix = np.array([0])
            res = self.rng.choice(ix, self.data_size, replace=True)
            self.client_x.append(jnp.asarray(private.x[res]))
            self.client_y.append(jnp.asarray(private.y[res]))
        self.client_hists = np.stack([
            class_histogram(private.y[np.asarray(ix)], self.n_classes)
            if len(ix) else np.zeros(self.n_classes, dtype=np.int64)
            for ix in client_indices])
        self.server_hist = class_histogram(public.y, self.n_classes)
        self.global_hist = self.server_hist + self.client_hists.sum(axis=0)

        pub_res = self.rng.choice(len(public.y), self.data_size, replace=True)
        self.public_x = jnp.asarray(public.x[pub_res])
        self.public_y = jnp.asarray(public.y[pub_res])
        self.public_x_raw = jnp.asarray(public.x)
        self.public_y_raw = jnp.asarray(public.y)

        # p weights (Eq. 1): dataset-size proportions, index 0 = server
        counts = np.array([len(public.y)] + [max(len(ix), 1)
                                             for ix in client_indices], float)
        self.p = counts / counts.sum()

        # --- params ---------------------------------------------------------
        self.base_params = init_fn(key)
        if lora_cfg is not None:
            self.global_params = lora_init(jax.random.fold_in(key, 1),
                                           self.base_params, lora_cfg)
        else:
            self.global_params = self.base_params

        # --- communication codec (repro.fl.comm) ------------------------------
        # The trainable pytree (adapters in LoRA mode, full params otherwise)
        # fixes the wire sizes: model_bytes derives from it unless the config
        # overrides, and the codec's exact compression ratio prices uploads.
        from repro.fl.comm import (CommState, is_adaptive_spec, make_codec,
                                   parse_adaptive_spec)
        self.adaptive_spec = cfg.codec if is_adaptive_spec(cfg.codec) else None
        if self.adaptive_spec:
            self._rung_lo, self._rung_hi = parse_adaptive_spec(cfg.codec)
            # the hi rung is the ceiling: it fixes the static accounting
            # (upload_bytes, ctx.upload_nbytes) the controller adapts below
            static_codec = make_codec(self._rung_hi)
        else:
            static_codec = make_codec(cfg.codec)
        dl_spec = cfg.downlink_codec
        if dl_spec is None and self.adaptive_spec:
            dl_spec = self._rung_hi
        self.downlink_codec_resolved = dl_spec or "fp32"
        dl_codec = (None if self.downlink_codec_resolved == "fp32"
                    else make_codec(self.downlink_codec_resolved))
        self.comm = CommState(static_codec, self.global_params,
                              model_bytes_override=cfg.model_bytes,
                              lora_cfg=lora_cfg, downlink_codec=dl_codec,
                              n_clients=cfg.n_clients)
        self.model_bytes = self.comm.ref_bytes            # fp32 reference size
        self.upload_bytes = self.comm.upload_bytes        # codec wire size
        self.download_bytes = self.comm.download_bytes    # broadcast wire size

        # --- network + failures ----------------------------------------------
        self.channels = net_mod.build_network(cfg.n_clients, seed=cfg.seed)
        rate = net_mod.uplink_rate(self.upload_bytes, cfg.tx_delay_s)
        if cfg.resource_opt:
            self.channels = net_mod.resource_opt(
                self.channels, rate, per_standard=cfg.resource_opt == "per_standard",
                seed=cfg.seed)
        mode = (f"replay:{cfg.trace_replay}" if cfg.trace_replay
                else cfg.failure_mode)
        self.failure_mode_resolved = mode
        if cfg.engine not in ("heap", "vectorized"):
            raise ValueError(f"unknown engine {cfg.engine!r}")
        self.failures = fail_mod.make_failure_model(
            mode, self.channels, rate,
            duration_max=cfg.duration_max, seed=cfg.seed,
            model_bytes=self.model_bytes, deadline_s=cfg.deadline_s,
            compute_s=cfg.compute_s, engine=cfg.engine)
        if cfg.server_mode not in ("sync", "async", "buffered"):
            raise ValueError(f"unknown server_mode {cfg.server_mode!r}")
        if cfg.streaming_agg not in ("auto", "off"):
            raise ValueError(f"unknown streaming_agg {cfg.streaming_agg!r} "
                             "(known: auto, off)")
        if ((cfg.server_mode != "sync" or self.adaptive_spec)
                and not hasattr(self.failures, "draw_events")):
            # Legacy boolean failure models have no time dimension; the async
            # server needs per-client arrival instants — and so does the
            # adaptive codec controller, whose whole input is arrival times —
            # so synthesize them from the physical channels (capacity ->
            # upload time, Eq. 41).
            from repro.fl.server.timeline import TimedFailureAdapter
            self.failures = TimedFailureAdapter(
                self.failures, self.channels, model_bytes=self.model_bytes,
                deadline_s=cfg.deadline_s, compute_s=cfg.compute_s,
                seed=cfg.seed, engine=cfg.engine)
        sim = getattr(self.failures, "sim", None)
        if sim is not None and cfg.cohort_size:
            sim.cohort_size = int(cfg.cohort_size)
        # Wire sizes into the timing model: uploads carry the codec's payload,
        # downloads the (possibly compressed) global broadcast.  Adaptive
        # runs re-price every round through the controller; this is the
        # round-1-and-static default.
        self.failures.set_payload_bytes(
            upload_bytes=np.full(cfg.n_clients, self.upload_bytes),
            download_bytes=np.full(cfg.n_clients, self.download_bytes))
        self.controller = None
        if self.adaptive_spec:
            from repro.fl.comm import AdaptiveCommController
            self.controller = AdaptiveCommController(
                cfg.n_clients, self.comm, lo=self._rung_lo, hi=self._rung_hi,
                deadline_s=cfg.deadline_s, compute_s=cfg.compute_s)
        if cfg.trace_replay:
            # self.failures is the ReplayFailureModel here (replay overrides
            # failure_mode and always has draw_events, so it is never
            # wrapped).  Codec AND wire sizes must match the recording: the
            # recorded timings were priced at the recorded byte counts.
            if self.failures.codec != cfg.codec:
                raise ValueError(
                    f"trace {cfg.trace_replay} was recorded under codec "
                    f"{self.failures.codec!r} but this run uses "
                    f"{cfg.codec!r}; the recorded upload timings would be "
                    "wrong — replay with the matching codec")
            rec_dl = self.failures.header.get("downlink_codec") or "fp32"
            if rec_dl != self.downlink_codec_resolved:
                raise ValueError(
                    f"trace {cfg.trace_replay} was recorded under downlink "
                    f"codec {rec_dl!r} but this run uses "
                    f"{self.downlink_codec_resolved!r}; the recorded "
                    "download timings would be wrong — replay with the "
                    "matching downlink_codec")
            # adaptive runs have no single upload size; the per-round byte
            # vectors in the v3 rounds are cross-checked by the round loop
            checks = [("model_bytes", self.model_bytes),
                      ("download_bytes", self.download_bytes)]
            if not self.adaptive_spec:
                checks.append(("upload_bytes", self.upload_bytes))
            for field, ours in checks:
                rec = self.failures.header.get(field)
                if rec is not None and not np.isclose(float(rec), ours,
                                                      rtol=1e-6):
                    raise ValueError(
                        f"trace {cfg.trace_replay} was recorded with "
                        f"{field}={float(rec):.0f} but this run derives "
                        f"{ours:.0f}; the recorded upload timings would be "
                        "wrong — replay with the matching model_bytes")
        mc = np.random.default_rng(cfg.seed + 7)
        self.eps_estimates = np.array([
            c.outage_probability(rate, mc, 200) for c in self.channels])

        # --- run telemetry (repro.obs; per-run hub built by run()) ------------
        from repro.obs import NULL_TELEMETRY
        self.telemetry = NULL_TELEMETRY
        self.report = None                # RunReport of the last telemetry run

        # --- jitted kernels ---------------------------------------------------
        self._build_jits()
        self._key = jax.random.fold_in(key, 2)

        if pretrain_steps:
            self.pretrain(pretrain_steps)

    # ------------------------------------------------------------------ jits
    def trainable(self, params):
        return params

    def _effective(self, t):
        if self.lora_cfg is not None:
            return apply_lora(self.base_params, t, self.lora_cfg)
        return t

    def _build_jits(self):
        apply_fn = self.apply_fn
        E, bs = self.cfg.local_steps, self.cfg.batch_size

        def loss_t(t, x, y):
            logits = apply_fn(self._effective(t), x)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32))
            return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

        self._loss_t = loss_t

        @functools.partial(jax.jit, static_argnames=())
        def local_update(t, t_global, corr, x, y, key, lr, mu):
            n = x.shape[0]

            def step(tt, k):
                idx = jax.random.randint(k, (bs,), 0, n)
                g = jax.grad(loss_t)(tt, x[idx], y[idx])
                g = jax.tree.map(
                    lambda gg, p_, pg, c: gg.astype(jnp.float32) +
                    mu * (p_.astype(jnp.float32) - pg.astype(jnp.float32)) + c,
                    g, tt, t_global, corr)
                tt = jax.tree.map(lambda p_, gg: (p_.astype(jnp.float32) -
                                                  lr * gg).astype(p_.dtype), tt, g)
                return tt, None

            keys = jax.random.split(key, E)
            t, _ = jax.lax.scan(step, t, keys)
            return t

        self._local_update = local_update

        @jax.jit
        def accuracy_batch(t, x, y):
            logits = apply_fn(self._effective(t), x)
            return jnp.sum(jnp.argmax(logits, -1) == y)

        self._accuracy_batch = accuracy_batch

        @jax.jit
        def loss_on(t, x, y):
            return loss_t(t, x, y)

        self._loss_on = loss_on

    # -------------------------------------------------------------- helpers
    def lr(self, rnd: int) -> float:
        if self.cfg.lr_boundary is not None and rnd > self.cfg.lr_boundary:
            return self.cfg.lr * 0.1
        return self.cfg.lr

    def _zeros_like_t(self, t):
        return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)

    def _next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    def run_local(self, t_global, x, y, rnd, *, mu=0.0, corr=None):
        tel = self.telemetry
        with tel.timer("phase.local_update"):
            corr = corr if corr is not None else self._zeros_like_t(t_global)
            out = self._local_update(t_global, t_global, corr, x, y,
                                     self._next_key(), self.lr(rnd), mu)
            if tel:
                # the update is one jitted lax.scan: without a sync the timer
                # would stop at dispatch, not completion
                jax.block_until_ready(out)
        return out

    def loss_on(self, t, x, y):
        return self._loss_on(t, x, y)

    def public_proxy_batch(self, n: int, rnd: int):
        idx = self.rng.integers(0, len(self.public_y_raw), n)
        return self.public_x_raw[idx], self.public_y_raw[idx]

    def fold_into_base(self, path: str, resid):
        from repro.fl.lora import _get, _set
        w = _get(self.base_params, path)
        _set(self.base_params, path,
             (w.astype(jnp.float32) + resid).astype(w.dtype))

    def train_compensatory(self, miss_mask: np.ndarray, rnd: int):
        """Module 1 (Eq. 6): E SGD steps on the missing-class public subset."""
        miss_classes = np.where(miss_mask)[0]
        sel = np.isin(np.asarray(self.public_y_raw), miss_classes)
        idx = np.where(sel)[0]
        if len(idx) == 0:
            return None, None
        res = self.rng.choice(idx, self.data_size, replace=True)
        x = self.public_x_raw[res]
        y = self.public_y_raw[res]
        model = self.run_local(self.global_params, x, y, rnd)
        hist = class_histogram(np.asarray(self.public_y_raw)[idx], self.n_classes)
        return model, hist

    def pretrain(self, steps: int) -> None:
        """Stage 1 (§II-B1): server pre-training on the public dataset."""
        t = self.global_params
        for s in range(0, steps, self.cfg.local_steps):
            t = self.run_local(t, self.public_x, self.public_y, 0)
        self.global_params = t

    def evaluate(self) -> float:
        with self.telemetry.timer("phase.eval"):
            t = self.global_params
            bs = self.cfg.eval_batch
            n = len(self.test.y)
            correct = 0
            for i in range(0, n, bs):
                x = jnp.asarray(self.test.x[i:i + bs])
                y = jnp.asarray(self.test.y[i:i + bs])
                # int() already forces the device sum, so the timer is honest
                correct += int(self._accuracy_batch(t, x, y))
            return correct / n

    def _draw_network(self, r: int):
        """(up, met_deadline, RoundEvents|None) for round ``r``.

        Scenario/replay models expose full per-client timing via
        ``draw_events``; legacy models have no time dimension, so every
        surviving draw trivially meets the deadline."""
        if hasattr(self.failures, "draw_events"):
            events = self.failures.draw_events(r)
            return events.up_mask(), events.deadline_mask(), events
        up = self.failures.draw(r)
        return up, np.ones(self.n_clients, dtype=bool), None

    # ------------------------------------------------------------------ run
    def run(self, strategy: Strategy, rounds: int,
            log: Optional[Callable[[int, float], None]] = None) -> List[float]:
        """Drive ``rounds`` rounds under ``cfg.server_mode``'s loop.

        Returns the accuracy history (one entry per evaluation, as before);
        ``self.timeline`` additionally holds ``TimePoint(rnd, t_s, acc)``
        entries indexed by simulated wall-clock seconds, and ``self.loop``
        exposes the driver (staleness stats for the async modes)."""
        from repro.fl.server.loops import TimePoint, make_round_loop

        strategy.init_state(self)
        self.failures.reset()
        self.comm.reset()                 # error-feedback residuals per run
        if self.controller is not None:
            self.controller.reset()       # capacity estimates per run
            if self.cfg.controller_state_in:
                # warm start: seed this run's capacity estimates with a
                # previous run's converged state (reset first, so a missing
                # field in the file falls back to the cold-start value)
                self.controller.load_state(self.cfg.controller_state_in)
        self.report = None
        self.telemetry = self._make_telemetry(strategy, rounds)
        tracer = None
        if self.cfg.trace_record:
            from repro.fl.scenarios.trace import TraceRecorder
            # resolved mode: a replayed run's re-recording must name the
            # replay source, not the scenario the config nominally asked for
            version_override = {}
            if self.cfg.trace_replay and self.adaptive_spec:
                src_v = int(self.failures.header.get("version", 0) or 0)
                if 0 < src_v < 4:
                    # a legacy replay re-derives its controller trajectory
                    # under the pre-v4 enrollment pricing; stamp the
                    # re-recording with the source version so future replays
                    # apply the same shim instead of tripping the drift check
                    version_override = {"version": src_v}
            tracer = TraceRecorder(self.cfg.trace_record, {
                **version_override,
                "scenario": self.failure_mode_resolved,
                "n_clients": self.n_clients,
                "deadline_s": self.cfg.deadline_s,
                "compute_s": self.cfg.compute_s,
                "model_bytes": self.model_bytes,
                "codec": self.cfg.codec,
                # adaptive runs have no single upload size: the per-round
                # per-client byte vectors in the round records are the truth
                "upload_bytes": (None if self.adaptive_spec
                                 else self.upload_bytes),
                "downlink_codec": self.downlink_codec_resolved,
                "download_bytes": self.download_bytes,
                "seed": self.cfg.seed}, mode=self.cfg.trace_mode)
        self.timeline: List[TimePoint] = []
        self.loop = make_round_loop(self.cfg.server_mode, self, strategy,
                                    tracer=tracer, log=log)
        try:
            return self.loop.run(rounds)
        finally:
            self.telemetry.end_run()
            if tracer is not None:
                tracer.close()
            if self.controller is not None and self.cfg.controller_state_out:
                self.controller.save_state(self.cfg.controller_state_out)

    def _make_telemetry(self, strategy: Strategy, rounds: int):
        """Build this run's telemetry hub (a fresh one per run, like the
        error-feedback residuals) and attach it to every collaborator that
        emits into it.  Disabled (the default) this is the shared falsy
        no-op hub — zero per-round work, bit-identical histories."""
        from repro.obs import (ChromeTraceRecorder, ConsoleSink,
                               DashboardSink, HealthMonitors, NdjsonSink,
                               NULL_TELEMETRY, RunReport, SketchReport,
                               SketchState, Telemetry)
        cfg = self.cfg
        mode = cfg.telemetry
        if mode is True:
            mode = "full"
        elif mode and mode not in ("full", "sketch"):
            raise ValueError(f"FFTConfig.telemetry must be False, True, "
                             f"'full', or 'sketch', got {cfg.telemetry!r}")
        enabled = bool(mode or cfg.telemetry_log or cfg.telemetry_console
                       or cfg.telemetry_trace or cfg.telemetry_dashboard)
        if enabled:
            mode = mode or "full"
            sketch = None
            if mode == "sketch":
                # bounded-memory mode: per-client events fold into sketches;
                # the report mirrors RunReport's aggregate API
                sketch = SketchState(self.n_clients,
                                     k=cfg.telemetry_sketch_k, seed=cfg.seed)
                self.report = SketchReport()
            else:
                self.report = RunReport()
            sinks = [self.report]
            if cfg.telemetry_log:
                sinks.append(NdjsonSink(cfg.telemetry_log))
            if cfg.telemetry_console:
                sinks.append(ConsoleSink())
            if cfg.telemetry_dashboard:
                # after the report sink, so each frame sees the new round
                sinks.append(DashboardSink(self.report))
            health = HealthMonitors() if cfg.telemetry_health else None
            trace = (ChromeTraceRecorder(cfg.telemetry_trace)
                     if cfg.telemetry_trace else None)
            tel = Telemetry(sinks=sinks, sketch=sketch, health=health,
                            trace=trace)
            tel.start_run({
                "scenario": self.failure_mode_resolved,
                "server_mode": cfg.server_mode,
                "strategy": strategy.name,
                "codec": cfg.codec,
                "downlink_codec": self.downlink_codec_resolved,
                "n_clients": self.n_clients,
                "k_selected": self.k_selected,
                "rounds": rounds,
                "deadline_s": cfg.deadline_s,
                "tau_max": cfg.tau_max,
                "seed": cfg.seed})
        else:
            tel = NULL_TELEMETRY
        # observational fan-in points; each holds NULL_TELEMETRY otherwise
        self.comm.telemetry = tel
        if self.controller is not None:
            self.controller.telemetry = tel
        sim = getattr(self.failures, "sim", None)
        if sim is not None:
            sim.telemetry = tel
        return tel
