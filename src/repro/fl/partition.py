"""Federated data partitioners matching the paper's protocol (§V-A3).

* iid: shuffle and split uniformly.
* group_classes: the paper's non-iid scheme — clients are grouped in fours;
  each group owns a disjoint set of ``classes_per_group`` classes
  (MNIST/CIFAR-10: 2 of 10; CIFAR-100: 20 of 100).
* dirichlet: standard Dir(α) label-skew partitioner (extra coverage).

All return ``client_indices: List[np.ndarray]`` into the dataset plus the
per-client class histograms (N, C) the server uses for Eq. (8) (Remark 2:
clients share only their label histograms).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def class_histogram(labels: np.ndarray, n_classes: int) -> np.ndarray:
    return np.bincount(labels, minlength=n_classes).astype(np.int64)


def iid_partition(labels: np.ndarray, n_clients: int,
                  seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(labels))
    return [np.sort(part) for part in np.array_split(idx, n_clients)]


def group_classes_partition(labels: np.ndarray, n_clients: int,
                            n_classes: int, classes_per_group: int,
                            group_size: int = 4,
                            seed: int = 0) -> List[np.ndarray]:
    """Paper scheme: clients 1–4 → classes {0,1}, clients 5–8 → {2,3}, …"""
    rng = np.random.default_rng(seed)
    n_groups = (n_clients + group_size - 1) // group_size
    out: List[np.ndarray] = []
    for g in range(n_groups):
        cls = [(g * classes_per_group + j) % n_classes
               for j in range(classes_per_group)]
        pool = np.where(np.isin(labels, cls))[0]
        pool = rng.permutation(pool)
        members = list(range(g * group_size, min((g + 1) * group_size, n_clients)))
        for part in np.array_split(pool, len(members)):
            out.append(np.sort(part))
    return out


def dirichlet_partition(labels: np.ndarray, n_clients: int, n_classes: int,
                        alpha: float, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    buckets: List[List[int]] = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        pool = rng.permutation(np.where(labels == c)[0])
        props = rng.dirichlet([alpha] * n_clients)
        splits = (np.cumsum(props) * len(pool)).astype(int)[:-1]
        for i, part in enumerate(np.split(pool, splits)):
            buckets[i].extend(part.tolist())
    return [np.sort(np.array(b, dtype=int)) for b in buckets]


def partition(mode: str, labels: np.ndarray, n_clients: int, n_classes: int,
              *, classes_per_group: int = 2, dirichlet_alpha: float = 0.3,
              group_size: int = 4,
              seed: int = 0) -> Tuple[List[np.ndarray], np.ndarray]:
    if mode == "iid":
        parts = iid_partition(labels, n_clients, seed)
    elif mode == "group_classes":
        parts = group_classes_partition(labels, n_clients, n_classes,
                                        classes_per_group,
                                        group_size=group_size, seed=seed)
    elif mode == "dirichlet":
        parts = dirichlet_partition(labels, n_clients, n_classes,
                                    dirichlet_alpha, seed)
    else:
        raise ValueError(mode)
    hists = np.stack([class_histogram(labels[p], n_classes) for p in parts])
    return parts, hists
