"""Shared run-level metrics for benchmarks and examples.

One definition of the headline numbers (post-outage accuracy drawdown, mean
upload distortion) so ``benchmarks/bench_fidelity.py`` and
``examples/fidelity_discount.py`` cannot drift apart.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np


def accuracy_drawdown(hist: List[float], warmup: int = 0) -> float:
    """Worst accuracy drawdown (running max − current) over an eval curve,
    counted from eval index ``warmup`` onward (the running max still warms
    up over the skipped prefix)."""
    worst, run_max = 0.0, 0.0
    for i, acc in enumerate(hist):
        run_max = max(run_max, acc)
        if i >= warmup:
            worst = max(worst, run_max - acc)
    return worst


def mean_distortion(distortion_history: List[Dict[int, float]]) -> float:
    """Mean per-upload compression distortion over a run
    (``RoundLoop.distortion_history``); 0.0 if nothing was uploaded."""
    vals = [d for per_round in distortion_history
            for d in per_round.values()]
    return float(np.mean(vals)) if vals else 0.0


def distortion_replay_matches(failures, distortion_history, rounds: int
                              ) -> bool:
    """True iff the distortions a v4 trace recorded for rounds
    ``1..rounds`` equal a same-config replay's recomputed ones bit-exactly
    (``failures`` is the replay's ``ReplayFailureModel``,
    ``distortion_history`` the replaying loop's).  A NaN / absent field
    means that client uploaded nothing that round.  Only meaningful for a
    replay under the *same* strategy and config — distortion depends on the
    model trajectory, not just the network realization."""
    for r in range(1, rounds + 1):
        rec = failures.distortions(r)
        live = distortion_history[r - 1]
        if rec is None:
            if live:
                return False
            continue
        for i, v in enumerate(rec):
            if np.isnan(v):
                if i in live:
                    return False
            elif live.get(i) != v:
                return False
    return True
