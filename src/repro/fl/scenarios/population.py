"""Population-scale scenario rounds: timing-only simulation to 1M clients.

The training runner (``FFTRunner``) carries real models, datasets, and
jitted updates — appropriate at Table-6 scale (tens of clients), hopeless
at a million.  This driver runs the *network* side of a round at
population scale with none of the training state: the vectorized scenario
engine draws every client's link and arrival time as dense arrays, an
optional :class:`~repro.fl.comm.AdaptiveCommController` prices per-client
rungs against a synthetic wire model (``_SyntheticComm`` — exact codec
byte counts from a single-leaf template, no parameters materialized), and
each round folds into O(1) :class:`PopulationRoundStats`.

Peak memory is O(population) only in the handful of per-client scalars
that *are* the simulation state (capacities, arrival times, estimates —
a few hundred MB at 1M clients); every temporary above that is bounded by
``cohort_size``, the same streaming unit the round loops use.  Traces
recorded here default to the v5 sketch schema
(``repro.fl.scenarios.trace``), so a 1M-client recording stays kilobytes
per round and cross-checks against regeneration by up-mask digest.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.fl.scenarios import make_scenario_model
from repro.fl.scenarios.trace import TraceRecorder


@dataclasses.dataclass
class PopulationRoundStats:
    """One simulated round, folded to O(1) state."""
    rnd: int
    n_selected: int
    n_up: int                 # links up (whole population)
    n_connected: int          # selected & up & met_deadline
    n_missed: int             # selected & up & ~met_deadline
    n_skipped: int            # excluded from the draw (straggler skip)
    server_wait_s: float
    causes: Dict[str, int]    # whole-population drop-cause histogram


class _SyntheticComm:
    """Just enough of ``CommState`` for the adaptive controller's pricing.

    The controller only reads ``nbytes_for(rung)`` and ``download_bytes``;
    both derive from a single-leaf float32 template of
    ``model_bytes / 4`` parameters, so rung byte counts are the *exact*
    codec formulas at the simulated model size with no training state."""

    def __init__(self, model_bytes: float,
                 downlink_codec: Optional[str] = None):
        import jax.numpy as jnp
        n_params = max(int(round(float(model_bytes) / 4.0)), 1)
        self._template = {"w": jnp.zeros((n_params,), jnp.float32)}
        self._cache: Dict[str, float] = {}
        self.ref_bytes = 4.0 * n_params
        self.download_bytes = (self.ref_bytes if downlink_codec is None
                               else self.nbytes_for(downlink_codec))

    def nbytes_for(self, name: str) -> float:
        from repro.fl.comm import make_codec
        if name not in self._cache:
            self._cache[name] = float(
                make_codec(name).nbytes(self._template))
        return self._cache[name]


def _cause_histogram(events) -> Dict[str, int]:
    codes = getattr(events, "cause_codes", None)
    if codes is not None:
        counts = np.bincount(np.asarray(codes),
                             minlength=len(events.cause_table))
        return {name: int(c) for name, c
                in zip(events.cause_table, counts) if c}
    from collections import Counter
    return dict(Counter(events.cause_list()))


def simulate_population(world: str, n_clients: int, rounds: int, *,
                        model_bytes: float = 4e6, deadline_s: float = 30.0,
                        compute_s: float = 2.0, seed: int = 0,
                        engine: str = "vectorized", cohort_size: int = 0,
                        k_selected: Optional[int] = None,
                        adaptive: Optional[str] = None,
                        skip_stragglers: bool = False,
                        trace_path: Optional[str] = None,
                        trace_mode: str = "auto"
                        ) -> List[PopulationRoundStats]:
    """Run ``rounds`` timing-only rounds of ``world`` at ``n_clients``.

    ``adaptive`` takes an ``"adaptive:<lo>-<hi>"`` codec spec to drive a
    real :class:`AdaptiveCommController` over the synthetic wire model —
    per-client rung assignment, repricing, and capacity learning all run
    exactly as in a training run, just without the training.
    ``skip_stragglers`` additionally excludes clients whose estimate
    cannot land the lowest rung from the selection draw (counted in
    ``n_skipped``).  ``trace_path`` records the realization (v5 sketch
    rounds at this scale, unless ``trace_mode`` forces rows)."""
    model = make_scenario_model(
        world, n_clients, model_bytes=model_bytes, deadline_s=deadline_s,
        compute_s=compute_s, seed=seed, engine=engine)
    if cohort_size:
        model.sim.cohort_size = int(cohort_size)

    controller = None
    if adaptive is not None:
        from repro.fl.comm import (AdaptiveCommController,
                                   parse_adaptive_spec)
        lo, hi = parse_adaptive_spec(adaptive)
        controller = AdaptiveCommController(
            n_clients, _SyntheticComm(model_bytes), lo=lo, hi=hi,
            deadline_s=deadline_s, compute_s=compute_s)

    tracer = None
    if trace_path is not None:
        tracer = TraceRecorder(trace_path, {
            "scenario": f"scenario:{world}", "n_clients": n_clients,
            "deadline_s": deadline_s, "compute_s": compute_s,
            "model_bytes": model_bytes,
            "codec": adaptive or "fp32",
            "upload_bytes": None if adaptive else model_bytes,
            "download_bytes": model_bytes,
            "seed": seed}, mode=trace_mode)

    sel_rng = np.random.default_rng(seed + 17)
    stats: List[PopulationRoundStats] = []
    try:
        for r in range(1, rounds + 1):
            n_skipped = 0
            if k_selected is None and not (skip_stragglers and controller):
                selected = np.ones(n_clients, dtype=bool)
            else:
                eligible = np.arange(n_clients)
                if skip_stragglers and controller is not None:
                    landable = controller.landable_mask()
                    n_skipped = int((~landable).sum())
                    eligible = np.where(landable)[0]
                selected = np.zeros(n_clients, dtype=bool)
                k = len(eligible) if k_selected is None else k_selected
                if k >= len(eligible):
                    selected[eligible] = True
                elif len(eligible):
                    selected[sel_rng.choice(eligible, k,
                                            replace=False)] = True
            assignment = None
            if controller is not None:
                assignment = controller.assign(r, selected)
                model.set_payload_bytes(
                    upload_bytes=assignment.upload_bytes,
                    download_bytes=np.full(n_clients,
                                           assignment.download_bytes))
            events = model.draw_events(r)
            if controller is not None:
                controller.observe(r, events, selected)
            up = events.up_mask()
            met = events.deadline_mask()
            connected = selected & up & met
            if tracer is not None:
                tracer.write_round(
                    r, selected, connected, events,
                    payload_bytes=(assignment.upload_bytes
                                   if assignment is not None
                                   else model_bytes),
                    download_bytes=(assignment.download_bytes
                                    if assignment is not None
                                    else model_bytes))
            stats.append(PopulationRoundStats(
                rnd=r,
                n_selected=int(selected.sum()),
                n_up=int(up.sum()),
                n_connected=int(connected.sum()),
                n_missed=int((selected & up & ~met).sum()),
                n_skipped=n_skipped,
                server_wait_s=float(events.server_wait(selected)),
                causes=_cause_histogram(events)))
    finally:
        if tracer is not None:
            tracer.close()
    return stats
