"""Event-driven network scenario engine (deadline rounds + trace replay).

Three layers:

* ``worlds``  — registry of named stochastic network worlds
  (``scenario:<name>`` in ``FFTConfig.failure_mode``).
* ``engine``  — discrete-event wall-clock simulator turning link capacities
  into upload durations; a server deadline decides participation.
* ``trace``   — NDJSON record/replay of realized rounds, bit-exact.
"""
from repro.fl.scenarios.engine import (ArrayRoundEvents, CAUSE_DEADLINE,
                                       CAUSE_LINK_DOWN, CAUSE_OK,
                                       ClientRoundEvent, DeadlineSimulator,
                                       ENGINES, LinkArrays, LinkState,
                                       RoundEvents, ScenarioFailureModel)
from repro.fl.scenarios.trace import (ReplayFailureModel, TraceRecorder,
                                      load_trace)
from repro.fl.scenarios.worlds import (SCENARIOS, Scenario,
                                       available_scenarios, make_scenario,
                                       register)

__all__ = [
    "ArrayRoundEvents", "CAUSE_DEADLINE", "CAUSE_LINK_DOWN", "CAUSE_OK",
    "ClientRoundEvent", "DeadlineSimulator", "ENGINES", "LinkArrays",
    "LinkState", "RoundEvents", "ScenarioFailureModel",
    "ReplayFailureModel", "TraceRecorder", "load_trace",
    "SCENARIOS", "Scenario", "available_scenarios", "make_scenario",
    "register", "make_scenario_model",
    "PopulationRoundStats", "simulate_population",
]


def make_scenario_model(name: str, n_clients: int, *, model_bytes: float,
                        deadline_s: float, compute_s: float = 2.0,
                        seed: int = 0, channels=None,
                        engine: str = "vectorized",
                        **scenario_kwargs) -> ScenarioFailureModel:
    """Scenario world + deadline simulator, wired as a ``FailureModel``.

    ``channels`` forwards the runner's physical channel list (including any
    ResourceOpt intervention) to worlds grounded in the path-loss model;
    ``engine`` picks the timing engine (``"vectorized"`` closed-form batch,
    ``"heap"`` reference event loop — bit-identical, see ``ENGINES``)."""
    scenario = make_scenario(name, n_clients, seed=seed, channels=channels,
                             **scenario_kwargs)
    sim = DeadlineSimulator(n_clients, model_bytes=model_bytes,
                            deadline_s=deadline_s, compute_s=compute_s,
                            seed=seed + 1, engine=engine)
    return ScenarioFailureModel(scenario, sim)


# imported last: population builds on make_scenario_model above
from repro.fl.scenarios.population import (PopulationRoundStats,  # noqa: E402
                                           simulate_population)
