"""Discrete-event wall-clock round simulator.

Turns per-round link states (capacity, up/down) into a timeline of
DOWNLOAD_DONE / COMPUTE_DONE / UPLOAD_DONE events per client, processed in
time order against the server's round deadline.  A client participates in
the round iff its link is up *and* its upload completes by the deadline —
this subsumes the seed's transient outage model (capacity ≈ 0 ⇒ upload never
finishes) and adds the time dimension: slow links and compute stragglers are
dropped exactly like dead ones, which is what a real synchronous FFT server
with a round timeout does.

The engine is deliberately separate from the scenario worlds
(``repro.fl.scenarios.worlds``): a ``Scenario`` describes *what the network
does*, the ``DeadlineSimulator`` describes *what time does to it*.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.fl.failures import FailureModel

# Event kinds, in per-client causal order.
DOWNLOAD_DONE = "download_done"
COMPUTE_DONE = "compute_done"
UPLOAD_DONE = "upload_done"

# Engine dispatch (mirrors kernels' ref/ops split): the heap is the
# reference event loop, the vectorized path is the closed-form batch
# computation — bit-identical by construction, cross-checked in tests.
ENGINES = ("heap", "vectorized")

# Participation causes recorded per client per round.
CAUSE_OK = "ok"                 # upload finished before the deadline
CAUSE_LINK_DOWN = "link_down"   # scenario reported the link down (scenario
#                                 worlds refine this: "ap_outage", "handover",
#                                 "churned", "weather", ...)
CAUSE_DEADLINE = "deadline"     # link up but upload finished too late


@dataclasses.dataclass
class LinkState:
    """One client's network condition for one round (scenario output)."""
    capacity_bps: float          # uplink Shannon capacity; inf for wired-like
    up: bool = True              # False = hard outage for the whole round
    cause: str = CAUSE_OK        # refined cause when ``up`` is False
    downlink_ratio: float = 8.0  # downlink capacity = ratio * uplink


@dataclasses.dataclass
class LinkArrays:
    """Struct-of-arrays form of one round's link states (scenario output).

    The population-scale twin of ``List[LinkState]``: one float64 capacity
    array, one up mask, and per-client cause *codes* into a small string
    table (code 0 is always ``CAUSE_OK``) instead of N Python objects.
    Worlds emit this directly (``Scenario.sample_round_arrays``); the
    object-list view is derived from it via ``to_links`` only when a
    consumer actually needs per-client objects, so both engine paths see
    the identical numeric realization by construction.
    """
    capacity_bps: np.ndarray          # (N,) float64
    up: np.ndarray                    # (N,) bool
    cause_codes: np.ndarray           # (N,) small int into cause_table
    cause_table: Tuple[str, ...]      # cause_table[0] == CAUSE_OK
    downlink_ratio: float = 8.0       # downlink capacity = ratio * uplink

    def __post_init__(self):
        self.capacity_bps = np.asarray(self.capacity_bps, dtype=np.float64)
        self.up = np.asarray(self.up, dtype=bool)
        self.cause_codes = np.asarray(self.cause_codes, dtype=np.int16)

    def __len__(self) -> int:
        return len(self.capacity_bps)

    @staticmethod
    def all_up(capacity_bps, downlink_ratio: float = 8.0) -> "LinkArrays":
        caps = np.asarray(capacity_bps, dtype=np.float64)
        return LinkArrays(caps, np.ones(len(caps), dtype=bool),
                          np.zeros(len(caps), dtype=np.int16), (CAUSE_OK,),
                          downlink_ratio=downlink_ratio)

    @staticmethod
    def from_links(links: Sequence[LinkState]) -> "LinkArrays":
        caps = np.array([l.capacity_bps for l in links], dtype=np.float64)
        up = np.array([l.up for l in links], dtype=bool)
        table: List[str] = [CAUSE_OK]
        index = {CAUSE_OK: 0}
        codes = np.zeros(len(links), dtype=np.int16)
        for i, l in enumerate(links):
            if l.cause not in index:
                index[l.cause] = len(table)
                table.append(l.cause)
            codes[i] = index[l.cause]
        ratios = {float(l.downlink_ratio) for l in links}
        if len(ratios) > 1:
            raise ValueError(
                f"LinkArrays carries one shared downlink_ratio; links mix "
                f"{sorted(ratios)}")
        return LinkArrays(caps, up, codes, tuple(table),
                          downlink_ratio=(ratios.pop() if ratios else 8.0))

    def cause_of(self, i: int) -> str:
        return self.cause_table[int(self.cause_codes[i])]

    def to_links(self) -> List[LinkState]:
        return [LinkState(capacity_bps=float(self.capacity_bps[i]),
                          up=bool(self.up[i]), cause=self.cause_of(i),
                          downlink_ratio=self.downlink_ratio)
                for i in range(len(self))]


# Either form of a round's link realization; the simulator accepts both.
Links = Union[Sequence[LinkState], LinkArrays]


@dataclasses.dataclass
class ClientRoundEvent:
    """Resolved participation of one client in one round."""
    client: int
    capacity_bps: float
    up: bool
    t_download_s: float
    t_compute_s: float
    t_upload_s: float
    finish_s: float              # download + compute + upload (inf if down)
    met_deadline: bool
    cause: str

    @property
    def connected(self) -> bool:
        return self.up and self.met_deadline


@dataclasses.dataclass
class RoundEvents:
    """Everything the server observed about one round."""
    rnd: int
    deadline_s: float
    events: List[ClientRoundEvent]
    duration_s: float            # wall-clock the server waited

    def up_mask(self) -> np.ndarray:
        return np.array([e.up for e in self.events], dtype=bool)

    def deadline_mask(self) -> np.ndarray:
        return np.array([e.met_deadline for e in self.events], dtype=bool)

    def connected_mask(self) -> np.ndarray:
        return self.up_mask() & self.deadline_mask()

    def late_mask(self) -> np.ndarray:
        """Clients whose upload physically lands, just after the deadline —
        the asynchronous server's staleness-buffer candidates."""
        return np.array([e.up and math.isfinite(e.finish_s)
                         and not e.met_deadline for e in self.events],
                        dtype=bool)

    def server_wait(self, selected: Optional[np.ndarray] = None) -> float:
        """Wall-clock the server waited on the given cohort: the last
        upload's landing time if every selected client delivered, else the
        full deadline (a missing straggler is indistinguishable from a dead
        link until the timeout).  An *empty* cohort also waits the full
        deadline — a real server that selected nobody (or whose selection
        came up empty) still sits out its round timeout; returning zero here
        would advance the simulated clock by nothing and flatter the
        wall-clock comparisons in ``bench_async``."""
        events = self.events if selected is None else [
            e for e, s in zip(self.events, selected) if s]
        if not events:
            return self.deadline_s
        if all(e.connected for e in events):
            return float(max(e.finish_s for e in events))
        return self.deadline_s

    # Array accessors shared with ArrayRoundEvents, so timing consumers
    # (the adaptive controller, the round loops' outcome emission) can stay
    # vectorized regardless of which engine produced the round.
    def finish_array(self) -> np.ndarray:
        return np.array([e.finish_s for e in self.events], dtype=np.float64)

    def capacity_array(self) -> np.ndarray:
        return np.array([e.capacity_bps for e in self.events],
                        dtype=np.float64)

    def upload_time_array(self) -> np.ndarray:
        return np.array([e.t_upload_s for e in self.events],
                        dtype=np.float64)

    def cause_list(self) -> List[str]:
        return [e.cause for e in self.events]


class ArrayRoundEvents:
    """Array-backed ``RoundEvents`` twin produced by the vectorized engine.

    Duck-types the object-list API (``rnd``/``deadline_s``/``duration_s``,
    the masks, ``server_wait``) with O(1)-per-field array storage; the
    ``events`` list of ``ClientRoundEvent`` objects is materialized lazily
    and cached, so small-n consumers (trace rows, tests) keep working while
    population-scale paths never pay for N Python objects.
    """

    def __init__(self, rnd: int, deadline_s: float, *,
                 capacity_bps: np.ndarray, up: np.ndarray,
                 t_download_s: np.ndarray, t_compute_s: np.ndarray,
                 t_upload_s: np.ndarray, finish_s: np.ndarray,
                 met_deadline: np.ndarray, cause_codes: np.ndarray,
                 cause_table: Tuple[str, ...]):
        self.rnd = rnd
        self.deadline_s = deadline_s
        self.capacity_bps = capacity_bps
        self.up = up
        self.t_download_s = t_download_s
        self.t_compute_s = t_compute_s
        self.t_upload_s = t_upload_s
        self.finish_s = finish_s
        self.met_deadline = met_deadline
        self.cause_codes = cause_codes
        self.cause_table = cause_table
        self._events: Optional[List[ClientRoundEvent]] = None
        self.duration_s = self.server_wait()

    def __len__(self) -> int:
        return len(self.finish_s)

    def up_mask(self) -> np.ndarray:
        return self.up

    def deadline_mask(self) -> np.ndarray:
        return self.met_deadline

    def connected_mask(self) -> np.ndarray:
        return self.up & self.met_deadline

    def late_mask(self) -> np.ndarray:
        return self.up & np.isfinite(self.finish_s) & ~self.met_deadline

    def server_wait(self, selected: Optional[np.ndarray] = None) -> float:
        if selected is None:
            finish, connected = self.finish_s, self.connected_mask()
        else:
            sel = np.asarray(selected, dtype=bool)
            if not sel.any():
                return float(self.deadline_s)
            finish, connected = self.finish_s[sel], self.connected_mask()[sel]
        if len(finish) == 0 or not connected.all():
            return float(self.deadline_s)
        return float(finish.max())

    def finish_array(self) -> np.ndarray:
        return self.finish_s

    def capacity_array(self) -> np.ndarray:
        return self.capacity_bps

    def upload_time_array(self) -> np.ndarray:
        return self.t_upload_s

    def cause_list(self) -> List[str]:
        table = self.cause_table
        return [table[c] for c in self.cause_codes]

    @property
    def events(self) -> List[ClientRoundEvent]:
        if self._events is None:
            table = self.cause_table
            self._events = [ClientRoundEvent(
                client=i, capacity_bps=float(self.capacity_bps[i]),
                up=bool(self.up[i]),
                t_download_s=float(self.t_download_s[i]),
                t_compute_s=float(self.t_compute_s[i]),
                t_upload_s=float(self.t_upload_s[i]),
                finish_s=float(self.finish_s[i]),
                met_deadline=bool(self.met_deadline[i]),
                cause=table[self.cause_codes[i]])
                for i in range(len(self))]
        return self._events


class DeadlineSimulator:
    """Event-driven timing model for one FFT round.

    Per client: download the global model, run E local steps, upload the
    update.  Compute speed is heterogeneous (persistent per-client lognormal
    straggler factor) with per-round jitter.  All phase completions are
    pushed onto one event heap; clients whose UPLOAD_DONE lands after the
    deadline are dropped (the boundary is inclusive: ``t <= deadline_s``
    delivers).
    """

    def __init__(self, n_clients: int, *, model_bytes: float,
                 deadline_s: float, compute_s: float = 2.0,
                 hetero_sigma: float = 0.4, jitter_sigma: float = 0.1,
                 seed: int = 0, engine: str = "vectorized",
                 cohort_size: int = 0):
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine!r} (known: {ENGINES})")
        self.n_clients = n_clients
        self.model_bytes = model_bytes
        self.deadline_s = deadline_s
        self.compute_s = compute_s
        self.hetero_sigma = hetero_sigma
        self.jitter_sigma = jitter_sigma
        self.seed = seed
        self.engine = engine
        # vectorized path: >0 bounds per-chunk temporaries to O(cohort_size)
        # (the outputs are necessarily O(N): finish, met, causes)
        self.cohort_size = int(cohort_size)
        # telemetry hub (repro.obs): counts simulated rounds/heap events;
        # the runner swaps in a live hub per instrumented run
        from repro.obs.telemetry import NULL_TELEMETRY
        self.telemetry = NULL_TELEMETRY
        # Per-client, per-direction payload sizes.  ``model_bytes`` is the
        # symmetric default; a codec-aware runner overrides them via
        # ``set_payload_bytes`` (compressed uploads finish earlier, so
        # clients that would miss the deadline at fp32 size can recover).
        self.upload_bytes: Optional[np.ndarray] = None
        self.download_bytes: Optional[np.ndarray] = None
        self.reset()

    def reset(self) -> None:
        self.rng = np.random.default_rng(self.seed)
        # Persistent hardware heterogeneity: factor ~ lognormal, median 1.
        self.speed = np.exp(self.rng.normal(0.0, self.hetero_sigma,
                                            self.n_clients))

    def set_payload_bytes(self, upload_bytes=None, download_bytes=None
                          ) -> None:
        """Override the per-client wire sizes (scalar or (N,) array); None
        keeps the symmetric ``model_bytes`` default for that direction.
        Payload sizes survive ``reset()`` — they are configuration, not
        realization state."""
        def as_arr(x):
            if x is None:
                return None
            return np.broadcast_to(np.asarray(x, float),
                                   (self.n_clients,)).copy()
        self.upload_bytes = as_arr(upload_bytes)
        self.download_bytes = as_arr(download_bytes)

    # ------------------------------------------------------------------ core
    def round_jitters(self, rnd: int) -> np.ndarray:
        """Per-client compute-jitter factors for round ``rnd``, drawn
        vectorized from an RNG keyed by ``(seed, rnd)`` alone.

        Client *i*'s jitter therefore never depends on other clients' link
        states, on payload sizes, or on how many times the round has been
        simulated — realizations are common-random-number comparable across
        worlds/codecs, and re-pricing a round at new payload bytes replays
        the identical compute times.  (The old implementation drew one
        normal per *up* link from a shared stream, so flipping an unrelated
        client's outage shifted everyone after it.)
        """
        rng = np.random.default_rng([self.seed, 0x6A17, rnd])
        return np.exp(rng.normal(0.0, self.jitter_sigma, self.n_clients))

    def _phase_durations(self, i: int, link: LinkState, jitter: float):
        ul_bytes = (self.model_bytes if self.upload_bytes is None
                    else self.upload_bytes[i])
        dl_bytes = (self.model_bytes if self.download_bytes is None
                    else self.download_bytes[i])
        if not link.up:
            return math.inf, math.inf, math.inf
        cap = max(link.capacity_bps, 1e-9)
        t_ul = 0.0 if math.isinf(cap) else ul_bytes * 8.0 / cap
        dl_cap = cap * max(link.downlink_ratio, 1e-9)
        t_dl = 0.0 if math.isinf(dl_cap) else dl_bytes * 8.0 / dl_cap
        t_cp = self.compute_s * self.speed[i] * jitter
        return t_dl, t_cp, t_ul

    def simulate_round(self, rnd: int, links: Links,
                       deadline_s: Optional[float] = None):
        """Resolve one round's participation; returns ``RoundEvents`` (heap
        engine) or the duck-typed ``ArrayRoundEvents`` (vectorized engine).

        Idempotent for a fixed ``(rnd, links, payload bytes)``: jitters come
        from ``round_jitters`` (no shared RNG stream is consumed), so callers
        may re-simulate the same link realization at different payload sizes
        — the per-round repricing the adaptive codec controller relies on.
        Accepts either link representation; each engine converts to its
        native one, so both consume the identical numeric realization.
        """
        if self.engine == "vectorized":
            arrays = (links if isinstance(links, LinkArrays)
                      else LinkArrays.from_links(links))
            return self._simulate_vectorized(rnd, arrays, deadline_s)
        if isinstance(links, LinkArrays):
            links = links.to_links()
        return self._simulate_heap(rnd, links, deadline_s)

    def _simulate_vectorized(self, rnd: int, arrays: LinkArrays,
                             deadline_s: Optional[float] = None
                             ) -> ArrayRoundEvents:
        """Closed-form batch timing: per-client arrival is
        ``(t_dl + t_cp) + t_ul`` with no cross-client coupling, so the heap
        is pure overhead — the same float64 operations applied in the same
        association order reproduce its results bit-for-bit."""
        deadline = self.deadline_s if deadline_s is None else deadline_s
        jitters = self.round_jitters(rnd)
        n = self.n_clients
        t_dl = np.empty(n)
        t_cp = np.empty(n)
        t_ul = np.empty(n)
        finish = np.empty(n)
        met = np.zeros(n, dtype=bool)
        chunk = self.cohort_size if self.cohort_size > 0 else n
        for lo in range(0, n, max(chunk, 1)):
            hi = min(lo + chunk, n)
            s = slice(lo, hi)
            cap = np.maximum(arrays.capacity_bps[s], 1e-9)
            up = arrays.up[s]
            ul_b = (self.model_bytes if self.upload_bytes is None
                    else self.upload_bytes[s])
            dl_b = (self.model_bytes if self.download_bytes is None
                    else self.download_bytes[s])
            with np.errstate(divide="ignore", invalid="ignore",
                             over="ignore"):
                ul = np.where(np.isinf(cap), 0.0, ul_b * 8.0 / cap)
                dl_cap = cap * max(arrays.downlink_ratio, 1e-9)
                dl = np.where(np.isinf(dl_cap), 0.0, dl_b * 8.0 / dl_cap)
            cp = self.compute_s * self.speed[s] * jitters[s]
            # down links: the heap path prices every phase at +inf
            t_dl[s] = np.where(up, dl, np.inf)
            t_cp[s] = np.where(up, cp, np.inf)
            t_ul[s] = np.where(up, ul, np.inf)
            # same association order as the heap's running event clock:
            # (download + compute) + upload
            f = np.where(up, (dl + cp) + ul, np.inf)
            finish[s] = f
            met[s] = f <= deadline                 # inclusive boundary
        # refined causes: the scenario's own code while down, ok/deadline
        # decided by the timing above
        table = tuple(arrays.cause_table)
        # down links whose scenario left cause at OK refine to "link_down"
        if CAUSE_LINK_DOWN in table:
            down_code = table.index(CAUSE_LINK_DOWN)
        else:
            table = table + (CAUSE_LINK_DOWN,)
            down_code = len(table) - 1
        if CAUSE_DEADLINE in table:
            late_code = table.index(CAUSE_DEADLINE)
        else:
            table = table + (CAUSE_DEADLINE,)
            late_code = len(table) - 1
        codes = np.where(arrays.up,
                         np.where(met, 0, late_code),
                         np.where(arrays.cause_codes == 0, down_code,
                                  arrays.cause_codes)).astype(np.int16)
        tel = self.telemetry
        if tel:
            tel.counter("sim.rounds")
            tel.counter("sim.vectorized_clients", n)
        return ArrayRoundEvents(
            rnd, deadline, capacity_bps=arrays.capacity_bps, up=arrays.up,
            t_download_s=t_dl, t_compute_s=t_cp, t_upload_s=t_ul,
            finish_s=finish, met_deadline=met, cause_codes=codes,
            cause_table=table)

    def _simulate_heap(self, rnd: int, links: List[LinkState],
                       deadline_s: Optional[float] = None) -> RoundEvents:
        """Reference event loop (the original engine), kept for
        cross-checking the vectorized path."""
        deadline = self.deadline_s if deadline_s is None else deadline_s
        jitters = self.round_jitters(rnd)
        heap: List[tuple] = []
        seq = 0
        finish = np.full(self.n_clients, math.inf)
        durations = {}
        for i, link in enumerate(links):
            t_dl, t_cp, t_ul = self._phase_durations(i, link, jitters[i])
            durations[i] = (t_dl, t_cp, t_ul)
            if link.up and math.isfinite(t_dl):
                seq += 1
                heapq.heappush(heap, (t_dl, seq, i, DOWNLOAD_DONE))

        met = np.zeros(self.n_clients, dtype=bool)
        while heap:
            t, _, i, kind = heapq.heappop(heap)
            t_dl, t_cp, t_ul = durations[i]
            if kind == DOWNLOAD_DONE:
                if math.isfinite(t_cp):
                    seq += 1
                    heapq.heappush(heap, (t + t_cp, seq, i, COMPUTE_DONE))
            elif kind == COMPUTE_DONE:
                if math.isfinite(t_ul):
                    seq += 1
                    heapq.heappush(heap, (t + t_ul, seq, i, UPLOAD_DONE))
            elif kind == UPLOAD_DONE:
                finish[i] = t
                # Inclusive boundary: an upload landing at exactly the
                # deadline is delivered.  (A DEADLINE sentinel event used to
                # decide this by heap tie-break — its seq=0 won against any
                # equal-time UPLOAD_DONE, silently dropping t == deadline
                # uploads.)
                met[i] = t <= deadline

        events = []
        for i, link in enumerate(links):
            t_dl, t_cp, t_ul = durations[i]
            if not link.up:
                cause = link.cause if link.cause != CAUSE_OK else CAUSE_LINK_DOWN
            elif met[i]:
                cause = CAUSE_OK
            else:
                cause = CAUSE_DEADLINE
            events.append(ClientRoundEvent(
                client=i, capacity_bps=float(link.capacity_bps), up=link.up,
                t_download_s=t_dl, t_compute_s=t_cp, t_upload_s=t_ul,
                finish_s=float(finish[i]), met_deadline=bool(met[i]),
                cause=cause))
        tel = self.telemetry
        if tel:
            tel.counter("sim.rounds")
            tel.counter("sim.heap_events", seq)
        # Full-cohort wait (all clients treated as selected); callers that
        # know the actual selection use RoundEvents.server_wait(selected).
        out = RoundEvents(rnd=rnd, deadline_s=deadline, events=events,
                          duration_s=0.0)
        out.duration_s = out.server_wait()
        return out


class LinkRealizationCache:
    """Mixin: link realization cached *separately* from timing simulation.

    ``_links`` freezes the stochastic per-round draw (subclasses provide it
    via ``_sample_links``), while ``_events`` memoizes the deterministic
    timing simulation on top of it.  ``set_payload_bytes`` may therefore be
    called between rounds — it prices rounds simulated *after* the call,
    which is how the round loops apply the adaptive controller's per-round
    byte vectors (assign → set_payload_bytes → draw_events) — and
    ``reprice_round`` re-runs an *already-simulated* round's cached link
    draw at the current sizes without perturbing it (offline what-if
    analysis; the repricing invariants are property-tested through it).

    Subclasses set ``self.sim`` (a ``DeadlineSimulator``) and call
    ``_reset_realization()`` from their ``reset``.
    """

    sim: DeadlineSimulator

    def _reset_realization(self) -> None:
        self._links: dict = {}
        self._events: dict = {}

    def _sample_links(self, r: int) -> Links:
        """One round's link realization, as a ``List[LinkState]`` or a
        ``LinkArrays`` — the simulator accepts either."""
        raise NotImplementedError

    def set_payload_bytes(self, upload_bytes=None, download_bytes=None
                          ) -> None:
        """Set per-client wire sizes for rounds simulated from now on.
        Already-simulated rounds keep their cached pricing until
        ``reprice_round`` is called for them explicitly."""
        self.sim.set_payload_bytes(upload_bytes, download_bytes)

    def links_for(self, r: int) -> Links:
        # Cache keyed by round: repeated draws of a past round return the
        # recorded realization instead of re-advancing the underlying
        # stochastic state.  First-time draws must still arrive in round
        # order — the processes are stateful, so sampling round 7 before
        # round 3 would hand round 3 the round-8 state.
        if r not in self._links:
            self._links[r] = self._sample_links(r)
        return self._links[r]

    def reprice_round(self, r: int):
        """Re-simulate round ``r``'s cached link realization at the current
        payload sizes.  Only the transfer durations (and what follows from
        them: ``finish_s``, ``met_deadline``, causes *between* ``ok`` and
        ``deadline``) may change; ``up`` and the link draw never do."""
        self._events[r] = self.sim.simulate_round(r, self.links_for(r))
        return self._events[r]

    def draw_events(self, r: int):
        if r not in self._events:
            self._events[r] = self.sim.simulate_round(r, self.links_for(r))
        return self._events[r]

    def draw(self, r: int) -> np.ndarray:
        return self.draw_events(r).connected_mask()


class ScenarioFailureModel(LinkRealizationCache, FailureModel):
    """Adapter: (Scenario world × DeadlineSimulator) → ``FailureModel``.

    ``draw(r)`` keeps the seed contract (True = connected) so every existing
    strategy works unchanged; ``draw_events(r)`` exposes the full timing
    detail for the runtime's ``connected = selected & up & met_deadline``
    split and for trace recording.  Caching/repricing semantics come from
    ``LinkRealizationCache``.
    """

    def __init__(self, scenario, sim: DeadlineSimulator):
        self.scenario = scenario
        self.sim = sim
        self._reset_realization()

    def reset(self) -> None:
        self.scenario.reset()
        self.sim.reset()
        self._reset_realization()

    def _sample_links(self, r: int) -> Links:
        return self.scenario.sample_round_arrays(r)
