"""Discrete-event wall-clock round simulator.

Turns per-round link states (capacity, up/down) into a timeline of
DOWNLOAD_DONE / COMPUTE_DONE / UPLOAD_DONE events per client, processed in
time order against the server's round deadline.  A client participates in
the round iff its link is up *and* its upload completes by the deadline —
this subsumes the seed's transient outage model (capacity ≈ 0 ⇒ upload never
finishes) and adds the time dimension: slow links and compute stragglers are
dropped exactly like dead ones, which is what a real synchronous FFT server
with a round timeout does.

The engine is deliberately separate from the scenario worlds
(``repro.fl.scenarios.worlds``): a ``Scenario`` describes *what the network
does*, the ``DeadlineSimulator`` describes *what time does to it*.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import List, Optional

import numpy as np

from repro.fl.failures import FailureModel

# Event kinds, in per-client causal order.
DOWNLOAD_DONE = "download_done"
COMPUTE_DONE = "compute_done"
UPLOAD_DONE = "upload_done"

# Participation causes recorded per client per round.
CAUSE_OK = "ok"                 # upload finished before the deadline
CAUSE_LINK_DOWN = "link_down"   # scenario reported the link down (scenario
#                                 worlds refine this: "ap_outage", "handover",
#                                 "churned", "weather", ...)
CAUSE_DEADLINE = "deadline"     # link up but upload finished too late


@dataclasses.dataclass
class LinkState:
    """One client's network condition for one round (scenario output)."""
    capacity_bps: float          # uplink Shannon capacity; inf for wired-like
    up: bool = True              # False = hard outage for the whole round
    cause: str = CAUSE_OK        # refined cause when ``up`` is False
    downlink_ratio: float = 8.0  # downlink capacity = ratio * uplink


@dataclasses.dataclass
class ClientRoundEvent:
    """Resolved participation of one client in one round."""
    client: int
    capacity_bps: float
    up: bool
    t_download_s: float
    t_compute_s: float
    t_upload_s: float
    finish_s: float              # download + compute + upload (inf if down)
    met_deadline: bool
    cause: str

    @property
    def connected(self) -> bool:
        return self.up and self.met_deadline


@dataclasses.dataclass
class RoundEvents:
    """Everything the server observed about one round."""
    rnd: int
    deadline_s: float
    events: List[ClientRoundEvent]
    duration_s: float            # wall-clock the server waited

    def up_mask(self) -> np.ndarray:
        return np.array([e.up for e in self.events], dtype=bool)

    def deadline_mask(self) -> np.ndarray:
        return np.array([e.met_deadline for e in self.events], dtype=bool)

    def connected_mask(self) -> np.ndarray:
        return self.up_mask() & self.deadline_mask()

    def late_mask(self) -> np.ndarray:
        """Clients whose upload physically lands, just after the deadline —
        the asynchronous server's staleness-buffer candidates."""
        return np.array([e.up and math.isfinite(e.finish_s)
                         and not e.met_deadline for e in self.events],
                        dtype=bool)

    def server_wait(self, selected: Optional[np.ndarray] = None) -> float:
        """Wall-clock the server waited on the given cohort: the last
        upload's landing time if every selected client delivered, else the
        full deadline (a missing straggler is indistinguishable from a dead
        link until the timeout).  An *empty* cohort also waits the full
        deadline — a real server that selected nobody (or whose selection
        came up empty) still sits out its round timeout; returning zero here
        would advance the simulated clock by nothing and flatter the
        wall-clock comparisons in ``bench_async``."""
        events = self.events if selected is None else [
            e for e, s in zip(self.events, selected) if s]
        if not events:
            return self.deadline_s
        if all(e.connected for e in events):
            return float(max(e.finish_s for e in events))
        return self.deadline_s


class DeadlineSimulator:
    """Event-driven timing model for one FFT round.

    Per client: download the global model, run E local steps, upload the
    update.  Compute speed is heterogeneous (persistent per-client lognormal
    straggler factor) with per-round jitter.  All phase completions are
    pushed onto one event heap; clients whose UPLOAD_DONE lands after the
    deadline are dropped (the boundary is inclusive: ``t <= deadline_s``
    delivers).
    """

    def __init__(self, n_clients: int, *, model_bytes: float,
                 deadline_s: float, compute_s: float = 2.0,
                 hetero_sigma: float = 0.4, jitter_sigma: float = 0.1,
                 seed: int = 0):
        self.n_clients = n_clients
        self.model_bytes = model_bytes
        self.deadline_s = deadline_s
        self.compute_s = compute_s
        self.hetero_sigma = hetero_sigma
        self.jitter_sigma = jitter_sigma
        self.seed = seed
        # telemetry hub (repro.obs): counts simulated rounds/heap events;
        # the runner swaps in a live hub per instrumented run
        from repro.obs.telemetry import NULL_TELEMETRY
        self.telemetry = NULL_TELEMETRY
        # Per-client, per-direction payload sizes.  ``model_bytes`` is the
        # symmetric default; a codec-aware runner overrides them via
        # ``set_payload_bytes`` (compressed uploads finish earlier, so
        # clients that would miss the deadline at fp32 size can recover).
        self.upload_bytes: Optional[np.ndarray] = None
        self.download_bytes: Optional[np.ndarray] = None
        self.reset()

    def reset(self) -> None:
        self.rng = np.random.default_rng(self.seed)
        # Persistent hardware heterogeneity: factor ~ lognormal, median 1.
        self.speed = np.exp(self.rng.normal(0.0, self.hetero_sigma,
                                            self.n_clients))

    def set_payload_bytes(self, upload_bytes=None, download_bytes=None
                          ) -> None:
        """Override the per-client wire sizes (scalar or (N,) array); None
        keeps the symmetric ``model_bytes`` default for that direction.
        Payload sizes survive ``reset()`` — they are configuration, not
        realization state."""
        def as_arr(x):
            if x is None:
                return None
            return np.broadcast_to(np.asarray(x, float),
                                   (self.n_clients,)).copy()
        self.upload_bytes = as_arr(upload_bytes)
        self.download_bytes = as_arr(download_bytes)

    # ------------------------------------------------------------------ core
    def round_jitters(self, rnd: int) -> np.ndarray:
        """Per-client compute-jitter factors for round ``rnd``, drawn
        vectorized from an RNG keyed by ``(seed, rnd)`` alone.

        Client *i*'s jitter therefore never depends on other clients' link
        states, on payload sizes, or on how many times the round has been
        simulated — realizations are common-random-number comparable across
        worlds/codecs, and re-pricing a round at new payload bytes replays
        the identical compute times.  (The old implementation drew one
        normal per *up* link from a shared stream, so flipping an unrelated
        client's outage shifted everyone after it.)
        """
        rng = np.random.default_rng([self.seed, 0x6A17, rnd])
        return np.exp(rng.normal(0.0, self.jitter_sigma, self.n_clients))

    def _phase_durations(self, i: int, link: LinkState, jitter: float):
        ul_bytes = (self.model_bytes if self.upload_bytes is None
                    else self.upload_bytes[i])
        dl_bytes = (self.model_bytes if self.download_bytes is None
                    else self.download_bytes[i])
        if not link.up:
            return math.inf, math.inf, math.inf
        cap = max(link.capacity_bps, 1e-9)
        t_ul = 0.0 if math.isinf(cap) else ul_bytes * 8.0 / cap
        dl_cap = cap * max(link.downlink_ratio, 1e-9)
        t_dl = 0.0 if math.isinf(dl_cap) else dl_bytes * 8.0 / dl_cap
        t_cp = self.compute_s * self.speed[i] * jitter
        return t_dl, t_cp, t_ul

    def simulate_round(self, rnd: int, links: List[LinkState],
                       deadline_s: Optional[float] = None) -> RoundEvents:
        """Run the event loop for one round; returns resolved participation.

        Idempotent for a fixed ``(rnd, links, payload bytes)``: jitters come
        from ``round_jitters`` (no shared RNG stream is consumed), so callers
        may re-simulate the same link realization at different payload sizes
        — the per-round repricing the adaptive codec controller relies on.
        """
        deadline = self.deadline_s if deadline_s is None else deadline_s
        jitters = self.round_jitters(rnd)
        heap: List[tuple] = []
        seq = 0
        finish = np.full(self.n_clients, math.inf)
        durations = {}
        for i, link in enumerate(links):
            t_dl, t_cp, t_ul = self._phase_durations(i, link, jitters[i])
            durations[i] = (t_dl, t_cp, t_ul)
            if link.up and math.isfinite(t_dl):
                seq += 1
                heapq.heappush(heap, (t_dl, seq, i, DOWNLOAD_DONE))

        met = np.zeros(self.n_clients, dtype=bool)
        while heap:
            t, _, i, kind = heapq.heappop(heap)
            t_dl, t_cp, t_ul = durations[i]
            if kind == DOWNLOAD_DONE:
                if math.isfinite(t_cp):
                    seq += 1
                    heapq.heappush(heap, (t + t_cp, seq, i, COMPUTE_DONE))
            elif kind == COMPUTE_DONE:
                if math.isfinite(t_ul):
                    seq += 1
                    heapq.heappush(heap, (t + t_ul, seq, i, UPLOAD_DONE))
            elif kind == UPLOAD_DONE:
                finish[i] = t
                # Inclusive boundary: an upload landing at exactly the
                # deadline is delivered.  (A DEADLINE sentinel event used to
                # decide this by heap tie-break — its seq=0 won against any
                # equal-time UPLOAD_DONE, silently dropping t == deadline
                # uploads.)
                met[i] = t <= deadline

        events = []
        for i, link in enumerate(links):
            t_dl, t_cp, t_ul = durations[i]
            if not link.up:
                cause = link.cause if link.cause != CAUSE_OK else CAUSE_LINK_DOWN
            elif met[i]:
                cause = CAUSE_OK
            else:
                cause = CAUSE_DEADLINE
            events.append(ClientRoundEvent(
                client=i, capacity_bps=float(link.capacity_bps), up=link.up,
                t_download_s=t_dl, t_compute_s=t_cp, t_upload_s=t_ul,
                finish_s=float(finish[i]), met_deadline=bool(met[i]),
                cause=cause))
        tel = self.telemetry
        if tel:
            tel.counter("sim.rounds")
            tel.counter("sim.heap_events", seq)
        # Full-cohort wait (all clients treated as selected); callers that
        # know the actual selection use RoundEvents.server_wait(selected).
        out = RoundEvents(rnd=rnd, deadline_s=deadline, events=events,
                          duration_s=0.0)
        out.duration_s = out.server_wait()
        return out


class LinkRealizationCache:
    """Mixin: link realization cached *separately* from timing simulation.

    ``_links`` freezes the stochastic per-round draw (subclasses provide it
    via ``_sample_links``), while ``_events`` memoizes the deterministic
    timing simulation on top of it.  ``set_payload_bytes`` may therefore be
    called between rounds — it prices rounds simulated *after* the call,
    which is how the round loops apply the adaptive controller's per-round
    byte vectors (assign → set_payload_bytes → draw_events) — and
    ``reprice_round`` re-runs an *already-simulated* round's cached link
    draw at the current sizes without perturbing it (offline what-if
    analysis; the repricing invariants are property-tested through it).

    Subclasses set ``self.sim`` (a ``DeadlineSimulator``) and call
    ``_reset_realization()`` from their ``reset``.
    """

    sim: DeadlineSimulator

    def _reset_realization(self) -> None:
        self._links: dict = {}
        self._events: dict = {}

    def _sample_links(self, r: int) -> List[LinkState]:
        raise NotImplementedError

    def set_payload_bytes(self, upload_bytes=None, download_bytes=None
                          ) -> None:
        """Set per-client wire sizes for rounds simulated from now on.
        Already-simulated rounds keep their cached pricing until
        ``reprice_round`` is called for them explicitly."""
        self.sim.set_payload_bytes(upload_bytes, download_bytes)

    def links_for(self, r: int) -> List[LinkState]:
        # Cache keyed by round: repeated draws of a past round return the
        # recorded realization instead of re-advancing the underlying
        # stochastic state.  First-time draws must still arrive in round
        # order — the processes are stateful, so sampling round 7 before
        # round 3 would hand round 3 the round-8 state.
        if r not in self._links:
            self._links[r] = self._sample_links(r)
        return self._links[r]

    def reprice_round(self, r: int) -> RoundEvents:
        """Re-simulate round ``r``'s cached link realization at the current
        payload sizes.  Only the transfer durations (and what follows from
        them: ``finish_s``, ``met_deadline``, causes *between* ``ok`` and
        ``deadline``) may change; ``up`` and the link draw never do."""
        self._events[r] = self.sim.simulate_round(r, self.links_for(r))
        return self._events[r]

    def draw_events(self, r: int) -> RoundEvents:
        if r not in self._events:
            self._events[r] = self.sim.simulate_round(r, self.links_for(r))
        return self._events[r]

    def draw(self, r: int) -> np.ndarray:
        return self.draw_events(r).connected_mask()


class ScenarioFailureModel(LinkRealizationCache, FailureModel):
    """Adapter: (Scenario world × DeadlineSimulator) → ``FailureModel``.

    ``draw(r)`` keeps the seed contract (True = connected) so every existing
    strategy works unchanged; ``draw_events(r)`` exposes the full timing
    detail for the runtime's ``connected = selected & up & met_deadline``
    split and for trace recording.  Caching/repricing semantics come from
    ``LinkRealizationCache``.
    """

    def __init__(self, scenario, sim: DeadlineSimulator):
        self.scenario = scenario
        self.sim = sim
        self._reset_realization()

    def reset(self) -> None:
        self.scenario.reset()
        self.sim.reset()
        self._reset_realization()

    def _sample_links(self, r: int) -> List[LinkState]:
        return self.scenario.sample_round(r)
