"""NDJSON network-trace schema with record/replay.

A trace freezes one *realization* of a failure process so it can be saved,
shared, and replayed bit-exactly — operationalizing the paper's
per-realization convergence claim: two runs that replay the same trace see
the identical sequence of ``connected`` masks.

Schema (one JSON object per line):

  {"record": "header", "version": 2, "scenario": "...", "n_clients": N,
   "deadline_s": ..., "model_bytes": ..., "codec": "fp32",
   "upload_bytes": ..., "seed": ...}
  {"record": "round", "round": r, "deadline_s": ..., "duration_s": ...,
   "clients": [{"id": i, "capacity_bps": ..., "up": true,
                "duration_s": ..., "t_download_s": ..., "t_compute_s": ...,
                "t_upload_s": ..., "payload_bytes": ...,
                "selected": true, "met_deadline": true,
                "connected": true, "cause": "ok"}, ...]}

``capacity_bps``/``duration_s``/``t_*_s`` are null for legacy failure models
that have no timing semantics; ``connected`` is always present, so any
model's realization is replayable.  Per-client ``duration_s`` is the landing
instant (``ClientRoundEvent.finish_s``) — recorded even for uploads that
missed the deadline, so an asynchronous run replays its staleness-buffered
arrivals bit-exactly.  Non-finite floats are serialized as the strings
"inf"/"-inf"/"nan" (JSON has no literals for them) and decoded back
losslessly by ``_unnum``.

Version 2 (communication codecs, ``repro.fl.comm``) adds the codec name to
the header and per-client ``payload_bytes`` (bytes-on-wire of that round's
upload) to each client row.  Version-1 traces still load — they predate
codecs, so they are implicitly ``fp32``; the runtime refuses to replay any
trace under a codec other than the one it was recorded with (the recorded
upload timings would be priced at the wrong byte count).

Version 3 (adaptive codec assignment + compressed downlink) adds
``downlink_codec`` / ``download_bytes`` to the header and, per client row,
``download_bytes`` plus — for adaptive runs — the per-round ``codec`` rung
that client was assigned.  An adaptive header carries the controller spec
(``"adaptive:<lo>-<hi>"``) and a null ``upload_bytes`` (there is no single
upload size; the per-round byte vectors are authoritative and the round
loop cross-checks the replaying controller against them).  Version-2 traces
still load as static-codec recordings with the fp32 broadcast.

Version 4 (fidelity-aware aggregation) adds per-client ``distortion`` — the
upload's measured normalized compression distortion (``‖carry −
decoded‖/‖carry‖`` from ``CommState.roundtrip``; null for clients that
uploaded nothing that round) — and restricts the per-round ``codec`` rung
to *selected* clients (a rung the server never handed out is policy state,
not an assignment; unselected rows carry no codec).  Distortion depends on
the model trajectory, not just the network realization, so replaying a
trace under a *different strategy* legitimately reproduces different
distortions — the replay machinery therefore exposes the recorded values
(``ReplayFailureModel.distortions``) for cross-checks instead of failing
loudly in the loop; same-configuration replays can (and the fidelity bench
does) assert they match bit-exactly.  Version-3 traces still load.

Version 5 (population scale) adds *sketch rounds*: above
``TRACE_SKETCH_THRESHOLD`` clients (or with ``FFTConfig.trace_mode =
"sketch"``), a round record stores O(1) state instead of N client rows —
exact participation counts, a per-cause drop histogram, Greenwald–Khanna
quantile sketches (``repro.obs.sketch``) of the finite arrival times and
link capacities, byte totals, and a SHA-1 digest of the round's up-mask.
The realization stays recoverable because scenario worlds are
deterministic in their seed: ``regenerate_model`` rebuilds the recorded
failure model from the header alone and the digest cross-checks that the
regenerated rounds are the recorded realization (the digest is
payload-independent, so the check holds for adaptive runs too, whose byte
repricing never perturbs the link draw).  Sketch rounds are *not*
row-replayable — ``draw_events`` on one raises, pointing at regeneration —
while v1–v4 traces and v5 full-mode rounds replay exactly as before.
"""
from __future__ import annotations

import hashlib
import json
import math
from collections import Counter
from typing import Dict, List, Optional

import numpy as np

from repro.fl.failures import FailureModel
from repro.fl.scenarios.engine import (CAUSE_OK, ClientRoundEvent,
                                       RoundEvents)

TRACE_VERSION = 5
SUPPORTED_TRACE_VERSIONS = (1, 2, 3, 4, 5)
# trace_mode="auto": per-client rows below this population, sketches at or
# above it (a 1M-client round would otherwise write ~1M JSON rows per round)
TRACE_SKETCH_THRESHOLD = 4096
TRACE_MODES = ("auto", "full", "sketch")


def up_mask_digest(up: np.ndarray) -> str:
    """SHA-1 of a round's packed up-mask (plus its length, so a prefix of a
    larger population never collides).  Payload-independent — repricing a
    round's bytes never changes which links were up — which is what lets a
    regenerated realization be cross-checked against a sketch trace even
    for adaptive runs."""
    up = np.asarray(up, dtype=bool)
    h = hashlib.sha1()
    h.update(str(len(up)).encode())
    h.update(np.packbits(up).tobytes())
    return h.hexdigest()


def _num(x) -> object:
    """JSON-safe float: inf/-inf/nan become strings, None passes through."""
    if x is None:
        return None
    x = float(x)
    if math.isinf(x):
        return "inf" if x > 0 else "-inf"
    if math.isnan(x):
        return "nan"
    return x


def _unnum(x) -> Optional[float]:
    if x is None:
        return None
    if x == "inf":
        return math.inf
    if x == "-inf":
        return -math.inf
    if x == "nan":
        return math.nan
    return float(x)


class TraceRecorder:
    """Append-per-round NDJSON writer.  Opens fresh (truncates) so one file
    always holds exactly one realization."""

    def __init__(self, path: str, header: Dict, mode: str = "auto"):
        if mode not in TRACE_MODES:
            raise ValueError(f"trace mode must be one of {TRACE_MODES}, "
                             f"got {mode!r}")
        self.path = path
        self._fh = open(path, "w")
        hdr = {"record": "header", "version": TRACE_VERSION}
        hdr.update(header)
        hdr.setdefault("codec", "fp32")
        hdr.setdefault("downlink_codec", "fp32")
        hdr["model_bytes"] = _num(hdr.get("model_bytes"))
        hdr["upload_bytes"] = _num(hdr.get("upload_bytes"))
        hdr["download_bytes"] = _num(hdr.get("download_bytes"))
        hdr["deadline_s"] = _num(hdr.get("deadline_s"))
        n = int(hdr.get("n_clients") or 0)
        self.sketch_mode = (mode == "sketch"
                            or (mode == "auto"
                                and n >= TRACE_SKETCH_THRESHOLD))
        if self.sketch_mode:
            hdr["mode"] = "sketch"
        self._fh.write(json.dumps(hdr) + "\n")

    def write_round(self, rnd: int, selected: np.ndarray,
                    connected: np.ndarray, events: Optional[RoundEvents],
                    up: Optional[np.ndarray] = None,
                    met_deadline: Optional[np.ndarray] = None,
                    payload_bytes=None, download_bytes=None,
                    codecs=None, distortions=None) -> None:
        """``up``/``met_deadline`` carry the failure draw for legacy models
        (no ``events``); without them replay would fabricate connectivity
        for clients that were down but unselected.  ``payload_bytes`` /
        ``download_bytes`` are scalars or (N,) arrays of this round's
        per-client wire sizes in each direction, recorded per client row;
        ``codecs`` is the per-client rung list of an adaptive round (None
        for static runs, whose codec lives in the header; per-entry None
        for clients the server did not select that round); ``distortions``
        maps client id → measured compression distortion of that round's
        upload (clients that uploaded nothing carry null).

        In sketch mode (v5) the per-client fields fold into O(1) summary
        state instead of rows — counts, cause histogram, GK sketches, byte
        totals, up-mask digest — and ``codecs``/``distortions`` are not
        stored (they are per-client by nature; a sketch round's realization
        is recovered by regeneration, not row replay)."""
        if self.sketch_mode:
            self._write_sketch_round(rnd, selected, connected, events,
                                     up=up, met_deadline=met_deadline,
                                     payload_bytes=payload_bytes,
                                     download_bytes=download_bytes)
            return
        clients = []
        n = len(selected)
        distortions = distortions or {}
        if payload_bytes is not None:
            payload_bytes = np.broadcast_to(
                np.asarray(payload_bytes, float), (n,))
        if download_bytes is not None:
            download_bytes = np.broadcast_to(
                np.asarray(download_bytes, float), (n,))
        for i in range(n):
            pb = _num(payload_bytes[i]) if payload_bytes is not None else None
            db = (_num(download_bytes[i]) if download_bytes is not None
                  else None)
            if events is not None:
                e = events.events[i]
                row = {"id": i, "capacity_bps": _num(e.capacity_bps),
                       "up": bool(e.up), "duration_s": _num(e.finish_s),
                       "t_download_s": _num(e.t_download_s),
                       "t_compute_s": _num(e.t_compute_s),
                       "t_upload_s": _num(e.t_upload_s),
                       "payload_bytes": pb,
                       "selected": bool(selected[i]),
                       "met_deadline": bool(e.met_deadline),
                       "connected": bool(connected[i]), "cause": e.cause}
            else:
                up_i = bool(up[i]) if up is not None else (
                    bool(connected[i]) or not bool(selected[i]))
                met_i = bool(met_deadline[i]) if met_deadline is not None \
                    else True
                row = {"id": i, "capacity_bps": None, "up": up_i,
                       "duration_s": None, "payload_bytes": pb,
                       "selected": bool(selected[i]),
                       "met_deadline": met_i,
                       "connected": bool(connected[i]),
                       "cause": CAUSE_OK if up_i and met_i else "outage"}
            if db is not None:
                row["download_bytes"] = db
            if codecs is not None and codecs[i] is not None:
                row["codec"] = str(codecs[i])
            if i in distortions:
                row["distortion"] = _num(distortions[i])
            clients.append(row)
        rec = {"record": "round", "round": int(rnd),
               "deadline_s": _num(events.deadline_s if events else None),
               # server wait over the round's actual cohort, not all clients
               "duration_s": _num(events.server_wait(selected)
                                  if events else None),
               "clients": clients}
        self._fh.write(json.dumps(rec) + "\n")
        self._fh.flush()

    def _write_sketch_round(self, rnd: int, selected, connected, events,
                            up=None, met_deadline=None, payload_bytes=None,
                            download_bytes=None) -> None:
        """One O(1)-state round record: exact counts + cause histogram +
        GK quantile sketches + byte totals + up-mask digest."""
        from repro.obs.sketch import GKQuantiles
        selected = np.asarray(selected, dtype=bool)
        connected = np.asarray(connected, dtype=bool)
        n = len(selected)
        if events is not None:
            up_arr = np.asarray(events.up_mask(), dtype=bool)
            met_arr = np.asarray(events.deadline_mask(), dtype=bool)
        else:
            up_arr = (np.asarray(up, dtype=bool) if up is not None
                      else connected | ~selected)
            met_arr = (np.asarray(met_deadline, dtype=bool)
                       if met_deadline is not None
                       else np.ones(n, dtype=bool))
        # cause histogram: bincount over the dense codes when the events
        # are array-backed, else a Counter over the per-client strings
        codes = getattr(events, "cause_codes", None)
        if codes is not None:
            counts = np.bincount(np.asarray(codes),
                                 minlength=len(events.cause_table))
            causes = {name: int(c) for name, c
                      in zip(events.cause_table, counts) if c}
        elif events is not None:
            causes = dict(Counter(events.cause_list()))
        else:
            down = ~(up_arr & met_arr)
            causes = {CAUSE_OK: int(n - down.sum())}
            if int(down.sum()):
                causes["outage"] = int(down.sum())
        sketch = {
            "n_clients": n,
            "n_selected": int(selected.sum()),
            "n_up": int(up_arr.sum()),
            "n_connected": int(connected.sum()),
            "n_met_deadline": int(met_arr.sum()),
            "causes": causes,
            "up_digest": up_mask_digest(up_arr),
        }
        if events is not None:
            finish = np.asarray(events.finish_array(), dtype=float)
            caps = np.asarray(events.capacity_array(), dtype=float)
            for name, vals in (("finish_s", finish), ("capacity_bps", caps)):
                gk = GKQuantiles()
                for v in vals[np.isfinite(vals)]:
                    gk.add(float(v))
                sketch[name] = gk.to_json()
        if payload_bytes is not None:
            pb = np.broadcast_to(np.asarray(payload_bytes, float), (n,))
            sketch["payload_bytes_total"] = _num(float(pb[selected].sum()))
        if download_bytes is not None:
            db = np.broadcast_to(np.asarray(download_bytes, float), (n,))
            sketch["download_bytes_total"] = _num(float(db[selected].sum()))
        rec = {"record": "round", "round": int(rnd),
               "deadline_s": _num(events.deadline_s if events else None),
               "duration_s": _num(events.server_wait(selected)
                                  if events else None),
               "sketch": sketch}
        self._fh.write(json.dumps(rec) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def load_trace(path: str):
    """Parse a trace file -> (header dict, {round -> round dict})."""
    header: Optional[Dict] = None
    rounds: Dict[int, Dict] = {}
    with open(path) as fh:
        for line_no, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            kind = rec.get("record")
            if kind == "header":
                if rec.get("version") not in SUPPORTED_TRACE_VERSIONS:
                    raise ValueError(
                        f"{path}:{line_no}: unsupported trace version "
                        f"{rec.get('version')!r} "
                        f"(supported: {SUPPORTED_TRACE_VERSIONS})")
                header = rec
            elif kind == "round":
                rounds[int(rec["round"])] = rec
            else:
                raise ValueError(f"{path}:{line_no}: unknown record {kind!r}")
    if header is None:
        raise ValueError(f"{path}: missing header record")
    return header, rounds


class ReplayFailureModel(FailureModel):
    """Replays a recorded trace bit-exactly.

    ``draw(r)`` / ``draw_events(r)`` return exactly what was recorded for
    round ``r`` — no randomness at all, so every strategy sees the identical
    failure realization the original run saw.
    """

    def __init__(self, path: str, n_clients: Optional[int] = None):
        self.path = path
        self.header, self._rounds = load_trace(path)
        if self.header.get("n_clients"):
            self.n = int(self.header["n_clients"])
        elif self._rounds:
            self.n = len(next(iter(self._rounds.values()))["clients"])
        else:
            raise ValueError(f"trace {path}: header lacks n_clients and no "
                             f"rounds are recorded")
        if n_clients is not None and n_clients != self.n:
            raise ValueError(
                f"trace {path} has {self.n} clients, runner has {n_clients}")

    def rounds_available(self) -> List[int]:
        return sorted(self._rounds)

    @property
    def codec(self) -> str:
        """Codec the trace was recorded under (v1 traces predate codecs)."""
        return str(self.header.get("codec", "fp32"))

    def payload_bytes(self, r: int) -> Optional[np.ndarray]:
        """Recorded per-client upload sizes for round ``r`` (None for v1)."""
        return self._client_floats(r, "payload_bytes")

    def download_bytes(self, r: int) -> Optional[np.ndarray]:
        """Recorded per-client broadcast sizes for round ``r`` (None before
        v3)."""
        return self._client_floats(r, "download_bytes")

    def codecs(self, r: int) -> Optional[List[Optional[str]]]:
        """Recorded per-client codec rungs for round ``r`` (adaptive v3+
        traces only; None means the header codec applied to everyone).
        Per-entry None marks a client the server did not select that round
        (v4 records rungs for selected clients only) — consumers must skip
        those entries, not substitute the header spec."""
        if "sketch" in self._round(r):
            return None
        rows = sorted(self._round(r)["clients"], key=lambda c: c["id"])
        vals = [c.get("codec") for c in rows]
        if all(v is None for v in vals):
            return None
        return [str(v) if v is not None else None for v in vals]

    def distortions(self, r: int) -> Optional[np.ndarray]:
        """Recorded per-client upload distortions for round ``r`` (v4
        traces; NaN for clients that uploaded nothing; None before v4).
        Distortion depends on the model trajectory, so this is only
        comparable against a replay under the *same* strategy and config —
        the fidelity bench uses it as a bit-exactness cross-check."""
        return self._client_floats(r, "distortion")

    def sketch_of(self, r: int) -> Optional[Dict]:
        """The recorded sketch summary of round ``r`` (None for full-mode
        rounds)."""
        return self._round(r).get("sketch")

    def _client_floats(self, r: int, field: str) -> Optional[np.ndarray]:
        if "sketch" in self._round(r):
            return None
        rows = sorted(self._round(r)["clients"], key=lambda c: c["id"])
        vals = [_unnum(c.get(field)) for c in rows]
        if all(v is None for v in vals):
            return None
        return np.array([math.nan if v is None else v for v in vals])

    def _round(self, r: int) -> Dict:
        if r not in self._rounds:
            raise ValueError(
                f"trace {self.path} has no round {r} "
                f"(recorded rounds: {min(self._rounds)}..{max(self._rounds)})")
        return self._rounds[r]

    def draw_events(self, r: int) -> RoundEvents:
        rec = self._round(r)
        if "sketch" in rec:
            raise ValueError(
                f"trace {self.path} round {r} was recorded in sketch mode "
                f"(v5): per-client rows were not stored, so it cannot be "
                f"row-replayed.  Regenerate the realization from the header "
                f"(repro.fl.scenarios.trace.regenerate_model) — scenario "
                f"worlds are deterministic in their seed — or re-record "
                f"with trace_mode='full'")
        def val(x, default):
            return x if x is not None else default

        events = []
        for c in sorted(rec["clients"], key=lambda c: c["id"]):
            events.append(ClientRoundEvent(
                client=int(c["id"]),
                capacity_bps=val(_unnum(c.get("capacity_bps")), 0.0),
                up=bool(c["up"]),
                t_download_s=val(_unnum(c.get("t_download_s")), 0.0),
                t_compute_s=val(_unnum(c.get("t_compute_s")), 0.0),
                t_upload_s=val(_unnum(c.get("t_upload_s")), 0.0),
                finish_s=val(_unnum(c.get("duration_s")), math.inf),
                met_deadline=bool(c.get("met_deadline", c["connected"])),
                cause=str(c.get("cause", CAUSE_OK))))
        return RoundEvents(
            rnd=r, deadline_s=val(_unnum(rec.get("deadline_s")), math.inf),
            events=events,
            duration_s=val(_unnum(rec.get("duration_s")), 0.0))

    def draw(self, r: int) -> np.ndarray:
        ev = self.draw_events(r)
        return ev.up_mask() & ev.deadline_mask()


# --------------------------------------------------------------------------
# Sketch-trace regeneration (v5)
# --------------------------------------------------------------------------
def regenerate_model(header: Dict):
    """Rebuild the failure model a sketch trace was recorded under.

    Scenario worlds are deterministic in their seed, so the header —
    scenario name, population, sizes, seed — is sufficient to re-derive
    every round's realization; ``verify_sketch_round`` cross-checks a
    regenerated round against a recorded sketch via the up-mask digest.
    Only ``scenario:*`` recordings regenerate (legacy modes were wrapped in
    a channel-dependent adapter whose channels the trace does not carry);
    rounds must then be drawn in order from round 0, exactly like the
    recording run drew them."""
    scn = str(header.get("scenario") or "")
    if not scn.startswith("scenario:"):
        raise ValueError(
            f"only scenario:* recordings can be regenerated from the "
            f"header; this trace was recorded under {scn!r}")
    from repro.fl import scenarios as scen
    return scen.make_scenario_model(
        scn.split(":", 1)[1], int(header["n_clients"]),
        model_bytes=float(_unnum(header["model_bytes"])),
        deadline_s=float(_unnum(header["deadline_s"])),
        compute_s=float(header.get("compute_s", 2.0)),
        seed=int(header.get("seed", 0)))


def verify_sketch_round(model, rec: Dict) -> bool:
    """True iff ``model``'s realization of ``rec``'s round matches the
    recorded sketch (up-mask digest + participation counts).  ``model``
    must have drawn all earlier rounds in order (stateful worlds)."""
    sketch = rec.get("sketch")
    if sketch is None:
        raise ValueError(f"round {rec.get('round')} is not a sketch round")
    ev = model.draw_events(int(rec["round"]))
    up = np.asarray(ev.up_mask(), dtype=bool)
    met = np.asarray(ev.deadline_mask(), dtype=bool)
    return (up_mask_digest(up) == sketch["up_digest"]
            and int(up.sum()) == int(sketch["n_up"])
            and int(met.sum()) == int(sketch["n_met_deadline"]))
