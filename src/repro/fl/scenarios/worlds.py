"""Named network scenario worlds beyond the paper's Table-6 topology.

Each ``Scenario`` is a seeded stochastic process emitting one ``LinkState``
per client per round; the registry makes them addressable from
``FFTConfig.failure_mode = "scenario:<name>"``.  Worlds model *correlated*
and *time-structured* dynamics the seed's memoryless outage draws cannot:
shared-AP Wi-Fi outages, diurnal capacity cycles, bursty cell handover,
client churn, and cross-region capacity mixes.

All worlds are reset()-able back to their seed so a run is reproducible per
realization — the property FedAuto's guarantee is stated against.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Type

import numpy as np

from repro.fl.scenarios.engine import CAUSE_OK, LinkArrays, LinkState

MBPS = 1e6


def _one_cause(caps: np.ndarray, up: np.ndarray, cause: str,
               downlink_ratio: float = 8.0) -> LinkArrays:
    """LinkArrays for a world with a single down-cause string."""
    codes = np.where(up, 0, 1).astype(np.int16)
    return LinkArrays(caps, up, codes, (CAUSE_OK, cause),
                      downlink_ratio=downlink_ratio)


class Scenario:
    """Base class: seeded per-round link-state process.

    Worlds implement ``sample_round_arrays`` (one vectorized struct-of-
    arrays draw per round — the population-scale hot path); the object-list
    ``sample_round`` view is derived from it, so both views expose the
    identical numeric realization.  Legacy out-of-tree worlds that only
    override ``sample_round`` still work: the base ``sample_round_arrays``
    wraps their list draw.

    ``channels`` optionally carries the runner's physical channel list
    (e.g. after a ResourceOpt intervention) for worlds grounded in the
    paper's path-loss model; synthetic worlds ignore it.
    """

    name = "base"

    def __init__(self, n_clients: int, seed: int = 0, channels=None):
        self.n_clients = n_clients
        self.seed = seed
        self.channels_hint = channels
        self.reset()

    def reset(self) -> None:
        self.rng = np.random.default_rng(self.seed)
        self._setup()

    def _setup(self) -> None:
        pass

    def sample_round(self, r: int) -> List[LinkState]:
        if type(self).sample_round_arrays is not Scenario.sample_round_arrays:
            return self.sample_round_arrays(r).to_links()
        raise NotImplementedError

    def sample_round_arrays(self, r: int) -> LinkArrays:
        if type(self).sample_round is not Scenario.sample_round:
            return LinkArrays.from_links(self.sample_round(r))
        raise NotImplementedError

    # helper: lognormal capacity around a base rate (scalar, legacy worlds)
    def _cap(self, base_bps: float, sigma: float = 0.5) -> float:
        return float(base_bps * math.exp(self.rng.normal(0.0, sigma)))

    # helper: vectorized lognormal capacities, one draw per entry
    def _caps(self, base_bps, sigma: float = 0.5) -> np.ndarray:
        base = np.asarray(base_bps, dtype=np.float64)
        return base * np.exp(self.rng.normal(0.0, sigma, base.shape))


SCENARIOS: Dict[str, Type[Scenario]] = {}


def register(cls: Type[Scenario]) -> Type[Scenario]:
    SCENARIOS[cls.name] = cls
    return cls


def available_scenarios() -> List[str]:
    return sorted(SCENARIOS)


def make_scenario(name: str, n_clients: int, seed: int = 0,
                  **kwargs) -> Scenario:
    if name not in SCENARIOS:
        raise ValueError(f"unknown scenario {name!r}; "
                         f"registered: {available_scenarios()}")
    return SCENARIOS[name](n_clients, seed=seed, **kwargs)


# ---------------------------------------------------------------------------
# worlds
# ---------------------------------------------------------------------------
@register
class Table6Scenario(Scenario):
    """The paper's Appendix-III topology, lifted into the time domain.

    Capacities come from the seed's log-distance path-loss channel
    (``repro.fl.network``); instead of thresholding capacity against a fixed
    rate (Eq. 40), the deadline decides — a deep shadow fade yields an
    upload too slow to land before the timeout, which *is* a transient
    failure, now with a duration attached.
    """

    name = "table6"

    def _setup(self) -> None:
        from repro.fl.network import build_network
        if self.channels_hint is not None:
            self.channels = self.channels_hint
        else:
            self.channels = build_network(self.n_clients, seed=self.seed)

    def sample_round_arrays(self, r: int) -> LinkArrays:
        from repro.fl.network import capacity_array
        return LinkArrays.all_up(capacity_array(self.channels, self.rng))


@register
class CorrelatedWifiScenario(Scenario):
    """Clients share access points; an AP outage drops its whole group.

    Each AP is a two-state Markov chain (up/down); client capacity when the
    AP is up is lognormal around a per-client base drawn once.  This breaks
    the seed's independence assumption: failures arrive in correlated
    bundles, which skews the effective class distribution far more than
    i.i.d. drops of the same marginal rate.
    """

    name = "correlated_wifi"

    def __init__(self, n_clients: int, seed: int = 0, n_aps: int = 4,
                 p_fail: float = 0.08, p_recover: float = 0.45,
                 base_mbps: float = 12.0, **kw):
        self.n_aps = n_aps
        self.p_fail = p_fail
        self.p_recover = p_recover
        self.base_mbps = base_mbps
        super().__init__(n_clients, seed, **kw)

    def _setup(self) -> None:
        self.ap_of = np.arange(self.n_clients) % self.n_aps
        self.ap_up = np.ones(self.n_aps, dtype=bool)
        self.base = self.base_mbps * MBPS * np.exp(
            self.rng.normal(0.0, 0.6, self.n_clients))

    def sample_round_arrays(self, r: int) -> LinkArrays:
        flip = self.rng.uniform(size=self.n_aps)
        self.ap_up = np.where(self.ap_up, flip > self.p_fail,
                              flip < self.p_recover)
        up = self.ap_up[self.ap_of]
        caps = np.zeros(self.n_clients)
        caps[up] = self._caps(self.base[up], 0.4)
        return _one_cause(caps, up, "ap_outage")


@register
class DiurnalScenario(Scenario):
    """Capacity follows a day/night cycle with per-timezone phase offsets.

    Congestion peaks cut capacity to ``trough`` of the off-peak rate, so the
    same deadline that admits everyone at 4 a.m. drops whole timezones at
    8 p.m. — slow, *predictable* non-stationarity that memoryless draws
    cannot express.
    """

    name = "diurnal"

    def __init__(self, n_clients: int, seed: int = 0, period: int = 48,
                 n_zones: int = 4, base_mbps: float = 10.0,
                 trough: float = 0.012, **kw):
        self.period = period
        self.n_zones = n_zones
        self.base_mbps = base_mbps
        self.trough = trough
        super().__init__(n_clients, seed, **kw)

    def _setup(self) -> None:
        zone = np.arange(self.n_clients) % self.n_zones
        self.phase = zone * (self.period / self.n_zones)
        self.base = self.base_mbps * MBPS * np.exp(
            self.rng.normal(0.0, 0.3, self.n_clients))

    def sample_round_arrays(self, r: int) -> LinkArrays:
        cyc = 0.5 * (1.0 + np.sin(
            2.0 * np.pi * (r + self.phase) / self.period))
        scale = self.trough + (1.0 - self.trough) * cyc
        return LinkArrays.all_up(self._caps(self.base * scale, 0.25))


@register
class BurstyHandoverScenario(Scenario):
    """Mobile clients with Gilbert–Elliott bursty handover outages.

    Each client is a two-state chain: GOOD (full capacity) and HANDOVER
    (link down, geometric dwell).  Entering handover is rare but dwelling is
    sticky, producing the multi-round failure bursts of §V-A2's intermittent
    model — driven here by an explicit channel state instead of a renewal
    clock, and mixed with capacity fading while GOOD.
    """

    name = "bursty_handover"

    def __init__(self, n_clients: int, seed: int = 0, p_enter: float = 0.06,
                 p_exit: float = 0.35, base_mbps: float = 8.0, **kw):
        self.p_enter = p_enter
        self.p_exit = p_exit
        self.base_mbps = base_mbps
        super().__init__(n_clients, seed, **kw)

    def _setup(self) -> None:
        self.in_handover = np.zeros(self.n_clients, dtype=bool)
        self.base = self.base_mbps * MBPS * np.exp(
            self.rng.normal(0.0, 0.5, self.n_clients))

    def sample_round_arrays(self, r: int) -> LinkArrays:
        u = self.rng.uniform(size=self.n_clients)
        self.in_handover = np.where(self.in_handover, u > self.p_exit,
                                    u < self.p_enter)
        up = ~self.in_handover
        caps = np.zeros(self.n_clients)
        caps[up] = self._caps(self.base[up], 0.6)
        return _one_cause(caps, up, "handover")


@register
class ChurnScenario(Scenario):
    """Client churn: devices alternate present/away sessions (mobility,
    app backgrounding, battery).  Session and away lengths are geometric;
    away clients are simply gone for the round."""

    name = "churn"

    def __init__(self, n_clients: int, seed: int = 0, mean_stay: float = 12.0,
                 mean_away: float = 5.0, base_mbps: float = 15.0, **kw):
        self.mean_stay = mean_stay
        self.mean_away = mean_away
        self.base_mbps = base_mbps
        super().__init__(n_clients, seed, **kw)

    def _setup(self) -> None:
        self.present = self.rng.uniform(size=self.n_clients) < (
            self.mean_stay / (self.mean_stay + self.mean_away))
        self.base = self.base_mbps * MBPS * np.exp(
            self.rng.normal(0.0, 0.4, self.n_clients))

    def sample_round_arrays(self, r: int) -> LinkArrays:
        u = self.rng.uniform(size=self.n_clients)
        leave = u < 1.0 / self.mean_stay
        arrive = u < 1.0 / self.mean_away
        self.present = np.where(self.present, ~leave, arrive)
        up = self.present.astype(bool)
        caps = np.zeros(self.n_clients)
        caps[up] = self._caps(self.base[up], 0.3)
        return _one_cause(caps, up, "churned")


@register
class CrossRegionScenario(Scenario):
    """Clients striped across regions with very different link classes:
    datacenter fiber, urban 5G, suburban cable, and satellite (high capacity
    but weather-driven outages).  Stresses aggregation under persistent
    capacity heterogeneity rather than randomness."""

    name = "cross_region"

    REGIONS = (
        dict(name="fiber", mbps=400.0, sigma=0.1, p_out=0.001, cause="fiber_cut"),
        dict(name="urban5g", mbps=40.0, sigma=0.5, p_out=0.02, cause="congestion"),
        dict(name="suburban", mbps=6.0, sigma=0.4, p_out=0.03, cause="congestion"),
        dict(name="satellite", mbps=18.0, sigma=0.8, p_out=0.10, cause="weather"),
    )

    def _setup(self) -> None:
        self.region_of = np.arange(self.n_clients) % len(self.REGIONS)
        regions = self.REGIONS
        self.base = np.array([regions[k]["mbps"] for k in self.region_of]) \
            * MBPS
        self.sigma = np.array([regions[k]["sigma"] for k in self.region_of])
        self.p_out = np.array([regions[k]["p_out"] for k in self.region_of])
        # per-region down causes, deduplicated into one cause table
        self.cause_table = (CAUSE_OK,) + tuple(dict.fromkeys(
            r["cause"] for r in regions))
        self.down_code = np.array(
            [self.cause_table.index(regions[k]["cause"])
             for k in self.region_of], dtype=np.int16)

    def sample_round_arrays(self, r: int) -> LinkArrays:
        u = self.rng.uniform(size=self.n_clients)
        up = u >= self.p_out
        caps = np.zeros(self.n_clients)
        caps[up] = self.base[up] * np.exp(
            self.rng.normal(0.0, self.sigma[up]))
        codes = np.where(up, 0, self.down_code).astype(np.int16)
        return LinkArrays(caps, up, codes, self.cause_table)


@register
class LossyUplinkScenario(Scenario):
    """Uniformly flaky uplinks: every client has an independent per-round
    outage probability plus heavy-tailed capacity fading — the closest world
    to the seed's i.i.d. transient model, kept as the control scenario."""

    name = "lossy_uplink"

    def __init__(self, n_clients: int, seed: int = 0, p_out: float = 0.15,
                 base_mbps: float = 10.0, **kw):
        self.p_out = p_out
        self.base_mbps = base_mbps
        super().__init__(n_clients, seed, **kw)

    def sample_round_arrays(self, r: int) -> LinkArrays:
        u = self.rng.uniform(size=self.n_clients)
        up = u >= self.p_out
        caps = np.zeros(self.n_clients)
        caps[up] = self._caps(np.full(int(up.sum()),
                                      self.base_mbps * MBPS), 0.7)
        return _one_cause(caps, up, "outage")


@register
class BlackoutScenario(Scenario):
    """Fault-injection world for the run-health monitors.

    Nominal lognormal links for the first ``onset`` rounds, then a core-
    network blackout: a seeded ``dark_frac`` of clients lose their links
    outright and the survivors' capacity collapses to ``residual`` of its
    base — uploads slide down the codec ladder, cohorts empty out, buffered
    uploads age past any staleness horizon, and the adaptive controller's
    capacity estimates fall off a cliff.  Every detector in
    ``repro.obs.health`` has something to say about this world; the healthy
    worlds above are the silence baselines.
    """

    name = "blackout"

    def __init__(self, n_clients: int, seed: int = 0, onset: int = 6,
                 dark_frac: float = 0.9, residual: float = 0.02,
                 base_mbps: float = 12.0, **kw):
        self.onset = onset
        self.dark_frac = dark_frac
        self.residual = residual
        self.base_mbps = base_mbps
        super().__init__(n_clients, seed, **kw)

    def _setup(self) -> None:
        self.base = self.base_mbps * MBPS * np.exp(
            self.rng.normal(0.0, 0.4, self.n_clients))
        # who goes dark is drawn once at setup, so the realization is fixed
        # by the seed regardless of how many rounds run before the onset
        self.dark = self.rng.uniform(size=self.n_clients) < self.dark_frac

    def sample_round_arrays(self, r: int) -> LinkArrays:
        up = (np.ones(self.n_clients, dtype=bool) if r <= self.onset
              else ~self.dark)
        caps = np.zeros(self.n_clients)
        caps[up] = self._caps(self.base[up], 0.3)
        if r > self.onset:
            caps[up] *= self.residual
        return _one_cause(caps, up, "blackout")
