"""Small self-contained FFT problems for examples and tests.

One factory instead of each caller hand-rolling the
dataset → split → partition → model → runner pipeline (the full-size
benchmark variant with LoRA/ResourceOpt knobs lives in
``benchmarks.common.make_problem``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.data.synthetic import fft_split, make_dataset, train_test_split
from repro.fl.partition import partition
from repro.fl.runtime import FFTConfig, FFTRunner


def make_toy_runner(cfg: FFTConfig, *, n_samples: int = 1500,
                    n_classes: int = 4, image_size: int = 8,
                    public_per_class: int = 15,
                    pretrain_steps: int = 30, seed: int = 0) -> FFTRunner:
    """CNN on a synthetic class-structured dataset, non-iid group split."""
    from repro.models.vision import make_model
    ds = make_dataset(n_samples, n_classes=n_classes, image_size=image_size,
                      channels=1, seed=seed)
    train, test = train_test_split(ds, n_samples // 5, seed=seed + 1)
    public, private = fft_split(train, public_per_class=public_per_class,
                                seed=seed)
    parts, _ = partition("group_classes", private.y, cfg.n_clients,
                         n_classes, classes_per_group=1, group_size=2,
                         seed=seed)
    init_fn, apply_fn = make_model("cnn", n_classes, image_size, 1)
    return FFTRunner(cfg, init_fn, apply_fn, public, parts, private, test,
                     pretrain_steps=pretrain_steps)


def make_server_mode_runners(cfg: FFTConfig, modes=("sync", "async"),
                             **toy_kwargs) -> Dict[str, FFTRunner]:
    """Identically-seeded runners differing only in ``server_mode`` — the
    fair way to compare the synchronous and asynchronous servers: same
    data split, same initial params, same failure realization seed."""
    return {mode: make_toy_runner(dataclasses.replace(cfg, server_mode=mode),
                                  **toy_kwargs)
            for mode in modes}
