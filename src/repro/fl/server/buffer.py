"""Staleness buffer: late uploads carried across round boundaries.

A synchronous server discards every upload that lands after the round
deadline.  The asynchronous server instead parks it here: the update was
computed from the round-``origin_round`` global model and physically lands at
absolute simulated time ``arrival_s``; it may still be aggregated in any
round ``origin_round + 1 .. origin_round + tau_max``, tagged with its
staleness, after which it is evicted.

Invariants (tested in ``tests/test_async_server.py``):
  * an update is applied at most once — ``(client, origin_round)`` keys are
    tracked and a duplicate push raises;
  * every applied update has staleness ``<= tau_max``;
  * nothing outlives its horizon: after ``collect(now, r)`` the buffer holds
    only updates with staleness ``<= tau_max`` that have not yet arrived.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Set, Tuple


@dataclasses.dataclass
class PendingUpdate:
    """One in-flight client upload."""
    client: int
    origin_round: int            # global round whose params seeded the update
    arrival_s: float             # absolute simulated landing time
    model: Any                   # w_i^{origin,E}
    delta: Any = None            # w_i^{origin,E} − w̄^{origin} (for FedBuff)
    origin_version: int = 0      # global-model version at dispatch; version
    #                              lag (not round lag) is the staleness that
    #                              discounts the update — a buffered server's
    #                              deferred rounds don't age anything
    codec: Optional[str] = None  # rung the upload traveled under
    upload_nbytes: Optional[float] = None  # bytes it cost on the wire
    distortion: float = 0.0      # compression distortion measured at encode
    packed: Any = None           # streaming mode: the wire PackedUpdate held
    #                              instead of the decoded model/delta pytrees
    #                              (model/delta stay None; payloads are
    #                              wire-sized, and stale origin globals are
    #                              shared references — ≤ tau_max+1 distinct)

    def staleness(self, current_round: int) -> int:
        """Round lag — bounds buffer lifetime (eviction horizon)."""
        return int(current_round - self.origin_round)


class StalenessBuffer:
    """Holds uploads that missed their round's deadline until they land."""

    def __init__(self, tau_max: int):
        if tau_max < 0:
            raise ValueError(f"tau_max must be >= 0, got {tau_max}")
        self.tau_max = tau_max
        self._entries: List[PendingUpdate] = []
        self._seen: Set[Tuple[int, int]] = set()
        self.n_applied = 0
        self.n_evicted = 0
        # telemetry hub (repro.obs); when live, evictions are additionally
        # logged as (client, origin_round) pairs for the loop to drain into
        # resolution events — a ``buffered`` outcome's terminal fate
        from repro.obs.telemetry import NULL_TELEMETRY
        self.telemetry = NULL_TELEMETRY
        self.evictions: List[Tuple[int, int]] = []

    def __len__(self) -> int:
        return len(self._entries)

    def pending(self) -> List[PendingUpdate]:
        return list(self._entries)

    def push(self, upd: PendingUpdate) -> None:
        key = (upd.client, upd.origin_round)
        if key in self._seen:
            raise ValueError(f"update {key} pushed twice")
        self._seen.add(key)
        self._entries.append(upd)
        if self.telemetry:
            self.telemetry.counter("buffer.pushed")

    def collect(self, now_s: float, current_round: int
                ) -> List[PendingUpdate]:
        """Pop every update that has landed by ``now_s`` and is still fresh
        enough (staleness ``<= tau_max``); silently evict updates whose
        staleness exceeded the horizon (landed or not — they can only get
        staler).  Returns arrivals sorted by landing time."""
        with self.telemetry.timer("phase.buffer"):
            ready, kept = [], []
            for e in self._entries:
                if e.staleness(current_round) > self.tau_max:
                    self.n_evicted += 1
                    if self.telemetry:
                        self.telemetry.counter("buffer.evicted")
                        self.evictions.append((e.client, e.origin_round))
                elif e.arrival_s <= now_s:
                    ready.append(e)
                else:
                    kept.append(e)
            self._entries = kept
            ready.sort(key=lambda e: (e.arrival_s, e.client))
            self.n_applied += len(ready)
            if self.telemetry and ready:
                self.telemetry.counter("buffer.applied", len(ready))
        return ready

    def ready_count(self, now_s: float, current_round: int) -> int:
        """How many still-fresh updates have landed by ``now_s`` (the
        buffered-K server's trigger condition), without popping them."""
        return sum(1 for e in self._entries
                   if e.arrival_s <= now_s
                   and e.staleness(current_round) <= self.tau_max)

    def evict(self, current_round: int) -> int:
        """Drop every update whose staleness exceeded the horizon; returns
        the number evicted.  ``collect`` does this implicitly — this is for
        rounds where the server defers aggregation."""
        with self.telemetry.timer("phase.buffer"):
            n0 = len(self._entries)
            if self.telemetry:
                for e in self._entries:
                    if e.staleness(current_round) > self.tau_max:
                        self.telemetry.counter("buffer.evicted")
                        self.evictions.append((e.client, e.origin_round))
            self._entries = [e for e in self._entries
                             if e.staleness(current_round) <= self.tau_max]
            self.n_evicted += n0 - len(self._entries)
            return n0 - len(self._entries)

    def drop_client(self, client: int) -> int:
        """Discard every pending upload from ``client`` (e.g. permanent
        churn observed before its stragglers landed). Returns #dropped."""
        n0 = len(self._entries)
        if self.telemetry:
            for e in self._entries:
                if e.client == client:
                    self.telemetry.counter("buffer.evicted")
                    self.evictions.append((e.client, e.origin_round))
        self._entries = [e for e in self._entries if e.client != client]
        dropped = n0 - len(self._entries)
        self.n_evicted += dropped
        return dropped

    def reset(self) -> None:
        self._entries.clear()
        self._seen.clear()
        self.n_applied = 0
        self.n_evicted = 0
        self.evictions.clear()
