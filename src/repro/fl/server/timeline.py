"""Arrival-timeline synthesis for legacy (boolean) failure models.

The async server needs to know *when* each upload lands, but the seed
failure processes (``transient`` / ``intermittent`` / ``mixed`` / ``none``)
only answer up-or-down.  This adapter gives them the time dimension the
scenario worlds already have: each round it takes the inner model's up/down
draw, samples a capacity realization from the client's physical channel
(Eq. 37–39), and runs the same ``DeadlineSimulator`` the scenario engine
uses — capacity → upload time via the Eq. 41 rate relation
(``net_mod.uplink_rate`` fixes the bits; the channel draw fixes the bps).

The synthesized capacity is an independent realization of the same channel,
so under ``transient`` an up-flagged client can still draw a slow channel
and become a straggler — richer than the boolean model, by design.  Rounds
are cached so repeated draws replay the realization, matching
``ScenarioFailureModel``'s contract.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.fl.failures import FailureModel
from repro.fl.network import ClientChannel
from repro.fl.scenarios.engine import (DeadlineSimulator, LinkState,
                                       RoundEvents)


class TimedFailureAdapter(FailureModel):
    """Wraps a boolean ``FailureModel`` with synthesized arrival timelines."""

    def __init__(self, inner: FailureModel, channels: List[ClientChannel], *,
                 model_bytes: float, deadline_s: float,
                 compute_s: float = 2.0, seed: int = 0):
        self.inner = inner
        self.channels = channels
        self.sim = DeadlineSimulator(len(channels), model_bytes=model_bytes,
                                     deadline_s=deadline_s,
                                     compute_s=compute_s, seed=seed + 13)
        self.seed = seed
        self.reset()

    def reset(self) -> None:
        self.inner.reset()
        self.sim.reset()
        self.rng = np.random.default_rng(self.seed + 29)
        self._cache: Dict[int, RoundEvents] = {}

    def set_payload_bytes(self, upload_bytes=None, download_bytes=None
                          ) -> None:
        if self._cache:
            raise RuntimeError("payload bytes must be set before any round "
                               "is drawn — cached realizations would be "
                               "priced at the old sizes")
        self.sim.set_payload_bytes(upload_bytes, download_bytes)

    def draw_events(self, r: int) -> RoundEvents:
        if r not in self._cache:
            up = self.inner.draw(r)
            links = []
            for i, chan in enumerate(self.channels):
                if not up[i]:
                    links.append(LinkState(0.0, up=False, cause="outage"))
                else:
                    links.append(LinkState(float(chan.capacity(self.rng))))
            self._cache[r] = self.sim.simulate_round(r, links)
        return self._cache[r]

    def draw(self, r: int) -> np.ndarray:
        return self.draw_events(r).connected_mask()
