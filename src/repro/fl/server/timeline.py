"""Arrival-timeline synthesis for legacy (boolean) failure models.

The async server needs to know *when* each upload lands, but the seed
failure processes (``transient`` / ``intermittent`` / ``mixed`` / ``none``)
only answer up-or-down.  This adapter gives them the time dimension the
scenario worlds already have: each round it takes the inner model's up/down
draw, samples a capacity realization from the client's physical channel
(Eq. 37–39), and runs the same ``DeadlineSimulator`` the scenario engine
uses — capacity → upload time via the Eq. 41 rate relation
(``net_mod.uplink_rate`` fixes the bits; the channel draw fixes the bps).

The synthesized capacity is an independent realization of the same channel,
so under ``transient`` an up-flagged client can still draw a slow channel
and become a straggler — richer than the boolean model, by design.  The
link realization is cached separately from its timing simulation
(``LinkRealizationCache``), so repeated draws replay the realization and
per-round payload repricing never perturbs the inner model's draw.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.fl.failures import FailureModel
from repro.fl.network import ClientChannel, capacity_array
from repro.fl.scenarios.engine import (CAUSE_OK, DeadlineSimulator,
                                       LinkArrays, LinkRealizationCache)


class TimedFailureAdapter(LinkRealizationCache, FailureModel):
    """Wraps a boolean ``FailureModel`` with synthesized arrival timelines."""

    def __init__(self, inner: FailureModel, channels: List[ClientChannel], *,
                 model_bytes: float, deadline_s: float,
                 compute_s: float = 2.0, seed: int = 0,
                 engine: str = "vectorized"):
        self.inner = inner
        self.channels = channels
        self.sim = DeadlineSimulator(len(channels), model_bytes=model_bytes,
                                     deadline_s=deadline_s,
                                     compute_s=compute_s, seed=seed + 13,
                                     engine=engine)
        self.seed = seed
        self.reset()

    def reset(self) -> None:
        self.inner.reset()
        self.sim.reset()
        self._reset_realization()

    def _sample_links(self, r: int) -> LinkArrays:
        up = np.asarray(self.inner.draw(r), dtype=bool)
        # Capacity draws come from an RNG keyed by (seed, round) and are
        # made for *every* client, up or down — mirroring the
        # DeadlineSimulator jitter fix, so one client's outage (or a
        # different inner failure mode at the same seed) never shifts
        # another client's synthesized capacity: realizations stay
        # common-random-number comparable.
        rng = np.random.default_rng([self.seed + 29, 0x71D3, r])
        caps = capacity_array(self.channels, rng)
        caps = np.where(up, caps, 0.0)
        codes = np.where(up, 0, 1).astype(np.int16)
        return LinkArrays(caps, up, codes, (CAUSE_OK, "outage"))
