"""Asynchronous aggregation server: staleness-buffered rounds over the
scenario engine's per-client arrival timelines.

Three layers:

* ``buffer``   — ``StalenessBuffer``: late uploads carried into rounds
  ``r+1..r+tau_max``, tagged with staleness and originating round.
* ``loops``    — pluggable ``SyncRoundLoop`` / ``AsyncRoundLoop`` drivers
  behind ``FFTConfig.server_mode = "sync" | "async" | "buffered"``, sharing
  the runner's jitted local-update path; simulated wall-clock ``timeline``.
* ``timeline`` — ``TimedFailureAdapter``: synthesizes arrival times for
  legacy boolean failure models so every ``failure_mode`` works async.

Strategy-side counterparts (``fedasync`` / ``fedbuff`` / ``fedauto_async``)
live in ``repro.core.strategies``.
"""
from repro.fl.server.buffer import PendingUpdate, StalenessBuffer
from repro.fl.server.loops import (SERVER_MODES, AsyncRoundLoop, RoundLoop,
                                   SyncRoundLoop, TimePoint, make_round_loop)
from repro.fl.server.timeline import TimedFailureAdapter

__all__ = [
    "PendingUpdate", "StalenessBuffer",
    "SERVER_MODES", "AsyncRoundLoop", "RoundLoop", "SyncRoundLoop",
    "TimePoint", "make_round_loop",
    "TimedFailureAdapter",
]
