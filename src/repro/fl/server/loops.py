"""Pluggable server round loops (``FFTConfig.server_mode``).

``FFTRunner.run`` used to hard-code the synchronous Algorithm-1 loop; it now
delegates to one of these drivers, all sharing the runner's jitted
local-update path, client selection RNG, trace recording, and evaluation
cadence:

* ``SyncRoundLoop``  ("sync", the default) — the original behavior:
  ``connected = selected & up & met_deadline``, stragglers discarded.
* ``AsyncRoundLoop`` ("async") — stragglers are *computed anyway* (their
  local update started from the round's global model) and parked in a
  ``StalenessBuffer`` keyed by the exact wall-clock instant the scenario
  engine says their upload lands; they are aggregated, staleness-tagged, in
  the round their arrival time falls into (up to ``tau_max`` rounds late).
* ``AsyncRoundLoop(buffered=True)`` ("buffered") — semi-async FedBuff-style
  server: arrivals additionally accumulate until ``buffer_k`` of them have
  landed, and only then is an aggregation step taken.

Every loop also advances a simulated wall clock (``RoundEvents.server_wait``
per round) and records ``TimePoint(rnd, t_s, acc)`` into
``runner.timeline`` at each evaluation, so sync-vs-async comparisons can be
made in simulated seconds instead of round counts.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Dict, List

import jax
import numpy as np

from repro.core.aggregation import delta_pytree
from repro.core.strategies import (Arrival, AsyncRoundContext, AsyncStrategy,
                                   RoundContext, Strategy)
from repro.fl.server.buffer import PendingUpdate, StalenessBuffer
from repro.obs.telemetry import (AGGREGATED, BUFFERED, EVICTED, LINK_DOWN,
                                 MISSED_DEADLINE, NOT_SELECTED,
                                 NULL_TELEMETRY, SKIPPED_STRAGGLER)


@dataclasses.dataclass
class TimePoint:
    """One evaluation, indexed by both round and simulated wall clock."""
    rnd: int
    t_s: float                   # simulated seconds since training start
    acc: float


class RoundLoop:
    """Skeleton shared by all server modes."""

    def __init__(self, runner, strategy: Strategy, tracer=None, log=None):
        self.runner = runner
        self.strategy = strategy
        self.tracer = tracer
        self.log = log
        self.clock_s = 0.0
        # telemetry hub: the runner builds its per-run hub (or the shared
        # no-op) in run() before constructing the loop
        self.obs = getattr(runner, "telemetry", NULL_TELEMETRY)
        self.participants_per_round: List[int] = []
        # per-round {client: normalized compression distortion} of the
        # uploads encoded that round (what the trace records and
        # fidelity-aware aggregation discounts by)
        self.distortion_history: List[Dict[int, float]] = []
        # clients excluded from this round's selection draw because their
        # capacity estimate cannot land even the lowest rung
        # (cfg.skip_stragglers); written by _select each round
        self.skipped = np.zeros(runner.n_clients, dtype=bool)
        self.n_skipped = 0
        # Streaming aggregation: a streaming-capable strategy receives the
        # round's uploads as wire PackedUpdates through a StreamAccumulator
        # (fl/comm/stream.py) instead of a dict of decoded model pytrees —
        # K arrivals never materialize K fp32 models.  Strategies that need
        # per-client models keep the materializing path, as does
        # ``cfg.streaming_agg = "off"`` (the benchmark's control arm).
        self.streaming = (bool(getattr(strategy, "streaming", False)) and
                          getattr(runner.cfg, "streaming_agg", "auto")
                          != "off")

    def _uplink(self, client: int, model, t_global, codec_name=None):
        """Ship one local update through the communication codec: encode
        client-side (error feedback applied), decode server-side.  Returns
        ``(reconstructed_model, codec_name, wire_bytes, distortion)`` — the
        model the strategy aggregates plus the upload's actual wire
        metadata.  ``codec_name`` overrides the run's static codec (adaptive
        per-client rungs)."""
        comm = self.runner.comm
        codec = comm.codec_named(codec_name) if codec_name else comm.codec
        recon, _payload, distortion = comm.roundtrip(client, model, t_global,
                                                     codec=codec)
        return recon, codec.name, comm.nbytes_for(codec), float(distortion)

    def _uplink_packed(self, client: int, model, t_global, r: int,
                       codec_name=None):
        """Streaming sibling of ``_uplink``: encode client-side only and
        hand back the wire ``PackedUpdate`` — the server never reconstructs
        a model pytree for this upload (the StreamAccumulator decodes it
        in-kernel at aggregation).  Error feedback, distortion measurement,
        and byte accounting are identical to ``_uplink``."""
        from repro.fl.comm.stream import PackedUpdate
        comm = self.runner.comm
        codec = comm.codec_named(codec_name) if codec_name else comm.codec
        payload, distortion = comm.encode_upload(client, model, t_global,
                                                 codec=codec)
        nbytes = comm.nbytes_for(codec)
        return PackedUpdate(client=client, payload=payload,
                            origin_global=t_global, codec=codec.name,
                            nbytes=nbytes, distortion=float(distortion),
                            origin_round=r)

    def _materialize_gauges(self, r: int, n_decoded: int) -> None:
        """The materializing path's side of the ``uplink_decode``
        attribution: ``n_decoded`` fp32 model pytrees were held at once for
        this round's aggregate (the streaming path's gauges come from the
        StreamAccumulator and report an O(1) peak instead)."""
        tel = self.obs
        if not tel:
            return
        fp32 = self.runner.comm.fp32_nbytes
        if n_decoded:
            tel.counter("uplink.fallback_payloads", n_decoded)
            tel.counter("uplink.decoded_bytes", n_decoded * fp32)
        tel.gauge(r, "uplink_fused_payloads", 0)
        tel.gauge(r, "uplink_fallback_payloads", n_decoded)
        tel.gauge(r, "uplink_peak_decoded_bytes", n_decoded * fp32)

    def _begin_round(self, r: int, selected: np.ndarray):
        """Round preamble shared by every server mode: the adaptive
        controller (when present) assigns this round's per-client rungs and
        re-prices the timing model *before* the network is drawn, then the
        server broadcasts the global model through the downlink codec.

        Returns ``(t_global, assignment, dl_bytes)`` — the parameters
        clients actually start local training from (the decoded broadcast;
        identical to ``runner.global_params`` without a downlink codec), the
        round's ``RoundAssignment`` (None for static runs), and the
        broadcast bytes this round actually moved (the full-model
        ``ref_bytes`` enrollment on a downlink codec's first round, the
        compressed rate afterwards — the simulator, the trace, and
        ``CommState``'s accounting all use this same number)."""
        runner = self.runner
        assignment = None
        dl_bytes = runner.comm.next_broadcast_nbytes()
        if runner.controller is not None:
            # v3 adaptive traces were recorded with the controller observing
            # the steady-state compressed broadcast in round 1 (the
            # enrollment repricing postdates them): feed the replaying
            # controller the same number, or its capacity estimates — and
            # therefore its re-derived rungs — would diverge from the
            # recording and the drift check below would blame the user's
            # configuration for a schema change.
            hdr = getattr(runner.failures, "header", None)
            legacy_enroll = hdr is not None and hdr.get("version", 0) < 4
            assignment = runner.controller.assign(
                r, selected,
                download_bytes=(None if legacy_enroll else dl_bytes))
            if legacy_enroll:
                # keep any re-recorded trace consistent with the legacy
                # observation the replaying controller is fed
                dl_bytes = assignment.download_bytes
            runner.failures.set_payload_bytes(
                upload_bytes=assignment.upload_bytes,
                download_bytes=np.full(runner.n_clients, dl_bytes))
            # Replaying a recorded adaptive run: the controller re-derives
            # its assignments from the replayed events, so any divergence
            # from the recorded byte vectors — or from the recorded rungs,
            # which can differ even at identical bytes (qsgd:8 and int8 are
            # byte-tied but decode differently) — means the trace and this
            # configuration disagree: fail loudly, don't mis-price quietly.
            if hasattr(runner.failures, "payload_bytes"):
                rec = runner.failures.payload_bytes(r)
                if rec is not None:
                    known = ~np.isnan(rec)
                    if not np.allclose(rec[known],
                                       assignment.upload_bytes[known],
                                       rtol=1e-6):
                        raise ValueError(
                            f"round {r}: replayed trace recorded per-client "
                            f"upload bytes {rec} but the adaptive controller "
                            f"assigns {assignment.upload_bytes}; the trace "
                            "was recorded under a different adaptive "
                            "configuration")
            if hasattr(runner.failures, "codecs"):
                rec_codecs = runner.failures.codecs(r)
                if rec_codecs is not None:
                    # rows without a recorded rung (unselected that round)
                    # carry None — only the rungs the server actually handed
                    # out are cross-checked
                    drift = {i: (rc, ac) for i, (rc, ac) in
                             enumerate(zip(rec_codecs, assignment.codecs))
                             if rc is not None and rc != ac}
                    if drift:
                        raise ValueError(
                            f"round {r}: replayed trace recorded per-client "
                            f"codec rungs {rec_codecs} but the adaptive "
                            f"controller assigns {assignment.codecs} "
                            f"(drift at {drift}); the trace was recorded "
                            "under a different adaptive configuration")
        elif runner.comm.downlink_codec is not None:
            # static run with a downlink codec: reprice the timing model
            # each round so the enrollment broadcast (round 1) travels at
            # full size there too — not just in the byte accounting — and
            # later rounds drop back to the compressed rate.  The upload
            # size must be restated: set_payload_bytes resets any direction
            # passed as None back to the symmetric model_bytes default.
            runner.failures.set_payload_bytes(
                upload_bytes=np.full(runner.n_clients,
                                     runner.comm.upload_bytes),
                download_bytes=np.full(runner.n_clients, dl_bytes))
        t_global, dl_charged = runner.comm.broadcast(runner.global_params)
        if self.obs:
            # the bytes CommState actually charged (which is what
            # total_downlink_bytes accumulates), not the repriced dl_bytes a
            # legacy-trace shim may have substituted for the timing model
            self.obs.gauge(r, "downlink_bytes", float(dl_charged))
        return t_global, assignment, dl_bytes

    def _trace_round(self, r, selected, connected, events, up, met_deadline,
                     assignment, dl_bytes, distortions=None) -> None:
        if self.tracer is None:
            return
        with self.obs.timer("phase.trace"):
            runner = self.runner
            codecs = None
            if assignment is not None:
                # only rungs the server actually handed out this round are
                # assignments; unselected clients' rows carry no codec
                codecs = [c if selected[i] else None
                          for i, c in enumerate(assignment.codecs)]
            self.tracer.write_round(
                r, selected, connected, events, up=up,
                met_deadline=met_deadline,
                payload_bytes=(assignment.upload_bytes
                               if assignment is not None
                               else runner.comm.upload_bytes),
                download_bytes=dl_bytes,
                codecs=codecs, distortions=distortions)

    def _observe(self, r, events, selected) -> None:
        runner = self.runner
        if runner.controller is not None and events is not None:
            runner.controller.observe(r, events, selected)

    # ------------------------------------------------------------- shared
    def _select(self) -> np.ndarray:
        """Uniform K-of-N selection; with ``cfg.skip_stragglers`` and an
        adaptive controller, clients whose capacity estimate cannot land
        even the lowest rung are excluded from the draw (selecting them
        buys nothing: the coarsest upload is already predicted to miss).
        Skipped clients are recorded in ``self.skipped`` and emitted as the
        distinct ``skipped_straggler`` outcome, so the reconcile invariant
        (exactly one terminal outcome per (round, client)) still closes."""
        runner = self.runner
        self.skipped = np.zeros(runner.n_clients, dtype=bool)
        if runner.cfg.skip_stragglers and runner.controller is not None:
            landable = runner.controller.landable_mask()
            self.skipped = ~landable
            self.n_skipped += int(self.skipped.sum())
            eligible = np.where(landable)[0]
            selected = np.zeros(runner.n_clients, dtype=bool)
            if runner.k_selected >= len(eligible):
                selected[eligible] = True
            elif len(eligible):
                sel = runner.rng.choice(eligible, runner.k_selected,
                                        replace=False)
                selected[sel] = True
            return selected
        if runner.k_selected >= runner.n_clients:
            return np.ones(runner.n_clients, dtype=bool)
        sel = runner.rng.choice(runner.n_clients, runner.k_selected,
                                replace=False)
        selected = np.zeros(runner.n_clients, dtype=bool)
        selected[sel] = True
        return selected

    def _cohorts(self, idx: np.ndarray):
        """Yield ``idx`` in fixed-size cohorts (``cfg.cohort_size``; 0 =
        everyone at once) — the round loop's streaming unit, so a large
        population's local updates and uploads are processed in bounded
        batches instead of one unbounded sweep."""
        cs = int(getattr(self.runner.cfg, "cohort_size", 0) or 0)
        if cs <= 0 or len(idx) <= cs:
            yield idx
            return
        for k in range(0, len(idx), cs):
            yield idx[k:k + cs]

    def _round_duration(self, selected, connected, events) -> float:
        """Simulated seconds the server spent on this round."""
        if events is not None:
            return float(events.server_wait(selected))
        # Legacy models have no time dimension: the server waits out its
        # timeout whenever a selected client is missing, else a nominal
        # compute+transmit round.
        cfg = self.runner.cfg
        if bool((selected & ~connected).any()):
            return float(cfg.deadline_s)
        return float(cfg.compute_s + cfg.tx_delay_s)

    def _maybe_eval(self, r: int, rounds: int, history: List[float]) -> None:
        runner = self.runner
        if r % runner.cfg.eval_every == 0 or r == rounds:
            acc = runner.evaluate()
            history.append(acc)
            runner.timeline.append(TimePoint(rnd=r, t_s=self.clock_s,
                                             acc=acc))
            if self.obs:
                self.obs.gauge(r, "eval_acc", float(acc))
            if self.log:
                self.log(r, acc)

    def run(self, rounds: int) -> List[float]:
        history: List[float] = []
        tel = self.obs
        for r in range(1, rounds + 1):
            tel.begin_round(r)
            if tel:
                # snapshot the run-wide phase accumulators so this round's
                # share can be emitted as per-round gauges below
                phase_snap = dict(tel.timers_s)
                wall_t0 = time.perf_counter()
            duration = self.run_round(r)
            self.clock_s += duration
            if tel:
                comm = self.runner.comm
                tel.gauge(r, "server_wait_s", float(duration))
                tel.gauge(r, "clock_s", float(self.clock_s))
                tel.gauge(r, "participants",
                          float(self.participants_per_round[-1]))
                tel.gauge(r, "cum_uplink_bytes",
                          float(comm.total_uplink_bytes))
                tel.gauge(r, "cum_downlink_bytes",
                          float(comm.total_downlink_bytes))
            self._maybe_eval(r, rounds, history)
            if tel:
                # real (host) wall seconds of this round, eval included —
                # distinct from the *simulated* server_wait_s — plus each
                # phase timer's delta since the round began; phases are
                # exclusive, so the deltas are disjoint and sum ≤ wall
                tel.gauge(r, "round_wall_s", time.perf_counter() - wall_t0)
                for name, total in tel.timers_s.items():
                    if not name.startswith("phase."):
                        continue
                    delta = total - phase_snap.get(name, 0.0)
                    if delta > 0.0:
                        tel.gauge(r, name, delta)
            tel.end_round(r)
        return history

    def run_round(self, r: int) -> float:
        raise NotImplementedError


class SyncRoundLoop(RoundLoop):
    """Algorithm 1 verbatim: deadline stragglers are discarded."""

    def run_round(self, r: int) -> float:
        runner, strategy = self.runner, self.strategy
        selected = self._select()
        t_global, assignment, dl_bytes = self._begin_round(r, selected)
        with self.obs.timer("phase.network_draw"):
            up, met_deadline, events = runner._draw_network(r)
        connected = selected & up & met_deadline
        self.participants_per_round.append(int(connected.sum()))
        self._observe(r, events, selected)

        client_models: Dict[int, Any] = {}
        packed: Dict[int, Any] = {}             # streaming: wire PackedUpdates
        codecs_used: Dict[int, str] = {}
        nbytes_used: Dict[int, float] = {}
        distortions: Dict[int, float] = {}
        mu = strategy.prox_mu()
        rung_names = assignment.codecs if assignment else None
        for cohort in self._cohorts(np.where(connected)[0]):
            for i in cohort:
                corr = strategy.correction(i, runner)
                m = runner.run_local(t_global, runner.client_x[i],
                                     runner.client_y[i], r, mu=mu, corr=corr)
                m = strategy.post_local(i, r, m, t_global, runner)
                cname_over = rung_names[int(i)] if rung_names else None
                if self.streaming:
                    pu = self._uplink_packed(int(i), m, t_global, r,
                                             codec_name=cname_over)
                    packed[int(i)] = pu
                    cname, nbytes, dist = pu.codec, pu.nbytes, pu.distortion
                else:
                    recon, cname, nbytes, dist = self._uplink(
                        int(i), m, t_global, codec_name=cname_over)
                    client_models[int(i)] = recon
                codecs_used[int(i)] = cname
                nbytes_used[int(i)] = nbytes
                distortions[int(i)] = dist
        if not self.streaming:
            self._materialize_gauges(r, len(client_models))
        self.distortion_history.append(dict(distortions))
        tel = self.obs
        if tel:
            tel.gauge(r, "selected", float(selected.sum()))
            if self.skipped.any():
                tel.gauge(r, "skipped_stragglers",
                          float(self.skipped.sum()))
            causes = events.cause_list() if events is not None else None
            finish = events.finish_array() if events is not None else None
            for i in range(runner.n_clients):
                if not selected[i]:
                    tel.client_outcome(
                        r, i, SKIPPED_STRAGGLER if self.skipped[i]
                        else NOT_SELECTED)
                elif not up[i]:
                    tel.client_outcome(
                        r, i, LINK_DOWN,
                        detail=(causes[i] if causes is not None else None))
                elif not met_deadline[i]:
                    never = (finish is not None and
                             not math.isfinite(finish[i]))
                    tel.client_outcome(r, i, MISSED_DEADLINE,
                                       detail="never_lands" if never else None)
                else:
                    tel.client_outcome(r, i, AGGREGATED,
                                       rung=codecs_used.get(int(i)),
                                       upload_bytes=nbytes_used.get(int(i)),
                                       distortion=distortions.get(int(i)))
        # trace written after the uploads, so each client row carries the
        # upload's measured distortion alongside its rung and byte count
        self._trace_round(r, selected, connected, events, up, met_deadline,
                          assignment, dl_bytes, distortions=distortions)
        server_model = runner.run_local(t_global, runner.public_x,
                                        runner.public_y, r)

        ctx = RoundContext(
            rnd=r, global_params=t_global, server_model=server_model,
            client_models=client_models, selected=selected,
            connected=connected, p=runner.p,
            client_hists=runner.client_hists, server_hist=runner.server_hist,
            global_hist=runner.global_hist,
            full_participation=runner.k_selected >= runner.n_clients,
            eps_estimates=runner.eps_estimates, runner=runner,
            # a decodable codec name and a scalar size only exist for static
            # runs; adaptive rounds carry the per-client truth instead
            codec=(None if assignment else runner.comm.codec.name),
            upload_nbytes=(None if assignment else runner.comm.upload_bytes),
            codecs=codecs_used, upload_bytes=nbytes_used,
            distortions=distortions,
            packed=(packed if self.streaming else None), telemetry=self.obs)
        with tel.timer("phase.aggregate"):
            new_global = strategy.aggregate(ctx)
            if tel:
                jax.block_until_ready(new_global)
        runner.global_params = new_global
        return self._round_duration(selected, connected, events)


class AsyncRoundLoop(RoundLoop):
    """Staleness-buffered server over the scenario engine's arrival times.

    Per round: every selected client with an up link *and a physically
    landing upload* runs its local update from the current global model.
    On-deadline uploads land this round; late ones are pushed into the
    ``StalenessBuffer`` with their absolute landing instant (round start +
    ``ClientRoundEvent.finish_s``) — unless even ``tau_max`` extra rounds of
    server waiting (``(tau_max+1) * deadline_s``) could not cover their
    upload, in which case they are dropped up front (``n_unreachable``).
    At the round's end the buffer releases everything that landed within the
    round's window, staleness-tagged, and the strategy aggregates.
    """

    def __init__(self, runner, strategy, tracer=None, log=None,
                 buffered: bool = False):
        super().__init__(runner, strategy, tracer=tracer, log=log)
        self.buffer = StalenessBuffer(runner.cfg.tau_max)
        self.buffer.telemetry = self.obs
        self.buffered = buffered
        self.n_unreachable = 0
        self.staleness_applied: List[int] = []
        # Global-model version: bumped per *aggregation step*, not per round.
        # Discount staleness is version lag, so a buffered server's deferred
        # rounds (global unchanged) don't penalize updates that are still
        # computed from the current model.  Eviction stays round-based.
        self.version = 0

    def run_round(self, r: int) -> float:
        runner, strategy, cfg = self.runner, self.strategy, self.runner.cfg
        selected = self._select()
        t_global, assignment, dl_bytes = self._begin_round(r, selected)
        with self.obs.timer("phase.network_draw"):
            up, met_deadline, events = runner._draw_network(r)
        if events is None:
            raise RuntimeError(
                "async server modes need per-client arrival timelines; the "
                "runner should have wrapped this failure model in "
                "TimedFailureAdapter")
        fresh_connected = selected & up & met_deadline
        self._observe(r, events, selected)

        mu = strategy.prox_mu()
        t_start = self.clock_s
        horizon_s = cfg.deadline_s * (cfg.tau_max + 1)
        distortions: Dict[int, float] = {}
        tel = self.obs
        pushed: Dict[int, PendingUpdate] = {}   # this round's buffer pushes
        finish_s = events.finish_array()
        rung_names = assignment.codecs if assignment else None
        for cohort in self._cohorts(np.where(selected & up)[0]):
            for i in cohort:
                fin = float(finish_s[int(i)])
                if not math.isfinite(fin):
                    continue                   # never lands at all
                late = not met_deadline[int(i)]
                if late and (cfg.tau_max == 0 or fin > horizon_s):
                    # even tau_max full-deadline rounds cannot stretch to
                    # this landing time: don't waste the local compute
                    self.n_unreachable += 1
                    continue
                corr = strategy.correction(int(i), runner)
                m = runner.run_local(t_global, runner.client_x[i],
                                     runner.client_y[i], r, mu=mu, corr=corr)
                m = strategy.post_local(int(i), r, m, t_global, runner)
                # The wire sits between dispatch and landing: what the
                # buffer holds is the upload exactly as the server will
                # eventually see it (the scenario engine already priced its
                # bytes), tagged with the rung, byte count, and distortion
                # it traveled under — measured now, at encode time, not at
                # landing.  Streaming mode parks the wire-sized packed
                # payload; materializing mode parks the decoded model.
                cname_over = rung_names[int(i)] if rung_names else None
                if self.streaming:
                    pu = self._uplink_packed(int(i), m, t_global, r,
                                             codec_name=cname_over)
                    dist = pu.distortion
                    distortions[int(i)] = dist
                    # decode(payload) IS the origin-relative delta, so
                    # delta-based strategies (FedBuff) need no dispatch-time
                    # snapshot either
                    upd = PendingUpdate(
                        client=int(i), origin_round=r,
                        arrival_s=t_start + fin, model=None, delta=None,
                        origin_version=self.version, codec=pu.codec,
                        upload_nbytes=pu.nbytes, distortion=dist, packed=pu)
                else:
                    m, cname, nbytes, dist = self._uplink(
                        int(i), m, t_global, codec_name=cname_over)
                    distortions[int(i)] = dist
                    # Only delta-based strategies (FedBuff) need the
                    # dispatch-time snapshot; skipping it elsewhere halves
                    # the buffer's memory.
                    delta = (delta_pytree(m, t_global)
                             if getattr(strategy, "wants_delta", False)
                             else None)
                    upd = PendingUpdate(
                        client=int(i), origin_round=r,
                        arrival_s=t_start + fin, model=m, delta=delta,
                        origin_version=self.version, codec=cname,
                        upload_nbytes=nbytes, distortion=dist)
                self.buffer.push(upd)
                if tel:
                    pushed[int(i)] = upd
        self.distortion_history.append(dict(distortions))
        # trace written after the uploads, so each client row carries the
        # upload's measured distortion alongside its rung and byte count
        self._trace_round(r, selected, fresh_connected, events, up,
                          met_deadline, assignment, dl_bytes,
                          distortions=distortions)

        duration = self._round_duration(selected, fresh_connected, events)
        if not math.isfinite(duration):
            raise RuntimeError(
                f"round {r}: infinite server wait — the failure model has no "
                "timing data (e.g. a trace recorded from a legacy boolean "
                "mode); async server modes need real arrival timelines")
        now = t_start + duration
        if self.buffered and self.buffer.ready_count(now, r) < cfg.buffer_k:
            # semi-async server: not enough landed updates to justify a step;
            # advance the clock, age the buffer, keep the global model
            self.buffer.evict(r)
            self.participants_per_round.append(0)
            if tel:
                self._emit_async_outcomes(r, selected, up, events, pushed, {})
            return duration

        arrivals = [Arrival(client=p.client, origin_round=p.origin_round,
                            staleness=self.version - p.origin_version,
                            arrival_s=p.arrival_s,
                            model=p.model, delta=p.delta, codec=p.codec,
                            upload_nbytes=p.upload_nbytes,
                            distortion=p.distortion, packed=p.packed)
                    for p in self.buffer.collect(now, r)]
        self.staleness_applied.extend(a.staleness for a in arrivals)
        self.participants_per_round.append(len(arrivals))
        if not self.streaming:
            self._materialize_gauges(r, len(arrivals))
        if tel:
            self._emit_async_outcomes(
                r, selected, up, events, pushed,
                {(a.client, a.origin_round): a for a in arrivals})
        server_model = runner.run_local(t_global, runner.public_x,
                                        runner.public_y, r)
        with tel.timer("phase.aggregate"):
            new_global = self._aggregate(r, now, t_global, server_model,
                                         selected, arrivals)
            if tel:
                jax.block_until_ready(new_global)
        runner.global_params = new_global
        self.version += 1
        return duration

    def _emit_async_outcomes(self, r, selected, up, events, pushed,
                             collected) -> None:
        """One terminal outcome per (round, client), async semantics: this
        round's buffer pushes are ``aggregated`` when collected within the
        same round, else provisionally ``buffered`` (upgraded later by a
        resolution event); selected-and-up clients that never pushed either
        never land at all (``missed_deadline``/never_lands) or could not
        land inside the staleness horizon (``evicted``/unreachable).  Past
        rounds' collected arrivals and the buffer's horizon evictions are
        forwarded as resolution events against their origin round."""
        tel = self.obs
        tel.gauge(r, "selected", float(selected.sum()))
        if self.skipped.any():
            tel.gauge(r, "skipped_stragglers", float(self.skipped.sum()))
        for a in collected.values():
            if a.origin_round != r:
                tel.resolve(a.origin_round, a.client, AGGREGATED,
                            staleness=int(a.staleness), applied_round=r)
        for client, origin in self.buffer.evictions:
            tel.resolve(origin, client, EVICTED, applied_round=r)
        self.buffer.evictions.clear()
        causes = events.cause_list()
        finish = events.finish_array()
        for i in range(self.runner.n_clients):
            if not selected[i]:
                tel.client_outcome(
                    r, i, SKIPPED_STRAGGLER if self.skipped[i]
                    else NOT_SELECTED)
            elif not up[i]:
                tel.client_outcome(r, i, LINK_DOWN, detail=causes[i])
            elif i in pushed:
                upd = pushed[i]
                a = collected.get((i, r))
                if a is not None:
                    tel.client_outcome(r, i, AGGREGATED,
                                       staleness=int(a.staleness),
                                       rung=upd.codec,
                                       upload_bytes=upd.upload_nbytes,
                                       distortion=upd.distortion)
                else:
                    tel.client_outcome(r, i, BUFFERED, rung=upd.codec,
                                       upload_bytes=upd.upload_nbytes,
                                       distortion=upd.distortion)
            else:
                if not math.isfinite(finish[i]):
                    tel.client_outcome(r, i, MISSED_DEADLINE,
                                       detail="never_lands")
                else:
                    tel.client_outcome(r, i, EVICTED, detail="unreachable")

    @staticmethod
    def _freshest(arrivals) -> Dict[int, Arrival]:
        """Freshest landed update per client (highest origin round)."""
        freshest: Dict[int, Arrival] = {}
        for a in arrivals:
            cur = freshest.get(a.client)
            if cur is None or a.origin_round > cur.origin_round:
                freshest[a.client] = a
        return freshest

    @staticmethod
    def _wire_metadata(freshest: Dict[int, Arrival]):
        """The per-client wire-metadata dicts a round context carries,
        keyed off the freshest arrival per client.  Async strategies read
        per-arrival metadata from the ``Arrival`` rows themselves; these
        dicts are the one-value-per-client summary both context flavors
        expose."""
        codecs = {c: a.codec for c, a in freshest.items()
                  if a.codec is not None}
        upload_bytes = {c: a.upload_nbytes for c, a in freshest.items()
                        if a.upload_nbytes is not None}
        distortions = {c: float(a.distortion) for c, a in freshest.items()}
        return codecs, upload_bytes, distortions

    def _aggregate(self, r, now, t_global, server_model, selected, arrivals):
        runner, strategy = self.runner, self.strategy
        # a decodable scalar codec/size only exists for static runs
        adaptive = runner.controller is not None
        static_codec = None if adaptive else runner.comm.codec.name
        static_nbytes = None if adaptive else runner.comm.upload_bytes
        # one freshest-arrival scan feeds both context flavors
        freshest = self._freshest(arrivals)
        codecs, upload_bytes, distortions = self._wire_metadata(freshest)
        if isinstance(strategy, AsyncStrategy):
            ctx = AsyncRoundContext(
                rnd=r, now_s=now, global_params=t_global,
                server_model=server_model, arrivals=arrivals, p=runner.p,
                client_hists=runner.client_hists,
                server_hist=runner.server_hist,
                global_hist=runner.global_hist, runner=runner,
                codec=static_codec, upload_nbytes=static_nbytes,
                codecs=codecs, upload_bytes=upload_bytes,
                distortions=distortions, telemetry=self.obs)
            return strategy.aggregate_async(ctx)
        # Synchronous strategy under the async server: present the freshest
        # landed update per client as this round's cohort (staleness is
        # invisible to it — the documented degradation).
        connected = np.zeros(runner.n_clients, dtype=bool)
        for c in freshest:
            connected[c] = True
        streaming = self.streaming and all(a.packed is not None
                                           for a in freshest.values())
        ctx = RoundContext(
            rnd=r, global_params=t_global, server_model=server_model,
            client_models=({} if streaming else
                           {c: a.model for c, a in freshest.items()}),
            selected=selected, connected=connected, p=runner.p,
            client_hists=runner.client_hists, server_hist=runner.server_hist,
            global_hist=runner.global_hist,
            full_participation=runner.k_selected >= runner.n_clients,
            eps_estimates=runner.eps_estimates, runner=runner,
            codec=static_codec, upload_nbytes=static_nbytes,
            codecs=codecs, upload_bytes=upload_bytes,
            distortions=distortions,
            packed=({c: a.packed for c, a in freshest.items()}
                    if streaming else None),
            telemetry=self.obs)
        return strategy.aggregate(ctx)


SERVER_MODES = ("sync", "async", "buffered")


def make_round_loop(mode: str, runner, strategy: Strategy, tracer=None,
                    log=None) -> RoundLoop:
    if mode == "sync":
        return SyncRoundLoop(runner, strategy, tracer=tracer, log=log)
    if mode == "async":
        return AsyncRoundLoop(runner, strategy, tracer=tracer, log=log)
    if mode == "buffered":
        return AsyncRoundLoop(runner, strategy, tracer=tracer, log=log,
                              buffered=True)
    raise ValueError(f"unknown server_mode {mode!r} "
                     f"(known: {', '.join(SERVER_MODES)})")
