"""Heterogeneous commercial-network simulation (paper Appendix III-A/B).

Implements Table 6 verbatim: 20 clients across wired / Wi-Fi 2.4 / Wi-Fi 5 /
4G / 5G, with the log-distance path-loss + shadowing channel (Eq. 38–39),
FDMA capacity (Eq. 37) and outage-driven transient failures (Eq. 40–41).
Also implements ResourceOpt-1/2 (Eq. 54–56): gradient-descent allocation of
transmit power / bandwidth to equalize failure probabilities.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

import numpy as np

N0_DBM_HZ = -174.0          # noise PSD
PATHLOSS_EXP = 3.0          # λ in Eq. (38)

# Table 6 — standard -> (transmit power dBm, bandwidth Hz, carrier MHz, wall dB)
STANDARDS = {
    "wired":   dict(power_dbm=-20.0, bandwidth=10e6, freq_mhz=0.0, wall_db=0.0),
    "wifi24":  dict(power_dbm=20.0, bandwidth=10e6, freq_mhz=2400.0, wall_db=12.0),
    "wifi5":   dict(power_dbm=23.0, bandwidth=10e6, freq_mhz=5000.0, wall_db=18.0),
    "4g":      dict(power_dbm=23.0, bandwidth=1.8e6, freq_mhz=1800.0, wall_db=10.0),
    "5g":      dict(power_dbm=23.0, bandwidth=2.88e6, freq_mhz=3500.0, wall_db=15.0),
}

# Table 6 client index assignment (1-based in the paper)
def standard_of_client(i: int) -> str:
    idx = i + 1
    if idx <= 4:
        return "wired"
    return {1: "wifi24", 2: "wifi5", 3: "4g", 0: "5g"}[idx % 4]


@dataclasses.dataclass
class ClientChannel:
    standard: str
    power_dbm: float
    bandwidth: float
    freq_mhz: float
    wall_db: float
    distance_m: float
    indoor: bool
    shadow_sigma: float      # 4 dB LOS, 8 dB NLOS

    def capacity(self, rng: np.random.Generator) -> float:
        """One channel realization -> Shannon capacity (bps), Eq. (37)-(39)."""
        if self.standard == "wired":
            return float("inf")
        d_km = max(self.distance_m, 1.0) / 1000.0
        pl0 = 20.0 * math.log10(d_km) + 20.0 * math.log10(max(self.freq_mhz, 1.0)) + 32.44
        shadow = rng.normal(0.0, self.shadow_sigma)
        gain_db = -pl0 - 10.0 * PATHLOSS_EXP * math.log10(max(self.distance_m, 1.0)) \
            + shadow - self.wall_db
        p_rx_dbm = self.power_dbm + gain_db
        noise_dbm = N0_DBM_HZ + 10.0 * math.log10(self.bandwidth)
        snr = 10.0 ** ((p_rx_dbm - noise_dbm) / 10.0)
        return self.bandwidth * math.log2(1.0 + snr)

    def outage_probability(self, rate_bps: float, rng: np.random.Generator,
                           n_mc: int = 400) -> float:
        """Monte-Carlo ε_i (Eq. 40) over the shadowing distribution."""
        if self.standard == "wired":
            return 0.0
        fails = sum(self.capacity(rng) <= rate_bps for _ in range(n_mc))
        return fails / n_mc


def capacity_array(channels: List["ClientChannel"],
                   rng: np.random.Generator) -> np.ndarray:
    """Vectorized ``ClientChannel.capacity`` over a channel list.

    One shadowing draw per *non-wired* channel, in channel order — wired
    links are inf and consume no randomness, exactly like the scalar
    method's early return — so a single array draw replaces N scalar calls.
    """
    n = len(channels)
    caps = np.full(n, np.inf)
    idx = np.array([i for i, c in enumerate(channels)
                    if c.standard != "wired"], dtype=int)
    if len(idx) == 0:
        return caps
    dist = np.array([channels[i].distance_m for i in idx])
    freq = np.array([channels[i].freq_mhz for i in idx])
    sigma = np.array([channels[i].shadow_sigma for i in idx])
    wall = np.array([channels[i].wall_db for i in idx])
    power = np.array([channels[i].power_dbm for i in idx])
    bw = np.array([channels[i].bandwidth for i in idx])
    d_km = np.maximum(dist, 1.0) / 1000.0
    pl0 = (20.0 * np.log10(d_km) + 20.0 * np.log10(np.maximum(freq, 1.0))
           + 32.44)
    shadow = rng.normal(0.0, sigma)
    gain_db = (-pl0 - 10.0 * PATHLOSS_EXP * np.log10(np.maximum(dist, 1.0))
               + shadow - wall)
    p_rx_dbm = power + gain_db
    noise_dbm = N0_DBM_HZ + 10.0 * np.log10(bw)
    snr = 10.0 ** ((p_rx_dbm - noise_dbm) / 10.0)
    caps[idx] = bw * np.log2(1.0 + snr)
    return caps


def build_network(n_clients: int = 20, seed: int = 0) -> List[ClientChannel]:
    """Paper topology: 8 indoor (Wi-Fi, 20×20 m room), 12 outdoor (200 m cell)."""
    rng = np.random.default_rng(seed)
    chans = []
    for i in range(n_clients):
        std = standard_of_client(i)
        s = STANDARDS[std]
        indoor = std in ("wifi24", "wifi5")
        if indoor:
            x, y = rng.uniform(-10, 10, 2)
            d = math.sqrt(x * x + y * y + 3.0 ** 2)
        else:
            r = 200.0 * math.sqrt(rng.uniform(0.02, 1.0))
            d = math.sqrt(r * r + 20.0 ** 2)
        chans.append(ClientChannel(
            standard=std, power_dbm=s["power_dbm"], bandwidth=s["bandwidth"],
            freq_mhz=s["freq_mhz"], wall_db=s["wall_db"] if indoor else 0.0,
            distance_m=d, indoor=indoor, shadow_sigma=8.0 if indoor else 4.0))
    return chans


def uplink_rate(model_bytes: float, delay_s: float) -> float:
    """R_i = L_i / τ_i (Eq. 41), bits per second."""
    return model_bytes * 8.0 / delay_s


# ---------------------------------------------------------------------------
# ResourceOpt-1 / ResourceOpt-2 (Eq. 54–56)
# ---------------------------------------------------------------------------
def resource_opt(channels: List[ClientChannel], rate_bps: float, *,
                 per_standard: bool, eps_threshold: float = 0.9,
                 steps: int = 60, seed: int = 0) -> List[ClientChannel]:
    """Gradient-free coordinate search equalizing outage probabilities by
    reallocating power (within per-standard max) and bandwidth (within the
    per-standard total). per_standard=True is ResourceOpt-2."""
    rng = np.random.default_rng(seed)
    chans = [dataclasses.replace(c) for c in channels]
    groups = {}
    for idx, c in enumerate(chans):
        key = c.standard if per_standard else "all"
        if c.standard != "wired":
            groups.setdefault(key, []).append(idx)

    for key, idxs in groups.items():
        total_bw = sum(chans[i].bandwidth for i in idxs)
        pmax = max(chans[i].power_dbm for i in idxs)
        eps = np.array([chans[i].outage_probability(rate_bps, rng, 200) for i in idxs])
        eligible = eps <= eps_threshold
        for _ in range(steps):
            eps = np.array([chans[i].outage_probability(rate_bps, rng, 100)
                            for i in idxs])
            mean_eps = eps[eligible].mean() if eligible.any() else 0.0
            # move bandwidth from below-average-ε clients to above-average ones
            delta = np.where(eligible, eps - mean_eps, 0.0)
            for j, i in enumerate(idxs):
                bw = chans[i].bandwidth * (1.0 + 0.2 * delta[j])
                chans[i].bandwidth = float(np.clip(bw, 0.1e6, total_bw))
                chans[i].power_dbm = min(chans[i].power_dbm + 0.5 * delta[j], pmax)
            scale = total_bw / sum(chans[i].bandwidth for i in idxs)
            for i in idxs:
                chans[i].bandwidth *= scale
    return chans
