"""Parallel-client FFT round (DESIGN.md §2: clients ↦ mesh data-axis).

One SPMD program runs K selected clients' local updates in parallel (vmap
over the client axis, sharded over 'data') and applies the paper's Eq.-7
β-weighted aggregation as a collective reduce. Connection failures enter as
β_i = 0 (Prop. 1's per-round view): a failed client's update is masked, not
branched on — the program is failure-oblivious, exactly like the paper's
server.

Used by the multi-pod dry-run (`launch.dryrun --shape fft_round_4k`) and by
TPU training deployments; the CPU simulation runtime (`fl.runtime`) keeps
the serial loop for strategy plug-ins that need host-side logic (QP solve,
compensatory data selection).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T


def make_fft_round_step(cfg: ModelConfig, *, lr: float = 1e-3,
                        q_chunk: int = 2048, loss_chunk: int = 512):
    """Returns fft_round(params, tokens (K,b,S), labels (K,b,S), beta (K,))
    -> (new_global_params, weighted_loss). Shard K over 'data'; β from
    FedAuto's QP (Module 2) with failed clients already zeroed — Σβ = 1."""

    def fft_round(params, tokens, labels, beta):
        def local_update(toks, lbls):
            def loss_fn(p):
                return T.forward(p, cfg, {"tokens": toks, "labels": lbls},
                                 q_chunk=q_chunk, loss_chunk=loss_chunk)[0]

            loss, grads = jax.value_and_grad(loss_fn)(params)
            return jax.tree.map(
                lambda p, g: (p.astype(jnp.float32) -
                              lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads), loss

        client_params, losses = jax.vmap(local_update)(tokens, labels)
        # Eq. (7) in delta form (exact for Σβ=1): w̄ = w_g + Σ β (w_i − w_g).
        # Deltas travel bf16, accumulate fp32 (§Perf C1).
        new_global = jax.tree.map(
            lambda cp, g: (g.astype(jnp.float32) + jnp.einsum(
                "k...,k->...",
                (cp.astype(jnp.float32) - g.astype(jnp.float32)[None]
                 ).astype(jnp.bfloat16),
                beta, preferred_element_type=jnp.float32)).astype(cp.dtype),
            client_params, params)
        return new_global, jnp.sum(losses * beta)

    return fft_round
