"""Run-wide telemetry hub: counters, gauges, timers, per-round events.

The paper's convergence claim is *per-realization* — FedAuto converges for
each individual realization of connection failures — so understanding a run
means seeing, round by round, exactly why each client did or did not
contribute and at what weight, staleness, and fidelity.  The ``Telemetry``
hub is the one place that evidence lands: the round loops, the scenario
engine, the comm subsystem, the staleness buffer, the adaptive controller,
and the strategies all emit into it, and pluggable sinks
(``repro.obs.sinks``) consume immutable per-round records.

Drop-cause attribution: every client has exactly **one terminal outcome per
round** (enforced — a second ``client_outcome`` for the same ``(round,
client)`` raises):

  ``not_selected``     the server never contacted the client this round
  ``link_down``        selected, but the scenario reported the link down
                       (``detail`` carries the refined cause: ``ap_outage``,
                       ``handover``, ``churned``, …)
  ``missed_deadline``  selected and up, but the upload landed too late for a
                       synchronous server (or never lands at all)
  ``buffered``         async modes: the upload is parked in the
                       ``StalenessBuffer``; a later ``resolution`` event
                       upgrades the outcome to ``aggregated`` (with the
                       staleness it was applied at) or ``evicted``
  ``evicted``          the upload aged past the staleness horizon (or could
                       never physically land inside it — ``detail``
                       ``unreachable``) and was dropped
  ``aggregated``       the upload reached the strategy's aggregation step

so per-cause counts over a finished run sum to ``n_clients × rounds``
(still-in-flight uploads at run end legitimately remain ``buffered``).

The hub is **observational**: it never feeds back into the run (replay
consumes the scenario trace, never the telemetry log), and the disabled
path is a shared ``NULL_TELEMETRY`` no-op whose methods do nothing and
which is *falsy* — instrumentation sites guard any record-building work
with ``if tel:`` so a telemetry-off run executes no extra code beyond the
no-op call itself.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

# ---------------------------------------------------------------------------
# drop-cause / outcome vocabulary
# ---------------------------------------------------------------------------
NOT_SELECTED = "not_selected"
SKIPPED_STRAGGLER = "skipped_straggler"
LINK_DOWN = "link_down"
MISSED_DEADLINE = "missed_deadline"
BUFFERED = "buffered"
EVICTED = "evicted"
AGGREGATED = "aggregated"

OUTCOMES = (NOT_SELECTED, SKIPPED_STRAGGLER, LINK_DOWN, MISSED_DEADLINE,
            BUFFERED, EVICTED, AGGREGATED)
# a buffered upload can only ever resolve to one of these
RESOLUTIONS = (AGGREGATED, EVICTED)


def beta_row(beta: float, *, role: str = "client",
             client: Optional[int] = None,
             origin_round: Optional[int] = None,
             staleness: Optional[int] = None,
             rung: Optional[str] = None,
             distortion: Optional[float] = None) -> Dict[str, Any]:
    """One participant's actually-applied aggregation weight.

    ``role`` is ``"server"``, ``"comp"`` (compensatory model), or
    ``"client"``; client rows carry the id and, when known, the origin
    round, staleness, codec rung, and distortion the weight was computed
    under — the renderer's β-mass-by-staleness/rung tables group on these.
    """
    row: Dict[str, Any] = {"role": role, "beta": float(beta)}
    if client is not None:
        row["client"] = int(client)
    if origin_round is not None:
        row["origin_round"] = int(origin_round)
    if staleness is not None:
        row["staleness"] = int(staleness)
    if rung is not None:
        row["rung"] = str(rung)
    if distortion is not None:
        row["distortion"] = float(distortion)
    return row


class _NullTimer:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_TIMER = _NullTimer()


class NullTelemetry:
    """Disabled telemetry: every method is a no-op and the object is falsy,
    so ``if tel:``-guarded record building never runs.  One shared instance
    (``NULL_TELEMETRY``) is the default everywhere."""

    enabled = False

    def __bool__(self) -> bool:
        return False

    def start_run(self, meta: Optional[Dict] = None) -> None:
        pass

    def begin_round(self, rnd: int) -> None:
        pass

    def client_outcome(self, rnd: int, client: int, outcome: str,
                       **fields) -> None:
        pass

    def resolve(self, origin_round: int, client: int, outcome: str,
                staleness: Optional[int] = None,
                applied_round: Optional[int] = None) -> None:
        pass

    def betas(self, rnd: int, rows) -> None:
        pass

    def gauge(self, rnd: int, name: str, value: float) -> None:
        pass

    def distribution(self, rnd: int, name: str, values) -> None:
        pass

    def counter(self, name: str, inc: float = 1) -> None:
        pass

    def timer(self, name: str):
        return _NULL_TIMER

    def end_round(self, rnd: int) -> None:
        pass

    def end_run(self) -> None:
        pass


NULL_TELEMETRY = NullTelemetry()


class _Timer:
    """Exclusive (self-time) phase timer.

    Timers nest: entering a timer while another is active *pauses* the
    outer one, so each phase accumulates only the time no inner phase
    claimed.  Disjoint-by-construction means per-round phase seconds sum
    to at most the round's wall time, never more — ``phase.local_update``
    triggered from inside a strategy's aggregation step is attributed to
    the local update, not double-counted under ``phase.aggregate``.
    """

    __slots__ = ("_tel", "_name")

    def __init__(self, tel: "Telemetry", name: str):
        self._tel = tel
        self._name = name

    def __enter__(self):
        now = time.perf_counter()
        stack = self._tel._timer_stack
        if stack:                          # pause the enclosing phase
            outer = stack[-1]
            timers = self._tel.timers_s
            timers[outer[0]] = timers.get(outer[0], 0.0) + (now - outer[1])
        stack.append([self._name, now])
        trace = self._tel.trace
        if trace is not None:
            # the *same* timestamp feeds the timer accounting and the trace
            # span, so a self-time replay of the trace reproduces the
            # exclusive timers bit-for-bit
            trace.begin(self._name, now)
        return self

    def __exit__(self, *exc):
        now = time.perf_counter()
        stack = self._tel._timer_stack
        name, t0 = stack.pop()
        timers = self._tel.timers_s
        timers[name] = timers.get(name, 0.0) + (now - t0)
        if stack:                          # resume the enclosing phase
            stack[-1][1] = now
        trace = self._tel.trace
        if trace is not None:
            trace.end(name, now)
        return False


class Telemetry:
    """Enabled telemetry hub.

    Protocol (driven by ``RoundLoop.run``): ``start_run(meta)`` once, then
    per round ``begin_round(r)`` → any number of ``client_outcome`` /
    ``resolve`` / ``betas`` / ``gauge`` / ``counter`` / ``timer`` calls →
    ``end_round(r)``, then ``end_run()``.  ``client_outcome`` enforces the
    exactly-one-terminal-outcome-per-(round, client) invariant;
    ``resolve`` events are forwarded to sinks immediately (they refer to a
    *past* round's record), everything else is staged and flushed as one
    immutable round record at ``end_round``.
    """

    enabled = True

    def __init__(self, sinks=(), *, sketch=None, health=None, trace=None):
        self.sinks = list(sinks)
        self.sketch = sketch           # SketchState → bounded-memory mode
        self.health = health           # HealthMonitors → online detectors
        self.trace = trace             # ChromeTraceRecorder → span export
        self.meta: Dict[str, Any] = {}
        self.counters: Dict[str, float] = {}
        self.timers_s: Dict[str, float] = {}
        self._timer_stack: List[list] = []   # active (name, t0) phase frames
        self._round: Optional[Dict[str, Any]] = None

    # ------------------------------------------------------------ lifecycle
    def start_run(self, meta: Optional[Dict] = None) -> None:
        self.meta = dict(meta or {})
        self.meta.setdefault(
            "telemetry_mode", "sketch" if self.sketch is not None else "full")
        for s in self.sinks:
            s.on_run_start(self.meta)

    def begin_round(self, rnd: int) -> None:
        if self._round is not None:
            raise ValueError(
                f"begin_round({rnd}) before end_round({self._round['round']})")
        if self.sketch is not None:
            # bounded-memory mode: per-client events fold into the sketch
            # state instead of staging O(n_clients) rows
            self._round = {"round": int(rnd), "gauges": {}}
            self.sketch.begin_round(int(rnd))
        else:
            self._round = {"round": int(rnd), "clients": {}, "gauges": {},
                           "betas": []}
        if self.trace is not None:
            self.trace.begin("round", time.perf_counter(),
                             args={"round": int(rnd)})

    def _staged(self, rnd: int) -> Dict[str, Any]:
        if self._round is None or self._round["round"] != int(rnd):
            cur = None if self._round is None else self._round["round"]
            raise ValueError(f"telemetry event for round {rnd} but staged "
                             f"round is {cur}")
        return self._round

    # --------------------------------------------------------------- events
    def client_outcome(self, rnd: int, client: int, outcome: str,
                       **fields) -> None:
        """Record client ``client``'s terminal outcome for round ``rnd``.

        ``fields``: ``detail`` (refined cause), ``rung`` (codec name),
        ``upload_bytes``, ``download_bytes``, ``distortion``, ``staleness``
        — absent fields are simply not recorded."""
        if outcome not in OUTCOMES:
            raise ValueError(f"unknown outcome {outcome!r} "
                             f"(known: {OUTCOMES})")
        staged = self._staged(rnd)
        client = int(client)
        if self.sketch is not None:
            self.sketch.client_outcome(client, outcome, fields)
            return
        if client in staged["clients"]:
            raise ValueError(
                f"round {rnd}: client {client} already has outcome "
                f"{staged['clients'][client]['outcome']!r}; every client has "
                f"exactly one terminal outcome per round")
        rec: Dict[str, Any] = {"client": client, "outcome": outcome}
        for k, v in fields.items():
            if v is not None:
                rec[k] = v
        staged["clients"][client] = rec

    def resolve(self, origin_round: int, client: int, outcome: str,
                staleness: Optional[int] = None,
                applied_round: Optional[int] = None) -> None:
        """A previously-``buffered`` upload reached its terminal state."""
        if outcome not in RESOLUTIONS:
            raise ValueError(f"resolution outcome must be one of "
                             f"{RESOLUTIONS}, got {outcome!r}")
        rec = {"origin_round": int(origin_round), "client": int(client),
               "outcome": outcome}
        if staleness is not None:
            rec["staleness"] = int(staleness)
        if applied_round is not None:
            rec["applied_round"] = int(applied_round)
        if self.sketch is not None:
            self.sketch.resolve(rec)
        for s in self.sinks:
            s.on_resolution(rec)

    def betas(self, rnd: int, rows: List[Dict[str, Any]]) -> None:
        """The aggregation weights a strategy actually applied this round
        (``beta_row`` dicts).  Extends — a strategy that aggregates more
        than once per round (or a deferred flush) appends further rows."""
        staged = self._staged(rnd)
        if self.sketch is not None:
            self.sketch.betas(rows)
        else:
            staged["betas"].extend(rows)

    def gauge(self, rnd: int, name: str, value: float) -> None:
        self._staged(rnd)["gauges"][str(name)] = float(value)

    def distribution(self, rnd: int, name: str, values) -> None:
        """Fold a per-client value stream (e.g. the adaptive controller's
        capacity estimates) into a named quantile sketch.  Only sketch mode
        retains these — full mode already keeps richer per-client rows."""
        self._staged(rnd)
        if self.sketch is not None:
            self.sketch.distribution(name, values)

    def counter(self, name: str, inc: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0.0) + inc

    def timer(self, name: str) -> _Timer:
        """Context manager accumulating *exclusive* wall seconds into
        ``timers_s[name]`` (nested timers pause the enclosing one).  Names
        prefixed ``phase.`` are the per-round profiler phases: the round
        loops emit each round's delta as a same-named gauge, so phase
        seconds land in the ``RunReport`` / NDJSON log per round and
        ``RunReport.phase_table()`` can break a run down by phase."""
        return _Timer(self, name)

    # ------------------------------------------------------------- flushing
    def end_round(self, rnd: int) -> None:
        staged = self._staged(rnd)
        self._round = None
        if self.sketch is not None:
            staged["sketch"] = self.sketch.end_round(staged["gauges"])
        elif staged.get("betas"):
            ess = _beta_ess_from_rows(staged["betas"])
            if ess is not None:
                staged["gauges"]["beta_ess"] = ess
        if self.trace is not None:
            self.trace.end("round", time.perf_counter())
        for s in self.sinks:
            s.on_round(staged)
        if self.health is not None:
            for rec in self.health.observe_round(
                    _round_digest(staged, self.meta)):
                for s in self.sinks:
                    s.on_health(rec)

    def end_run(self) -> None:
        if self._round is not None:
            # a crashed round still flushes what it staged
            self.end_round(self._round["round"])
        summary = {"counters": dict(self.counters),
                   "timers_s": dict(self.timers_s)}
        if self.sketch is not None:
            summary["sketch"] = self.sketch.summary()
        if self.health is not None:
            summary["health"] = self.health.verdict()
        for s in self.sinks:
            s.on_run_end(summary)
        if self.trace is not None:
            self.trace.save(meta=self.meta)


def _beta_ess_from_rows(rows: List[Dict[str, Any]]) -> Optional[float]:
    """β effective sample size over the round's *client* rows:
    (Σβ)²/Σβ² — n when the applied client mass is uniform, → 1 as a single
    client dominates.  The ``beta_ess`` gauge is the health monitors' view
    of aggregation-weight concentration."""
    n = 0
    total = sumsq = 0.0
    for row in rows:
        if row.get("role", "client") != "client":
            continue
        b = float(row["beta"])
        n += 1
        total += b
        sumsq += b * b
    if n == 0 or sumsq <= 0.0:
        return None
    return (total * total) / sumsq


def _round_digest(staged: Dict[str, Any], meta: Dict[str, Any]
                  ) -> Dict[str, Any]:
    """Constant-size view of a flushed round record for the health
    monitors — identical shape whether the round was staged in full or
    sketch mode, so the detectors are mode-agnostic."""
    gauges = staged["gauges"]
    if "sketch" in staged:
        sk = staged["sketch"]
        counts = dict(sk["counts"])
        n_dist = sk["distortion_n"]
        distortion_mean = (sk["distortion_sum"] / n_dist) if n_dist else None
        beta_n = sk["beta"]["n"]
    else:
        counts = {o: 0 for o in OUTCOMES}
        dist_sum = 0.0
        n_dist = 0
        for rec in staged["clients"].values():
            counts[rec["outcome"]] += 1
            d = rec.get("distortion")
            if d is not None:
                dist_sum += float(d)
                n_dist += 1
        distortion_mean = (dist_sum / n_dist) if n_dist else None
        beta_n = sum(1 for row in staged.get("betas", ())
                     if row.get("role", "client") == "client")
    return {"round": staged["round"],
            "n_clients": int(meta.get("n_clients", 0) or 0),
            "counts": counts,
            "participants": gauges.get("participants"),
            "eval_acc": gauges.get("eval_acc"),
            "beta_n": beta_n,
            "beta_ess": gauges.get("beta_ess"),
            "distortion_mean": distortion_mean,
            "gauges": gauges}
