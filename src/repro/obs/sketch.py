"""Bounded-memory telemetry: streaming sketches and the sketch-mode report.

The full-mode flight recorder keeps one row per client per round — perfect
at tens of clients, and exactly the thing that becomes the memory and disk
bottleneck at the population scales the ROADMAP targets (100k–1M clients:
FeedSign-style O(1)-byte uplinks exist precisely because nothing per-client
survives contact with a million phones).  ``FFTConfig.telemetry="sketch"``
keeps the *accounting* exact and collapses the *distributions*:

* outcome/rung counters, β-mass-by-group sums, and additive byte/distortion
  totals stay **exact** — byte totals through a Shewchuk exact accumulator
  (``ExactSum``), so ``total_upload_bytes()`` is bit-equal to full mode's
  ``math.fsum`` over every individual upload and ``reconcile`` still proves
  closure against ``CommState``;
* per-client distributions (upload bytes, staleness, distortion, β weights,
  controller capacity estimates) collapse into Greenwald–Khanna streaming
  quantile sketches (``GKQuantiles``, rank error ≤ ε·n, default ε=0.01, no
  new deps) plus one seeded K-row reservoir sample (``Reservoir``) for
  spot-checking concrete rows;
* resident state is O(rounds + K + 1/ε·log εn): per round only a
  constant-size digest is retained, never the n_clients rows.

``SketchState`` is the hub-side fold (``repro.obs.Telemetry`` stages into
it instead of a per-client dict); ``SketchReport`` is the sink mirroring
``RunReport``'s aggregate API, so ``reconcile`` and ``render_markdown``
work identically in either mode.
"""
from __future__ import annotations

import math
import random
from bisect import bisect_right
from typing import Any, Dict, List, Optional, Sequence

from repro.obs.telemetry import BUFFERED, OUTCOMES, RESOLUTIONS

# documented rank-error bound of the quantile sketches: a query for
# quantile q returns a value whose rank is within EPS·n of q·n
SKETCH_EPS = 0.01


class ExactSum:
    """Incremental Shewchuk summation: ``add`` keeps exact non-overlapping
    partials, ``value()`` rounds once — bit-equal to ``math.fsum`` over the
    same multiset of addends, independent of order or batching.  This is
    what lets a sketch run's byte totals match full mode bit-for-bit."""

    __slots__ = ("partials",)

    def __init__(self, partials: Optional[Sequence[float]] = None):
        self.partials: List[float] = list(partials or [])

    def add(self, x: float) -> None:
        partials = self.partials
        x = float(x)
        i = 0
        for y in partials:
            if abs(x) < abs(y):
                x, y = y, x
            hi = x + y
            lo = y - (hi - x)
            if lo:
                partials[i] = lo
                i += 1
            x = hi
        partials[i:] = [x]

    def value(self) -> float:
        return math.fsum(self.partials)

    def to_json(self) -> List[float]:
        return list(self.partials)


class GKQuantiles:
    """Greenwald–Khanna ε-approximate streaming quantiles (GK01).

    Maintains tuples ``(v, g, Δ)`` with the invariant
    ``g_i + Δ_i ≤ ⌊2εn⌋``; a ``query(q)`` then returns a value whose rank in
    the stream is within ``ε·n`` of ``q·n``.  Size is O((1/ε)·log(εn)) —
    independent of the number of clients for fixed ε and round count.
    """

    __slots__ = ("eps", "n", "entries", "_values", "_since_compress")

    def __init__(self, eps: float = SKETCH_EPS):
        self.eps = float(eps)
        self.n = 0
        self.entries: List[List[float]] = []    # [v, g, delta], sorted by v
        self._values: List[float] = []          # parallel keys for bisect
        self._since_compress = 0

    def add(self, v: float) -> None:
        v = float(v)
        pos = bisect_right(self._values, v)
        if pos == 0 or pos == len(self.entries):
            delta = 0                           # new extremum is exact
        else:
            delta = max(int(2.0 * self.eps * self.n) - 1, 0)
        self.entries.insert(pos, [v, 1, delta])
        self._values.insert(pos, v)
        self.n += 1
        self._since_compress += 1
        if self._since_compress >= max(int(1.0 / (2.0 * self.eps)), 1):
            self._compress()

    def _compress(self) -> None:
        self._since_compress = 0
        threshold = int(2.0 * self.eps * self.n)
        entries = self.entries
        i = len(entries) - 2
        while i >= 1:                           # keep the extrema exact
            v, g, d = entries[i]
            nv, ng, nd = entries[i + 1]
            if g + ng + nd <= threshold:
                entries[i + 1][1] = g + ng
                del entries[i]
                del self._values[i]
            i -= 1

    def query(self, q: float) -> Optional[float]:
        """Value at quantile ``q`` (rank error ≤ ``eps * n``)."""
        if self.n == 0:
            return None
        q = min(max(float(q), 0.0), 1.0)
        want = max(1, math.ceil(q * self.n))
        budget = want + self.eps * self.n
        rmin = 0
        prev = self.entries[0][0]
        for v, g, d in self.entries:
            rmin += g
            if rmin + d > budget:
                return prev
            prev = v
        return self.entries[-1][0]

    def to_json(self) -> Dict[str, Any]:
        return {"eps": self.eps, "n": self.n,
                "entries": [list(e) for e in self.entries]}

    @classmethod
    def from_json(cls, doc: Dict[str, Any]) -> "GKQuantiles":
        gk = cls(eps=doc["eps"])
        gk.n = int(doc["n"])
        gk.entries = [[float(v), int(g), int(d)]
                      for v, g, d in doc["entries"]]
        gk._values = [e[0] for e in gk.entries]
        return gk


class Reservoir:
    """Seeded K-row uniform reservoir sample (Vitter's algorithm R) of the
    per-client outcome rows a sketch run no longer retains in full."""

    def __init__(self, k: int, seed: int = 0):
        self.k = int(k)
        self.n = 0
        self.rows: List[Dict[str, Any]] = []
        self._rng = random.Random(0x5EED ^ int(seed))

    def offer(self, row: Dict[str, Any]) -> None:
        self.n += 1
        if len(self.rows) < self.k:
            self.rows.append(row)
        else:
            j = self._rng.randrange(self.n)
            if j < self.k:
                self.rows[j] = row

    def to_json(self) -> Dict[str, Any]:
        return {"k": self.k, "n": self.n, "rows": list(self.rows)}


def _beta_stats(n: int, total: float, sumsq: float) -> Optional[float]:
    """Effective sample size of the applied client β mass: (Σβ)²/Σβ².
    n client rows all at equal weight → ESS = n; one dominating row → 1."""
    if n == 0 or sumsq <= 0.0:
        return None
    return (total * total) / sumsq


class SketchState:
    """Hub-side per-run fold for sketch-mode telemetry.

    ``Telemetry`` routes ``client_outcome``/``betas``/``resolve`` calls
    here instead of staging per-client rows; ``end_round`` returns the
    constant-size round digest that gets flushed to sinks, and
    ``summary()`` the run-long exact accumulators + sketches flushed at
    ``end_run``.
    """

    def __init__(self, n_clients: int, *, k: int = 64,
                 eps: float = SKETCH_EPS, seed: int = 0):
        self.n_clients = int(n_clients)
        self.k = int(k)
        self.eps = float(eps)
        self.exact_upload = ExactSum()
        self.exact_distortion = ExactSum()
        self.distortion_n = 0
        self.sketches: Dict[str, GKQuantiles] = {
            name: GKQuantiles(eps)
            for name in ("upload_bytes", "staleness", "distortion", "beta")}
        self.reservoir = Reservoir(k, seed=seed)
        self._round: Optional[Dict[str, Any]] = None

    # ------------------------------------------------------------ staging
    def begin_round(self, rnd: int) -> None:
        self._round = {
            "rnd": int(rnd), "seen": set(),
            "counts": {o: 0 for o in OUTCOMES}, "rungs": {},
            "upload_bytes": 0.0, "distortion_sum": 0.0, "distortion_n": 0,
            "beta_n": 0, "beta_sum": 0.0, "beta_sumsq": 0.0,
            "mass_staleness": {}, "mass_rung": {}, "mass_role": {}}

    def client_outcome(self, client: int, outcome: str,
                       fields: Dict[str, Any]) -> None:
        cur = self._round
        if client in cur["seen"]:
            raise ValueError(
                f"round {cur['rnd']}: client {client} already has an "
                f"outcome; every client has exactly one terminal outcome "
                f"per round")
        cur["seen"].add(client)
        cur["counts"][outcome] += 1
        ub = fields.get("upload_bytes")
        if ub is not None:
            ub = float(ub)
            cur["upload_bytes"] += ub
            self.exact_upload.add(ub)
            self.sketches["upload_bytes"].add(ub)
        dist = fields.get("distortion")
        if dist is not None:
            dist = float(dist)
            cur["distortion_sum"] += dist
            cur["distortion_n"] += 1
            self.exact_distortion.add(dist)
            self.distortion_n += 1
            self.sketches["distortion"].add(dist)
        st = fields.get("staleness")
        if st is not None:
            self.sketches["staleness"].add(float(st))
        rung = fields.get("rung")
        if rung is not None:
            cur["rungs"][rung] = cur["rungs"].get(rung, 0) + 1
        self.reservoir.offer(
            {"round": cur["rnd"], "client": int(client), "outcome": outcome,
             **{k: v for k, v in fields.items() if v is not None}})

    def betas(self, rows: Sequence[Dict[str, Any]]) -> None:
        cur = self._round
        for row in rows:
            beta = float(row["beta"])
            role = row.get("role", "client")
            if role != "client":
                g_st = g_rung = role
            else:
                cur["beta_n"] += 1
                cur["beta_sum"] += beta
                cur["beta_sumsq"] += beta * beta
                self.sketches["beta"].add(beta)
                g_st = row.get("staleness", 0)
                g_rung = row.get("rung", "?")
            for key, g in (("mass_staleness", g_st), ("mass_rung", g_rung),
                           ("mass_role", role)):
                cur[key][g] = cur[key].get(g, 0.0) + beta

    def resolve(self, rec: Dict[str, Any]) -> None:
        # upgraded staleness only becomes known at resolution time
        if rec.get("staleness") is not None:
            self.sketches["staleness"].add(float(rec["staleness"]))

    def distribution(self, name: str, values) -> None:
        """Fold an ad-hoc per-client value stream (e.g. the adaptive
        controller's capacity estimates) into a named quantile sketch."""
        gk = self.sketches.get(name)
        if gk is None:
            gk = self.sketches[name] = GKQuantiles(self.eps)
        for v in values:
            gk.add(float(v))

    def end_round(self, gauges: Dict[str, float]) -> Dict[str, Any]:
        """Finish the staged round: emit the β effective-sample-size gauge
        and return the constant-size digest that replaces per-client rows
        in the flushed round record."""
        cur = self._round
        self._round = None
        ess = _beta_stats(cur["beta_n"], cur["beta_sum"], cur["beta_sumsq"])
        if ess is not None:
            gauges["beta_ess"] = float(ess)
        return {
            "counts": cur["counts"], "rungs": cur["rungs"],
            "upload_bytes": cur["upload_bytes"],
            "distortion_sum": cur["distortion_sum"],
            "distortion_n": cur["distortion_n"],
            "beta": {"n": cur["beta_n"], "sum": cur["beta_sum"],
                     "sumsq": cur["beta_sumsq"],
                     "mass_staleness": cur["mass_staleness"],
                     "mass_rung": cur["mass_rung"],
                     "mass_role": cur["mass_role"]}}

    def summary(self) -> Dict[str, Any]:
        """Run-long exact accumulators + serialized sketches (the
        ``run_end`` record's ``sketch`` section)."""
        return {
            "k": self.k, "eps": self.eps,
            "exact": {"upload_bytes": self.exact_upload.to_json(),
                      "distortion": self.exact_distortion.to_json()},
            "distortion_n": self.distortion_n,
            "sketches": {name: gk.to_json()
                         for name, gk in self.sketches.items()},
            "reservoir": self.reservoir.to_json()}


class SketchReport:
    """Sketch-mode flight record: ``RunReport``'s aggregate API from
    O(rounds + K) state.

    Consumes the hub's constant-size round digests (``rec["sketch"]``) and
    the run-end exact accumulators; every view the renderer, ``reconcile``,
    and the benchmarks read — drop-cause counts, byte totals, β mass by
    group, rung histogram, phase/gauge views — is exact; quantiles come
    from the GK sketches within the documented ε rank error.
    """

    mode = "sketch"

    def __init__(self):
        self.meta: Dict[str, Any] = {}
        self.rounds: List[Dict] = []
        self.resolutions: List[Dict] = []
        self.health: List[Dict] = []
        self.summary: Dict[str, Any] = {"counters": {}, "timers_s": {}}

    # ---------------------------------------------------------------- sink
    def on_run_start(self, meta: Dict) -> None:
        self.meta = dict(meta)

    def on_round(self, rec: Dict) -> None:
        if "sketch" not in rec:
            raise ValueError(
                "SketchReport received a full-mode round record (per-client "
                "rows); use RunReport for telemetry='full' runs")
        self.rounds.append(rec)

    def on_resolution(self, rec: Dict) -> None:
        self.resolutions.append(rec)

    def on_health(self, rec: Dict) -> None:
        self.health.append(rec)

    def on_run_end(self, summary: Dict) -> None:
        self.summary = summary

    # ------------------------------------------------------------- loading
    @classmethod
    def from_ndjson(cls, path: str) -> "SketchReport":
        """Rebuild a sketch report from an ``NdjsonSink`` event log."""
        from repro.obs.sinks import read_telemetry_records
        rep = cls()
        for _line_no, rec in read_telemetry_records(path):
            kind = rec.get("record")
            if kind == "run_start":
                rep.meta = rec.get("meta", {})
            elif kind == "round":
                if "clients" in rec:
                    raise ValueError(
                        f"{path}: full-mode log (per-client rows); load it "
                        "with RunReport.from_ndjson or repro.obs.load_report")
                rep.rounds.append({k: v for k, v in rec.items()
                                   if k != "record"})
            elif kind == "resolution":
                rep.resolutions.append(
                    {k: v for k, v in rec.items() if k != "record"})
            elif kind == "health":
                rep.health.append(
                    {k: v for k, v in rec.items() if k != "record"})
            elif kind == "run_end":
                rep.summary = {k: v for k, v in rec.items()
                               if k != "record"}
        return rep

    # ------------------------------------------------------- derived views
    @property
    def n_rounds(self) -> int:
        return len(self.rounds)

    @property
    def n_clients(self) -> int:
        return int(self.meta.get("n_clients", 0))

    def drop_cause_counts(self) -> Dict[str, int]:
        """Exact per-cause counts with ``buffered`` records upgraded by
        their resolution events — identical semantics to full mode's
        ``final_outcomes``-derived counts, from O(1)-per-round state."""
        counts = {o: 0 for o in OUTCOMES}
        for r in self.rounds:
            for o, c in r["sketch"]["counts"].items():
                counts[o] = counts.get(o, 0) + int(c)
        for res in self.resolutions:
            out = res["outcome"]
            if out not in RESOLUTIONS:
                raise ValueError(f"resolution outcome {out!r} not in "
                                 f"{RESOLUTIONS}")
            if counts[BUFFERED] <= 0:
                raise ValueError(
                    "resolution event without a matching buffered outcome")
            counts[BUFFERED] -= 1
            counts[out] += 1
        return counts

    def participants_per_round(self) -> List[int]:
        return [int(r["gauges"].get("participants", 0)) for r in self.rounds]

    def mean_participants(self) -> float:
        parts = self.participants_per_round()
        return float(sum(parts) / len(parts)) if parts else 0.0

    def _exact_partials(self, name: str) -> Optional[List[float]]:
        sk = self.summary.get("sketch")
        if sk and "exact" in sk and name in sk["exact"]:
            return sk["exact"][name]
        return None

    def total_upload_bytes(self) -> float:
        """Bit-equal to full mode's ``math.fsum`` over every upload (the
        exact partials survive the NDJSON round-trip); a crashed run with
        no ``run_end`` record degrades to the per-round partial sums."""
        partials = self._exact_partials("upload_bytes")
        if partials is not None:
            return float(math.fsum(partials))
        return float(math.fsum(r["sketch"]["upload_bytes"]
                               for r in self.rounds))

    def total_download_bytes(self) -> float:
        return float(math.fsum(r["gauges"].get("downlink_bytes", 0.0)
                               for r in self.rounds))

    def accuracy_curve(self) -> List[tuple]:
        return [(r["round"], r["gauges"]["eval_acc"]) for r in self.rounds
                if "eval_acc" in r["gauges"]]

    def final_accuracy(self) -> Optional[float]:
        curve = self.accuracy_curve()
        return curve[-1][1] if curve else None

    def mean_distortion(self) -> float:
        partials = self._exact_partials("distortion")
        if partials is not None:
            n = int(self.summary["sketch"].get("distortion_n", 0))
            return float(math.fsum(partials) / n) if n else 0.0
        tot = math.fsum(r["sketch"]["distortion_sum"] for r in self.rounds)
        n = sum(r["sketch"]["distortion_n"] for r in self.rounds)
        return float(tot / n) if n else 0.0

    def beta_mass_by(self, key: str) -> Dict[Any, float]:
        """Total applied β mass grouped by ``key`` — exact (additive group
        sums), normalized to fractions like full mode."""
        field = {"staleness": "mass_staleness", "rung": "mass_rung",
                 "role": "mass_role"}.get(key)
        if field is None:
            return {}
        mass: Dict[Any, float] = {}
        for r in self.rounds:
            for g, m in r["sketch"]["beta"][field].items():
                # JSON round-trips dict keys as strings; staleness groups
                # are ints in-memory — normalize back where unambiguous
                if field == "mass_staleness" and isinstance(g, str):
                    try:
                        g = int(g)
                    except ValueError:
                        pass
                mass[g] = mass.get(g, 0.0) + float(m)
        tot = sum(mass.values())
        if tot > 0:
            mass = {k: v / tot for k, v in mass.items()}
        return mass

    def rung_histogram(self) -> Dict[str, int]:
        hist: Dict[str, int] = {}
        for r in self.rounds:
            for rung, c in r["sketch"]["rungs"].items():
                hist[rung] = hist.get(rung, 0) + int(c)
        return hist

    def quantiles(self, qs: Sequence[float] = (0.5, 0.9, 0.99)
                  ) -> Dict[str, Dict[float, float]]:
        """Per-metric streaming quantiles (rank error ≤ ε·n); empty until
        the run-end sketches have been flushed."""
        sk = self.summary.get("sketch")
        if not sk or "sketches" not in sk:
            return {}
        out: Dict[str, Dict[float, float]] = {}
        for name, doc in sk["sketches"].items():
            gk = GKQuantiles.from_json(doc)
            if gk.n == 0:
                continue
            out[name] = {float(q): float(gk.query(q)) for q in qs}
        return out

    def sample_rows(self) -> List[Dict[str, Any]]:
        """The seeded K-row reservoir sample of per-client outcome rows."""
        sk = self.summary.get("sketch")
        if not sk or "reservoir" not in sk:
            return []
        return list(sk["reservoir"].get("rows", []))

    # ------------------------------------------------ shared gauge views
    def total_wall_s(self) -> float:
        return float(math.fsum(r["gauges"].get("round_wall_s", 0.0)
                               for r in self.rounds))

    def phase_seconds(self, rnd: Optional[int] = None) -> Dict[str, float]:
        rounds = (self.rounds if rnd is None
                  else [r for r in self.rounds if r["round"] == rnd])
        out: Dict[str, float] = {}
        for r in rounds:
            for k, v in r["gauges"].items():
                if k.startswith("phase."):
                    name = k[len("phase."):]
                    out[name] = out.get(name, 0.0) + float(v)
        return out

    def phase_table(self) -> List[Dict[str, float]]:
        from repro.obs.sinks import build_phase_table
        return build_phase_table(self.phase_seconds(), self.total_wall_s(),
                                 self.n_rounds)

    def health_verdict(self) -> Optional[Dict[str, Any]]:
        return self.summary.get("health")

    def label(self) -> str:
        m = self.meta
        parts = [str(m.get(k)) for k in ("scenario", "server_mode", "codec",
                                         "strategy") if m.get(k)]
        return "/".join(parts) if parts else "run"

    def resident_estimate(self) -> Dict[str, int]:
        """Rough structural size of the retained state — what the scale
        test asserts is O(rounds + K), not O(n_clients × rounds)."""
        import json as _json
        from repro.obs.sinks import _jsonable
        return {
            "rounds": len(self.rounds),
            "round_record_bytes": max(
                (len(_json.dumps(_jsonable(r))) for r in self.rounds),
                default=0),
            "summary_bytes": len(_json.dumps(_jsonable(self.summary))),
            "reservoir_rows": len(self.sample_rows())}
