"""Live run dashboard: in-place console view of a running (or finished) run.

Two entry points over the same renderer:

* ``DashboardSink`` — attach with ``FFTConfig.telemetry_dashboard=True``;
  re-renders an in-place ANSI panel after every round record (falls back to
  plain append when stdout is not a TTY, so logs stay readable);
* ``python -m benchmarks.report watch <log.ndjson>`` — tail an NDJSON
  flight record another process is writing (the per-record flush plus the
  truncated-final-line tolerance make the file readable mid-run) and
  redraw until the ``run_end`` record lands.  ``--once`` renders a single
  frame and exits (CI smoke).

The renderer reads only the report's aggregate views, so full-mode
``RunReport`` and bounded-memory ``SketchReport`` both drive it.
"""
from __future__ import annotations

import sys
import time as _time
from typing import Dict, List

from repro.obs.sinks import Sink

_BLOCKS = "▁▂▃▄▅▆▇█"


def sparkline(values: List[float], width: int = 24) -> str:
    """Unicode mini-chart of the last ``width`` values."""
    vals = [float(v) for v in values][-width:]
    if not vals:
        return ""
    lo, hi = min(vals), max(vals)
    span = hi - lo
    if span <= 0:
        return _BLOCKS[0] * len(vals)
    return "".join(_BLOCKS[min(int((v - lo) / span * (len(_BLOCKS) - 1)),
                               len(_BLOCKS) - 1)] for v in vals)


def _fmt_bytes(n: float) -> str:
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("kB", 1e3)):
        if abs(n) >= div:
            return f"{n / div:.2f} {unit}"
    return f"{n:.0f} B"


def render_dashboard(report, width: int = 72) -> str:
    """One text frame of the dashboard panel for ``report`` as it stands."""
    lines: List[str] = []
    n_rounds = report.n_rounds
    meta = report.meta
    total = meta.get("rounds", "?")
    head = f"{report.label()}  ·  round {n_rounds}/{total}"
    mode = meta.get("telemetry_mode")
    if mode:
        head += f"  ·  telemetry={mode}"
    lines.append("┌ " + head[:width - 2])

    parts = report.participants_per_round()
    if parts:
        lines.append(f"│ participants  {sparkline(parts):<24s} "
                     f"last={parts[-1]}  mean={report.mean_participants():.1f}")

    counts = report.drop_cause_counts()
    total_outcomes = sum(counts.values())
    if total_outcomes:
        mix = "  ".join(
            f"{name}={c} ({c / total_outcomes:.0%})"
            for name, c in sorted(counts.items(), key=lambda kv: -kv[1])
            if c)
        lines.append(f"│ outcomes      {mix}"[:width])

    phases = report.phase_seconds()
    wall = report.total_wall_s()
    if phases and wall > 0:
        top = sorted(phases.items(), key=lambda kv: -kv[1])[:4]
        split = "  ".join(f"{name}={s / wall:.0%}" for name, s in top)
        lines.append(f"│ phase split   {split}  (wall {wall:.1f}s)")

    curve = [a for _r, a in report.accuracy_curve()]
    acc = (f"acc={curve[-1]:.4f} {sparkline(curve, 16)}" if curve
           else "acc=–")
    lines.append(f"│ progress      {acc}  up={_fmt_bytes(report.total_upload_bytes())}"
                 f"  down={_fmt_bytes(report.total_download_bytes())}")

    health = getattr(report, "health", None) or []
    verdict = (report.health_verdict()
               if hasattr(report, "health_verdict") else None)
    if verdict is not None:
        if verdict.get("healthy"):
            lines.append("│ health        OK (run complete, 0 alarms)")
        else:
            by = ",".join(f"{k}×{v}" for k, v in
                          sorted(verdict.get("by_monitor", {}).items()))
            lines.append(f"│ health        {verdict.get('n_alarms')} ALARMS "
                         f"[{by}] first r={verdict.get('first_alarm_round')}")
    elif health:
        last = health[-1]
        lines.append(f"│ health        {len(health)} alarm(s) — last: "
                     f"{last['monitor']}@r{last['round']}")
    else:
        lines.append("│ health        OK")
    lines.append("└")
    return "\n".join(lines)


class DashboardSink(Sink):
    """In-place console dashboard; reads the run's report sink (which is
    registered before it, so each ``on_round`` sees the round included)."""

    def __init__(self, report, stream=None):
        self.report = report
        self.stream = stream or sys.stdout
        self._last_height = 0

    def _paint(self) -> None:
        frame = render_dashboard(self.report)
        isatty = getattr(self.stream, "isatty", lambda: False)()
        if isatty and self._last_height:
            # move up over the previous frame and overwrite in place
            self.stream.write(f"\x1b[{self._last_height}F\x1b[J")
        self.stream.write(frame + "\n")
        self.stream.flush()
        self._last_height = frame.count("\n") + 1

    def on_round(self, rec: Dict) -> None:
        self._paint()

    def on_health(self, rec: Dict) -> None:
        pass                                   # next round's frame shows it

    def on_run_end(self, summary: Dict) -> None:
        # the report sink already consumed the summary (it precedes this
        # sink), so the final frame can show the verdict
        self._paint()


def watch(path: str, interval: float = 2.0, once: bool = False,
          stream=None) -> None:
    """Tail an NDJSON telemetry log, redrawing the dashboard until the
    ``run_end`` record appears (or forever, for an abandoned log —
    interrupt with ^C)."""
    from repro.obs.sinks import load_report
    stream = stream or sys.stdout
    last_height = 0
    while True:
        report = load_report(path)
        frame = render_dashboard(report)
        isatty = getattr(stream, "isatty", lambda: False)()
        if isatty and last_height:
            stream.write(f"\x1b[{last_height}F\x1b[J")
        stream.write(frame + "\n")
        stream.flush()
        last_height = frame.count("\n") + 1
        done = bool(report.summary.get("counters") or
                    report.summary.get("timers_s") or
                    report.summary.get("health"))
        if once or done:
            return
        _time.sleep(interval)
