"""Run-report rendering and telemetry↔accounting reconciliation.

``render_markdown`` turns one or more ``RunReport``s (in-memory or loaded
from NDJSON logs) into the Markdown tables the ``benchmarks.report
run-report`` mode prints: per-run summary, drop-cause breakdown,
bytes-vs-participation, and β-mass by staleness and by rung.

``reconcile`` is the cross-check that makes the instrumented numbers
provably the real ones: telemetry totals must agree with the accounting
that already existed — ``CommState.total_uplink_bytes`` /
``total_downlink_bytes``, the loop's ``participants_per_round``, and the
per-round per-client outcome closure (every client, every round, exactly
one terminal outcome).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

from repro.obs.sinks import RunReport
from repro.obs.telemetry import AGGREGATED, OUTCOMES


class ReconcileError(AssertionError):
    """Telemetry disagrees with the run's own accounting."""


def _close(a: float, b: float, *, rtol: float = 1e-9, atol: float = 1e-6
           ) -> bool:
    return abs(a - b) <= atol + rtol * max(abs(a), abs(b))


def reconcile(report: RunReport, runner) -> Dict[str, float]:
    """Assert ``report``'s aggregates match ``runner``'s accounting.

    Returns the reconciled numbers; raises ``ReconcileError`` naming the
    first disagreement.  Checks:

    * outcome closure — per-cause counts sum to ``n_clients × rounds`` and
      every outcome is from the known vocabulary;
    * telemetry byte totals equal ``CommState.total_uplink_bytes`` /
      ``total_downlink_bytes`` (and the hub's own ``comm.*`` counters);
    * the per-round participants gauge equals the loop's
      ``participants_per_round``.
    """
    counts = report.drop_cause_counts()
    unknown = set(counts) - set(OUTCOMES)
    if unknown:
        raise ReconcileError(f"unknown outcomes recorded: {sorted(unknown)}")
    total = sum(counts.values())
    want = report.n_clients * report.n_rounds
    if total != want:
        raise ReconcileError(
            f"outcome counts sum to {total}, expected n_clients × rounds = "
            f"{report.n_clients} × {report.n_rounds} = {want} ({counts})")

    comm = runner.comm
    up = report.total_upload_bytes()
    if not _close(up, comm.total_uplink_bytes):
        raise ReconcileError(
            f"telemetry uplink bytes {up} != CommState.total_uplink_bytes "
            f"{comm.total_uplink_bytes}")
    down = report.total_download_bytes()
    if not _close(down, comm.total_downlink_bytes):
        raise ReconcileError(
            f"telemetry downlink bytes {down} != "
            f"CommState.total_downlink_bytes {comm.total_downlink_bytes}")
    counters = report.summary.get("counters", {})
    for name, truth in (("comm.upload_bytes", comm.total_uplink_bytes),
                        ("comm.download_bytes", comm.total_downlink_bytes)):
        if name in counters and not _close(counters[name], truth):
            raise ReconcileError(
                f"counter {name} = {counters[name]} != {truth}")

    loop = getattr(runner, "loop", None)
    if loop is not None:
        parts = report.participants_per_round()
        if parts != [int(p) for p in loop.participants_per_round]:
            raise ReconcileError(
                f"participants gauge {parts} != loop.participants_per_round "
                f"{loop.participants_per_round}")

    # per-round phase gauges must telescope back to the run-summary timers
    # (the gauges are per-round deltas of the same accumulators), and no
    # round's phases may claim more than its measured wall time — the
    # profiler's exclusive-timer guarantee.
    timers = report.summary.get("timers_s", {})
    for name, want_s in timers.items():
        if not name.startswith("phase."):
            continue
        got_s = math.fsum(r["gauges"].get(name, 0.0) for r in report.rounds)
        if not _close(got_s, want_s):
            raise ReconcileError(
                f"per-round {name} gauges sum to {got_s} but the run "
                f"summary timer says {want_s}")
    for r in report.rounds:
        wall = r["gauges"].get("round_wall_s")
        if wall is None:
            continue
        claimed = math.fsum(v for k, v in r["gauges"].items()
                            if k.startswith("phase."))
        if claimed > wall + 1e-6:
            raise ReconcileError(
                f"round {r['round']}: phases claim {claimed}s of a "
                f"{wall}s round wall")

    return {"outcomes_total": float(total), "uplink_bytes": up,
            "downlink_bytes": down,
            "aggregated": float(counts[AGGREGATED])}


# ---------------------------------------------------------------------------
# Markdown rendering
# ---------------------------------------------------------------------------
def _table(header: Sequence[str], rows: Sequence[Sequence[str]]) -> str:
    out = ["| " + " | ".join(header) + " |",
           "|" + "---|" * len(header)]
    for row in rows:
        out.append("| " + " | ".join(str(c) for c in row) + " |")
    return "\n".join(out)


def _fmt(x, digits=2) -> str:
    if x is None:
        return "-"
    if isinstance(x, float):
        if math.isnan(x):
            return "-"
        return f"{x:.{digits}f}"
    return str(x)


def render_markdown(reports: List[RunReport],
                    labels: Optional[List[str]] = None) -> str:
    """Markdown run report over one or more telemetry ``RunReport``s."""
    labels = labels or [r.label() for r in reports]
    sections = ["# Run telemetry report", ""]

    rows = []
    for lab, rep in zip(labels, reports):
        rows.append([
            lab, rep.n_rounds, rep.n_clients,
            _fmt(rep.final_accuracy(), 4),
            _fmt(rep.mean_participants()),
            _fmt(rep.mean_distortion(), 3),
            _fmt(rep.total_upload_bytes() / 1e6),
            _fmt(rep.total_download_bytes() / 1e6)])
    sections += ["## Runs", "", _table(
        ["run", "rounds", "clients", "final_acc", "mean_participants",
         "mean_distortion", "uplink_MB", "downlink_MB"], rows), ""]

    rows = []
    for lab, rep in zip(labels, reports):
        counts = rep.drop_cause_counts()
        rows.append([lab] + [counts[c] for c in OUTCOMES]
                    + [sum(counts.values())])
    sections += ["## Drop-cause breakdown", "", _table(
        ["run"] + list(OUTCOMES) + ["total"], rows), ""]

    rows = []
    for lab, rep in zip(labels, reports):
        counts = rep.drop_cause_counts()
        agg = counts[AGGREGATED]
        up = rep.total_upload_bytes()
        rows.append([
            lab, agg, _fmt(rep.mean_participants()), _fmt(up / 1e6),
            _fmt(up / 1e3 / agg if agg else None),
            _fmt((up + rep.total_download_bytes()) / 1e6 /
                 max(rep.n_rounds, 1))])
    sections += ["## Bytes vs participation", "", _table(
        ["run", "aggregated_updates", "mean_participants", "uplink_MB",
         "KB_per_aggregated_update", "total_MB_per_round"], rows), ""]

    def mass_section(title: str, key: str, sort_key=None) -> List[str]:
        groups: List = []
        masses = []
        for rep in reports:
            m = rep.beta_mass_by(key)
            masses.append(m)
            for g in m:
                if g not in groups:
                    groups.append(g)
        if sort_key is not None:
            groups.sort(key=sort_key)
        rows = [[lab] + [_fmt(m.get(g, 0.0), 3) for g in groups]
                for lab, m in zip(labels, masses)]
        return [f"## {title}", "", _table(
            ["run"] + [str(g) for g in groups], rows), ""]

    # β-mass sections render for any report that recorded applied weights —
    # full mode keeps the rows, sketch mode keeps the per-group mass sums
    if any(rep.beta_mass_by("role") for rep in reports):
        sections += mass_section(
            "β-mass by staleness", "staleness",
            sort_key=lambda g: (isinstance(g, str), g))
        sections += mass_section("β-mass by rung", "rung",
                                 sort_key=lambda g: str(g))

    quantile_rows = []
    for lab, rep in zip(labels, reports):
        qdocs = rep.quantiles() if hasattr(rep, "quantiles") else {}
        for metric in sorted(qdocs):
            qs = qdocs[metric]
            quantile_rows.append(
                [lab, metric,
                 _fmt(qs.get(0.5), 4), _fmt(qs.get(0.9), 4),
                 _fmt(qs.get(0.99), 4)])
    if quantile_rows:
        sections += ["## Distribution quantiles", "",
                     "Exact for full-mode reports; rank error ≤ ε·n "
                     "(sketch ε, default 0.01) for sketch-mode reports.", "",
                     _table(["run", "metric", "p50", "p90", "p99"],
                            quantile_rows), ""]

    health_rows = []
    for lab, rep in zip(labels, reports):
        verdict = (rep.health_verdict()
                   if hasattr(rep, "health_verdict") else None)
        alarms = getattr(rep, "health", None) or []
        if verdict is None and not alarms:
            continue
        if verdict is None:
            verdict = {"healthy": not alarms, "n_alarms": len(alarms),
                       "first_alarm_round": (alarms[0]["round"]
                                             if alarms else None),
                       "by_monitor": {}}
        by = ",".join(f"{k}×{v}" for k, v in
                      sorted(verdict.get("by_monitor", {}).items())) or "-"
        health_rows.append(
            [lab, "HEALTHY" if verdict.get("healthy") else "ALARMS",
             verdict.get("n_alarms", 0),
             _fmt(verdict.get("first_alarm_round")), by])
    if health_rows:
        sections += ["## Health", "", _table(
            ["run", "verdict", "alarms", "first_alarm_round", "by_monitor"],
            health_rows), ""]
        for lab, rep in zip(labels, reports):
            for a in (getattr(rep, "health", None) or []):
                sections.append(f"- **{lab}** r={a['round']} "
                                f"`{a['monitor']}`: {a['message']}")
        if any(getattr(rep, "health", None) for rep in reports):
            sections.append("")

    if any(rep.phase_table() for rep in reports):
        rows = []
        for lab, rep in zip(labels, reports):
            for p in rep.phase_table():
                rows.append([lab, p["phase"], _fmt(p["total_s"], 3),
                             _fmt(p["s_per_round"] * 1e3, 1),
                             _fmt(p["share"] * 100.0, 1)])
        sections += ["## Phase timings", "", _table(
            ["run", "phase", "total_s", "ms_per_round", "share_%"], rows),
            ""]

    return "\n".join(sections)
