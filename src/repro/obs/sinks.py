"""Telemetry sinks: in-memory ``RunReport``, NDJSON event log, console line.

A sink consumes the hub's immutable records; it never feeds anything back
into the run.  The NDJSON log is schema-versioned and **distinct from the
replay trace** (``repro.fl.scenarios.trace``): the trace freezes a network
realization for bit-exact replay, the telemetry log is an observational
flight recording — replay never reads it.
"""
from __future__ import annotations

import json
import math
from typing import Any, Dict, List, Optional

import numpy as np

from repro.obs.telemetry import AGGREGATED, BUFFERED, OUTCOMES

TELEMETRY_SCHEMA = "fft-telemetry"
# v2 (PR 7): per-round profiler phase gauges (``phase.*``, ``round_wall_s``)
# emitted by the round loops.  Structurally backward compatible — v1 logs
# (no phase gauges) still load; the loader accepts both versions.
TELEMETRY_VERSION = 2
TELEMETRY_VERSIONS_READABLE = (1, 2)


def _jnum(x):
    """JSON-safe number: non-finite floats become strings (JSON has no
    literals for them); ints and finite floats pass through."""
    if isinstance(x, float):
        if math.isinf(x):
            return "inf" if x > 0 else "-inf"
        if math.isnan(x):
            return "nan"
    return x


def _unjnum(x):
    if x == "inf":
        return math.inf
    if x == "-inf":
        return -math.inf
    if x == "nan":
        return math.nan
    return x


def _jsonable(obj):
    """Recursively make a record JSON-serializable (numpy scalars → Python,
    non-finite floats → strings)."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return _jnum(float(obj))
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    if isinstance(obj, float):
        return _jnum(obj)
    return obj


class Sink:
    """Telemetry consumer interface; every hook is optional."""

    def on_run_start(self, meta: Dict) -> None:
        pass

    def on_round(self, rec: Dict) -> None:
        pass

    def on_resolution(self, rec: Dict) -> None:
        pass

    def on_run_end(self, summary: Dict) -> None:
        pass


class RunReport(Sink):
    """In-memory flight record of one run, with the derived views the
    benchmarks and the report renderer read their headline numbers from."""

    def __init__(self):
        self.meta: Dict[str, Any] = {}
        self.rounds: List[Dict] = []
        self.resolutions: List[Dict] = []
        self.summary: Dict[str, Any] = {"counters": {}, "timers_s": {}}

    # ---------------------------------------------------------------- sink
    def on_run_start(self, meta: Dict) -> None:
        self.meta = dict(meta)

    def on_round(self, rec: Dict) -> None:
        self.rounds.append(rec)

    def on_resolution(self, rec: Dict) -> None:
        self.resolutions.append(rec)

    def on_run_end(self, summary: Dict) -> None:
        self.summary = summary

    # ------------------------------------------------------------- loading
    @classmethod
    def from_ndjson(cls, path: str) -> "RunReport":
        """Rebuild a report from an ``NdjsonSink`` event log."""
        rep = cls()
        with open(path) as fh:
            for line_no, line in enumerate(fh, 1):
                line = line.strip()
                if not line:
                    continue
                rec = json.loads(line)
                kind = rec.get("record")
                if kind == "run_start":
                    if (rec.get("schema") != TELEMETRY_SCHEMA
                            or rec.get("version")
                            not in TELEMETRY_VERSIONS_READABLE):
                        raise ValueError(
                            f"{path}:{line_no}: not a "
                            f"{TELEMETRY_SCHEMA} "
                            f"v{TELEMETRY_VERSIONS_READABLE} log "
                            f"(got {rec.get('schema')!r} "
                            f"v{rec.get('version')!r})")
                    rep.meta = rec.get("meta", {})
                elif kind == "round":
                    clients = {int(c["client"]): {
                        k: _unjnum(v) for k, v in c.items()}
                        for c in rec.get("clients", [])}
                    rep.rounds.append({
                        "round": int(rec["round"]), "clients": clients,
                        "gauges": {k: _unjnum(v) for k, v in
                                   rec.get("gauges", {}).items()},
                        "betas": rec.get("betas", [])})
                elif kind == "resolution":
                    rep.resolutions.append(
                        {k: v for k, v in rec.items() if k != "record"})
                elif kind == "run_end":
                    rep.summary = {"counters": rec.get("counters", {}),
                                   "timers_s": rec.get("timers_s", {})}
                else:
                    raise ValueError(
                        f"{path}:{line_no}: unknown record {kind!r}")
        return rep

    # ------------------------------------------------------- derived views
    @property
    def n_rounds(self) -> int:
        return len(self.rounds)

    @property
    def n_clients(self) -> int:
        n = self.meta.get("n_clients")
        if n is not None:
            return int(n)
        return max((len(r["clients"]) for r in self.rounds), default=0)

    def final_outcomes(self) -> Dict[tuple, Dict]:
        """``(round, client) → record`` with buffered records upgraded by
        their resolution events — the terminal per-client per-round truth.
        Uploads still in flight at run end legitimately stay ``buffered``.
        """
        out = {}
        for rnd_rec in self.rounds:
            r = rnd_rec["round"]
            for c, rec in rnd_rec["clients"].items():
                out[(r, int(c))] = dict(rec)
        for res in self.resolutions:
            key = (int(res["origin_round"]), int(res["client"]))
            rec = out.get(key)
            if rec is None:
                raise ValueError(f"resolution for unknown record {key}")
            if rec["outcome"] != BUFFERED:
                raise ValueError(
                    f"resolution for {key} but its outcome is "
                    f"{rec['outcome']!r}, not {BUFFERED!r}")
            rec["outcome"] = res["outcome"]
            for k in ("staleness", "applied_round"):
                if k in res:
                    rec[k] = res[k]
        return out

    def drop_cause_counts(self) -> Dict[str, int]:
        counts = {c: 0 for c in OUTCOMES}
        for rec in self.final_outcomes().values():
            counts[rec["outcome"]] += 1
        return counts

    def participants_per_round(self) -> List[int]:
        return [int(r["gauges"].get("participants", 0)) for r in self.rounds]

    def mean_participants(self) -> float:
        parts = self.participants_per_round()
        return float(np.mean(parts)) if parts else 0.0

    def total_upload_bytes(self) -> float:
        """Simulated uplink bytes summed over every recorded upload —
        reconciles with ``CommState.total_uplink_bytes``."""
        return float(math.fsum(
            rec["upload_bytes"]
            for r in self.rounds for rec in r["clients"].values()
            if rec.get("upload_bytes") is not None))

    def total_download_bytes(self) -> float:
        """Broadcast bytes summed over rounds — reconciles with
        ``CommState.total_downlink_bytes``."""
        return float(math.fsum(r["gauges"].get("downlink_bytes", 0.0)
                               for r in self.rounds))

    def accuracy_curve(self) -> List[tuple]:
        """``(round, accuracy)`` for every evaluated round."""
        return [(r["round"], r["gauges"]["eval_acc"]) for r in self.rounds
                if "eval_acc" in r["gauges"]]

    def final_accuracy(self) -> Optional[float]:
        curve = self.accuracy_curve()
        return curve[-1][1] if curve else None

    def mean_distortion(self) -> float:
        """Mean recorded per-upload compression distortion (same definition
        as ``repro.fl.metrics.mean_distortion`` over the loop's history)."""
        vals = [rec["distortion"]
                for r in self.rounds for rec in r["clients"].values()
                if rec.get("distortion") is not None]
        return float(np.mean(vals)) if vals else 0.0

    def beta_rows(self, rnd: Optional[int] = None) -> List[Dict]:
        if rnd is None:
            return [row for r in self.rounds for row in r["betas"]]
        for r in self.rounds:
            if r["round"] == rnd:
                return list(r["betas"])
        return []

    def beta_mass_by(self, key: str) -> Dict[Any, float]:
        """Total applied β mass grouped by ``key`` (``"staleness"``,
        ``"rung"``, or ``"role"``); non-client rows group under their role.
        Normalized to fractions of the total recorded mass."""
        mass: Dict[Any, float] = {}
        for row in self.beta_rows():
            if key == "role" or row.get("role") != "client":
                g = row.get("role", "client")
            else:
                g = row.get(key)
                if g is None:
                    g = 0 if key == "staleness" else "?"
            mass[g] = mass.get(g, 0.0) + float(row["beta"])
        tot = sum(mass.values())
        if tot > 0:
            mass = {k: v / tot for k, v in mass.items()}
        return mass

    def total_wall_s(self) -> float:
        """Measured wall seconds summed over rounds (the ``round_wall_s``
        gauge the round loops emit; 0.0 for uninstrumented/v1 records)."""
        return float(math.fsum(r["gauges"].get("round_wall_s", 0.0)
                               for r in self.rounds))

    def phase_seconds(self, rnd: Optional[int] = None) -> Dict[str, float]:
        """Per-phase exclusive wall seconds (``phase.*`` gauges), summed
        over the run — or for one round — keyed by the bare phase name."""
        rounds = (self.rounds if rnd is None
                  else [r for r in self.rounds if r["round"] == rnd])
        out: Dict[str, float] = {}
        for r in rounds:
            for k, v in r["gauges"].items():
                if k.startswith("phase."):
                    name = k[len("phase."):]
                    out[name] = out.get(name, 0.0) + float(v)
        return out

    def phase_table(self) -> List[Dict[str, float]]:
        """Per-phase profile of the run, hottest phase first.

        One row per recorded ``phase.*`` gauge plus a final ``(untimed)``
        row for wall time no phase claimed: ``{"phase", "total_s",
        "s_per_round", "share"}`` where ``share`` is the fraction of the
        measured round wall time (phases are exclusive, so shares sum to
        ≤ 1 and the ``(untimed)`` row closes the gap).  Empty when the run
        recorded no phase gauges (telemetry off, or a v1 log)."""
        totals = self.phase_seconds()
        if not totals:
            return []
        wall = self.total_wall_s()
        n = max(self.n_rounds, 1)
        rows = [{"phase": name, "total_s": s, "s_per_round": s / n,
                 "share": (s / wall) if wall > 0 else math.nan}
                for name, s in sorted(totals.items(),
                                      key=lambda kv: -kv[1])]
        untimed = wall - math.fsum(totals.values())
        if wall > 0:
            rows.append({"phase": "(untimed)", "total_s": untimed,
                         "s_per_round": untimed / n,
                         "share": untimed / wall})
        return rows

    def rung_histogram(self) -> Dict[str, int]:
        """Uploads per codec rung over the whole run (every outcome that
        shipped bytes: aggregated, buffered, or later evicted)."""
        hist: Dict[str, int] = {}
        for r in self.rounds:
            for rec in r["clients"].values():
                rung = rec.get("rung")
                if rung is not None:
                    hist[rung] = hist.get(rung, 0) + 1
        return hist

    def label(self) -> str:
        """Short human label for multi-run tables."""
        m = self.meta
        parts = [str(m.get(k)) for k in ("scenario", "server_mode", "codec",
                                         "strategy") if m.get(k)]
        return "/".join(parts) if parts else "run"


class NdjsonSink(Sink):
    """Append-only, schema-versioned NDJSON event-log writer.

    One line per event, in emission order: ``run_start``, then per round a
    ``round`` record (interleaved with any ``resolution`` events for past
    rounds), finally ``run_end``.  Opens fresh (truncates) so one file
    always holds exactly one run.
    """

    def __init__(self, path: str):
        self.path = path
        self._fh = open(path, "w")

    def _write(self, rec: Dict) -> None:
        self._fh.write(json.dumps(_jsonable(rec)) + "\n")

    def on_run_start(self, meta: Dict) -> None:
        self._write({"record": "run_start", "schema": TELEMETRY_SCHEMA,
                     "version": TELEMETRY_VERSION, "meta": meta})
        self._fh.flush()

    def on_round(self, rec: Dict) -> None:
        clients = [rec["clients"][c] for c in sorted(rec["clients"])]
        self._write({"record": "round", "round": rec["round"],
                     "gauges": rec["gauges"], "betas": rec["betas"],
                     "clients": clients})
        self._fh.flush()

    def on_resolution(self, rec: Dict) -> None:
        self._write({"record": "resolution", **rec})

    def on_run_end(self, summary: Dict) -> None:
        self._write({"record": "run_end", **summary})
        self._fh.close()


class ConsoleSink(Sink):
    """One terminal summary line per round."""

    def on_round(self, rec: Dict) -> None:
        g = rec["gauges"]
        causes: Dict[str, int] = {}
        for c in rec["clients"].values():
            causes[c["outcome"]] = causes.get(c["outcome"], 0) + 1
        drops = ",".join(f"{k}={v}" for k, v in sorted(causes.items())
                         if k != AGGREGATED and v)
        acc = (f" acc={g['eval_acc']:.4f}" if "eval_acc" in g else "")
        print(f"[obs] r={rec['round']:>3} "
              f"agg={causes.get(AGGREGATED, 0)}/{len(rec['clients'])} "
              f"[{drops}] wait={g.get('server_wait_s', 0.0):.2f}s "
              f"up={g.get('cum_uplink_bytes', 0.0) / 1e6:.2f}MB "
              f"down={g.get('cum_downlink_bytes', 0.0) / 1e6:.2f}MB{acc}")
