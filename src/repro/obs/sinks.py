"""Telemetry sinks: in-memory ``RunReport``, NDJSON event log, console line.

A sink consumes the hub's immutable records; it never feeds anything back
into the run.  The NDJSON log is schema-versioned and **distinct from the
replay trace** (``repro.fl.scenarios.trace``): the trace freezes a network
realization for bit-exact replay, the telemetry log is an observational
flight recording — replay never reads it.
"""
from __future__ import annotations

import json
import math
import warnings
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.telemetry import AGGREGATED, BUFFERED, OUTCOMES

TELEMETRY_SCHEMA = "fft-telemetry"
# v2 (PR 7): per-round profiler phase gauges (``phase.*``, ``round_wall_s``)
# emitted by the round loops.
# v3 (PR 8): sketch-mode round records (``sketch`` digest instead of
# per-client ``clients``/``betas`` rows), ``health`` records from the online
# run-health monitors, and a ``health``/``sketch`` section in ``run_end``.
# Structurally backward compatible — v1/v2 logs still load.
TELEMETRY_VERSION = 3
TELEMETRY_VERSIONS_READABLE = (1, 2, 3)


def _jnum(x):
    """JSON-safe number: non-finite floats become strings (JSON has no
    literals for them); ints and finite floats pass through."""
    if isinstance(x, float):
        if math.isinf(x):
            return "inf" if x > 0 else "-inf"
        if math.isnan(x):
            return "nan"
    return x


def _unjnum(x):
    if x == "inf":
        return math.inf
    if x == "-inf":
        return -math.inf
    if x == "nan":
        return math.nan
    return x


def _jsonable(obj):
    """Recursively make a record JSON-serializable (numpy scalars → Python,
    non-finite floats → strings)."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return _jnum(float(obj))
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    if isinstance(obj, float):
        return _jnum(obj)
    return obj


def read_telemetry_records(path: str) -> Iterator[Tuple[int, Dict]]:
    """Yield ``(line_no, record)`` from an NDJSON telemetry log.

    Validates the schema/version on the ``run_start`` line and tolerates a
    *truncated final line* — a run killed mid-write still yields a loadable
    flight record (with a warning) instead of raising.  Corruption anywhere
    other than the last line still raises: that is a damaged log, not a
    crash artifact.
    """
    with open(path) as fh:
        lines = fh.readlines()
    last = -1
    for i in range(len(lines) - 1, -1, -1):
        if lines[i].strip():
            last = i
            break
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            if i == last:
                warnings.warn(
                    f"{path}:{i + 1}: truncated final record (run killed "
                    f"mid-write?) — loading the {i} complete records",
                    RuntimeWarning, stacklevel=3)
                return
            raise
        if rec.get("record") == "run_start":
            if (rec.get("schema") != TELEMETRY_SCHEMA
                    or rec.get("version") not in TELEMETRY_VERSIONS_READABLE):
                raise ValueError(
                    f"{path}:{i + 1}: not a {TELEMETRY_SCHEMA} "
                    f"v{TELEMETRY_VERSIONS_READABLE} log "
                    f"(got {rec.get('schema')!r} v{rec.get('version')!r})")
        yield i + 1, rec


def peek_telemetry_mode(path: str) -> str:
    """``"full"`` or ``"sketch"``, from the run_start meta (v3) or the
    shape of the first round record (v1/v2 logs predate the meta key)."""
    for _ln, rec in read_telemetry_records(path):
        kind = rec.get("record")
        if kind == "run_start":
            mode = rec.get("meta", {}).get("telemetry_mode")
            if mode in ("full", "sketch"):
                return mode
        elif kind == "round":
            return "sketch" if "sketch" in rec else "full"
    return "full"


def load_report(path: str):
    """Load an NDJSON telemetry log into the right report type —
    ``RunReport`` for full-mode logs, ``SketchReport`` for sketch-mode."""
    if peek_telemetry_mode(path) == "sketch":
        from repro.obs.sketch import SketchReport
        return SketchReport.from_ndjson(path)
    return RunReport.from_ndjson(path)


def build_phase_table(totals: Dict[str, float], wall: float,
                      n_rounds: int) -> List[Dict[str, float]]:
    """Shared phase-profile table builder (``RunReport.phase_table`` /
    ``SketchReport.phase_table``): one row per phase, hottest first, plus
    an ``(untimed)`` row closing the gap to the measured wall time."""
    if not totals:
        return []
    n = max(n_rounds, 1)
    rows = [{"phase": name, "total_s": s, "s_per_round": s / n,
             "share": (s / wall) if wall > 0 else math.nan}
            for name, s in sorted(totals.items(), key=lambda kv: -kv[1])]
    untimed = wall - math.fsum(totals.values())
    if wall > 0:
        rows.append({"phase": "(untimed)", "total_s": untimed,
                     "s_per_round": untimed / n, "share": untimed / wall})
    return rows


class Sink:
    """Telemetry consumer interface; every hook is optional."""

    def on_run_start(self, meta: Dict) -> None:
        pass

    def on_round(self, rec: Dict) -> None:
        pass

    def on_resolution(self, rec: Dict) -> None:
        pass

    def on_health(self, rec: Dict) -> None:
        pass

    def on_run_end(self, summary: Dict) -> None:
        pass


class RunReport(Sink):
    """In-memory flight record of one run, with the derived views the
    benchmarks and the report renderer read their headline numbers from."""

    mode = "full"

    def __init__(self):
        self.meta: Dict[str, Any] = {}
        self.rounds: List[Dict] = []
        self.resolutions: List[Dict] = []
        self.health: List[Dict] = []
        self.summary: Dict[str, Any] = {"counters": {}, "timers_s": {}}
        self._fo_cache: Optional[Dict[tuple, Dict]] = None
        self._fo_key: Optional[tuple] = None

    # ---------------------------------------------------------------- sink
    def on_run_start(self, meta: Dict) -> None:
        self.meta = dict(meta)

    def on_round(self, rec: Dict) -> None:
        self.rounds.append(rec)
        self._fo_cache = None

    def on_resolution(self, rec: Dict) -> None:
        self.resolutions.append(rec)
        self._fo_cache = None

    def on_health(self, rec: Dict) -> None:
        self.health.append(rec)

    def on_run_end(self, summary: Dict) -> None:
        self.summary = summary

    # ------------------------------------------------------------- loading
    @classmethod
    def from_ndjson(cls, path: str) -> "RunReport":
        """Rebuild a report from an ``NdjsonSink`` event log.  Tolerates a
        truncated final line (killed run) — see
        ``read_telemetry_records``."""
        rep = cls()
        for line_no, rec in read_telemetry_records(path):
            kind = rec.get("record")
            if kind == "run_start":
                rep.meta = rec.get("meta", {})
            elif kind == "round":
                if "clients" not in rec and "sketch" in rec:
                    raise ValueError(
                        f"{path}:{line_no}: sketch-mode log (no per-client "
                        f"rows); load it with repro.obs.load_report")
                clients = {int(c["client"]): {
                    k: _unjnum(v) for k, v in c.items()}
                    for c in rec.get("clients", [])}
                rep.rounds.append({
                    "round": int(rec["round"]), "clients": clients,
                    "gauges": {k: _unjnum(v) for k, v in
                               rec.get("gauges", {}).items()},
                    "betas": rec.get("betas", [])})
            elif kind == "resolution":
                rep.resolutions.append(
                    {k: v for k, v in rec.items() if k != "record"})
            elif kind == "health":
                rep.health.append(
                    {k: _unjnum(v) for k, v in rec.items()
                     if k != "record"})
            elif kind == "run_end":
                rep.summary = {k: v for k, v in rec.items()
                               if k != "record"}
                rep.summary.setdefault("counters", {})
                rep.summary.setdefault("timers_s", {})
            else:
                raise ValueError(
                    f"{path}:{line_no}: unknown record {kind!r}")
        rep._fo_cache = None
        return rep

    # ------------------------------------------------------- derived views
    @property
    def n_rounds(self) -> int:
        return len(self.rounds)

    @property
    def n_clients(self) -> int:
        n = self.meta.get("n_clients")
        if n is not None:
            return int(n)
        return max((len(r["clients"]) for r in self.rounds), default=0)

    def _rows_key(self) -> tuple:
        # cache key covering both appended records and in-place edits that
        # change row counts (reconcile's tamper tests mutate rounds
        # directly); cheap — O(rounds), not O(rounds × clients)
        return (len(self.rounds), len(self.resolutions),
                sum(len(r["clients"]) for r in self.rounds))

    def final_outcomes(self) -> Dict[tuple, Dict]:
        """``(round, client) → record`` with buffered records upgraded by
        their resolution events — the terminal per-client per-round truth.
        Uploads still in flight at run end legitimately stay ``buffered``.

        Cached: every derived view (``drop_cause_counts``,
        ``total_upload_bytes``, the renderer) funnels through here, and
        rebuilding O(rounds × clients) state per view made report
        rendering quadratic.  The cache invalidates on new round or
        resolution records (and on row-count changes).
        """
        key = self._rows_key()
        if self._fo_cache is not None and self._fo_key == key:
            return self._fo_cache
        out = {}
        for rnd_rec in self.rounds:
            r = rnd_rec["round"]
            for c, rec in rnd_rec["clients"].items():
                out[(r, int(c))] = dict(rec)
        for res in self.resolutions:
            rkey = (int(res["origin_round"]), int(res["client"]))
            rec = out.get(rkey)
            if rec is None:
                raise ValueError(f"resolution for unknown record {rkey}")
            if rec["outcome"] != BUFFERED:
                raise ValueError(
                    f"resolution for {rkey} but its outcome is "
                    f"{rec['outcome']!r}, not {BUFFERED!r}")
            rec["outcome"] = res["outcome"]
            for k in ("staleness", "applied_round"):
                if k in res:
                    rec[k] = res[k]
        self._fo_cache, self._fo_key = out, key
        return out

    def drop_cause_counts(self) -> Dict[str, int]:
        counts = {c: 0 for c in OUTCOMES}
        for rec in self.final_outcomes().values():
            counts[rec["outcome"]] += 1
        return counts

    def participants_per_round(self) -> List[int]:
        return [int(r["gauges"].get("participants", 0)) for r in self.rounds]

    def mean_participants(self) -> float:
        parts = self.participants_per_round()
        return float(np.mean(parts)) if parts else 0.0

    def total_upload_bytes(self) -> float:
        """Simulated uplink bytes summed over every recorded upload —
        reconciles with ``CommState.total_uplink_bytes``."""
        return float(math.fsum(
            rec["upload_bytes"]
            for r in self.rounds for rec in r["clients"].values()
            if rec.get("upload_bytes") is not None))

    def total_download_bytes(self) -> float:
        """Broadcast bytes summed over rounds — reconciles with
        ``CommState.total_downlink_bytes``."""
        return float(math.fsum(r["gauges"].get("downlink_bytes", 0.0)
                               for r in self.rounds))

    def accuracy_curve(self) -> List[tuple]:
        """``(round, accuracy)`` for every evaluated round."""
        return [(r["round"], r["gauges"]["eval_acc"]) for r in self.rounds
                if "eval_acc" in r["gauges"]]

    def final_accuracy(self) -> Optional[float]:
        curve = self.accuracy_curve()
        return curve[-1][1] if curve else None

    def mean_distortion(self) -> float:
        """Mean recorded per-upload compression distortion (same definition
        as ``repro.fl.metrics.mean_distortion`` over the loop's history)."""
        vals = [rec["distortion"]
                for r in self.rounds for rec in r["clients"].values()
                if rec.get("distortion") is not None]
        return float(np.mean(vals)) if vals else 0.0

    def beta_rows(self, rnd: Optional[int] = None) -> List[Dict]:
        if rnd is None:
            return [row for r in self.rounds for row in r["betas"]]
        for r in self.rounds:
            if r["round"] == rnd:
                return list(r["betas"])
        return []

    def beta_mass_by(self, key: str) -> Dict[Any, float]:
        """Total applied β mass grouped by ``key`` (``"staleness"``,
        ``"rung"``, or ``"role"``); non-client rows group under their role.
        Normalized to fractions of the total recorded mass."""
        mass: Dict[Any, float] = {}
        for row in self.beta_rows():
            if key == "role" or row.get("role") != "client":
                g = row.get("role", "client")
            else:
                g = row.get(key)
                if g is None:
                    g = 0 if key == "staleness" else "?"
            mass[g] = mass.get(g, 0.0) + float(row["beta"])
        tot = sum(mass.values())
        if tot > 0:
            mass = {k: v / tot for k, v in mass.items()}
        return mass

    def total_wall_s(self) -> float:
        """Measured wall seconds summed over rounds (the ``round_wall_s``
        gauge the round loops emit; 0.0 for uninstrumented/v1 records)."""
        return float(math.fsum(r["gauges"].get("round_wall_s", 0.0)
                               for r in self.rounds))

    def phase_seconds(self, rnd: Optional[int] = None) -> Dict[str, float]:
        """Per-phase exclusive wall seconds (``phase.*`` gauges), summed
        over the run — or for one round — keyed by the bare phase name."""
        rounds = (self.rounds if rnd is None
                  else [r for r in self.rounds if r["round"] == rnd])
        out: Dict[str, float] = {}
        for r in rounds:
            for k, v in r["gauges"].items():
                if k.startswith("phase."):
                    name = k[len("phase."):]
                    out[name] = out.get(name, 0.0) + float(v)
        return out

    def phase_table(self) -> List[Dict[str, float]]:
        """Per-phase profile of the run, hottest phase first.

        One row per recorded ``phase.*`` gauge plus a final ``(untimed)``
        row for wall time no phase claimed: ``{"phase", "total_s",
        "s_per_round", "share"}`` where ``share`` is the fraction of the
        measured round wall time (phases are exclusive, so shares sum to
        ≤ 1 and the ``(untimed)`` row closes the gap).  Empty when the run
        recorded no phase gauges (telemetry off, or a v1 log)."""
        return build_phase_table(self.phase_seconds(), self.total_wall_s(),
                                 self.n_rounds)

    def rung_histogram(self) -> Dict[str, int]:
        """Uploads per codec rung over the whole run (every outcome that
        shipped bytes: aggregated, buffered, or later evicted)."""
        hist: Dict[str, int] = {}
        for r in self.rounds:
            for rec in r["clients"].values():
                rung = rec.get("rung")
                if rung is not None:
                    hist[rung] = hist.get(rung, 0) + 1
        return hist

    def quantiles(self, qs: Sequence[float] = (0.5, 0.9, 0.99)
                  ) -> Dict[str, Dict[float, float]]:
        """Exact per-metric quantiles over the recorded per-client rows —
        the full-mode counterpart of ``SketchReport.quantiles`` (same keys,
        so the renderer's distribution table works in either mode)."""
        finals = self.final_outcomes()
        streams: Dict[str, List[float]] = {
            "upload_bytes": [], "staleness": [], "distortion": []}
        for rec in finals.values():
            for name in ("upload_bytes", "distortion", "staleness"):
                v = rec.get(name)
                if v is not None:
                    streams[name].append(float(v))
        streams["beta"] = [float(row["beta"]) for row in self.beta_rows()
                           if row.get("role", "client") == "client"]
        out: Dict[str, Dict[float, float]] = {}
        for name, vals in streams.items():
            if vals:
                out[name] = {float(q): float(np.quantile(vals, q))
                             for q in qs}
        return out

    def health_verdict(self) -> Optional[Dict[str, Any]]:
        """The run-end health verdict (None for runs without monitors)."""
        return self.summary.get("health")

    def label(self) -> str:
        """Short human label for multi-run tables."""
        m = self.meta
        parts = [str(m.get(k)) for k in ("scenario", "server_mode", "codec",
                                         "strategy") if m.get(k)]
        return "/".join(parts) if parts else "run"


class NdjsonSink(Sink):
    """Append-only, schema-versioned NDJSON event-log writer.

    One line per event, in emission order: ``run_start``, then per round a
    ``round`` record (interleaved with any ``resolution`` / ``health``
    events), finally ``run_end``.  Opens fresh (truncates) so one file
    always holds exactly one run.  Every record is flushed as written —
    a killed long run leaves at worst one truncated final line, which
    ``read_telemetry_records`` tolerates, so the flight record survives
    the crash it is most needed for.
    """

    def __init__(self, path: str):
        self.path = path
        self._fh = open(path, "w")

    def _write(self, rec: Dict) -> None:
        self._fh.write(json.dumps(_jsonable(rec)) + "\n")
        self._fh.flush()

    def on_run_start(self, meta: Dict) -> None:
        self._write({"record": "run_start", "schema": TELEMETRY_SCHEMA,
                     "version": TELEMETRY_VERSION, "meta": meta})

    def on_round(self, rec: Dict) -> None:
        if "sketch" in rec:                 # sketch mode: constant-size row
            self._write({"record": "round", "round": rec["round"],
                         "gauges": rec["gauges"], "sketch": rec["sketch"]})
            return
        clients = [rec["clients"][c] for c in sorted(rec["clients"])]
        self._write({"record": "round", "round": rec["round"],
                     "gauges": rec["gauges"], "betas": rec["betas"],
                     "clients": clients})

    def on_resolution(self, rec: Dict) -> None:
        self._write({"record": "resolution", **rec})

    def on_health(self, rec: Dict) -> None:
        self._write({"record": "health", **rec})

    def on_run_end(self, summary: Dict) -> None:
        self._write({"record": "run_end", **summary})
        self._fh.close()


class ConsoleSink(Sink):
    """One terminal summary line per round (plus health alarm lines)."""

    def on_round(self, rec: Dict) -> None:
        g = rec["gauges"]
        if "sketch" in rec:
            causes = {k: int(v) for k, v in rec["sketch"]["counts"].items()
                      if v}
            total = sum(causes.values())
        else:
            causes = {}
            for c in rec["clients"].values():
                causes[c["outcome"]] = causes.get(c["outcome"], 0) + 1
            total = len(rec["clients"])
        drops = ",".join(f"{k}={v}" for k, v in sorted(causes.items())
                         if k != AGGREGATED and v)
        acc = (f" acc={g['eval_acc']:.4f}" if "eval_acc" in g else "")
        print(f"[obs] r={rec['round']:>3} "
              f"agg={causes.get(AGGREGATED, 0)}/{total} "
              f"[{drops}] wait={g.get('server_wait_s', 0.0):.2f}s "
              f"up={g.get('cum_uplink_bytes', 0.0) / 1e6:.2f}MB "
              f"down={g.get('cum_downlink_bytes', 0.0) / 1e6:.2f}MB{acc}")

    def on_health(self, rec: Dict) -> None:
        print(f"[health] ALARM r={rec['round']:>3} {rec['monitor']}: "
              f"{rec['message']}")

    def on_run_end(self, summary: Dict) -> None:
        verdict = summary.get("health")
        if not verdict:
            return
        if verdict.get("healthy"):
            print(f"[health] verdict: HEALTHY "
                  f"({verdict.get('rounds_seen', 0)} rounds, 0 alarms)")
        else:
            by = ",".join(f"{k}={v}" for k, v in
                          sorted(verdict.get("by_monitor", {}).items()))
            print(f"[health] verdict: {verdict.get('n_alarms', 0)} ALARMS "
                  f"[{by}] first at r={verdict.get('first_alarm_round')}")
