"""Run telemetry subsystem: per-round flight recorder with drop-cause
attribution, counters/timers, pluggable sinks, and a report renderer.

Enable with ``FFTConfig.telemetry=True`` (off by default — the disabled
path is a falsy no-op hub and changes nothing about a run); add
``telemetry_log=<path>`` for a schema-versioned NDJSON event log and
``telemetry_console=True`` for a per-round terminal summary line.  After
``runner.run(...)`` the in-memory flight record is ``runner.report``
(a ``RunReport``); ``reconcile(runner.report, runner)`` cross-checks its
aggregates against the run's own accounting and ``render_markdown`` turns
reports into the ``benchmarks.report run-report`` tables.

Population-scale additions (PR 8): ``telemetry="sketch"`` swaps the
per-client rows for bounded-memory streaming sketches (``SketchReport``,
exact additive totals, ε-approximate quantiles, K-row reservoir);
``HealthMonitors`` watch the round stream online and emit schema'd alarm
records plus a run-end verdict; ``telemetry_trace=<path>`` exports the
phase timers as Perfetto-loadable Chrome trace-event JSON; and
``telemetry_dashboard=True`` / ``benchmarks.report watch`` render a live
in-place run dashboard.  ``load_report`` picks the right report type for
any NDJSON log.
"""
from repro.obs.chrometrace import (ChromeTraceError,  # noqa: F401
                                   ChromeTraceRecorder, load_trace,
                                   self_times, verify_trace)
from repro.obs.dashboard import (DashboardSink,  # noqa: F401
                                 render_dashboard, sparkline, watch)
from repro.obs.health import (HealthConfig, HealthMonitors,  # noqa: F401
                              health_record)
from repro.obs.report import (ReconcileError, reconcile,  # noqa: F401
                              render_markdown)
from repro.obs.sinks import (ConsoleSink, NdjsonSink, RunReport,  # noqa: F401
                             Sink, TELEMETRY_SCHEMA, TELEMETRY_VERSION,
                             TELEMETRY_VERSIONS_READABLE, load_report,
                             peek_telemetry_mode, read_telemetry_records)
from repro.obs.sketch import (ExactSum, GKQuantiles,  # noqa: F401
                              Reservoir, SKETCH_EPS, SketchReport,
                              SketchState)
from repro.obs.telemetry import (AGGREGATED, BUFFERED,  # noqa: F401
                                 EVICTED, LINK_DOWN, MISSED_DEADLINE,
                                 NOT_SELECTED, NULL_TELEMETRY, OUTCOMES,
                                 SKIPPED_STRAGGLER, NullTelemetry, Telemetry,
                                 beta_row)
