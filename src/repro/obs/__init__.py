"""Run telemetry subsystem: per-round flight recorder with drop-cause
attribution, counters/timers, pluggable sinks, and a report renderer.

Enable with ``FFTConfig.telemetry=True`` (off by default — the disabled
path is a falsy no-op hub and changes nothing about a run); add
``telemetry_log=<path>`` for a schema-versioned NDJSON event log and
``telemetry_console=True`` for a per-round terminal summary line.  After
``runner.run(...)`` the in-memory flight record is ``runner.report``
(a ``RunReport``); ``reconcile(runner.report, runner)`` cross-checks its
aggregates against the run's own accounting and ``render_markdown`` turns
reports into the ``benchmarks.report run-report`` tables.
"""
from repro.obs.report import (ReconcileError, reconcile,  # noqa: F401
                              render_markdown)
from repro.obs.sinks import (ConsoleSink, NdjsonSink, RunReport,  # noqa: F401
                             Sink, TELEMETRY_SCHEMA, TELEMETRY_VERSION,
                             TELEMETRY_VERSIONS_READABLE)
from repro.obs.telemetry import (AGGREGATED, BUFFERED,  # noqa: F401
                                 EVICTED, LINK_DOWN, MISSED_DEADLINE,
                                 NOT_SELECTED, NULL_TELEMETRY, OUTCOMES,
                                 NullTelemetry, Telemetry, beta_row)
