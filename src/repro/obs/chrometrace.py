"""Chrome trace-event export for the per-phase profiler.

The exclusive ``_Timer`` already holds begin timestamps on its stack; with
``FFTConfig.telemetry_trace`` set, every timer entry/exit (and every round)
additionally lands as a begin/end span in a ``ChromeTraceRecorder``, which
serializes the run as Chrome trace-event JSON — load ``trace.json`` in
Perfetto (https://ui.perfetto.dev) or ``chrome://tracing`` for a
flamegraph-style view of where round wall time went.

The recorder shares the *same* ``time.perf_counter()`` reading with the
timer accounting, so the trace is not merely "close to" the profiler: a
self-time replay of the B/E event stream (``self_times``) reproduces the
exclusive ``timers_s`` totals and the per-round ``phase.*`` gauges up to
float64 round-off in the µs conversion, and ``verify_trace`` proves that
telescoping for any saved trace against its run report.
"""
from __future__ import annotations

import json
import math
import time
from typing import Any, Dict, List, Optional, Tuple

BEGIN = "B"
END = "E"


class ChromeTraceError(AssertionError):
    """A saved trace failed to telescope to its run's phase accounting."""


class ChromeTraceRecorder:
    """Flag-gated span recorder; O(1) per timer entry/exit.

    Events are kept in memory as ``(name, phase, t_seconds, args)`` and
    serialized once at ``save()`` (called by ``Telemetry.end_run``).
    ``begin``/``end`` are driven by ``_Timer.__enter__``/``__exit__`` and
    the hub's round boundaries with the exact timestamps the timers
    account with.
    """

    def __init__(self, path: str):
        self.path = path
        self.events: List[Tuple[str, str, float, Optional[Dict]]] = []
        self._open: List[str] = []

    def begin(self, name: str, t: Optional[float] = None,
              args: Optional[Dict] = None) -> None:
        if t is None:
            t = time.perf_counter()
        self._open.append(name)
        self.events.append((name, BEGIN, t, args))

    def end(self, name: str, t: Optional[float] = None) -> None:
        if t is None:
            t = time.perf_counter()
        if self._open and self._open[-1] == name:
            self._open.pop()
        self.events.append((name, END, t, None))

    def save(self, meta: Optional[Dict] = None) -> str:
        """Write the trace-event JSON.  Spans still open (a crashed run)
        are closed at the last recorded timestamp so the file stays a
        valid, loadable trace."""
        events = list(self.events)
        if self._open and events:
            t_last = max(e[2] for e in events)
            for name in reversed(self._open):
                events.append((name, END, t_last, None))
        t0 = min((e[2] for e in events), default=0.0)
        trace_events = []
        for name, ph, t, args in events:
            ev: Dict[str, Any] = {
                "name": name, "ph": ph, "pid": 0, "tid": 0,
                "ts": (t - t0) * 1e6,
                "cat": "phase" if name.startswith("phase.") else "round"}
            if args:
                ev["args"] = dict(args)
            trace_events.append(ev)
        doc = {"traceEvents": trace_events, "displayTimeUnit": "ms",
               "otherData": dict(meta or {})}
        with open(self.path, "w") as fh:
            json.dump(doc, fh)
        return self.path


def load_trace(path: str) -> Dict[str, Any]:
    """Load + structurally validate a trace-event JSON file."""
    with open(path) as fh:
        doc = json.load(fh)
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError(f"{path}: no traceEvents array — not a Chrome "
                         f"trace-event JSON file")
    for ev in events:
        if not (isinstance(ev, dict) and ev.get("ph") in (BEGIN, END)
                and "name" in ev and "ts" in ev):
            raise ValueError(f"{path}: malformed trace event {ev!r}")
    return doc


def self_times(events: List[Dict[str, Any]]
               ) -> Tuple[Dict[str, float], Dict[int, Dict[str, float]]]:
    """Replay a B/E event stream with *exclusive* (self-time) attribution.

    Walks the events in order keeping the open-span stack; every interval
    between consecutive events is attributed to the span on top of the
    stack — exactly the accounting ``_Timer`` does live.  Returns
    ``(totals, per_round)``: exclusive seconds per span name over the whole
    stream, and per ``round`` span (keyed by its ``args.round``) the
    exclusive seconds of the phases nested inside it.
    """
    totals: Dict[str, float] = {}
    per_round: Dict[int, Dict[str, float]] = {}
    stack: List[Tuple[str, Optional[int]]] = []   # (name, round-id context)
    cur_round: Optional[int] = None
    last_ts: Optional[float] = None
    for ev in sorted(events, key=lambda e: e["ts"]):
        t = float(ev["ts"]) / 1e6
        if last_ts is not None and stack:
            name = stack[-1][0]
            dt = t - last_ts
            totals[name] = totals.get(name, 0.0) + dt
            if cur_round is not None and name != "round":
                bucket = per_round.setdefault(cur_round, {})
                bucket[name] = bucket.get(name, 0.0) + dt
        last_ts = t
        if ev["ph"] == BEGIN:
            if ev["name"] == "round":
                cur_round = ev.get("args", {}).get("round")
                if cur_round is not None:
                    per_round.setdefault(int(cur_round), {})
            stack.append((ev["name"], cur_round))
        else:
            if not stack or stack[-1][0] != ev["name"]:
                raise ValueError(
                    f"unbalanced trace: E({ev['name']!r}) at ts={ev['ts']} "
                    f"does not match open span "
                    f"{stack[-1][0] if stack else None!r}")
            stack.pop()
            if ev["name"] == "round":
                cur_round = None
    return totals, per_round


def verify_trace(path: str, report, *, atol: float = 2e-3) -> Dict[str, Any]:
    """Prove a saved trace telescopes to its run's phase accounting.

    Checks (raising ``ChromeTraceError`` on violation):

    * the file is valid trace-event JSON with balanced spans;
    * whole-run exclusive self-times per phase match the run summary's
      ``timers_s`` within ``atol`` seconds;
    * per round, the phase spans nested in that round's ``round`` span sum
      to the v2 ``phase.*`` gauges within ``atol``.

    ``atol`` covers float64 round-off of the µs conversion plus timer
    resolution; the timestamps themselves are shared with the timers, so
    observed error is orders of magnitude below it.
    """
    doc = load_trace(path)
    totals, per_round = self_times(doc["traceEvents"])
    summary_timers = report.summary.get("timers_s", {})
    checked = 0
    for name, want in summary_timers.items():
        got = totals.get(name, 0.0)
        if not math.isclose(got, want, rel_tol=1e-6, abs_tol=atol):
            raise ChromeTraceError(
                f"trace self-time for {name!r} is {got:.6f}s but the run "
                f"summary recorded {want:.6f}s")
        checked += 1
    rounds_checked = 0
    for rec in report.rounds:
        rnd = rec["round"]
        phases = {k: v for k, v in rec["gauges"].items()
                  if k.startswith("phase.")}
        if not phases:
            continue
        got_round = per_round.get(rnd, {})
        for name, want in phases.items():
            got = got_round.get(name, 0.0)
            if not math.isclose(got, want, rel_tol=1e-6, abs_tol=atol):
                raise ChromeTraceError(
                    f"round {rnd}: trace spans for {name!r} sum to "
                    f"{got:.6f}s but the gauge recorded {want:.6f}s")
        rounds_checked += 1
    return {"events": len(doc["traceEvents"]), "timers_checked": checked,
            "rounds_checked": rounds_checked}
