"""Online run-health monitors over the telemetry round stream.

Long federated runs fail *quietly*: the loop keeps turning while accuracy
bleeds, β mass collapses onto one survivor, the adaptive controller thrashes
between rungs, or every cohort comes back empty.  ``HealthMonitors``
watches the constant-size round digests the hub builds at ``end_round``
(mode-agnostic — full and sketch runs produce the same digest) and emits
schema'd **health records** on rising edges, plus a run-end **verdict**
surfaced by the console sink, ``run-report``, and benchmark exit codes.

Detectors (each gated by ``HealthConfig``):

* ``acc_drawdown``     evaluated accuracy fell more than ``acc_drawdown``
                       below its running max (same definition as
                       ``repro.fl.metrics.accuracy_drawdown``), after
                       ``acc_warmup_evals`` evaluations;
* ``beta_collapse``    β effective sample size (the ``beta_ess`` gauge,
                       (Σβ)²/Σβ²) stayed below ``beta_ess_frac`` of the
                       round's client rows for ``beta_streak`` consecutive
                       aggregating rounds — the aggregation view's "one
                       client is the model now" failure;
* ``rung_thrash``      the adaptive controller's ``rung_churn`` gauge
                       (fraction of clients whose assigned rung changed)
                       exceeded ``rung_churn_max`` for ``rung_streak``
                       consecutive rounds;
* ``cap_drift``        the controller's mean capacity estimate drifted more
                       than ``cap_drift_factor``× away from its running
                       median baseline — link collapse or estimator
                       divergence;
* ``distortion_spike`` the round's mean upload distortion jumped more than
                       ``distortion_spike``× (and ``distortion_min_jump``
                       absolute) above the running median of past rounds;
* ``empty_cohort``     ``empty_streak`` consecutive rounds aggregated
                       nothing;
* ``eviction_streak``  ``eviction_streak`` consecutive rounds evicted
                       buffered uploads.

Monitors are **observational** and edge-triggered: an alarm fires when a
condition becomes true and re-arms only after the condition clears, so a
ten-round blackout is one record per detector, not ten.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

from repro.obs.telemetry import AGGREGATED, EVICTED


@dataclass
class HealthConfig:
    """Thresholds for the online detectors; defaults are calibrated to stay
    silent on the committed healthy scenario baselines while firing on the
    seeded ``blackout`` fault-injection world."""

    acc_drawdown: float = 0.2          # drop below running-max accuracy
    acc_warmup_evals: int = 2          # evals before drawdown is armed
    beta_ess_frac: float = 0.12        # ESS / client rows considered collapse
    beta_min_rows: int = 4             # rounds with fewer rows can't collapse
    beta_streak: int = 2               # consecutive collapsed rounds to fire
    rung_churn_max: float = 0.5        # fraction of clients switching rungs
    rung_streak: int = 3               # consecutive thrashing rounds to fire
    cap_drift_factor: float = 8.0      # ×-fold drift from the running median
    cap_warmup_rounds: int = 3         # estimates before drift is armed
    distortion_spike: float = 3.0      # ×-fold jump over the running median
    distortion_min_jump: float = 0.1   # and at least this absolute jump
    empty_streak: int = 3              # consecutive zero-participant rounds
    eviction_streak: int = 3           # consecutive rounds with evictions


def health_record(rnd: int, monitor: str, value: float, threshold: float,
                  message: str) -> Dict[str, Any]:
    """One schema'd health event (the NDJSON ``health`` record payload)."""
    return {"round": int(rnd), "monitor": str(monitor),
            "severity": "alarm", "value": float(value),
            "threshold": float(threshold), "message": str(message)}


class _Median:
    """Running median over a small stream (one value per round — O(rounds)
    state, which the telemetry budget already carries)."""

    def __init__(self):
        self.values: List[float] = []

    def push(self, v: float) -> None:
        self.values.append(float(v))

    def get(self) -> Optional[float]:
        if not self.values:
            return None
        vs = sorted(self.values)
        n = len(vs)
        mid = n // 2
        return vs[mid] if n % 2 else 0.5 * (vs[mid - 1] + vs[mid])


class HealthMonitors:
    """Stateful online detectors; feed one round digest at a time."""

    def __init__(self, config: Optional[HealthConfig] = None):
        self.config = config or HealthConfig()
        self.records: List[Dict[str, Any]] = []
        self.rounds_seen = 0
        self._active: set = set()          # monitors currently in alarm
        self._acc_max = -math.inf
        self._acc_evals = 0
        self._beta_low = 0
        self._churn_high = 0
        self._cap_median = _Median()
        self._dist_median = _Median()
        self._empty = 0
        self._evict = 0

    # ------------------------------------------------------------ plumbing
    def _edge(self, out: List[Dict], monitor: str, firing: bool,
              rnd: int, value: float, threshold: float, message: str
              ) -> None:
        """Edge-triggered emission: record on False→True, re-arm on
        True→False."""
        if firing and monitor not in self._active:
            self._active.add(monitor)
            out.append(health_record(rnd, monitor, value, threshold,
                                     message))
        elif not firing:
            self._active.discard(monitor)

    # -------------------------------------------------------------- observe
    def observe_round(self, digest: Dict[str, Any]) -> List[Dict[str, Any]]:
        """Consume one round digest; return the health records (if any)
        that fired this round."""
        cfg = self.config
        out: List[Dict[str, Any]] = []
        rnd = digest["round"]
        gauges = digest.get("gauges", {})
        counts = digest.get("counts", {})
        self.rounds_seen += 1

        # accuracy drawdown from the running max, after warmup evals
        acc = digest.get("eval_acc")
        if acc is not None:
            self._acc_evals += 1
            self._acc_max = max(self._acc_max, float(acc))
            drawdown = self._acc_max - float(acc)
            armed = self._acc_evals > cfg.acc_warmup_evals
            self._edge(out, "acc_drawdown",
                       armed and drawdown > cfg.acc_drawdown, rnd,
                       drawdown, cfg.acc_drawdown,
                       f"accuracy {acc:.4f} is {drawdown:.4f} below its "
                       f"running max {self._acc_max:.4f}")

        # β-mass concentration collapse (ESS far below the row count)
        ess = digest.get("beta_ess")
        beta_n = digest.get("beta_n") or 0
        if ess is not None and beta_n >= cfg.beta_min_rows:
            frac = float(ess) / beta_n
            self._beta_low = (self._beta_low + 1
                              if frac < cfg.beta_ess_frac else 0)
            self._edge(out, "beta_collapse",
                       self._beta_low >= cfg.beta_streak, rnd,
                       frac, cfg.beta_ess_frac,
                       f"β effective sample size {ess:.2f} of {beta_n} "
                       f"client rows ({frac:.2f} < {cfg.beta_ess_frac}) "
                       f"for {self._beta_low} rounds")

        # adaptive-controller rung thrash
        churn = gauges.get("rung_churn")
        if churn is not None:
            self._churn_high = (self._churn_high + 1
                                if churn > cfg.rung_churn_max else 0)
            self._edge(out, "rung_thrash",
                       self._churn_high >= cfg.rung_streak, rnd,
                       churn, cfg.rung_churn_max,
                       f"{churn:.0%} of clients switched codec rungs, "
                       f"{self._churn_high} rounds running")

        # capacity-estimate drift vs the running median baseline
        cap = gauges.get("cap_hat_mean_bps")
        if cap is not None and cap > 0:
            base = self._cap_median.get()
            armed = len(self._cap_median.values) >= cfg.cap_warmup_rounds
            if armed and base is not None and base > 0:
                ratio = max(cap / base, base / cap)
                self._edge(out, "cap_drift",
                           ratio > cfg.cap_drift_factor, rnd,
                           ratio, cfg.cap_drift_factor,
                           f"mean capacity estimate {cap / 1e6:.2f} Mbps is "
                           f"{ratio:.1f}× away from its running median "
                           f"{base / 1e6:.2f} Mbps")
            self._cap_median.push(cap)

        # distortion spike over the running median of round means
        dist = digest.get("distortion_mean")
        if dist is not None:
            base = self._dist_median.get()
            if base is not None:
                jump = float(dist) - base
                firing = (dist > base * cfg.distortion_spike
                          and jump > cfg.distortion_min_jump)
                self._edge(out, "distortion_spike", firing, rnd,
                           float(dist), base * cfg.distortion_spike,
                           f"round mean distortion {dist:.3f} vs running "
                           f"median {base:.3f}")
            self._dist_median.push(float(dist))

        # empty-cohort and eviction streaks
        participants = digest.get("participants")
        if participants is None:
            participants = counts.get(AGGREGATED, 0)
        self._empty = self._empty + 1 if participants == 0 else 0
        self._edge(out, "empty_cohort", self._empty >= cfg.empty_streak,
                   rnd, self._empty, cfg.empty_streak,
                   f"{self._empty} consecutive rounds aggregated nothing")

        evicted = counts.get(EVICTED, 0)
        self._evict = self._evict + 1 if evicted > 0 else 0
        self._edge(out, "eviction_streak",
                   self._evict >= cfg.eviction_streak, rnd,
                   self._evict, cfg.eviction_streak,
                   f"evictions in {self._evict} consecutive rounds")

        self.records.extend(out)
        return out

    # -------------------------------------------------------------- verdict
    def verdict(self) -> Dict[str, Any]:
        """Run-end health verdict (the ``run_end`` record's ``health``
        section): healthy iff no detector ever fired."""
        by_monitor: Dict[str, int] = {}
        for rec in self.records:
            by_monitor[rec["monitor"]] = by_monitor.get(rec["monitor"], 0) + 1
        return {"healthy": not self.records,
                "n_alarms": len(self.records),
                "by_monitor": by_monitor,
                "first_alarm_round": (self.records[0]["round"]
                                      if self.records else None),
                "rounds_seen": self.rounds_seen}
