"""Mamba2 block (SSD — state-space duality, chunked algorithm).

Recurrence per head (state n = ssm_state_size, head dim dh):
    h_t = a_t * h_{t-1} + dt_t * (x_t ⊗ B_t),   y_t = C_t · h_t + D * x_t
with a_t = exp(-dt_t * exp(A_log)).

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
term within chunks of size Q, linear state carry between chunks via
``lax.scan`` — O(S·Q) compute, O(1) state, never materializes (S,S) or a
per-step (S, dh, n) tensor. Decode is the plain one-step recurrence.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense, dense_init, rmsnorm, rmsnorm_init


class MambaCache(NamedTuple):
    h: jax.Array        # (B, H, dh, n) fp32 SSM state
    conv: jax.Array     # (B, w-1, d_in) conv tail
    length: jax.Array   # () int32


def mamba2_init(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    H = cfg.ssm_num_heads or cfg.num_heads
    n = cfg.ssm_state_size
    ks = jax.random.split(key, 4)
    return {
        # order: [z (d_in), x (d_in), B (n), C (n), dt (H)]
        "in_proj": dense_init(ks[0], d, 2 * d_in + 2 * n + H, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, d_in)) * 0.1).astype(dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": rmsnorm_init(d_in, dtype),
        "out_proj": dense_init(ks[2], d_in, d, dtype),
    }


def _split_proj(p, cfg, x):
    d_in = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_num_heads or cfg.num_heads
    n = cfg.ssm_state_size
    zxbcd = dense(p["in_proj"], x)
    z, xi, Bm, Cm, dt = jnp.split(zxbcd, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (B,S,H)
    return z, xi, Bm, Cm, dt


def _causal_conv(p, xi, tail=None):
    """Depthwise causal conv. xi: (B,S,d_in); tail: (B,w-1,d_in) or None."""
    w = p["conv_w"].shape[0]
    if tail is None:
        tail = jnp.zeros((xi.shape[0], w - 1, xi.shape[2]), xi.dtype)
    xpad = jnp.concatenate([tail, xi], axis=1)
    out = sum(xpad[:, i:i + xi.shape[1]] * p["conv_w"][i] for i in range(w))
    new_tail = xpad[:, xpad.shape[1] - (w - 1):]
    return jax.nn.silu(out), new_tail


def _ssd_chunked(xh, Bm, Cm, dt, A_log, h0, chunk: int):
    """xh: (B,S,H,dh); Bm/Cm: (B,S,n); dt: (B,S,H); h0: (B,H,dh,n) fp32.
    Returns (y (B,S,H,dh) fp32, h_end)."""
    B, S, H, dh = xh.shape
    n = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    a_log = -dt * jnp.exp(A_log)[None, None, :]                      # (B,S,H) = log a_t
    xdt = xh.astype(jnp.float32) * dt[..., None]                     # (B,S,H,dh)

    def reshape_c(t, extra):
        return t.reshape((B, nc, Q) + extra).transpose((1, 0, 2) + tuple(range(3, 3 + len(extra))))

    xs = (reshape_c(xdt, (H, dh)), reshape_c(Bm.astype(jnp.float32), (n,)),
          reshape_c(Cm.astype(jnp.float32), (n,)), reshape_c(a_log, (H,)))

    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def body(h, xs_c):
        xdt_c, B_c, C_c, la_c = xs_c                                 # (B,Q,...)
        cums = jnp.cumsum(la_c, axis=1)                              # (B,Q,H)
        # intra-chunk: y[t] += sum_{s<=t} exp(cums_t - cums_s) (C_t.B_s) xdt_s
        Lm = jnp.exp(cums[:, :, None, :] - cums[:, None, :, :])      # (B,Q,Q,H)
        Lm = jnp.where(tri[None, :, :, None], Lm, 0.0)
        CB = jnp.einsum("bqn,bsn->bqs", C_c, B_c)                    # (B,Q,Q)
        W = CB[..., None] * Lm                                       # (B,Q,Q,H)
        y = jnp.einsum("bqsh,bshd->bqhd", W, xdt_c)
        # inter-chunk: y[t] += exp(cums_t) C_t . h
        dec = jnp.exp(cums)                                          # (B,Q,H)
        y = y + jnp.einsum("bqn,bqh,bhdn->bqhd", C_c, dec, h)
        # state update
        dec_end = jnp.exp(cums[:, -1:, :] - cums)                    # (B,Q,H)
        h_new = jnp.exp(cums[:, -1])[:, :, None, None] * h + \
            jnp.einsum("bqh,bqn,bqhd->bhdn", dec_end, B_c, xdt_c)
        return h_new, y

    h_end, ys = jax.lax.scan(body, h0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dh)
    return y, h_end


def mamba2_forward(p, cfg: ModelConfig, x, chunk: int = 256):
    """x: (B,S,d) -> (B,S,d). Training / prefill."""
    B, S, d = x.shape
    d_in = cfg.ssm_expand * d
    H = cfg.ssm_num_heads or cfg.num_heads
    dh = d_in // H
    z, xi, Bm, Cm, dt = _split_proj(p, cfg, x)
    xi, _ = _causal_conv(p, xi)
    xh = xi.reshape(B, S, H, dh)
    h0 = jnp.zeros((B, H, dh, cfg.ssm_state_size), jnp.float32)
    y, _ = _ssd_chunked(xh, Bm, Cm, dt, p["A_log"], h0, chunk)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, d_in).astype(x.dtype) * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    return dense(p["out_proj"], y)


def mamba2_init_cache(cfg: ModelConfig, batch: int, dtype) -> MambaCache:
    d_in = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_num_heads or cfg.num_heads
    dh = d_in // H
    return MambaCache(
        h=jnp.zeros((batch, H, dh, cfg.ssm_state_size), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, d_in), dtype),
        length=jnp.zeros((), jnp.int32))


def mamba2_decode(p, cfg: ModelConfig, x, cache: MambaCache):
    """x: (B,1,d); one-step recurrence."""
    B, _, d = x.shape
    d_in = cfg.ssm_expand * d
    H = cfg.ssm_num_heads or cfg.num_heads
    dh = d_in // H
    z, xi, Bm, Cm, dt = _split_proj(p, cfg, x)
    xi, new_tail = _causal_conv(p, xi, cache.conv)
    xh = xi.reshape(B, H, dh).astype(jnp.float32)
    dt1 = dt[:, 0]                                                   # (B,H)
    a = jnp.exp(-dt1 * jnp.exp(p["A_log"])[None, :])                 # (B,H)
    u = jnp.einsum("bhd,bn->bhdn", xh * dt1[..., None], Bm[:, 0].astype(jnp.float32))
    h = a[:, :, None, None] * cache.h + u
    y = jnp.einsum("bhdn,bn->bhd", h, Cm[:, 0].astype(jnp.float32))
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, 1, d_in).astype(x.dtype) * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    return dense(p["out_proj"], y), MambaCache(h=h, conv=new_tail, length=cache.length + 1)
