"""Unified model zoo: one functional Transformer covering all 10 assigned
architectures (dense GQA, MLA+MoE, SWA, qk-norm, GeGLU, Mamba2 hybrid,
xLSTM, enc-dec audio, VLM-with-stub-frontend).

Layout decisions (see DESIGN.md §5):
  * Homogeneous stacks (all big archs) are ``lax.scan`` over stacked layer
    params with per-layer ``jax.checkpoint`` — small HLO, fast compiles,
    remat keeps live activations to one layer input per layer.
  * Heterogeneous patterns (xlstm, zamba2 — small models) use a Python loop.
  * zamba2's SHARED_ATTN positions all reuse one shared param set.

API:
  init_params(key, cfg)               -> pytree
  forward(params, cfg, batch)         -> (loss, metrics)        # train
  hidden_states(params, cfg, batch)   -> (B,S,d)                # backbone out
  init_decode_state(params, cfg, batch, cache_len) -> state
  decode_step(params, cfg, state, tokens (B,1)) -> (logits (B,V), state)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, MAMBA2, MLSTM, SLSTM, SHARED_ATTN, ModelConfig
from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import moe as moe_mod
from repro.models import ssm, xlstm
from repro.models.layers import constrain, embed_init, rmsnorm, rmsnorm_init
from repro.models.loss import chunked_cross_entropy

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------
def _layer_uses_moe(cfg: ModelConfig, layer_idx: int) -> bool:
    return cfg.moe and layer_idx >= cfg.first_k_dense


def block_init(key, cfg: ModelConfig, kind: str, dtype, *, use_moe: bool,
               cross: bool = False):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if kind in (ATTN, SHARED_ATTN):
        p = {"ln1": rmsnorm_init(d, dtype), "attn": attn.attn_init(ks[0], cfg, dtype),
             "ln2": rmsnorm_init(d, dtype)}
        if use_moe:
            p["moe"] = moe_mod.moe_init(ks[1], cfg, dtype)
        else:
            p["ffn"] = ffn_mod.ffn_init(ks[1], cfg, dtype)
        if cross:
            p["ln_cross"] = rmsnorm_init(d, dtype)
            p["cross"] = attn.cross_attn_init(ks[2], cfg, dtype)
        return p
    if kind == MAMBA2:
        return {"ln1": rmsnorm_init(d, dtype), "mamba": ssm.mamba2_init(ks[0], cfg, dtype)}
    if kind == MLSTM:
        return {"ln1": rmsnorm_init(d, dtype), "mlstm": xlstm.mlstm_init(ks[0], cfg, dtype)}
    if kind == SLSTM:
        return {"ln1": rmsnorm_init(d, dtype), "slstm": xlstm.slstm_init(ks[0], cfg, dtype)}
    raise ValueError(kind)


def block_forward(p, cfg: ModelConfig, kind: str, x, positions, *,
                  enc_out=None, causal: bool = True, q_chunk: int = 2048):
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind in (ATTN, SHARED_ATTN):
        if cfg.mla:
            a = attn.mla_forward(p["attn"], cfg, h, positions, q_chunk=q_chunk)
        else:
            a = attn.gqa_forward(p["attn"], cfg, h, positions, causal=causal,
                                 q_chunk=q_chunk)
        x = x + a
        if "cross" in p:
            hc = rmsnorm(p["ln_cross"], x, cfg.norm_eps)
            x = x + attn.cross_attn_forward(p["cross"], cfg, hc, enc_out, q_chunk=q_chunk)
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if "moe" in p:
            f, aux = moe_mod.moe_forward(p["moe"], cfg, h2)
        else:
            f = ffn_mod.ffn_forward(p["ffn"], cfg, h2)
        return x + f, aux
    if kind == MAMBA2:
        return x + ssm.mamba2_forward(p["mamba"], cfg, h), aux
    if kind == MLSTM:
        return x + xlstm.mlstm_forward(p["mlstm"], cfg, h), aux
    if kind == SLSTM:
        return x + xlstm.slstm_forward(p["slstm"], cfg, h), aux
    raise ValueError(kind)


def block_decode(p, cfg: ModelConfig, kind: str, x, cache, *, enc_out=None):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind in (ATTN, SHARED_ATTN):
        if cfg.mla:
            a, cache = attn.mla_decode(p["attn"], cfg, h, cache)
        else:
            a, cache = attn.gqa_decode(p["attn"], cfg, h, cache)
        x = x + a
        if "cross" in p:
            hc = rmsnorm(p["ln_cross"], x, cfg.norm_eps)
            x = x + attn.cross_attn_forward(p["cross"], cfg, hc, enc_out, q_chunk=2048)
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if "moe" in p:
            f, _ = moe_mod.moe_forward(p["moe"], cfg, h2)
        else:
            f = ffn_mod.ffn_forward(p["ffn"], cfg, h2)
        return x + f, cache
    if kind == MAMBA2:
        y, cache = ssm.mamba2_decode(p["mamba"], cfg, h, cache)
    elif kind == MLSTM:
        y, cache = xlstm.mlstm_decode(p["mlstm"], cfg, h, cache)
    elif kind == SLSTM:
        y, cache = xlstm.slstm_decode(p["slstm"], cfg, h, cache)
    else:
        raise ValueError(kind)
    return x + y, cache


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------
def _is_homogeneous(cfg: ModelConfig) -> bool:
    return cfg.block_pattern is None


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    params: Params = {"embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
                      "final_norm": rmsnorm_init(cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(keys[1], cfg.vocab_size, cfg.d_model, dtype)

    cross = cfg.encoder_decoder
    if _is_homogeneous(cfg):
        n_scan = cfg.num_layers - cfg.first_k_dense
        for i in range(cfg.first_k_dense):
            params[f"dense_layer_{i}"] = block_init(
                jax.random.fold_in(keys[2], i), cfg, ATTN, dtype, use_moe=False,
                cross=cross)
        lkeys = jax.random.split(keys[3], n_scan)
        params["layers"] = jax.vmap(
            lambda k: block_init(k, cfg, ATTN, dtype, use_moe=cfg.moe, cross=cross)
        )(lkeys)
        if cfg.encoder_decoder:
            ekeys = jax.random.split(keys[4], cfg.num_encoder_layers)
            params["enc_layers"] = jax.vmap(
                lambda k: block_init(k, cfg, ATTN, dtype, use_moe=False)
            )(ekeys)
            params["enc_norm"] = rmsnorm_init(cfg.d_model, dtype)
    else:
        kinds = cfg.layer_kinds()
        blocks = {}
        shared = None
        for i, kind in enumerate(kinds):
            bk = jax.random.fold_in(keys[2], i)
            if kind == SHARED_ATTN:
                if shared is None:
                    shared = block_init(bk, cfg, SHARED_ATTN, dtype, use_moe=False)
                continue
            blocks[str(i)] = block_init(bk, cfg, kind, dtype,
                                        use_moe=_layer_uses_moe(cfg, i))
        params["blocks"] = blocks
        if shared is not None:
            params["shared_attn_block"] = shared
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------
def _embed_tokens(params, cfg: ModelConfig, tokens):
    x = params["embed"]["embedding"][tokens]
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _lm_head_w(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"]["embedding"].T
    return params["lm_head"]["embedding"].T


def _scan_stack(stacked, cfg, x, positions, *, enc_out=None, causal=True,
                q_chunk, use_remat=True):
    def body(carry, layer_params):
        h, aux = carry
        h2, a = block_forward(layer_params, cfg, ATTN, h, positions,
                              enc_out=enc_out, causal=causal, q_chunk=q_chunk)
        return (h2, aux + a), None

    fn = jax.checkpoint(body) if use_remat else body
    (x, aux), _ = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)), stacked)
    return x, aux


def hidden_states(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
                  q_chunk: int = 2048, remat: bool = True):
    """Backbone forward. batch keys: tokens (B,St) int32; optional
    image_embeds (B,Ni,d); encoder_embeds (B,Se,d). Returns ((B,S,d), aux)."""
    tokens = batch["tokens"]
    x = _embed_tokens(params, cfg, tokens)
    if cfg.vision_frontend and "image_embeds" in batch:
        x = jnp.concatenate([batch["image_embeds"].astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = constrain(x, "batch", "seq", "embed")

    enc_out = None
    if cfg.encoder_decoder:
        e = batch["encoder_embeds"].astype(x.dtype)
        Be, Se, _ = e.shape
        epos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (Be, Se))
        e, _ = _scan_stack(params["enc_layers"], cfg, e, epos, causal=False,
                           q_chunk=q_chunk)
        enc_out = rmsnorm(params["enc_norm"], e, cfg.norm_eps)

    aux = jnp.zeros((), jnp.float32)
    if _is_homogeneous(cfg):
        for i in range(cfg.first_k_dense):
            x, a = block_forward(params[f"dense_layer_{i}"], cfg, ATTN, x, positions,
                                 enc_out=enc_out, q_chunk=q_chunk)
            aux += a
        x, a = _scan_stack(params["layers"], cfg, x, positions, enc_out=enc_out,
                           q_chunk=q_chunk, use_remat=remat)
        aux += a
    else:
        for i, kind in enumerate(cfg.layer_kinds()):
            p = params["shared_attn_block"] if kind == SHARED_ATTN else params["blocks"][str(i)]
            x, a = block_forward(p, cfg, kind, x, positions, q_chunk=q_chunk)
            aux += a
    return rmsnorm(params["final_norm"], x, cfg.norm_eps), aux


def forward(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            q_chunk: int = 2048, loss_chunk: int = 512, remat: bool = True):
    """Next-token LM loss. labels: (B, S_total) int32, negatives masked."""
    h, aux = hidden_states(params, cfg, batch, q_chunk=q_chunk, remat=remat)
    loss, cnt = chunked_cross_entropy(h, _lm_head_w(params, cfg), batch["labels"],
                                      chunk=loss_chunk)
    return loss + aux, {"ce_loss": loss, "aux_loss": aux, "target_tokens": cnt}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
def _init_block_cache(params_block, cfg: ModelConfig, kind: str, batch: int,
                      cache_len: int, dtype):
    if kind in (ATTN, SHARED_ATTN):
        if cfg.mla:
            return attn.mla_init_cache(cfg, batch, cache_len, dtype)
        return attn.gqa_init_cache(cfg, batch, cache_len, dtype)
    if kind == MAMBA2:
        return ssm.mamba2_init_cache(cfg, batch, dtype)
    if kind == MLSTM:
        return xlstm.mlstm_init_cache(cfg, batch)
    if kind == SLSTM:
        return xlstm.slstm_init_cache(cfg, batch)
    raise ValueError(kind)


def init_decode_state(params, cfg: ModelConfig, batch: int, cache_len: int,
                      encoder_embeds: Optional[jax.Array] = None):
    """Build the per-layer cache pytree (plus enc_out for enc-dec)."""
    dtype = jnp.dtype(cfg.dtype)
    state: Dict[str, Any] = {}
    if _is_homogeneous(cfg):
        n_scan = cfg.num_layers - cfg.first_k_dense
        one = _init_block_cache(None, cfg, ATTN, batch, cache_len, dtype)
        state["layers"] = jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (n_scan,) + t.shape).copy(), one)
        for i in range(cfg.first_k_dense):
            state[f"dense_layer_{i}"] = _init_block_cache(None, cfg, ATTN, batch,
                                                          cache_len, dtype)
    else:
        state["blocks"] = {
            str(i): _init_block_cache(None, cfg, kind, batch, cache_len, dtype)
            for i, kind in enumerate(cfg.layer_kinds())}
    if cfg.encoder_decoder:
        assert encoder_embeds is not None
        e = encoder_embeds.astype(dtype)
        Be, Se, _ = e.shape
        epos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (Be, Se))
        e, _ = _scan_stack(params["enc_layers"], cfg, e, epos, causal=False,
                           q_chunk=2048, use_remat=False)
        state["enc_out"] = rmsnorm(params["enc_norm"], e, cfg.norm_eps)
    return state


def decode_step(params, cfg: ModelConfig, state, tokens):
    """tokens: (B, 1) int32 -> (logits (B, V), new_state)."""
    x = _embed_tokens(params, cfg, tokens)
    enc_out = state.get("enc_out")
    if _is_homogeneous(cfg):
        for i in range(cfg.first_k_dense):
            x, c = block_decode(params[f"dense_layer_{i}"], cfg, ATTN, x,
                                state[f"dense_layer_{i}"], enc_out=enc_out)
            state = dict(state)
            state[f"dense_layer_{i}"] = c

        def body(h, xs):
            layer_params, layer_cache = xs
            h2, c2 = block_decode(layer_params, cfg, ATTN, h, layer_cache,
                                  enc_out=enc_out)
            return h2, c2

        x, new_caches = jax.lax.scan(body, x, (params["layers"], state["layers"]))
        state = dict(state)
        state["layers"] = new_caches
    else:
        state = dict(state, blocks=dict(state["blocks"]))
        for i, kind in enumerate(cfg.layer_kinds()):
            p = params["shared_attn_block"] if kind == SHARED_ATTN else params["blocks"][str(i)]
            x, c = block_decode(p, cfg, kind, x, state["blocks"][str(i)])
            state["blocks"][str(i)] = c
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = (h[:, 0] @ _lm_head_w(params, cfg)).astype(jnp.float32)
    logits = constrain(logits, "batch", "vocab")
    return logits, state
