"""Sequence-chunked cross-entropy.

With 100k–256k vocabularies, materializing (B, S, V) logits for train_4k
(256×4096×256000 ≈ 0.5 TB bf16) is impossible. We scan over sequence chunks,
computing logits → logsumexp → gold-logit per chunk, and ``jax.checkpoint``
the chunk body so backward recomputes chunk logits instead of saving them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import constrain


def chunked_cross_entropy(h, w, labels, *, chunk: int = 512):
    """h: (B,S,d); w: (d,V); labels: (B,S) int32, negative = masked.
    Returns (mean_loss, num_target_tokens)."""
    B, S, d = h.shape
    c = min(chunk, S)
    assert S % c == 0, (S, c)
    n = S // c
    hs = h.reshape(B, n, c, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, c).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, xs):
        hc, lc = xs                                    # (B,c,d), (B,c)
        logits = (hc @ w).astype(jnp.float32)          # (B,c,V)
        logits = constrain(logits, "batch", "seq", "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)        # (B,c)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        loss_sum, cnt = carry
        return (loss_sum + jnp.sum((lse - gold) * mask), cnt + jnp.sum(mask)), None

    (loss_sum, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                             jnp.zeros((), jnp.float32)), (hs, ls))
    return loss_sum / jnp.maximum(cnt, 1.0), cnt


def full_cross_entropy(logits, labels):
    """Reference implementation for tests: logits (B,S,V), labels (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
