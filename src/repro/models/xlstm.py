"""xLSTM blocks [arXiv:2405.04517]: sLSTM (scalar memory, strictly
sequential recurrence with exponential gating + stabilizer) and mLSTM
(matrix memory C = f C + i v kᵀ, parallel-queryable).

Both are implemented as ``lax.scan`` over time carrying O(1) state — the
sub-quadratic property that qualifies xlstm-125m for ``long_500k``.
(A chunked-parallel mLSTM is a recorded §Perf candidate.)
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense, dense_init, rmsnorm, rmsnorm_init


class MLSTMCache(NamedTuple):
    C: jax.Array      # (B, H, dh, dh) matrix memory
    n: jax.Array      # (B, H, dh) normalizer
    m: jax.Array      # (B, H) log-stabilizer
    length: jax.Array


class SLSTMCache(NamedTuple):
    c: jax.Array      # (B, d_in) cell
    n: jax.Array      # (B, d_in)
    h: jax.Array      # (B, d_in) hidden (recurrent input)
    m: jax.Array      # (B, d_in) stabilizer
    length: jax.Array


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_init(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    ks = jax.random.split(key, 7)
    return {
        "up": dense_init(ks[0], d, 2 * d_in, dtype),       # [x_inner, z-gate]
        "wq": dense_init(ks[1], d_in, d_in, dtype),
        "wk": dense_init(ks[2], d_in, d_in, dtype),
        "wv": dense_init(ks[3], d_in, d_in, dtype),
        "w_if": dense_init(ks[4], d_in, 2 * (cfg.ssm_num_heads or cfg.num_heads),
                           jnp.float32, bias=True),
        "norm": rmsnorm_init(d_in, dtype),
        "down": dense_init(ks[5], d_in, d, dtype),
    }


def _mlstm_step(carry, qkvif, dh):
    C, n, m = carry
    q, k, v, i_pre, f_pre = qkvif            # q,k,v: (B,H,dh); gates: (B,H)
    log_f = -jax.nn.softplus(-f_pre)         # log sigmoid(f)
    m_new = jnp.maximum(log_f + m, i_pre)
    i = jnp.exp(i_pre - m_new)
    f = jnp.exp(log_f + m - m_new)
    C = f[..., None, None] * C + i[..., None, None] * jnp.einsum("bhd,bhe->bhde", v, k)
    n = f[..., None] * n + i[..., None] * k
    num = jnp.einsum("bhde,bhe->bhd", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)), jnp.exp(-m_new))
    h = num / den[..., None]
    return (C, n, m_new), h


def _mlstm_qkvif(p, cfg, x):
    B, S, d = x.shape
    d_in = cfg.ssm_expand * d
    H = cfg.ssm_num_heads or cfg.num_heads
    dh = d_in // H
    xu = dense(p["up"], x)
    xi, z = jnp.split(xu, 2, axis=-1)
    q = dense(p["wq"], xi).reshape(B, S, H, dh).astype(jnp.float32) / math.sqrt(dh)
    k = dense(p["wk"], xi).reshape(B, S, H, dh).astype(jnp.float32) / math.sqrt(dh)
    v = dense(p["wv"], xi).reshape(B, S, H, dh).astype(jnp.float32)
    gif = dense(p["w_if"], xi).astype(jnp.float32).reshape(B, S, H, 2)
    return q, k, v, gif[..., 0], gif[..., 1], z, d_in, H, dh


def mlstm_forward(p, cfg: ModelConfig, x):
    B, S, d = x.shape
    q, k, v, i_pre, f_pre, z, d_in, H, dh = _mlstm_qkvif(p, cfg, x)
    carry = (jnp.zeros((B, H, dh, dh), jnp.float32),
             jnp.zeros((B, H, dh), jnp.float32),
             jnp.full((B, H), -1e30, jnp.float32))
    xs = jax.tree.map(lambda t: t.transpose(1, 0, 2, 3) if t.ndim == 4 else t.transpose(1, 0, 2),
                      (q, k, v, i_pre, f_pre))
    _, hs = jax.lax.scan(lambda c, xs_t: _mlstm_step(c, xs_t, dh), carry, xs)
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d_in).astype(x.dtype)
    h = h * jax.nn.silu(z)
    h = rmsnorm(p["norm"], h, cfg.norm_eps)
    return dense(p["down"], h)


def mlstm_init_cache(cfg: ModelConfig, batch: int) -> MLSTMCache:
    d_in = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_num_heads or cfg.num_heads
    dh = d_in // H
    return MLSTMCache(
        C=jnp.zeros((batch, H, dh, dh), jnp.float32),
        n=jnp.zeros((batch, H, dh), jnp.float32),
        m=jnp.full((batch, H), -1e30, jnp.float32),
        length=jnp.zeros((), jnp.int32))


def mlstm_decode(p, cfg: ModelConfig, x, cache: MLSTMCache):
    B = x.shape[0]
    q, k, v, i_pre, f_pre, z, d_in, H, dh = _mlstm_qkvif(p, cfg, x)
    (C, n, m), h = _mlstm_step((cache.C, cache.n, cache.m),
                               (q[:, 0], k[:, 0], v[:, 0], i_pre[:, 0], f_pre[:, 0]), dh)
    h = h.reshape(B, 1, d_in).astype(x.dtype) * jax.nn.silu(z)
    h = rmsnorm(p["norm"], h, cfg.norm_eps)
    return dense(p["down"], h), MLSTMCache(C=C, n=n, m=m, length=cache.length + 1)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_init(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    ks = jax.random.split(key, 6)
    return {
        "w_zifo": dense_init(ks[0], d, 4 * d_in, dtype, bias=True),
        "r_zifo": dense_init(ks[1], d_in, 4 * d_in, dtype),   # recurrent
        "norm": rmsnorm_init(d_in, dtype),
        "down": dense_init(ks[2], d_in, d, dtype),
    }


def _slstm_step(p, cfg, carry, x_t):
    """x_t: (B, 4*d_in) pre-projected input; carry: SLSTMCache w/o length."""
    c, n, h_prev, m = carry
    pre = (x_t + dense(p["r_zifo"], h_prev.astype(x_t.dtype))).astype(jnp.float32)
    z_pre, i_pre, f_pre, o_pre = jnp.split(pre, 4, axis=-1)
    log_f = -jax.nn.softplus(-f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i = jnp.exp(i_pre - m_new)
    f = jnp.exp(log_f + m - m_new)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_forward(p, cfg: ModelConfig, x):
    B, S, d = x.shape
    d_in = cfg.ssm_expand * d
    xp = dense(p["w_zifo"], x)                                  # (B,S,4*d_in)
    carry = (jnp.zeros((B, d_in), jnp.float32), jnp.zeros((B, d_in), jnp.float32),
             jnp.zeros((B, d_in), jnp.float32), jnp.full((B, d_in), -1e30, jnp.float32))
    _, hs = jax.lax.scan(lambda c, xt: _slstm_step(p, cfg, c, xt), carry,
                         xp.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(x.dtype)
    h = rmsnorm(p["norm"], h, cfg.norm_eps)
    return dense(p["down"], h)


def slstm_init_cache(cfg: ModelConfig, batch: int) -> SLSTMCache:
    d_in = cfg.ssm_expand * cfg.d_model
    zero = jnp.zeros((batch, d_in), jnp.float32)
    return SLSTMCache(c=zero, n=zero, h=zero,
                      m=jnp.full((batch, d_in), -1e30, jnp.float32),
                      length=jnp.zeros((), jnp.int32))


def slstm_decode(p, cfg: ModelConfig, x, cache: SLSTMCache):
    B = x.shape[0]
    xp = dense(p["w_zifo"], x)[:, 0]
    (c, n, h, m), h_out = _slstm_step(p, cfg, (cache.c, cache.n, cache.h, cache.m), xp)
    y = h_out[:, None, :].astype(x.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    return dense(p["down"], y), SLSTMCache(c=c, n=n, h=h, m=m, length=cache.length + 1)
