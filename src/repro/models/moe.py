"""Mixture-of-Experts block.

TPU-native design (see DESIGN.md §5): tokens stay sharded over the batch axes
and *replicated* over the tensor axis; experts are sharded over the tensor
('model') axis. Each model-shard selects the (token, k) pairs routed to its
local experts with a sort, runs grouped matmuls via ``jax.lax.ragged_dot``
(MXU-friendly, no one-hot dispatch tensors), scatter-adds into the output and
``psum``s over the tensor axis. No all-to-all is needed because activations
are already replicated across that axis — the psum doubles as the combine.

Two paths:
  * ``_moe_local``  — single device / GSPMD-auto fallback (also the oracle).
  * ``_moe_sharded`` — shard_map expert-parallel path, enabled when a
    MeshContext is installed and num_experts % model_axis_size == 0.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import dist
from repro.models.layers import dense_init, gelu


def moe_init(key, cfg: ModelConfig, dtype):
    d_ff = cfg.moe_d_ff or cfg.d_ff
    E = cfg.num_experts
    ks = jax.random.split(key, 6)
    scale = 1.0 / jnp.sqrt(cfg.d_model).astype(jnp.float32)
    p = {
        "router": dense_init(ks[0], cfg.d_model, E, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, cfg.d_model, d_ff)) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, cfg.d_model, d_ff)) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, d_ff, cfg.d_model))
                   * (1.0 / jnp.sqrt(d_ff))).astype(dtype),
    }
    if cfg.num_shared_experts:
        from repro.models.ffn import ffn_init
        p["shared"] = ffn_init(ks[4], cfg, dtype,
                               d_ff=(cfg.moe_d_ff or cfg.d_ff) * cfg.num_shared_experts)
    return p


def _activation(cfg, g, u):
    return (jax.nn.silu(g) if cfg.ffn_activation == "swiglu" else gelu(g)) * u


def _route(p, cfg: ModelConfig, x2d):
    """x2d: (T, d) -> (gates (T,k), eids (T,k) int32, aux_loss scalar)."""
    logits = (x2d.astype(jnp.float32) @ p["router"]["w"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, eids = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    gates = top_p / jnp.sum(top_p, axis=-1, keepdims=True)         # renormalize
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    E = cfg.num_experts
    me = jnp.mean(probs, axis=0)                                   # (E,)
    one_hot = jax.nn.one_hot(eids, E, dtype=jnp.float32)           # (T,k,E)
    fe = jnp.mean(jnp.sum(one_hot, axis=1), axis=0)                # (E,)
    aux = E * jnp.sum(fe * me)
    return gates, eids.astype(jnp.int32), aux


def _grouped_ffn(cfg, x_sel, w_gate, w_up, w_down, group_sizes):
    """x_sel: (R, d) rows grouped contiguously by expert; ragged matmuls."""
    g = jax.lax.ragged_dot(x_sel, w_gate, group_sizes)
    u = jax.lax.ragged_dot(x_sel, w_up, group_sizes)
    h = _activation(cfg, g, u)
    return jax.lax.ragged_dot(h, w_down, group_sizes)


def _sort_by_expert(eids_flat, num_buckets):
    """Returns (sorted_eids, perm) sorting (token,k) pairs by expert id."""
    T = eids_flat.shape[0]
    sorted_eids, perm = jax.lax.sort_key_val(eids_flat, jnp.arange(T, dtype=jnp.int32))
    return sorted_eids, perm


def _moe_local(p, cfg: ModelConfig, x2d):
    T, d = x2d.shape
    k, E = cfg.num_experts_per_tok, cfg.num_experts
    gates, eids, aux = _route(p, cfg, x2d)
    eflat = eids.reshape(T * k)
    gflat = gates.reshape(T * k)
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    se, perm = _sort_by_expert(eflat, E)
    tok_s, gate_s = tok[perm], gflat[perm]
    group_sizes = jnp.bincount(se, length=E).astype(jnp.int32)
    x_sel = x2d[tok_s]                                              # (T*k, d)
    y_sel = _grouped_ffn(cfg, x_sel, p["w_gate"], p["w_up"], p["w_down"], group_sizes)
    out = jnp.zeros_like(x2d).at[tok_s].add(
        (y_sel.astype(jnp.float32) * gate_s[:, None]).astype(x2d.dtype))
    return out, aux


def _moe_sharded_body(x, wr, wg, wu, wd, *, cfg: ModelConfig, ctx: dist.MeshContext,
                      capacity: int):
    """Per-device body under shard_map. x: (B_loc, S, d) replicated over the
    model axis; wg/wu/wd: local expert shards (E_loc, ...)."""
    B, S, d = x.shape
    T = B * S
    k = cfg.num_experts_per_tok
    E = cfg.num_experts
    E_loc = wg.shape[0]
    midx = jax.lax.axis_index(ctx.model_axis)
    x2d = x.reshape(T, d)
    gates, eids, aux = _route({"router": {"w": wr}}, cfg, x2d)
    eflat = eids.reshape(T * k)
    gflat = gates.reshape(T * k)
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    local = (eflat // E_loc) == midx
    local_eid = eflat - midx * E_loc
    # sort key: local expert id for local pairs, E_loc (sentinel) otherwise —
    # local pairs become a contiguous prefix grouped by local expert.
    key = jnp.where(local, local_eid, E_loc)
    sk, perm = jax.lax.sort_key_val(key, jnp.arange(T * k, dtype=jnp.int32))
    sk, perm = sk[:capacity], perm[:capacity]
    tok_s = tok[perm]
    gate_s = jnp.where(sk < E_loc, gflat[perm], 0.0)   # sentinel rows: weight 0
    eid_s = jnp.minimum(sk, E_loc - 1)                 # sentinel rows: run thru last expert
    group_sizes = jnp.bincount(eid_s, length=E_loc).astype(jnp.int32)
    x_sel = x2d[tok_s]
    y_sel = _grouped_ffn(cfg, x_sel, wg, wu, wd, group_sizes)
    out = jnp.zeros_like(x2d).at[tok_s].add(
        (y_sel.astype(jnp.float32) * gate_s[:, None]).astype(x2d.dtype))
    out = jax.lax.psum(out, ctx.model_axis)
    aux = jax.lax.pmean(aux, ctx.batch_axes)           # identical over model axis
    return out.reshape(B, S, d), aux


def _moe_sharded_body_virtual(x, wr, wg, wu, wd, *, cfg: ModelConfig,
                              ctx: dist.MeshContext, within: int,
                              capacity: int):
    """Virtual-expert body for num_experts < model-axis size (§Perf B):
    each real expert's FFN hidden dim is split over `within` shards; wg/wu
    arrive as (1, d, f/within) and wd as (1, f/within, d) local slices. The
    final psum over the model axis simultaneously reduces the partial-hidden
    sums (within an expert) and combines disjoint experts' tokens."""
    B, S, d = x.shape
    T = B * S
    k = cfg.num_experts_per_tok
    midx = jax.lax.axis_index(ctx.model_axis)
    real_e = midx // within
    x2d = x.reshape(T, d)
    gates, eids, aux = _route({"router": {"w": wr}}, cfg, x2d)
    eflat = eids.reshape(T * k)
    gflat = gates.reshape(T * k)
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    local = eflat == real_e
    # bring local pairs to a contiguous prefix, truncate at capacity
    key = jnp.where(local, 0, 1).astype(jnp.int32)
    sk, perm = jax.lax.sort_key_val(key, jnp.arange(T * k, dtype=jnp.int32))
    sk, perm = sk[:capacity], perm[:capacity]
    tok_s = tok[perm]
    gate_s = jnp.where(sk == 0, gflat[perm], 0.0)
    x_sel = x2d[tok_s]                                   # (cap, d)
    g = x_sel @ wg[0]                                    # (cap, f/within)
    u = x_sel @ wu[0]
    y_sel = _activation(cfg, g, u) @ wd[0]               # partial over hidden
    out = jnp.zeros_like(x2d).at[tok_s].add(
        (y_sel.astype(jnp.float32) * gate_s[:, None]).astype(x2d.dtype))
    out = jax.lax.psum(out, ctx.model_axis)
    aux = jax.lax.pmean(aux, ctx.batch_axes)
    return out.reshape(B, S, d), aux


def moe_forward(p, cfg: ModelConfig, x) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B,S,d), aux_loss scalar). Adds shared experts."""
    B, S, d = x.shape
    ctx = dist.get_mesh_context()
    E = cfg.num_experts
    ms = ctx.model_size if ctx is not None else 0
    d_ff = cfg.moe_d_ff or cfg.d_ff
    bspec = P(ctx.batch_axes, None, None) if ctx else None
    m = ctx.model_axis if ctx else None
    if ctx is not None and E % ms == 0 and (B % ctx.batch_size == 0):
        E_loc = E // ms
        T_loc = (B // ctx.batch_size) * S
        # expected local load = T_loc*k*E_loc/E, scaled by the capacity
        # factor (default 2x), clamped to all pairs
        capacity = min(T_loc * cfg.num_experts_per_tok,
                       int(cfg.moe_capacity_factor * T_loc *
                           cfg.num_experts_per_tok * E_loc / E) + 64)
        body = functools.partial(_moe_sharded_body, cfg=cfg, ctx=ctx,
                                 capacity=capacity)
        out, aux = dist.shard_map(
            body, mesh=ctx.mesh,
            in_specs=(bspec, P(None, None), P(m, None, None),
                      P(m, None, None), P(m, None, None)),
            out_specs=(bspec, P()),
            check_vma=False,
        )(x, p["router"]["w"], p["w_gate"], p["w_up"], p["w_down"])
    elif (ctx is not None and ms % E == 0 and d_ff % (ms // E) == 0
          and B % ctx.batch_size == 0):
        # virtual experts: E real experts × (ms/E) hidden slices (§Perf B)
        within = ms // E
        T_loc = (B // ctx.batch_size) * S
        capacity = min(T_loc * cfg.num_experts_per_tok,
                       int(cfg.moe_capacity_factor * T_loc *
                           cfg.num_experts_per_tok / E) + 64)
        f_loc = d_ff // within
        wg = p["w_gate"].reshape(E, cfg.d_model, within, f_loc) \
            .transpose(0, 2, 1, 3).reshape(E * within, cfg.d_model, f_loc)
        wu = p["w_up"].reshape(E, cfg.d_model, within, f_loc) \
            .transpose(0, 2, 1, 3).reshape(E * within, cfg.d_model, f_loc)
        wd = p["w_down"].reshape(E, within, f_loc, cfg.d_model) \
            .reshape(E * within, f_loc, cfg.d_model)
        body = functools.partial(_moe_sharded_body_virtual, cfg=cfg, ctx=ctx,
                                 within=within, capacity=capacity)
        out, aux = dist.shard_map(
            body, mesh=ctx.mesh,
            in_specs=(bspec, P(None, None), P(m, None, None),
                      P(m, None, None), P(m, None, None)),
            out_specs=(bspec, P()),
            check_vma=False,
        )(x, p["router"]["w"], wg, wu, wd)
    else:
        out2d, aux = _moe_local(p, cfg, x.reshape(B * S, d))
        out = out2d.reshape(B, S, d)
    if cfg.num_shared_experts:
        from repro.models.ffn import ffn_forward
        out = out + ffn_forward(p["shared"], cfg, x)
    return out, aux * cfg.router_aux_loss_coef
