from repro.models import transformer, vision  # noqa: F401
from repro.models.transformer import (  # noqa: F401
    decode_step,
    forward,
    hidden_states,
    init_decode_state,
    init_params,
)
