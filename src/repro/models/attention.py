"""Attention: GQA (with RoPE / qk-norm / sliding-window / bias), MLA
(DeepSeek-V2 multi-head latent attention with absorbed decode), and
cross-attention for the enc-dec arch.

Two execution paths:
  * XLA path (default, portable): einsum attention with optional
    query-chunking so 32k prefill never materializes (S, S) score tensors.
  * Pallas path (TPU target): repro.kernels.flash_attention /
    decode_attention — selected by ``repro.kernels.ops.use_pallas()``.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import dist
from repro.models.layers import apply_rope, constrain, dense, dense_init, rmsnorm, rmsnorm_init

NEG_INF = -1e30


class KVCache(NamedTuple):
    """Ring-buffer KV cache. For SWA archs ``k.shape[1]`` is the window."""
    k: jax.Array          # (B, S_cache, KV, hd)  — MLA: c_kv (B, S, lora)
    v: jax.Array          # (B, S_cache, KV, hd)  — MLA: k_rope (B, S, rope_hd)
    length: jax.Array     # (), int32: tokens seen so far


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def attn_init(key, cfg: ModelConfig, dtype):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 8)
    if cfg.mla:
        qh = cfg.mla_nope_head_dim + cfg.mla_rope_head_dim
        return {
            "q_down": dense_init(ks[0], cfg.d_model, cfg.mla_q_lora_rank, dtype),
            "q_norm": rmsnorm_init(cfg.mla_q_lora_rank, dtype),
            "q_up": dense_init(ks[1], cfg.mla_q_lora_rank, cfg.num_heads * qh, dtype),
            "kv_down": dense_init(
                ks[2], cfg.d_model, cfg.mla_kv_lora_rank + cfg.mla_rope_head_dim, dtype),
            "kv_norm": rmsnorm_init(cfg.mla_kv_lora_rank, dtype),
            "kv_up": dense_init(
                ks[3], cfg.mla_kv_lora_rank,
                cfg.num_heads * (cfg.mla_nope_head_dim + cfg.mla_v_head_dim), dtype),
            "wo": dense_init(ks[4], cfg.num_heads * cfg.mla_v_head_dim, cfg.d_model, dtype),
        }
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.num_heads * hd, dtype, bias=cfg.attn_bias),
        "wk": dense_init(ks[1], cfg.d_model, cfg.num_kv_heads * hd, dtype, bias=cfg.attn_bias),
        "wv": dense_init(ks[2], cfg.d_model, cfg.num_kv_heads * hd, dtype, bias=cfg.attn_bias),
        "wo": dense_init(ks[3], cfg.num_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def cross_attn_init(key, cfg: ModelConfig, dtype):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.num_heads * hd, dtype, bias=cfg.attn_bias),
        "wk": dense_init(ks[1], cfg.d_model, cfg.num_kv_heads * hd, dtype, bias=cfg.attn_bias),
        "wv": dense_init(ks[2], cfg.d_model, cfg.num_kv_heads * hd, dtype, bias=cfg.attn_bias),
        "wo": dense_init(ks[3], cfg.num_heads * hd, cfg.d_model, dtype),
    }


# ---------------------------------------------------------------------------
# core scaled-dot-product with GQA + chunked queries (XLA path)
# ---------------------------------------------------------------------------
def _sdpa(q, k, v, *, causal: bool, window: Optional[int], q_offset,
          scale: float, q_chunk: int = 2048):
    """q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd). q_offset: absolute position of q[0]
    minus position of k[0] (for caches/chunks). Returns (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    vd = v.shape[-1]                     # may differ from hd (MLA)
    groups = H // KV

    def attend(qc, off):
        # qc: (B, C, H, hd) -> scores (B, KV, groups, C, Sk)
        qg = qc.reshape(B, qc.shape[1], KV, groups, hd)
        s = jnp.einsum("bckgh,bskh->bkgcs", qg.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        qpos = off + jnp.arange(qc.shape[1])[:, None]     # (C,1) absolute q pos
        kpos = jnp.arange(Sk)[None, :]                    # (1,Sk)
        mask = jnp.ones((qc.shape[1], Sk), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgcs,bskh->bckgh", p, v.astype(jnp.float32))
        return o.reshape(B, qc.shape[1], H, vd).astype(q.dtype)

    if Sq <= q_chunk:
        return attend(q, q_offset)
    assert Sq % q_chunk == 0, (Sq, q_chunk)
    n = Sq // q_chunk
    qs = q.reshape(B, n, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    offs = q_offset + jnp.arange(n) * q_chunk

    def body(_, xs):
        qc, off = xs
        return None, attend(qc, off)

    _, out = jax.lax.scan(body, None, (qs, offs))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, vd)


# ---------------------------------------------------------------------------
# GQA forward (prefill / train)
# ---------------------------------------------------------------------------
def gqa_forward(p, cfg: ModelConfig, x, positions, *, causal=True,
                q_chunk: int = 2048):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = dense(p["wq"], x).reshape(B, S, cfg.num_heads, hd)
    k = dense(p["wk"], x).reshape(B, S, cfg.num_kv_heads, hd)
    v = dense(p["wv"], x).reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads", None)
    from repro.kernels import ops as kops
    if kops.use_pallas():
        o = kops.flash_attention(q, k, v, causal=causal, window=cfg.sliding_window)
    else:
        o = _sdpa(q, k, v, causal=causal, window=cfg.sliding_window,
                  q_offset=0, scale=1.0 / math.sqrt(hd), q_chunk=q_chunk)
    o = constrain(o, "batch", "seq", "heads", None)
    return dense(p["wo"], o.reshape(B, S, cfg.num_heads * hd))


def cross_attn_forward(p, cfg: ModelConfig, x, enc_out, q_chunk: int = 2048):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = dense(p["wq"], x).reshape(B, S, cfg.num_heads, hd)
    k = dense(p["wk"], enc_out).reshape(B, enc_out.shape[1], cfg.num_kv_heads, hd)
    v = dense(p["wv"], enc_out).reshape(B, enc_out.shape[1], cfg.num_kv_heads, hd)
    o = _sdpa(q, k, v, causal=False, window=None, q_offset=0,
              scale=1.0 / math.sqrt(hd), q_chunk=q_chunk)
    return dense(p["wo"], o.reshape(B, S, cfg.num_heads * hd))


# ---------------------------------------------------------------------------
# GQA decode (1 token against ring-buffer cache)
#
# When num_kv_heads < model-axis size, a head-sharded cache is impossible and
# GSPMD falls back to all-gathering the multi-GB cache every step (measured:
# 60 GB/step on qwen3 decode_32k — EXPERIMENTS.md §Perf A). The production
# path instead SEQUENCE-shards the cache over the model axis and runs a
# distributed flash combine (local partial softmax + tiny psum of per-head
# stats) inside shard_map.
# ---------------------------------------------------------------------------
def _use_seq_sharded_cache(cfg: ModelConfig, cache_len: int, batch: int):
    from repro.models import dist
    ctx = dist.get_mesh_context()
    if ctx is None:
        return None
    ms = ctx.model_size
    if cfg.num_kv_heads % ms == 0:       # head sharding works — keep it
        return None
    if cache_len % ms != 0:
        return None
    if batch % ctx.batch_size != 0 and batch != 1:
        return None
    return ctx


def _gqa_decode_core_seq_sharded(ctx, cfg: ModelConfig, q, k_new, v_new,
                                 cache: KVCache, window):
    """q: (B,1,H,hd); k_new/v_new: (B,1,KV,hd); cache.k/v seq-sharded over
    the model axis. Returns (o (B,1,H,hd), new_cache)."""
    import functools as _ft
    from jax.sharding import PartitionSpec as P

    B = q.shape[0]
    S = cache.k.shape[1]
    ms = ctx.model_size
    S_loc = S // ms
    hd = q.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    m_ax = ctx.model_axis
    b_ax = ctx.batch_axes if B % ctx.batch_size == 0 else ()
    bspec = (b_ax if len(b_ax) > 1 else (b_ax[0] if b_ax else None))

    def body(q_, kn, vn, ck, cv, pos):
        midx = jax.lax.axis_index(m_ax)
        slot = pos % S
        local_start = midx * S_loc
        in_shard = (slot >= local_start) & (slot < local_start + S_loc)
        off = jnp.where(in_shard, slot - local_start, 0)
        ck_upd = jax.lax.dynamic_update_slice(ck, kn.astype(ck.dtype), (0, off, 0, 0))
        cv_upd = jax.lax.dynamic_update_slice(cv, vn.astype(cv.dtype), (0, off, 0, 0))
        ck = jnp.where(in_shard, ck_upd, ck)
        cv = jnp.where(in_shard, cv_upd, cv)
        # validity of local ring-buffer slots (global positions)
        kpos = local_start + jnp.arange(S_loc)
        abs_pos = jnp.where(kpos <= slot, pos - slot + kpos, pos - slot - S + kpos)
        ok = abs_pos >= 0
        if window is not None:
            ok &= abs_pos > pos - window
        KV = ck.shape[2]
        g = q_.shape[2] // KV
        bloc = q_.shape[0]
        qg = q_.reshape(bloc, KV, g, hd).astype(jnp.float32)
        s = jnp.einsum("bkgh,bskh->bkgs", qg, ck.astype(jnp.float32)) * scale
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        m_loc = jnp.max(s, axis=-1)                         # (b,KV,g)
        m_glob = jax.lax.pmax(m_loc, m_ax)
        p = jnp.exp(s - m_glob[..., None])
        l_loc = jnp.sum(p, axis=-1)
        o_loc = jnp.einsum("bkgs,bskh->bkgh", p, cv.astype(jnp.float32))
        l_glob = jax.lax.psum(l_loc, m_ax)
        o_glob = jax.lax.psum(o_loc, m_ax)
        o = o_glob / jnp.maximum(l_glob, 1e-30)[..., None]
        o = o.reshape(bloc, 1, q_.shape[2], hd).astype(q_.dtype)
        return o, ck, cv

    cache_spec = P(bspec, m_ax, None, None)
    rep4 = P(bspec, None, None, None)
    o, ck, cv = dist.shard_map(
        body, mesh=ctx.mesh,
        in_specs=(rep4, rep4, rep4, cache_spec, cache_spec, P()),
        out_specs=(rep4, cache_spec, cache_spec),
        check_vma=False,
    )(q, k_new, v_new, cache.k, cache.v, cache.length)
    return o, KVCache(k=ck, v=cv, length=cache.length + 1)



def gqa_init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype) -> KVCache:
    hd = cfg.resolved_head_dim
    S = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    shape = (batch, S, cfg.num_kv_heads, hd)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   length=jnp.zeros((), jnp.int32))


def gqa_decode(p, cfg: ModelConfig, x, cache: KVCache):
    """x: (B, 1, d). Returns (out, new_cache)."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    pos = cache.length                                   # scalar absolute pos
    q = dense(p["wq"], x).reshape(B, 1, cfg.num_heads, hd)
    k = dense(p["wk"], x).reshape(B, 1, cfg.num_kv_heads, hd)
    v = dense(p["wv"], x).reshape(B, 1, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    posb = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q, posb, cfg.rope_theta)
    k = apply_rope(k, posb, cfg.rope_theta)
    S = cache.k.shape[1]
    ctx = _use_seq_sharded_cache(cfg, S, B)
    if ctx is not None:
        # PERF (EXPERIMENTS.md §Perf A): seq-sharded cache + distributed
        # flash combine — avoids GSPMD's full cache all-gather when
        # num_kv_heads < model-axis size.
        o, new_cache = _gqa_decode_core_seq_sharded(
            ctx, cfg, q, k, v, cache, cfg.sliding_window)
        out = dense(p["wo"], o.reshape(B, 1, cfg.num_heads * hd))
        return out, new_cache
    slot = pos % S                                       # ring-buffer slot
    # PERF (EXPERIMENTS.md §Perf A, iteration 1 — kept): force the 1-token
    # k/v update onto the cache's head layout BEFORE the in-place write.
    # Batch axis left unpinned: constraining it on B=1 decode (long_500k)
    # made GSPMD rematerialize the cache (measured 4× regression).
    k = constrain(k, None, None, "kv_cache_heads", None)
    v = constrain(v, None, None, "kv_cache_heads", None)
    ck = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, slot, 0, 0))
    kpos = jnp.arange(S)
    # absolute position currently stored in each slot of the ring buffer
    abs_pos = jnp.where(kpos <= slot, pos - slot + kpos, pos - slot - S + kpos)
    valid = abs_pos >= 0
    if cfg.sliding_window:
        valid &= abs_pos > pos - cfg.sliding_window
    from repro.kernels import ops as kops
    groups = cfg.num_heads // cfg.num_kv_heads
    if kops.use_pallas():
        o = kops.decode_attention(q, ck, cv, valid, scale=1.0 / math.sqrt(hd))
    else:
        qg = q.reshape(B, cfg.num_kv_heads, groups, hd)
        s = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                       ck.astype(jnp.float32)) / math.sqrt(hd)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgs,bskh->bkgh", w, cv.astype(jnp.float32))
        o = o.reshape(B, 1, cfg.num_heads, hd).astype(x.dtype)
    out = dense(p["wo"], o.reshape(B, 1, cfg.num_heads * hd))
    return out, KVCache(k=ck, v=cv, length=pos + 1)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------
def _mla_project_q(p, cfg, x, B, S):
    q = dense(p["q_down"], x)
    q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
    qh = cfg.mla_nope_head_dim + cfg.mla_rope_head_dim
    q = dense(p["q_up"], q).reshape(B, S, cfg.num_heads, qh)
    return jnp.split(q, [cfg.mla_nope_head_dim], axis=-1)   # nope, rope


def mla_forward(p, cfg: ModelConfig, x, positions, q_chunk: int = 2048):
    """Training/prefill MLA: expand the latent, run standard attention."""
    B, S, _ = x.shape
    nh, nd, rd, vd = cfg.num_heads, cfg.mla_nope_head_dim, cfg.mla_rope_head_dim, cfg.mla_v_head_dim
    q_nope, q_rope = _mla_project_q(p, cfg, x, B, S)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kv = dense(p["kv_down"], x)
    c_kv, k_rope = jnp.split(kv, [cfg.mla_kv_lora_rank], axis=-1)
    c_kv = rmsnorm(p["kv_norm"], c_kv, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,rd)
    kvu = dense(p["kv_up"], c_kv).reshape(B, S, nh, nd + vd)
    k_nope, v = jnp.split(kvu, [nd], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, nh, rd))], axis=-1)
    q = constrain(q, "batch", "seq", "heads", None)
    scale = 1.0 / math.sqrt(nd + rd)
    o = _sdpa(q, k, v, causal=True, window=cfg.sliding_window, q_offset=0,
              scale=scale, q_chunk=q_chunk)
    return dense(p["wo"], o.reshape(B, S, nh * vd))


def mla_init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, seq_len, cfg.mla_kv_lora_rank), dtype),   # c_kv
        v=jnp.zeros((batch, seq_len, cfg.mla_rope_head_dim), dtype),  # k_rope
        length=jnp.zeros((), jnp.int32))


def mla_decode(p, cfg: ModelConfig, x, cache: KVCache):
    """Absorbed MLA decode: score via latent space, never expand the cache."""
    B = x.shape[0]
    nh, nd, rd, vd = cfg.num_heads, cfg.mla_nope_head_dim, cfg.mla_rope_head_dim, cfg.mla_v_head_dim
    lora = cfg.mla_kv_lora_rank
    pos = cache.length
    q_nope, q_rope = _mla_project_q(p, cfg, x, B, 1)
    posb = jnp.full((B, 1), pos, jnp.int32)
    q_rope = apply_rope(q_rope, posb, cfg.rope_theta)       # (B,1,H,rd)
    kv = dense(p["kv_down"], x)                             # (B,1,lora+rd)
    c_kv, k_rope = jnp.split(kv, [lora], axis=-1)
    c_kv = rmsnorm(p["kv_norm"], c_kv, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], posb, cfg.rope_theta)[:, :, 0, :]
    ck = jax.lax.dynamic_update_slice(cache.k, c_kv.astype(cache.k.dtype), (0, pos, 0))
    cr = jax.lax.dynamic_update_slice(cache.v, k_rope.astype(cache.v.dtype), (0, pos, 0))
    # absorb kv_up into the query:  q_c[h] = W_uk[h]^T q_nope[h]
    w_uk = p["kv_up"]["w"].reshape(lora, nh, nd + vd)[:, :, :nd]      # (lora,H,nd)
    w_uv = p["kv_up"]["w"].reshape(lora, nh, nd + vd)[:, :, nd:]      # (lora,H,vd)
    q_c = jnp.einsum("bhn,lhn->bhl", q_nope[:, 0].astype(jnp.float32),
                     w_uk.astype(jnp.float32))                         # (B,H,lora)
    s = jnp.einsum("bhl,bsl->bhs", q_c, ck.astype(jnp.float32))
    s = s + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(jnp.float32),
                       cr.astype(jnp.float32))
    s = s / math.sqrt(nd + rd)
    kpos = jnp.arange(cache.k.shape[1])
    s = jnp.where((kpos <= pos)[None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsl->bhl", w, ck.astype(jnp.float32))      # (B,H,lora)
    o = jnp.einsum("bhl,lhv->bhv", o_lat, w_uv.astype(jnp.float32))    # (B,H,vd)
    o = o.reshape(B, 1, nh * vd).astype(x.dtype)
    out = dense(p["wo"], o)
    return out, KVCache(k=ck, v=cr, length=pos + 1)
