"""Shared low-level layers: norms, RoPE, initializers, sharding constraints.

Everything is functional: ``init_*`` returns a param dict, ``apply``-style
functions are pure. Models are dtype-polymorphic; params are created in
``config.dtype`` and math runs in that dtype with fp32 accumulation where it
matters (norms, softmax, losses).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# Logical-axis sharding constraints.
#
# Models annotate activations with *logical* axes; the launcher installs a
# mapping logical -> mesh axes. When no mesh is active the constraint is a
# no-op, so all model code runs unchanged on a single CPU device.
# ---------------------------------------------------------------------------
_LOGICAL_RULES: dict = {}


def set_logical_rules(rules: dict) -> None:
    """rules: logical axis name -> mesh axis (str, tuple of str, or None)."""
    global _LOGICAL_RULES
    _LOGICAL_RULES = dict(rules)


def clear_logical_rules() -> None:
    set_logical_rules({})


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Apply with_sharding_constraint mapped through the logical rules."""
    if not _LOGICAL_RULES:
        return x
    spec = P(*[_LOGICAL_RULES.get(a) if a is not None else None for a in logical_axes])
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x  # no ambient mesh — single-device execution


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype, bias: bool = False,
               scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def embed_init(key, vocab: int, d: int, dtype):
    return {"embedding": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


# ---------------------------------------------------------------------------
# Norms (fp32 internal)
# ---------------------------------------------------------------------------
def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, head_dim); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                 # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    angles = angles[..., None, :]                              # (..., S, 1, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)
