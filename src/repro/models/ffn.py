"""Feed-forward variants: SwiGLU / GeGLU (gated) and plain GELU MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import constrain, dense, dense_init, gelu


def ffn_init(key, cfg: ModelConfig, dtype, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.ffn_activation in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], cfg.d_model, d_ff, dtype),
            "w_up": dense_init(ks[1], cfg.d_model, d_ff, dtype),
            "w_down": dense_init(ks[2], d_ff, cfg.d_model, dtype),
        }
    return {  # plain MLP (starcoder2 / seamless style, with bias)
        "w_up": dense_init(ks[0], cfg.d_model, d_ff, dtype, bias=True),
        "w_down": dense_init(ks[1], d_ff, cfg.d_model, dtype, bias=True),
    }


def ffn_forward(p, cfg: ModelConfig, x):
    if cfg.ffn_activation in ("swiglu", "geglu"):
        g = dense(p["w_gate"], x)
        u = dense(p["w_up"], x)
        act = jax.nn.silu(g) if cfg.ffn_activation == "swiglu" else gelu(g)
        h = act * u
        h = constrain(h, "batch", "seq", "mlp")
        return dense(p["w_down"], h)
    h = gelu(dense(p["w_up"], x))
    h = constrain(h, "batch", "seq", "mlp")
    return dense(p["w_down"], h)
