"""The paper's experiment models (Appendix III-C): small CNN (MNIST),
ResNet-GN (CIFAR-10), ResNet18-GN (CIFAR-100), and a ViT classifier that is
LoRA-fine-tuned in the partial-parameter experiments.

Functional style: ``make_model(name, num_classes, image_size, channels)``
returns ``(init_fn(key) -> params, apply_fn(params, images) -> logits)``.
GroupNorm (not BatchNorm) everywhere, matching the paper's FL-friendly choice.
"""
from __future__ import annotations

import math
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_init, layernorm, layernorm_init


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------
def conv_init(key, kh, kw, cin, cout, dtype=jnp.float32):
    fan_in = kh * kw * cin
    w = jax.random.normal(key, (kh, kw, cin, cout)) * math.sqrt(2.0 / fan_in)
    return {"w": w.astype(dtype), "b": jnp.zeros((cout,), dtype)}


def conv(p, x, stride=1, padding="SAME"):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def groupnorm_init(c, dtype=jnp.float32):
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}


def groupnorm(p, x, groups, eps=1e-5):
    B, H, W, C = x.shape
    g = min(groups, C)
    xg = x.reshape(B, H, W, g, C // g).astype(jnp.float32)
    mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return (xg.reshape(B, H, W, C) * p["scale"] + p["bias"]).astype(x.dtype)


def maxpool(x, k=2, s=2):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, k, k, 1),
                                 (1, s, s, 1), "VALID")


# ---------------------------------------------------------------------------
# CNN (Table 9)
# ---------------------------------------------------------------------------
def cnn_init(key, num_classes, image_size, channels):
    ks = jax.random.split(key, 4)
    flat = (image_size // 4) ** 2 * 32
    return {
        "conv1": conv_init(ks[0], 5, 5, channels, 16), "gn1": groupnorm_init(16),
        "conv2": conv_init(ks[1], 5, 5, 16, 32), "gn2": groupnorm_init(32),
        "fc1": dense_init(ks[2], flat, 128, jnp.float32, bias=True),
        "fc2": dense_init(ks[3], 128, num_classes, jnp.float32, bias=True),
    }


def cnn_apply(p, x):
    x = maxpool(jax.nn.relu(groupnorm(p["gn1"], conv(p["conv1"], x), 4)))
    x = maxpool(jax.nn.relu(groupnorm(p["gn2"], conv(p["conv2"], x), 4)))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(dense(p["fc1"], x))
    return dense(p["fc2"], x)


# ---------------------------------------------------------------------------
# ResNet-GN (Tables 11 / 12)
# ---------------------------------------------------------------------------
def _basic_block_init(key, cin, cout, stride):
    ks = jax.random.split(key, 3)
    p = {"conv1": conv_init(ks[0], 3, 3, cin, cout), "gn1": groupnorm_init(cout),
         "conv2": conv_init(ks[1], 3, 3, cout, cout), "gn2": groupnorm_init(cout)}
    if stride != 1 or cin != cout:
        p["proj"] = conv_init(ks[2], 1, 1, cin, cout)
    return p


def _basic_block_apply(p, x, stride, groups):
    h = jax.nn.relu(groupnorm(p["gn1"], conv(p["conv1"], x, stride), groups))
    h = groupnorm(p["gn2"], conv(p["conv2"], h), groups)
    sc = conv(p["proj"], x, stride) if "proj" in p else x
    return jax.nn.relu(h + sc)


def resnet_init(key, num_classes, image_size, channels, *, stages, widths, groups):
    ks = jax.random.split(key, 2 + sum(stages))
    p = {"stem": conv_init(ks[0], 3, 3, channels, widths[0]),
         "gn0": groupnorm_init(widths[0])}
    i = 1
    cin = widths[0]
    for s, (n, w) in enumerate(zip(stages, widths)):
        for b in range(n):
            stride = 2 if (b == 0 and s > 0) else 1
            p[f"s{s}b{b}"] = _basic_block_init(ks[i], cin, w, stride)
            cin = w
            i += 1
    p["fc"] = dense_init(ks[i], cin, num_classes, jnp.float32, bias=True)
    return p


def resnet_apply(p, x, *, stages, widths, groups):
    x = jax.nn.relu(groupnorm(p["gn0"], conv(p["stem"], x), groups[0]))
    for s, (n, w) in enumerate(zip(stages, widths)):
        for b in range(n):
            stride = 2 if (b == 0 and s > 0) else 1
            x = _basic_block_apply(p[f"s{s}b{b}"], x, stride, groups[s])
    x = jnp.mean(x, axis=(1, 2))
    return dense(p["fc"], x)


# ---------------------------------------------------------------------------
# ViT classifier (Table 10, reduced-scale by default)
# ---------------------------------------------------------------------------
def vit_init(key, num_classes, image_size, channels, *, patch=4, d=192,
             depth=6, heads=3, mlp_ratio=4):
    ks = jax.random.split(key, 4 + depth)
    n_patches = (image_size // patch) ** 2
    p = {
        "patch": dense_init(ks[0], patch * patch * channels, d, jnp.float32, bias=True),
        "pos": jax.random.normal(ks[1], (1, n_patches + 1, d)) * 0.02,
        "cls": jnp.zeros((1, 1, d)),
        "head": dense_init(ks[2], d, num_classes, jnp.float32, bias=True),
        "ln_f": layernorm_init(d, jnp.float32),
    }
    for i in range(depth):
        bs = jax.random.split(ks[3 + i], 4)
        p[f"blk{i}"] = {
            "ln1": layernorm_init(d, jnp.float32),
            "qkv": dense_init(bs[0], d, 3 * d, jnp.float32, bias=True),
            "proj": dense_init(bs[1], d, d, jnp.float32, bias=True),
            "ln2": layernorm_init(d, jnp.float32),
            "fc1": dense_init(bs[2], d, mlp_ratio * d, jnp.float32, bias=True),
            "fc2": dense_init(bs[3], mlp_ratio * d, d, jnp.float32, bias=True),
        }
    return p


def vit_apply(p, x, *, patch=4, heads=3, depth=6):
    B, H, W, C = x.shape
    xp = x.reshape(B, H // patch, patch, W // patch, patch, C)
    xp = xp.transpose(0, 1, 3, 2, 4, 5).reshape(B, -1, patch * patch * C)
    h = dense(p["patch"], xp)
    h = jnp.concatenate([jnp.broadcast_to(p["cls"], (B, 1, h.shape[-1])), h], axis=1)
    h = h + p["pos"]
    d = h.shape[-1]
    hd = d // heads
    for i in range(depth):
        blk = p[f"blk{i}"]
        hn = layernorm(blk["ln1"], h)
        qkv = dense(blk["qkv"], hn).reshape(B, -1, 3, heads, hd)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(B, -1, d)
        h = h + dense(blk["proj"], o)
        hn = layernorm(blk["ln2"], h)
        h = h + dense(blk["fc2"], jax.nn.gelu(dense(blk["fc1"], hn)))
    h = layernorm(p["ln_f"], h)
    return dense(p["head"], h[:, 0])


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def make_model(name: str, num_classes: int, image_size: int,
               channels: int) -> Tuple[Callable, Callable]:
    if name == "cnn":
        return (lambda k: cnn_init(k, num_classes, image_size, channels), cnn_apply)
    if name == "resnet":        # paper's 0.27M CIFAR-10 ResNet
        kw = dict(stages=(3, 3, 3), widths=(16, 32, 64), groups=(4, 8, 16))
        return (lambda k: resnet_init(k, num_classes, image_size, channels, **kw),
                lambda p, x: resnet_apply(p, x, **kw))
    if name == "resnet18":      # paper's 11M CIFAR-100 ResNet-18
        kw = dict(stages=(2, 2, 2, 2), widths=(64, 128, 256, 512),
                  groups=(32, 32, 32, 32))
        return (lambda k: resnet_init(k, num_classes, image_size, channels, **kw),
                lambda p, x: resnet_apply(p, x, **kw))
    if name == "vit":           # reduced-scale stand-in for ViT-B/16 + LoRA
        kw = dict(patch=4, heads=3, depth=6)
        return (lambda k: vit_init(k, num_classes, image_size, channels,
                                   d=192, depth=6, heads=3),
                lambda p, x: vit_apply(p, x, **kw))
    raise ValueError(name)
