"""Distributed execution context for model code.

The launcher installs a mesh + axis-role mapping here; model code (the MoE
block) queries it to decide between the single-device path and the
expert-parallel ``shard_map`` path. When nothing is installed models run as
plain single-device JAX.
"""
from __future__ import annotations

import contextlib
import dataclasses
import inspect
from typing import Optional, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class MeshContext:
    mesh: jax.sharding.Mesh
    batch_axes: Tuple[str, ...]      # e.g. ('pod', 'data') or ('data',)
    model_axis: str                  # tensor/expert-parallel axis, e.g. 'model'

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    @property
    def batch_size(self) -> int:
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n


# jax.shard_map landed after 0.4.x (jax.experimental.shard_map before), and
# its replication-check kwarg was renamed check_rep -> check_vma separately,
# so detect the kwarg from the signature rather than from which import won.
try:
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map
try:
    _CHECK_KW = ("check_vma" if "check_vma" in
                 inspect.signature(_shard_map).parameters else "check_rep")
except (TypeError, ValueError):
    _CHECK_KW = "check_vma"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check_vma})


# jax.set_mesh landed after 0.4.x; jax.sharding.use_mesh briefly preceded
# it, and on 0.4.x the Mesh object itself is the activating context manager.
set_mesh = getattr(jax, "set_mesh",
                   getattr(jax.sharding, "use_mesh", lambda m: m))


_CTX: Optional[MeshContext] = None


def set_mesh_context(ctx: Optional[MeshContext]) -> None:
    global _CTX
    _CTX = ctx


def get_mesh_context() -> Optional[MeshContext]:
    return _CTX


@contextlib.contextmanager
def mesh_context(ctx: Optional[MeshContext]):
    prev = _CTX
    set_mesh_context(ctx)
    try:
        yield
    finally:
        set_mesh_context(prev)
