"""Distributed execution context for model code.

The launcher installs a mesh + axis-role mapping here; model code (the MoE
block) queries it to decide between the single-device path and the
expert-parallel ``shard_map`` path. When nothing is installed models run as
plain single-device JAX.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class MeshContext:
    mesh: jax.sharding.Mesh
    batch_axes: Tuple[str, ...]      # e.g. ('pod', 'data') or ('data',)
    model_axis: str                  # tensor/expert-parallel axis, e.g. 'model'

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    @property
    def batch_size(self) -> int:
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n


_CTX: Optional[MeshContext] = None


def set_mesh_context(ctx: Optional[MeshContext]) -> None:
    global _CTX
    _CTX = ctx


def get_mesh_context() -> Optional[MeshContext]:
    return _CTX


@contextlib.contextmanager
def mesh_context(ctx: Optional[MeshContext]):
    prev = _CTX
    set_mesh_context(ctx)
    try:
        yield
    finally:
        set_mesh_context(prev)
