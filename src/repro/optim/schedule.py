"""Learning-rate schedules. The paper uses step decay at round 4000 (Tab 13)."""
from __future__ import annotations

import math


def constant(lr: float):
    return lambda step: lr


def step_decay(lr: float, boundary: int, factor: float = 0.1):
    """Paper Table 13: 0.1 for r <= 4000 then 0.01."""
    return lambda step: lr * (factor if step > boundary else 1.0)


def warmup_cosine(lr: float, warmup: int, total: int, floor: float = 0.0):
    def f(step):
        if step < warmup:
            return lr * (step + 1) / warmup
        frac = (step - warmup) / max(total - warmup, 1)
        return floor + 0.5 * (lr - floor) * (1 + math.cos(math.pi * min(frac, 1.0)))
    return f
