"""Plain / momentum SGD over pytrees (the paper fine-tunes with SGD, Eq. 2-3)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd_init(params, momentum: float = 0.0):
    if momentum == 0.0:
        return {}
    return {"mu": jax.tree.map(jnp.zeros_like, params)}


def sgd_update(params, grads, state, lr, momentum: float = 0.0,
               weight_decay: float = 0.0):
    if weight_decay:
        grads = jax.tree.map(lambda g, p: g + weight_decay * p.astype(g.dtype),
                             grads, params)
    if momentum == 0.0:
        new = jax.tree.map(lambda p, g: (p - lr * g.astype(jnp.float32)).astype(p.dtype),
                           params, grads)
        return new, state
    mu = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype),
                      state["mu"], grads)
    new = jax.tree.map(lambda p, m: (p - lr * m).astype(p.dtype), params, mu)
    return new, {"mu": mu}
