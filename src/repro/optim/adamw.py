"""AdamW over pytrees (used by server pre-training and the LLM examples)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
            "t": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8,
                 weight_decay=0.01):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                     state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) *
                     jnp.square(g.astype(jnp.float32)), state["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, m_, v_):
        upd_ = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        return (p - lr * (upd_ + weight_decay * p.astype(jnp.float32))).astype(p.dtype)

    return jax.tree.map(upd, params, m, v), {"m": m, "v": v, "t": t}
