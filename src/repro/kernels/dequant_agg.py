"""Pallas TPU kernels: batched decode-and-accumulate over packed uploads.

The kernel family behind the streaming aggregation server — every rung of
the comm ladder has a batched form that takes K packed payloads plus β
weights and produces ONE fp32 accumulator pass, so K arrivals never
materialize K fp32 delta pytrees:

    dequant_fedagg  int8-family rungs (``sign1``/``qsgd:<bits>``/``int8``):
                    out[p] = Σ_m β_m · s_m · q[m, p]
    float_fedagg    fp16/fp32 rungs: out[p] = Σ_m β_m · x[m, p], fp32 out
    topk_fedagg     sparse top-k rungs — β-weighted scatter-add; dynamic
                    index scatter is XLA's territory on TPU, so it lives in
                    ``kernels.ref`` and every dispatch mode shares it

Each fuses ``fedagg`` (Eq. 7) with server-side payload decode: instead of
materializing M float32 participant vectors (4 bytes/param) and then
reducing them, the packed payloads stream HBM→VMEM *once at wire width*
(1 byte/param for int8, 2 for fp16) and decode in-tile — up to 4× less HBM
traffic on a purely memory-bound op, exactly the regime the aggregation
server lives in at 10k+ arrivals/round.  Mixed-rung cohorts batch per rung
family and add the per-family partial sums into one shared accumulator
(``repro.fl.comm.stream.StreamAccumulator``).

β and the per-participant dequant scales collapse into one coefficient
c_m = β_m·s_m before the kernel, so the inner loop is a single scaled
reduction over the participant axis.

Tiling: the flat parameter axis P is tiled into (32, BP) VMEM blocks —
int8's minimum sublane tile is 32 (vs 16 for fp16 and 8 for fp32; 32 is a
common multiple, shared by both kernels) — with the participant axis M
whole inside the block: an (M, 32, BP) int8 tile is M·BP·32 bytes (≤ 1.5 MB
VMEM for M=22, BP=2048), the (32, BP) fp32 accumulator 256 kB.  The 1-D
grid over P-tiles lets the Pallas pipeline double-buffer the payload
stream: tile i+1's HBM→VMEM copy overlaps tile i's decode+reduce.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE_I8 = 32     # int8 minimum sublane tile (fp16's 16, fp32's 8 divide it)


def _kernel(coef_ref, q_ref, o_ref):
    # coef: (M, 1) fp32 = β·scale (β alone for float payloads);
    # q: (M, SUBLANE_I8, BP) int8/fp16/fp32; o: (SUBLANE_I8, BP) fp32 —
    # decode in-tile, reduce over M.
    q = q_ref[...].astype(jnp.float32)
    c = coef_ref[...]                              # (M, 1)
    o_ref[...] = jnp.sum(q * c[:, :, None], axis=0)


def _coef_reduce(x: jax.Array, coef: jax.Array, *, block: int,
                 interpret: bool) -> jax.Array:
    """Shared host-side wrapper: pad/tile the (M, P) payload matrix and run
    the coefficient-weighted in-tile decode+reduce, (P,) fp32 out."""
    M, P = x.shape
    rows = SUBLANE_I8 * block
    P_pad = ((P + rows - 1) // rows) * rows
    if P_pad != P:
        x = jnp.pad(x, ((0, 0), (0, P_pad - P)))
    x3 = x.reshape(M, P_pad // block, block)
    n_rows = x3.shape[1]
    grid = (n_rows // SUBLANE_I8,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((M, 1), lambda i: (0, 0)),
            pl.BlockSpec((M, SUBLANE_I8, block), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((SUBLANE_I8, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rows, block), jnp.float32),
        interpret=interpret,
    )(coef, x3)
    return out.reshape(P_pad)[:P]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dequant_fedagg(q: jax.Array, scales: jax.Array, betas: jax.Array, *,
                   block: int = 2048, interpret: bool = False) -> jax.Array:
    """q: (M, P) int8; scales, betas: (M,) -> (P,) fp32 = Σ_m β_m s_m q[m]."""
    M = q.shape[0]
    coef = (betas.astype(jnp.float32) *
            scales.astype(jnp.float32)).reshape(M, 1)
    return _coef_reduce(q, coef, block=block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def float_fedagg(x: jax.Array, betas: jax.Array, *,
                 block: int = 2048, interpret: bool = False) -> jax.Array:
    """x: (M, P) fp16/fp32; betas: (M,) -> (P,) fp32 = Σ_m β_m x[m]."""
    coef = betas.astype(jnp.float32).reshape(x.shape[0], 1)
    return _coef_reduce(x, coef, block=block, interpret=interpret)
