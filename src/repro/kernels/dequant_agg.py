"""Pallas TPU kernel: fused dequantize-and-β-accumulate for quantized
uploads (``repro.fl.comm`` int8/qsgd/sign payloads):

    out[p] = Σ_m β_m · s_m · q[m, p]          q int8, s per-participant scale

This is ``fedagg`` (Eq. 7) with the server-side dequantization fused in:
instead of materializing M float32 participant vectors (4 bytes/param) and
then reducing them, the quantized payloads stream HBM→VMEM *once at 1
byte/param* and are dequantized in-tile — 4× less HBM traffic than
decode-then-fedagg on a purely memory-bound op, exactly the regime the
aggregation server lives in when every client ships int8.

β and the per-participant dequant scales collapse into one coefficient
c_m = β_m·s_m before the kernel, so the inner loop is a single scaled
reduction over the participant axis.

Tiling: the flat parameter axis P is tiled into (32, BP) VMEM blocks —
int8's minimum sublane tile is 32 (vs 8 for fp32) — with the participant
axis M whole inside the block: an (M, 32, BP) int8 tile is M·BP·32 bytes
(≤ 1.5 MB VMEM for M=22, BP=2048), the (32, BP) fp32 accumulator 256 kB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE_I8 = 32     # int8 minimum sublane tile (fp32's is 8)


def _kernel(coef_ref, q_ref, o_ref):
    # coef: (M, 1) fp32 = β·scale; q: (M, SUBLANE_I8, BP) int8;
    # o: (SUBLANE_I8, BP) fp32 — dequantize in-tile, reduce over M.
    q = q_ref[...].astype(jnp.float32)
    c = coef_ref[...]                              # (M, 1)
    o_ref[...] = jnp.sum(q * c[:, :, None], axis=0)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dequant_fedagg(q: jax.Array, scales: jax.Array, betas: jax.Array, *,
                   block: int = 2048, interpret: bool = False) -> jax.Array:
    """q: (M, P) int8; scales, betas: (M,) -> (P,) fp32 = Σ_m β_m s_m q[m]."""
    M, P = q.shape
    coef = (betas.astype(jnp.float32) *
            scales.astype(jnp.float32)).reshape(M, 1)
    rows = SUBLANE_I8 * block
    P_pad = ((P + rows - 1) // rows) * rows
    if P_pad != P:
        q = jnp.pad(q, ((0, 0), (0, P_pad - P)))
    q3 = q.reshape(M, P_pad // block, block)
    n_rows = q3.shape[1]
    grid = (n_rows // SUBLANE_I8,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((M, 1), lambda i: (0, 0)),
            pl.BlockSpec((M, SUBLANE_I8, block), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((SUBLANE_I8, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rows, block), jnp.float32),
        interpret=interpret,
    )(coef, q3)
    return out.reshape(P_pad)[:P]
