"""Pallas TPU kernel for FedAuto's hot aggregation op (Eq. 7):

    out[p] = Σ_m β_m · stacked[m, p]

This is the server's per-round global aggregation over M = K+2 participant
parameter vectors (clients + server + compensatory model). It is purely
memory-bound (arithmetic intensity ≈ 1 FLOP / 2 bytes), so the kernel's job
is to stream each parameter tile HBM→VMEM exactly once and fuse the β-scaled
reduction — instead of XLA's M separate scale+add passes over the full
parameter vector, which reads the aggregate M times.

Tiling: the flat parameter axis P is tiled into (8, BP) VMEM blocks; the
participant axis M stays whole inside the block (M ≤ ~32 in the paper's
setting, so an (M, 8, BP) fp32 tile is ≤ 4 MB VMEM for BP=4096).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8


def _kernel(beta_ref, x_ref, o_ref):
    # beta: (M, 1) fp32 in VMEM; x: (M, SUBLANE, BP); o: (SUBLANE, BP)
    x = x_ref[...].astype(jnp.float32)
    b = beta_ref[...].astype(jnp.float32)          # (M, 1)
    o_ref[...] = jnp.sum(x * b[:, :, None], axis=0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fedagg(stacked: jax.Array, betas: jax.Array, *, block: int = 4096,
           interpret: bool = False) -> jax.Array:
    """stacked: (M, P); betas: (M,) -> (P,) = Σ_m β_m stacked[m]."""
    M, P = stacked.shape
    rows = SUBLANE * block
    P_pad = ((P + rows - 1) // rows) * rows
    if P_pad != P:
        stacked = jnp.pad(stacked, ((0, 0), (0, P_pad - P)))
    x3 = stacked.reshape(M, P_pad // block // SUBLANE * SUBLANE, block)
    n_rows = x3.shape[1]
    grid = (n_rows // SUBLANE,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((M, 1), lambda i: (0, 0)),
            pl.BlockSpec((M, SUBLANE, block), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((SUBLANE, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rows, block), stacked.dtype),
        interpret=interpret,
    )(betas.astype(jnp.float32).reshape(M, 1), x3)
    return out.reshape(P_pad)[:P]
