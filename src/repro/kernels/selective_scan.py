"""Pallas TPU kernel for the Mamba2 SSD chunked selective scan.

Recurrence (per (batch, head)):  h_t = a_t·h_{t-1} + x̃_t ⊗ B_t,
y_t = C_t·h_t  with x̃ = dt-scaled input. The chunked algorithm does the
quadratic intra-chunk part on the MXU ((Q,Q) decay×CB matmuls) and carries
the (dh, n) state across chunks in VMEM scratch — the grid's innermost
(chunk) axis executes sequentially on TPU, so the scratch state IS the scan
carry; HBM sees each input tile exactly once.

Layout: grid (B·H, n_chunks); blocks x̃ (Q, dh), a_log (1, Q), B/C (Q, n);
state scratch (dh, n) fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(alog_ref, x_ref, b_ref, c_ref, o_ref, h_ref, *, q: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    la = alog_ref[0].astype(jnp.float32)                  # (Q,)
    x = x_ref[0].astype(jnp.float32)                      # (Q, dh)
    bm = b_ref[0].astype(jnp.float32)                     # (Q, n)
    cm = c_ref[0].astype(jnp.float32)                     # (Q, n)
    cums = jnp.cumsum(la)                                 # (Q,)
    # intra-chunk: y[t] = Σ_{s<=t} e^{cums_t - cums_s} (C_t·B_s) x̃_s
    Lm = jnp.exp(cums[:, None] - cums[None, :])
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    W = jnp.where(tri, Lm, 0.0) * jax.lax.dot(cm, bm.T)   # (Q, Q)
    y = jax.lax.dot(W, x)                                 # (Q, dh)
    # inter-chunk: y[t] += e^{cums_t} C_t · h
    h = h_ref[...]
    y = y + jnp.exp(cums)[:, None] * jax.lax.dot(cm, h.T)
    # state update: h' = e^{cums_Q} h + Σ_s e^{cums_Q - cums_s} x̃_s ⊗ B_s
    dec_end = jnp.exp(cums[-1] - cums)                    # (Q,)
    h_ref[...] = jnp.exp(cums[-1]) * h + jax.lax.dot(x.T, dec_end[:, None] * bm)
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def selective_scan(xdt, a_log, B_mat, C_mat, *, chunk: int = 128,
                   interpret: bool = False):
    """xdt: (B,S,H,dh) dt-scaled input; a_log: (B,S,H) = log a_t;
    B_mat/C_mat: (B,S,n). Returns y (B,S,H,dh) fp32. Zero initial state
    (matches ref.selective_scan with h0 = 0)."""
    B, S, H, dh = xdt.shape
    n = B_mat.shape[-1]
    q = min(chunk, _cm(S, 8))
    S_pad = _cm(S, q)
    dh_p, n_p = _cm(dh, 128), _cm(n, 128)

    x = jnp.pad(xdt, ((0, 0), (0, S_pad - S), (0, 0), (0, dh_p - dh)))
    x = x.transpose(0, 2, 1, 3).reshape(B * H, S_pad, dh_p)
    # padded steps must be identity on the state: a_log = 0 -> a = 1, x̃ = 0
    al = jnp.pad(a_log, ((0, 0), (0, S_pad - S), (0, 0)))
    al = al.transpose(0, 2, 1).reshape(B * H, S_pad)
    bm = jnp.pad(B_mat, ((0, 0), (0, S_pad - S), (0, n_p - n)))
    cm = jnp.pad(C_mat, ((0, 0), (0, S_pad - S), (0, n_p - n)))
    nc = S_pad // q

    kernel = functools.partial(_kernel, q=q)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, q), lambda bh, c: (bh, c)),
            pl.BlockSpec((1, q, dh_p), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, q, n_p), lambda bh, c, H_=H: (bh // H_, c, 0)),
            pl.BlockSpec((1, q, n_p), lambda bh, c, H_=H: (bh // H_, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, dh_p), lambda bh, c: (bh, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S_pad, dh_p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((dh_p, n_p), jnp.float32)],
        interpret=interpret,
    )(al, x, bm, cm)
    out = out.reshape(B, H, S_pad, dh_p).transpose(0, 2, 1, 3)
    return out[:, :S, :, :dh]


def _cm(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
