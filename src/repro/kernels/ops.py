"""Jit'd dispatch layer for the Pallas kernels.

``set_mode``:
  * "off"       — pure-jnp reference path (default on CPU; portable).
  * "interpret" — Pallas kernels in interpret mode (CPU correctness tests).
  * "on"        — compiled Pallas kernels (the TPU target).

Models call through this module so the same model code runs in smoke tests
(off/interpret) and on real hardware (on).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import ref as _ref

_MODE = "off"


def set_mode(mode: str) -> None:
    assert mode in ("off", "interpret", "on"), mode
    global _MODE
    _MODE = mode


def get_mode() -> str:
    return _MODE


def use_pallas() -> bool:
    return _MODE != "off"


def _interpret() -> bool:
    return _MODE == "interpret"


def fedagg(stacked, betas):
    if _MODE == "off":
        return _ref.fedagg(stacked, betas)
    from repro.kernels.fedagg import fedagg as k
    return k(stacked, betas, interpret=_interpret())


def dequant_fedagg(q, scales, betas):
    if _MODE == "off":
        return _ref.dequant_fedagg(q, scales, betas)
    from repro.kernels.dequant_agg import dequant_fedagg as k
    return k(q, scales, betas, interpret=_interpret())


def float_fedagg(stacked, betas):
    if _MODE == "off":
        return _ref.float_fedagg(stacked, betas)
    from repro.kernels.dequant_agg import float_fedagg as k
    return k(stacked, betas, interpret=_interpret())


def topk_fedagg(idx, vals, betas, n):
    # Scatter-accumulate over dynamic indices is XLA's territory on TPU (no
    # contiguous-tile reuse for a Pallas kernel to exploit), so every
    # dispatch mode shares the sequential-fold reference — which is also
    # what keeps the streaming path bit-identical to per-payload decode.
    return _ref.topk_fedagg(idx, vals, betas, n)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, scale=None):
    if _MODE == "off":
        return _ref.flash_attention(q, k, v, causal=causal, window=window, scale=scale)
    from repro.kernels.flash_attention import flash_attention as kn
    return kn(q, k, v, causal=causal, window=window, scale=scale,
              interpret=_interpret())


def decode_attention(q, k, v, valid, *, scale: float):
    if _MODE == "off":
        return _ref.decode_attention(q, k, v, valid, scale=scale)
    from repro.kernels.decode_attention import decode_attention as kn
    return kn(q, k, v, valid, scale=scale, interpret=_interpret())


def lora_matmul(x, w, a, b, scaling: float):
    if _MODE == "off":
        return _ref.lora_matmul(x, w, a, b, scaling)
    from repro.kernels.lora_matmul import lora_matmul as kn
    return kn(x, w, a, b, scaling, interpret=_interpret())


def selective_scan(xdt, a_log, B_mat, C_mat, *, chunk: int = 128):
    if _MODE == "off":
        import jax.numpy as jnp
        h0 = jnp.zeros((xdt.shape[0], xdt.shape[2], xdt.shape[3],
                        B_mat.shape[-1]), jnp.float32)
        return _ref.selective_scan(xdt, a_log, B_mat, C_mat, h0)[0]
    from repro.kernels.selective_scan import selective_scan as kn
    return kn(xdt, a_log, B_mat, C_mat, chunk=chunk, interpret=_interpret())
