"""Pallas TPU flash attention (blockwise, online softmax) with causal and
sliding-window masking and GQA head grouping.

Layout: (B, H, S, hd) inside the kernel (the ops wrapper transposes from the
model's (B, S, H, hd)). Grid = (B, H, nQ, nK) with the K loop innermost;
running max / sum / accumulator live in VMEM scratch, the output block is
written on the last K step. Causal + window structure prunes K blocks via
``pl.when`` so skipped blocks cost no MXU work.

Block shapes default to (128, head_dim) q-tiles × (128, head_dim) k-tiles —
MXU-aligned for head dims that are multiples of 128 (the wrapper zero-pads
smaller head dims up to 128 lanes).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: Optional[int],
            bq: int, bk: int, sk_valid: int, nk: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    iq = pl.program_id(2)
    q_start = iq * bq
    k_start = ik * bk

    def compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (BQ, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (BK, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < sk_valid
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]          # (BQ, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = corr * l_prev + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)              # (BK, hd)
        acc_ref[...] = corr * acc_ref[...] + jax.lax.dot(p, v)
        m_ref[...] = m_new
        l_ref[...] = l_new

    # prune: block needed iff some (q,k) in it passes causal+window structure
    need = k_start < sk_valid
    if causal:
        need &= k_start <= q_start + bq - 1
    if window is not None:
        need &= (k_start + bk - 1) > q_start - window
    pl.when(need)(compute)

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B,Sq,H,hd); k/v: (B,Sk,KV,hd) -> (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    g = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    hd_pad = max(128, ((hd + 127) // 128) * 128)
    bq, bk = min(block_q, _ceil_mult(Sq, 8)), min(block_k, _ceil_mult(Sk, 8))
    Sq_pad, Sk_pad = _ceil_mult(Sq, bq), _ceil_mult(Sk, bk)

    def prep(t, S_pad):
        t = jnp.pad(t, ((0, 0), (0, S_pad - t.shape[1]), (0, 0), (0, hd_pad - hd)))
        return t.transpose(0, 2, 1, 3)                   # (B, heads, S, hd)

    qt, kt, vt = prep(q, Sq_pad), prep(k, Sk_pad), prep(v, Sk_pad)
    nq, nk = Sq_pad // bq, Sk_pad // bk

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, sk_valid=Sk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd_pad), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd_pad), lambda b, h, i, j, g_=g: (b, h // g_, j, 0)),
            pl.BlockSpec((1, 1, bk, hd_pad), lambda b, h, i, j, g_=g: (b, h // g_, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd_pad), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq_pad, hd_pad), q.dtype),
        scratch_shapes=[
            # (BQ, 1) running max / sum, (BQ, hd) accumulator — VMEM residents
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd_pad), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out.transpose(0, 2, 1, 3)[:, :Sq, :, :hd]
    return out


def _ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
