"""Pure-jnp oracles for every Pallas kernel. These are the correctness
reference (tests assert_allclose kernel-vs-ref across shape/dtype sweeps) and
the portable fallback used on non-TPU backends.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# fedagg: β-weighted aggregation of stacked model parameters (Eq. 7)
# ---------------------------------------------------------------------------
def fedagg(stacked: jax.Array, betas: jax.Array) -> jax.Array:
    """stacked: (M, P) — M participant parameter vectors; betas: (M,).
    Returns (P,) = Σ_m β_m · stacked[m], fp32 accumulation."""
    return jnp.einsum("mp,m->p", stacked.astype(jnp.float32),
                      betas.astype(jnp.float32)).astype(stacked.dtype)


# ---------------------------------------------------------------------------
# dequant_fedagg: fedagg fused with int8 payload dequantization
# (repro.fl.comm int8/qsgd/sign uploads)
# ---------------------------------------------------------------------------
def dequant_fedagg(q: jax.Array, scales: jax.Array,
                   betas: jax.Array) -> jax.Array:
    """q: (M, P) int8 quantized payloads; scales/betas: (M,).
    Returns (P,) fp32 = Σ_m β_m · s_m · q[m] — the unfused oracle
    (dequantize to fp32, then β-reduce)."""
    deq = q.astype(jnp.float32) * scales.astype(jnp.float32)[:, None]
    return jnp.einsum("mp,m->p", deq, betas.astype(jnp.float32))


# ---------------------------------------------------------------------------
# float_fedagg: fedagg over packed fp16/fp32 payloads, fp32 accumulator out
# ---------------------------------------------------------------------------
def float_fedagg(stacked: jax.Array, betas: jax.Array) -> jax.Array:
    """stacked: (M, P) fp16/fp32 payload vectors; betas: (M,).
    Returns (P,) fp32 = Σ_m β_m · stacked[m].  Unlike :func:`fedagg` the
    accumulator stays fp32 (it feeds a shared cross-rung accumulator, not a
    finished model), which also makes it bit-compatible with the per-payload
    decode-to-fp32 + β-weighted-sum reference."""
    return jnp.einsum("mp,m->p", stacked.astype(jnp.float32),
                      betas.astype(jnp.float32))


# ---------------------------------------------------------------------------
# topk_fedagg: β-weighted scatter-accumulate of sparse top-k payloads
# ---------------------------------------------------------------------------
def topk_fedagg(idx: jax.Array, vals: jax.Array, betas: jax.Array,
                n: int) -> jax.Array:
    """idx: (M, k) int32 (indices unique within a row), vals: (M, k) fp32,
    betas: (M,).  Returns (n,) fp32 = Σ_m β_m · scatter(idx[m], vals[m]).

    Accumulates as a sequential left-fold over the participant axis so the
    result is bit-identical to decoding each sparse payload to dense fp32
    and running-summing β·decode(p_m) in payload order (adding β_m·0 at
    untouched positions is exact)."""
    out = jnp.zeros((int(n),), jnp.float32)

    def step(acc, x):
        i, v, b = x
        return acc.at[i].add(b.astype(jnp.float32) *
                             v.astype(jnp.float32)), None

    out, _ = jax.lax.scan(step, out, (idx, vals, betas))
    return out


# ---------------------------------------------------------------------------
# flash attention (causal / sliding-window, GQA)
# ---------------------------------------------------------------------------
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, scale: Optional[float] = None):
    """q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd) -> (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    g = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, KV, g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# decode attention (1 query token vs KV cache with validity mask)
# ---------------------------------------------------------------------------
def decode_attention(q, k, v, valid, *, scale: float):
    """q: (B,1,H,hd), k/v: (B,S,KV,hd), valid: (S,) bool -> (B,1,H,hd)."""
    B, _, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    qg = q.reshape(B, KV, g, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", w, v.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# fused LoRA matmul: y = x @ W + scaling * (x @ A) @ B
# ---------------------------------------------------------------------------
def lora_matmul(x, w, a, b, scaling: float):
    """x: (T, d), w: (d, o), a: (d, r), b: (r, o)."""
    base = x @ w
    delta = (x @ a) @ b
    return base + jnp.asarray(scaling, base.dtype) * delta.astype(base.dtype)


# ---------------------------------------------------------------------------
# selective scan (Mamba2 SSD recurrence, per head)
# ---------------------------------------------------------------------------
def selective_scan(xdt, a_log, B_mat, C_mat, h0):
    """Sequential oracle of the SSD recurrence.
    xdt: (B,S,H,dh) fp32 (already dt-scaled), a_log: (B,S,H) = log a_t,
    B_mat/C_mat: (B,S,n), h0: (B,H,dh,n). Returns (y (B,S,H,dh), h_end)."""
    def step(h, t):
        a = jnp.exp(a_log[:, t])                                     # (B,H)
        u = jnp.einsum("bhd,bn->bhdn", xdt[:, t], B_mat[:, t])
        h = a[:, :, None, None] * h + u
        y = jnp.einsum("bhdn,bn->bhd", h, C_mat[:, t])
        return h, y

    S = xdt.shape[1]
    h_end, ys = jax.lax.scan(step, h0, jnp.arange(S))
    return ys.transpose(1, 0, 2, 3), h_end
