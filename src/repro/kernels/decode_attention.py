"""Pallas TPU decode attention: one query token per sequence against a long
(32k–500k) KV cache. This op is strictly memory-bound — the kernel streams
the cache HBM→VMEM once per (batch, kv-head) and keeps the whole GQA group
of queries resident, amortizing each cache byte across `group` heads.

Grid = (B, KV, nS) with the cache-block loop innermost; online-softmax
scratch (m, l, acc) keyed by the (group, hd) query tile. Invalid ring-buffer
slots are masked via an int32 validity vector (blocked alongside the cache).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, ns: int):
    js = pl.program_id(2)

    @pl.when(js == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)                 # (BS, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (G, BS)
    ok = valid_ref[0] > 0                               # (BS,)
    s = jnp.where(ok[None, :], s, NEG_INF)
    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = corr * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = corr * acc_ref[...] + jax.lax.dot(p, v_ref[0, 0].astype(jnp.float32))
    m_ref[...] = m_new

    @pl.when(js == ns - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_s", "interpret"))
def decode_attention(q, k, v, valid, *, scale: float, block_s: int = 512,
                     interpret: bool = False):
    """q: (B,1,H,hd); k/v: (B,S,KV,hd); valid: (S,) bool -> (B,1,H,hd)."""
    B, _, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    g = H // KV
    hd_pad = max(128, ((hd + 127) // 128) * 128)
    g_pad = max(8, ((g + 7) // 8) * 8)
    bs = min(block_s, ((S + 7) // 8) * 8)
    S_pad = ((S + bs - 1) // bs) * bs

    qg = q.reshape(B, KV, g, hd)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, g_pad - g), (0, hd_pad - hd)))
    kt = jnp.pad(k, ((0, 0), (0, S_pad - S), (0, 0), (0, hd_pad - hd))).transpose(0, 2, 1, 3)
    vt = jnp.pad(v, ((0, 0), (0, S_pad - S), (0, 0), (0, hd_pad - hd))).transpose(0, 2, 1, 3)
    valid_i = jnp.pad(valid.astype(jnp.int32), (0, S_pad - S)).reshape(1, S_pad)
    ns = S_pad // bs

    kernel = functools.partial(_kernel, scale=scale, ns=ns)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, ns),
        in_specs=[
            pl.BlockSpec((1, 1, g_pad, hd_pad), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd_pad), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bs, hd_pad), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, bs), lambda b, h, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, g_pad, hd_pad), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, g_pad, hd_pad), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g_pad, 1), jnp.float32),
            pltpu.VMEM((g_pad, 1), jnp.float32),
            pltpu.VMEM((g_pad, hd_pad), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kt, vt, valid_i)
    return out[:, :, :g, :hd].reshape(B, 1, H, hd)
