"""Pallas TPU fused LoRA matmul:  y = x @ W + s · (x @ A) @ B.

In FFT with LoRA only A/B train, but the forward still pays the full base
matmul; XLA emits two separate GEMM passes over x (one for W, one for A) plus
an extra pass for the rank-r expansion. The fused kernel reads each x tile
once, accumulating both the base product and the rank-r projection in VMEM
scratch, and applies B on the final reduction step — one HBM pass over x.

Grid = (nT, nO, nD), d innermost. Scratch: acc (BT,BO) fp32 and xa (BT,r).
r is zero-padded to the 128-lane boundary by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref, xa_ref, *,
            scaling: float, nd: int):
    jd = pl.program_id(2)

    @pl.when(jd == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(x, w_ref[...].astype(jnp.float32))
    xa_ref[...] += jax.lax.dot(x, a_ref[...].astype(jnp.float32))

    @pl.when(jd == nd - 1)
    def _finish():
        delta = jax.lax.dot(xa_ref[...], b_ref[...].astype(jnp.float32))
        o_ref[...] = (acc_ref[...] + scaling * delta).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scaling", "block_t", "block_o",
                                             "block_d", "interpret"))
def lora_matmul(x, w, a, b, scaling: float, *, block_t: int = 256,
                block_o: int = 512, block_d: int = 512,
                interpret: bool = False):
    """x: (T,d); w: (d,o); a: (d,r); b: (r,o) -> (T,o)."""
    T, D = x.shape
    O = w.shape[1]
    r = a.shape[1]
    bt, bo, bd = min(block_t, _cm(T, 8)), min(block_o, _cm(O, 128)), min(block_d, _cm(D, 128))
    T_p, O_p, D_p = _cm(T, bt), _cm(O, bo), _cm(D, bd)
    r_p = _cm(r, 128)
    xp = jnp.pad(x, ((0, T_p - T), (0, D_p - D)))
    wp = jnp.pad(w, ((0, D_p - D), (0, O_p - O)))
    ap = jnp.pad(a, ((0, D_p - D), (0, r_p - r)))
    bp = jnp.pad(b, ((0, r_p - r), (0, O_p - O)))
    nt, no, nd = T_p // bt, O_p // bo, D_p // bd

    kernel = functools.partial(_kernel, scaling=scaling, nd=nd)
    out = pl.pallas_call(
        kernel,
        grid=(nt, no, nd),
        in_specs=[
            pl.BlockSpec((bt, bd), lambda i, j, kd: (i, kd)),
            pl.BlockSpec((bd, bo), lambda i, j, kd: (kd, j)),
            pl.BlockSpec((bd, r_p), lambda i, j, kd: (kd, 0)),
            pl.BlockSpec((r_p, bo), lambda i, j, kd: (0, j)),
        ],
        out_specs=pl.BlockSpec((bt, bo), lambda i, j, kd: (i, j)),
        out_shape=jax.ShapeDtypeStruct((T_p, O_p), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bt, bo), jnp.float32),
            pltpu.VMEM((bt, r_p), jnp.float32),
        ],
        interpret=interpret,
    )(xp, wp, ap, bp)
    return out[:T, :O]


def _cm(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
