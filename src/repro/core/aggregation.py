"""FedAuto adaptive aggregation (Algorithm 2) and pytree aggregation utils.

The aggregation itself (Eq. 7) is a β-weighted sum of participant parameter
pytrees — executed leaf-wise through the ``fedagg`` kernel dispatch (Pallas on
TPU, fused einsum elsewhere). Module 1 (compensatory training) is triggered by
``missing_classes``; Module 2 (weight optimization) is ``fedauto_weights``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.weights_qp import solve_weights
from repro.kernels import ops as kops


# ---------------------------------------------------------------------------
# weighted pytree aggregation (Eq. 5 / 7 / 10)
# ---------------------------------------------------------------------------
def aggregate_pytrees(trees: Sequence, betas) -> object:
    """Σ_m β_m · tree_m over a list of identically-structured pytrees."""
    betas = jnp.asarray(betas, jnp.float32)

    def agg(*leaves):
        stacked = jnp.stack([l.reshape(-1) for l in leaves], axis=0)
        out = kops.fedagg(stacked, betas)
        return out.reshape(leaves[0].shape).astype(leaves[0].dtype)

    return jax.tree.map(agg, *trees)


def delta_pytree(model, ref):
    """float32 update direction ``model − ref``, leaf-wise."""
    return jax.tree.map(
        lambda w, g: w.astype(jnp.float32) - g.astype(jnp.float32),
        model, ref)


# ---------------------------------------------------------------------------
# Module 1 — missing-class detection (Eq. 6 trigger)
# ---------------------------------------------------------------------------
def missing_classes(client_hists: np.ndarray, received: np.ndarray) -> np.ndarray:
    """client_hists: (N, C) per-client class sample counts; received: (N,)
    bool (selected AND connected). Returns bool (C,): classes with zero
    samples among received client updates."""
    if received.sum() == 0:
        return np.ones(client_hists.shape[1], dtype=bool)
    covered = client_hists[received].sum(axis=0) > 0
    return ~covered


# ---------------------------------------------------------------------------
# Module 2 — FedAuto weights (Eq. 8 with Eq. 9 pin)
# ---------------------------------------------------------------------------
def fedauto_weights(alpha_rows: np.ndarray, alpha_g: np.ndarray,
                    active: np.ndarray, server_row: int) -> np.ndarray:
    """alpha_rows: (J, C) — row per participant (server, [compensatory],
    clients…); active: (J,) bool. Server pinned per Eq. 9:
    β_s = 1 / (1 + #connected non-server participants)."""
    m = int(active.sum()) - 1              # connected participants besides server
    beta_s = 1.0 / (1.0 + max(m, 0))
    beta = solve_weights(jnp.asarray(alpha_rows), jnp.asarray(alpha_g),
                         jnp.asarray(active), fixed_idx=server_row,
                         fixed_val=jnp.float32(beta_s))
    return np.asarray(beta)


def fedauto_discounted_weights(alpha_rows: np.ndarray, alpha_g: np.ndarray,
                               staleness: np.ndarray,
                               distortion: np.ndarray, server_row: int,
                               discount_a: float = 0.5,
                               discount_b: float = 0.0) -> np.ndarray:
    """One post-QP discount pipeline: staleness × compression fidelity.

    ``staleness[j]`` is the age in rounds of participant j's update (0 =
    computed from the current global model; the server row is always 0).
    ``distortion[j]`` is the upload's normalized compression distortion
    ``‖carry − decoded‖ / ‖carry‖`` measured by ``CommState.roundtrip``
    (0 = lossless; clipped into [0, 1]; the server row is always 0).

    The QP is solved exactly as in the synchronous case — Eq. 9 pin
    ``β_s = 1/(1+m)`` included — then each non-server weight is discounted
    by ``(1 + s_j)^{-discount_a} · (1 − d_j)^{discount_b}`` and the free
    mass ``1 − β_s`` is redistributed, so the result stays on the simplex
    with the pin intact.  Reductions are bit-exact: with every update fresh
    and every discount inactive this *is* ``fedauto_weights``; with zero
    distortion (or ``discount_b = 0``) it *is* ``fedauto_async_weights``.
    """
    staleness = np.asarray(staleness, dtype=float)
    distortion = np.clip(np.asarray(distortion, dtype=float), 0.0, 1.0)
    active = np.ones(len(alpha_rows), dtype=bool)
    beta = fedauto_weights(alpha_rows, alpha_g, active, server_row)
    stale_on = bool(np.any(staleness > 0))
    fid_on = discount_b > 0 and bool(np.any(distortion > 0))
    if not stale_on and not fid_on:
        return beta          # fresh + lossless: exactly the sync solution
    disc = np.power(1.0 + np.maximum(staleness, 0.0), -discount_a)
    if fid_on:
        disc = disc * np.power(1.0 - distortion, discount_b)
    disc[server_row] = 1.0
    free = beta * disc
    free[server_row] = 0.0
    mass = 1.0 - beta[server_row]
    tot = free.sum()
    out = np.zeros_like(beta)
    out[server_row] = beta[server_row]
    if tot > 1e-12:
        out += free * (mass / tot)
    else:
        # every client weight vanished (all maximally stale/distorted): the
        # server keeps the whole budget, as with an empty round
        out[server_row] = 1.0
    return out


def fedauto_async_weights(alpha_rows: np.ndarray, alpha_g: np.ndarray,
                          staleness: np.ndarray, server_row: int,
                          discount_a: float = 0.5) -> np.ndarray:
    """FedAuto-Async (staleness-aware Eq. 8 + Eq. 9 pin): the lossless
    special case of ``fedauto_discounted_weights``."""
    return fedauto_discounted_weights(
        alpha_rows, alpha_g, staleness,
        np.zeros(len(alpha_rows)), server_row,
        discount_a=discount_a, discount_b=0.0)


def fedauto_simple_average_weights(active: np.ndarray, server_row: int,
                                   has_comp: bool) -> np.ndarray:
    """Ablation (Appendix III-F2): Module 1 without Module 2 — Eq. (58)."""
    J = len(active)
    m = int(active.sum()) - 1 - (1 if has_comp else 0)  # connected clients
    beta = np.zeros(J)
    beta[server_row] = 1.0 / (1.0 + max(m, 0))
    rest = 1.0 - beta[server_row]
    others = [j for j in range(J) if j != server_row and active[j]]
    for j in others:
        beta[j] = rest / max(len(others), 1)
    return beta


# ---------------------------------------------------------------------------
# effective class distribution diagnostics (Theorem 1 terms)
# ---------------------------------------------------------------------------
def effective_distribution(beta: np.ndarray, alpha_rows: np.ndarray) -> np.ndarray:
    return beta @ alpha_rows


def chi2(p: np.ndarray, q: np.ndarray) -> float:
    """χ²(p‖q) = Σ (q_i − p_i)² / p_i with the paper's convention χ²_{p‖q}."""
    return float(np.sum(np.square(q - p) / np.maximum(p, 1e-12)))
