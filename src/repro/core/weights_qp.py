"""Module 2 — aggregation-weight optimization (paper Eq. 8–9).

    min_β  Σ_c ( α_{g,c} − Σ_j β_j α_{j,c} )² / α_{g,c}
    s.t.   β ≥ 0,  Σ_j β_j = 1,  β_s pinned to 1/(1+m)  (Eq. 9),
           β_j = 0 for unselected / disconnected participants (Eq. 10c).

This is a simplex-constrained weighted least squares (convex QP). The paper
solves it with CVX/Gurobi; offline we use FISTA (accelerated projected
gradient) on the scaled simplex — jittable, deterministic, and validated in
tests against a float64 long-horizon PGD oracle (``solve_weights_oracle``).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_BIG = 1e9


def project_simplex(v: jax.Array, mask: jax.Array, total: jax.Array) -> jax.Array:
    """Euclidean projection of v onto {x >= 0, sum(x) = total, x[~mask] = 0}."""
    n = v.shape[0]
    vm = jnp.where(mask, v, -_BIG)
    vs = jnp.sort(vm)[::-1]
    css = jnp.cumsum(vs)
    j = jnp.arange(1, n + 1, dtype=v.dtype)
    cond = (vs - (css - total) / j > 0) & (vs > -_BIG / 2)
    rho = jnp.max(jnp.where(cond, jnp.arange(1, n + 1), 0))
    rho = jnp.maximum(rho, 1)
    tau = (css[rho - 1] - total) / rho.astype(v.dtype)
    return jnp.where(mask, jnp.clip(v - tau, 0.0, None), 0.0)


def chi2_effective(beta: jax.Array, alpha: jax.Array, alpha_g: jax.Array) -> jax.Array:
    """χ²(α_g ‖ ᾰ) with ᾰ_c = Σ_j β_j α_{j,c} — the paper's objective (8a)."""
    eff = beta @ alpha
    return jnp.sum(jnp.square(alpha_g - eff) / jnp.maximum(alpha_g, 1e-12))


@functools.partial(jax.jit, static_argnames=("iters",))
def solve_weights(alpha: jax.Array, alpha_g: jax.Array, mask: jax.Array,
                  fixed_idx: Optional[int] = None,
                  fixed_val: Optional[jax.Array] = None,
                  iters: int = 400) -> jax.Array:
    """FISTA for Eq. (8).

    alpha: (J, C) per-participant class distributions (rows sum to 1).
    alpha_g: (C,) global class distribution.
    mask: (J,) bool — participant present this round (Eq. 10c).
    fixed_idx/fixed_val: pin β[fixed_idx] (the server, Eq. 9). The remaining
    mass 1 − fixed_val is distributed over the other active participants.
    Returns β (J,) satisfying all constraints exactly.
    """
    J, C = alpha.shape
    alpha = alpha.astype(jnp.float32)
    alpha_g = alpha_g.astype(jnp.float32)
    dinv = 1.0 / jnp.maximum(alpha_g, 1e-12)

    if fixed_idx is not None:
        fmask = jnp.arange(J) == fixed_idx
        fixed_vec = jnp.where(fmask, fixed_val, 0.0).astype(jnp.float32)
        free_mask = mask & (~fmask)
        total = 1.0 - fixed_val
    else:
        fixed_vec = jnp.zeros((J,), jnp.float32)
        free_mask = mask
        total = jnp.asarray(1.0, jnp.float32)

    resid0 = alpha_g - fixed_vec @ alpha       # target for the free part

    def grad(z):
        eff = z @ alpha
        return 2.0 * ((eff - resid0) * dinv) @ alpha.T

    # Lipschitz bound: 2 * ||A D^-1 A^T||_F  (A = alpha)
    M = (alpha * dinv[None, :]) @ alpha.T
    L = 2.0 * jnp.sqrt(jnp.sum(jnp.square(M))) + 1e-6
    step = 1.0 / L

    n_active = jnp.maximum(jnp.sum(free_mask.astype(jnp.float32)), 1.0)
    z0 = jnp.where(free_mask, total / n_active, 0.0)

    def body(carry, _):
        z, y, t = carry
        z_new = project_simplex(y - step * grad(y), free_mask, total)
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        y_new = z_new + ((t - 1.0) / t_new) * (z_new - z)
        return (z_new, y_new, t_new), None

    (z, _, _), _ = jax.lax.scan(body, (z0, z0, jnp.asarray(1.0, jnp.float32)),
                                None, length=iters)
    return z + fixed_vec


def solve_weights_oracle(alpha: np.ndarray, alpha_g: np.ndarray,
                         mask: np.ndarray, fixed_idx: Optional[int] = None,
                         fixed_val: Optional[float] = None,
                         iters: int = 200_000) -> np.ndarray:
    """Float64 long-horizon PGD — the test oracle for solve_weights."""
    J, C = alpha.shape
    alpha = alpha.astype(np.float64)
    alpha_g = alpha_g.astype(np.float64)
    dinv = 1.0 / np.maximum(alpha_g, 1e-12)
    if fixed_idx is not None:
        fmask = np.arange(J) == fixed_idx
        fixed_vec = np.where(fmask, fixed_val, 0.0)
        free_mask = mask & (~fmask)
        total = 1.0 - fixed_val
    else:
        fixed_vec = np.zeros(J)
        free_mask = mask.copy()
        total = 1.0
    resid0 = alpha_g - fixed_vec @ alpha
    M = (alpha * dinv[None]) @ alpha.T
    L = 2.0 * np.linalg.norm(M, 2) + 1e-9
    z = np.where(free_mask, total / max(free_mask.sum(), 1), 0.0)

    def proj(v):
        vm = np.where(free_mask, v, -np.inf)
        vs = np.sort(vm)[::-1]
        fin = np.isfinite(vs)
        css = np.cumsum(np.where(fin, vs, 0.0))
        j = np.arange(1, J + 1)
        cond = fin & (vs - (css - total) / j > 0)
        rho = int(np.max(np.where(cond, j, 0)))
        rho = max(rho, 1)
        tau = (css[rho - 1] - total) / rho
        return np.where(free_mask, np.clip(v - tau, 0.0, None), 0.0)

    for _ in range(iters):
        eff = z @ alpha
        g = 2.0 * ((eff - resid0) * dinv) @ alpha.T
        z = proj(z - g / L)
    return z + fixed_vec


def heuristic_weights(p: np.ndarray, mask: np.ndarray, server_idx: int,
                      full_participation: bool) -> np.ndarray:
    """Footnote-2 heuristic weights used by FedAvg/FedProx under failures."""
    J = len(p)
    beta = np.zeros(J)
    if full_participation:
        denom = p[server_idx] + sum(p[j] for j in range(J)
                                    if mask[j] and j != server_idx)
        for j in range(J):
            if j == server_idx or mask[j]:
                beta[j] = p[j] / max(denom, 1e-12)
    else:
        m = sum(1 for j in range(J) if mask[j] and j != server_idx)
        beta[server_idx] = p[server_idx]
        for j in range(J):
            if j != server_idx and mask[j]:
                beta[j] = (1.0 - p[server_idx]) / max(m, 1)
    return beta
