"""The paper's primary contribution: FedAuto adaptive aggregation
(Modules 1+2, Eq. 6-9) + every baseline strategy from §V-A5."""
from repro.core.aggregation import (  # noqa: F401
    aggregate_pytrees,
    chi2,
    effective_distribution,
    fedauto_async_weights,
    fedauto_discounted_weights,
    fedauto_weights,
    missing_classes,
)
from repro.core.strategies import STRATEGIES, FedAuto, RoundContext, Strategy  # noqa: F401
from repro.core.weights_qp import (  # noqa: F401
    chi2_effective,
    heuristic_weights,
    project_simplex,
    solve_weights,
    solve_weights_oracle,
)
