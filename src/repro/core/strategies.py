"""All FFT aggregation strategies from the paper (§V-A5, Appendix III-E),
implemented against a common interface driven by ``repro.fl.runtime``.

Participant indexing convention: row 0 = server, rows 1..N = clients.
``RoundContext.connected[i]`` is True iff client i was selected AND its
upload survived the failure draw (1_i^r = 1) — the per-round view of Prop. 1.

Implemented verbatim (equation refs in each class):
  FedAvg (footnote-2 heuristic weights), FedProx (43), SCAFFOLD (44–45),
  FedLAW (46–47), TF-Aggregation (48–50), FedAWE (51), FedEx-LoRA (52–53),
  FedAuto (Alg. 2: Eq. 6–9), plus the two FedAuto ablations (App. III-F).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import (aggregate_pytrees, delta_pytree,
                                    fedauto_discounted_weights,
                                    fedauto_simple_average_weights,
                                    missing_classes)
from repro.core.weights_qp import heuristic_weights
from repro.obs.telemetry import NULL_TELEMETRY, beta_row


@dataclasses.dataclass
class RoundContext:
    rnd: int
    global_params: Any
    server_model: Any                     # w_s^{r,E}
    client_models: Dict[int, Any]         # client id -> w_i^{r,E} (connected only)
    selected: np.ndarray                  # (N,) bool
    connected: np.ndarray                 # (N,) bool (selected & survived)
    p: np.ndarray                         # (N+1,) dataset-size weights, [0]=server
    client_hists: np.ndarray              # (N, C) label histograms
    server_hist: np.ndarray               # (C,)
    global_hist: np.ndarray               # (C,)
    full_participation: bool
    eps_estimates: Optional[np.ndarray] = None   # TF-Aggregation inputs
    runner: Any = None                    # back-reference (compensatory training)
    codec: Optional[str] = None           # decodable wire codec shared by all
    #                                       uploads (None for adaptive runs,
    #                                       whose rungs live in ``codecs``)
    upload_nbytes: Optional[float] = None  # bytes-on-wire per client upload
    #                                       (None for adaptive runs)
    # per-client wire metadata of this round's *actual* uploads, keyed by
    # client id (participants only):
    codecs: Optional[Dict[int, str]] = None        # rung each upload used
    upload_bytes: Optional[Dict[int, float]] = None  # bytes each upload cost
    distortions: Optional[Dict[int, float]] = None   # ‖carry−dec‖/‖carry‖
    telemetry: Any = None                 # run telemetry hub (repro.obs);
    #                                       None/falsy = not recording
    # streaming server path: the round's uploads as wire PackedUpdates
    # (client id -> repro.fl.comm.stream.PackedUpdate).  Set — and
    # client_models left empty — when the loop runs a streaming-capable
    # strategy; strategies feed them through a StreamAccumulator so K
    # arrivals never materialize K fp32 model pytrees.
    packed: Optional[Dict[int, Any]] = None


def _record_betas(ctx, rows) -> None:
    """Forward the weights a strategy *actually applied* to the telemetry
    hub (``beta_row`` dicts); a no-op when telemetry is off."""
    tel = getattr(ctx, "telemetry", None)
    if tel:
        tel.betas(ctx.rnd, rows)


def _phase(ctx, name: str):
    """A ``phase.*`` profiler timer on the round's telemetry hub — the
    shared no-op context manager when the run is uninstrumented.  Strategies
    use it to split their aggregation between the weight solve
    (``phase.weight_solve``) and the pytree accumulate
    (``phase.accumulate``); both nest inside the loop's ``phase.aggregate``,
    which (timers being exclusive) keeps only its own bookkeeping time."""
    tel = getattr(ctx, "telemetry", None)
    return (tel or NULL_TELEMETRY).timer(name)


def _accumulate(ctx, models, betas):
    """``aggregate_pytrees`` under the ``phase.accumulate`` timer, synced
    when telemetry is live so the timer sees device time, not dispatch."""
    with _phase(ctx, "phase.accumulate"):
        out = aggregate_pytrees(models, betas)
        if getattr(ctx, "telemetry", None):
            jax.block_until_ready(out)
    return out


def _stream_accumulate(ctx, dense, packed):
    """Streaming counterpart of ``_accumulate``: the β-weighted model sum
    ``Σ w_t·tree_t + Σ β_j·(origin_global_j + decode(payload_j))`` computed
    through ``repro.fl.comm.stream.weighted_model_sum`` — K packed payloads
    batch through the decode-and-accumulate kernels and never materialize K
    model pytrees.  ``dense``/``packed`` are ``(weight, tree)`` /
    ``(weight, PackedUpdate)`` pairs; leaves come back cast to the global
    dtype, exactly like ``aggregate_pytrees``.  (Lazy import: ``repro.fl``
    imports this module at package load.)"""
    from repro.fl.comm.stream import weighted_model_sum
    tel = getattr(ctx, "telemetry", None)
    with _phase(ctx, "phase.accumulate"):
        out = weighted_model_sum(packed, dense, template=ctx.global_params,
                                 telemetry=tel or NULL_TELEMETRY, rnd=ctx.rnd)
        out = jax.tree.map(lambda g, v: v.astype(g.dtype),
                           ctx.global_params, out)
        if tel:
            jax.block_until_ready(out)
    return out


def _stream_delta_sum(ctx, dense, packed):
    """Like ``_stream_accumulate`` but over *deltas*: ``Σ w_t·tree_t +
    Σ β_j·decode(payload_j)`` with fp32 leaves and no origin-global terms —
    a payload's decode IS its origin-relative delta (what FedBuff holds)."""
    from repro.fl.comm.stream import StreamAccumulator
    tel = getattr(ctx, "telemetry", None)
    with _phase(ctx, "phase.accumulate"):
        acc = StreamAccumulator(ctx.global_params,
                                telemetry=tel or NULL_TELEMETRY)
        for w, pu in packed:
            acc.add(pu.payload, w)
        for w, t in dense:
            acc.add_tree(t, w)
        out = acc.total()
        if tel:
            tel.gauge(ctx.rnd, "uplink_fused_payloads", acc.n_fused)
            tel.gauge(ctx.rnd, "uplink_fallback_payloads", acc.n_fallback)
            tel.gauge(ctx.rnd, "uplink_peak_decoded_bytes",
                      acc.peak_decoded_bytes)
            jax.block_until_ready(out)
    return out


class Strategy:
    name = "base"
    # Streaming-capable strategies consume ctx.packed (wire payloads through
    # a StreamAccumulator) instead of ctx.client_models.  Strategies that
    # genuinely need per-client models/deltas — Scaffold's control variates,
    # FedLAW's proxy optimization over the stacked cohort, TF-Aggregation's
    # literal per-model weights, FedEx-LoRA's adapter matrix products — keep
    # streaming=False, and the loops materialize for them (the documented
    # fallback, counted in the uplink_decode attribution gauges).
    streaming = False

    def init_state(self, runner) -> None:
        pass

    # hooks used by the runner's local update ------------------------------
    def prox_mu(self) -> float:
        return 0.0

    def correction(self, client_id: int, runner):
        return None                       # SCAFFOLD overrides

    def post_local(self, client_id: int, rnd: int, local_model, ctx_global,
                   runner):
        return local_model                # FedAWE overrides

    # aggregation -----------------------------------------------------------
    def aggregate(self, ctx: RoundContext):
        raise NotImplementedError

    def _mask(self, ctx: RoundContext) -> np.ndarray:
        """(N+1,) active mask with the server at row 0."""
        return np.concatenate([[True], ctx.connected])


class FedAvg(Strategy):
    """Footnote-2 heuristic weights under failures; Remark-1 weights when
    the network is ideal."""
    name = "fedavg"
    streaming = True

    def aggregate(self, ctx: RoundContext):
        with _phase(ctx, "phase.weight_solve"):
            beta = heuristic_weights(
                ctx.p, self._mask(ctx), server_idx=0,
                full_participation=ctx.full_participation)
        ids = [i for i in range(len(ctx.connected)) if ctx.connected[i]]
        if getattr(ctx, "telemetry", None):
            codecs = ctx.codecs or {}
            dists = ctx.distortions or {}
            _record_betas(ctx, [beta_row(beta[0], role="server")] + [
                beta_row(beta[i + 1], client=i, rung=codecs.get(i),
                         distortion=dists.get(i)) for i in ids])
        if getattr(ctx, "packed", None) is not None:
            return _stream_accumulate(
                ctx, dense=[(beta[0], ctx.server_model)],
                packed=[(beta[i + 1], ctx.packed[i]) for i in ids])
        models = [ctx.server_model] + [ctx.client_models[i] for i in ids]
        weights = [beta[0]] + [beta[i + 1] for i in ids]
        return _accumulate(ctx, models, np.array(weights))


class FedProx(FedAvg):
    """FedAvg + proximal term μ/2·‖w − w̄‖² in the local objective (Eq. 43)."""
    name = "fedprox"

    def __init__(self, mu: float = 0.01):
        self.mu = mu

    def prox_mu(self) -> float:
        return self.mu


class Scaffold(Strategy):
    """Control variates (Eq. 44–45); client-only aggregation with γ_g = 1."""
    name = "scaffold"

    def __init__(self, global_lr: float = 1.0):
        self.global_lr = global_lr

    def init_state(self, runner) -> None:
        zeros = jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32),
                             runner.trainable(runner.global_params))
        self.c = zeros
        self.c_i = {i: zeros for i in range(runner.n_clients)}
        self._pending: Dict[int, Any] = {}

    def correction(self, client_id: int, runner):
        # gradient correction: −c_i + c
        return jax.tree.map(lambda c, ci: c - ci, self.c, self.c_i[client_id])

    def post_local(self, client_id: int, rnd: int, local_model, ctx_global,
                   runner):
        # c_i^+ = c_i − c + (w̄ − w_i)/(K γ_l E)   (Eq. 44b)
        coef = 1.0 / (runner.k_selected * runner.lr(rnd) * runner.local_steps)
        ci_new = jax.tree.map(
            lambda ci, c, g, w: ci - c + coef * (g.astype(jnp.float32) -
                                                 w.astype(jnp.float32)),
            self.c_i[client_id], self.c, ctx_global, local_model)
        self._pending[client_id] = ci_new
        return local_model

    def aggregate(self, ctx: RoundContext):
        ids = [i for i in range(len(ctx.connected)) if ctx.connected[i]]
        n_conn = max(len(ids), 1)
        if getattr(ctx, "telemetry", None):
            codecs = ctx.codecs or {}
            dists = ctx.distortions or {}
            # each connected delta enters the global step at global_lr/n
            _record_betas(ctx, [
                beta_row(self.global_lr / n_conn, client=i,
                         rung=codecs.get(i), distortion=dists.get(i))
                for i in ids])
        if ids:
            deltas = [jax.tree.map(lambda w, g: w.astype(jnp.float32) -
                                   g.astype(jnp.float32),
                                   ctx.client_models[i], ctx.global_params)
                      for i in ids]
            mean_delta = aggregate_pytrees(deltas, np.full(len(ids), 1.0 / n_conn))
            new_global = jax.tree.map(
                lambda g, d: (g.astype(jnp.float32) + self.global_lr * d).astype(g.dtype),
                ctx.global_params, mean_delta)
        else:
            new_global = ctx.global_params
        # c update (Eq. 45b) over clients that actually delivered
        N = len(ctx.connected)
        for i in ids:
            if i in self._pending:
                diff = jax.tree.map(lambda new, old: new - old,
                                    self._pending[i], self.c_i[i])
                self.c = jax.tree.map(lambda c, d: c + d / N, self.c, diff)
                self.c_i[i] = self._pending[i]
        self._pending.clear()
        return new_global


class FedLAW(Strategy):
    """Server-side proxy-data optimization of shrinking factor ρ and
    client aggregation weights (Eq. 46–47)."""
    name = "fedlaw"

    def __init__(self, opt_steps: int = 30, opt_lr: float = 0.05,
                 proxy_batch: int = 64):
        self.opt_steps = opt_steps
        self.opt_lr = opt_lr
        self.proxy_batch = proxy_batch

    def aggregate(self, ctx: RoundContext):
        ids = [i for i in range(len(ctx.connected)) if ctx.connected[i]]
        if not ids:
            return ctx.global_params
        models = [ctx.client_models[i] for i in ids]
        stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *models)
        runner = ctx.runner
        px, py = runner.public_proxy_batch(self.proxy_batch, ctx.rnd)

        def proxy_loss(opt_vars):
            rho = jax.nn.softplus(opt_vars["rho"])
            beta = jax.nn.softmax(opt_vars["logits"])
            merged = jax.tree.map(
                lambda s: jnp.einsum("m...,m->...", s.astype(jnp.float32), beta)
                .astype(s.dtype), stacked)
            merged = jax.tree.map(lambda w: (rho * w.astype(jnp.float32)).astype(w.dtype),
                                  merged)
            return runner.loss_on(merged, px, py)

        opt_vars = {"rho": jnp.asarray(0.5413, jnp.float32),   # softplus⁻¹(1)
                    "logits": jnp.zeros(len(ids), jnp.float32)}
        for _ in range(self.opt_steps):
            g = jax.grad(proxy_loss)(opt_vars)
            opt_vars = jax.tree.map(lambda v, gr: v - self.opt_lr * gr, opt_vars, g)
        rho = float(jax.nn.softplus(opt_vars["rho"]))
        beta = np.asarray(jax.nn.softmax(opt_vars["logits"]))
        if getattr(ctx, "telemetry", None):
            codecs = ctx.codecs or {}
            dists = ctx.distortions or {}
            # the model each client contributes is scaled by rho·β_k
            _record_betas(ctx, [
                beta_row(rho * float(beta[k]), client=i, rung=codecs.get(i),
                         distortion=dists.get(i))
                for k, i in enumerate(ids)])
        merged = aggregate_pytrees(models, beta)
        return jax.tree.map(lambda w: (rho * w.astype(jnp.float32)).astype(w.dtype),
                            merged)


class TFAggregation(Strategy):
    """Transient-failure-aware aggregation (Eq. 48–50), implemented literally
    — including its non-normalized weights, which is what destabilizes it in
    the paper's Tables 1–3."""
    name = "tf_aggregation"

    def __init__(self, eps_threshold: float = 0.9):
        self.eps_threshold = eps_threshold
        self.s: Optional[np.ndarray] = None

    def init_state(self, runner) -> None:
        # ``s`` is cached lazily from the first round's eps_estimates; a
        # reused strategy instance must not carry the previous run's (or the
        # previous world's) selection probabilities into the next run.
        self.s = None

    def selection_probs(self, ctx: RoundContext) -> np.ndarray:
        eps = np.clip(ctx.eps_estimates, 0.0, 0.999)
        p = ctx.p[1:]
        ok = eps <= self.eps_threshold
        s = np.where(ok, np.sqrt(p / np.maximum(1.0 - eps, 1e-6)), 0.0)
        tot = s.sum()
        return s / tot if tot > 0 else np.full_like(s, 1.0 / len(s))

    def aggregate(self, ctx: RoundContext):
        if self.s is None:
            self.s = self.selection_probs(ctx)
        eps = np.clip(ctx.eps_estimates, 0.0, 0.999)
        K = ctx.selected.sum()
        models, weights, ids = [], [], []
        for i in range(len(ctx.connected)):
            if ctx.connected[i] and self.s[i] > 0:
                w = ctx.p[i + 1] / (self.s[i] * (1.0 - eps[i])) / max(K, 1)
                models.append(ctx.client_models[i])
                weights.append(w)
                ids.append(i)
        if getattr(ctx, "telemetry", None):
            codecs = ctx.codecs or {}
            dists = ctx.distortions or {}
            _record_betas(ctx, [
                beta_row(w, client=i, rung=codecs.get(i),
                         distortion=dists.get(i))
                for w, i in zip(weights, ids)])
        if not models:
            return ctx.global_params
        return aggregate_pytrees(models, np.array(weights))


class FedAWE(Strategy):
    """Adaptive weighting via missed-round-scaled local extrapolation (Eq. 51)."""
    name = "fedawe"
    streaming = True              # aggregates via FedAvg; extrapolation is
    #                               client-side (post_local), before encode

    def __init__(self, gamma_g: float = 0.001):
        self.gamma_g = gamma_g

    def init_state(self, runner) -> None:
        self.tau = np.zeros(runner.n_clients, dtype=int)

    def post_local(self, client_id: int, rnd: int, local_model, ctx_global,
                   runner):
        gap = float(rnd - self.tau[client_id])
        adj = jax.tree.map(
            lambda w, g: (w.astype(jnp.float32) - self.gamma_g * gap *
                          (g.astype(jnp.float32) - w.astype(jnp.float32))).astype(w.dtype),
            local_model, ctx_global)
        return adj

    def aggregate(self, ctx: RoundContext):
        for i in range(len(ctx.connected)):
            if ctx.connected[i]:
                self.tau[i] = ctx.rnd
        return FedAvg.aggregate(self, ctx)


class FedExLoRA(Strategy):
    """Exact-aggregation residual for LoRA FFT (Eq. 52–53). Requires the
    runner to be in LoRA mode; aggregates adapters by plain averaging and
    folds the rank-mixing residual into the frozen base weights."""
    name = "fedex_lora"

    def aggregate(self, ctx: RoundContext):
        runner = ctx.runner
        ids = [i for i in range(len(ctx.connected)) if ctx.connected[i]]
        if not ids:
            return ctx.global_params
        adapters = [ctx.client_models[i] for i in ids]
        n = len(ids)
        if getattr(ctx, "telemetry", None):
            codecs = ctx.codecs or {}
            dists = ctx.distortions or {}
            _record_betas(ctx, [
                beta_row(1.0 / n, client=i, rung=codecs.get(i),
                         distortion=dists.get(i)) for i in ids])
        avg = aggregate_pytrees(adapters, np.full(n, 1.0 / n))
        # residual per adapted layer: mean(A_i B_i) − Ā B̄
        scaling = runner.lora_cfg.scaling
        for path in avg:
            mean_prod = sum(jnp.matmul(a[path]["a"], a[path]["b"])
                            for a in adapters) / n
            resid = (mean_prod - avg[path]["a"] @ avg[path]["b"]) * scaling
            runner.fold_into_base(path, resid)
        return avg


def _resolve_fidelity_discount(explicit: Optional[float], ctx) -> float:
    """Strategy knob wins; else ``FFTConfig.fidelity_discount_b``; else 0."""
    if explicit is not None:
        return float(explicit)
    cfg = getattr(getattr(ctx, "runner", None), "cfg", None)
    if cfg is None:
        return 0.0
    return float(getattr(cfg, "fidelity_discount_b", 0.0))


class FedAuto(Strategy):
    """The paper's method (Algorithm 2): Module 1 compensatory training
    (Eq. 6–7) + Module 2 weight optimization (Eq. 8) with the server pin
    (Eq. 9). ``use_module1``/``use_module2`` expose the Table-5 ablations.
    ``fidelity_discount`` (exponent b; None defers to
    ``FFTConfig.fidelity_discount_b``) discounts each upload's post-QP β by
    ``(1 − d)^b`` where d is its measured compression distortion, so a
    sign1-coarse reconstruction no longer weighs like a lossless fp32 one;
    at b = 0 (the default) this is bit-exact with the undiscounted QP."""
    name = "fedauto"
    streaming = True

    def __init__(self, use_module1: bool = True, use_module2: bool = True,
                 fidelity_discount: Optional[float] = None):
        self.use_module1 = use_module1
        self.use_module2 = use_module2
        self.fidelity_discount = fidelity_discount

    def aggregate(self, ctx: RoundContext):
        runner = ctx.runner
        N, C = ctx.client_hists.shape
        miss = missing_classes(ctx.client_hists, ctx.connected)
        comp_model, comp_hist = None, None
        if self.use_module1 and miss.any():
            comp_model, comp_hist = runner.train_compensatory(miss, ctx.rnd)

        def dist(h):
            tot = h.sum()
            return h / tot if tot > 0 else np.full_like(h, 1.0 / len(h), dtype=float)

        rows = [dist(ctx.server_hist.astype(float))]
        models = [ctx.server_model]
        distortion = [0.0]                    # server row: no wire, no loss
        if comp_model is not None:
            rows.append(dist(comp_hist.astype(float)))
            models.append(comp_model)
            distortion.append(0.0)
        ids = [i for i in range(N) if ctx.connected[i]]
        dmap = ctx.distortions or {}
        packed_map = getattr(ctx, "packed", None)
        for i in ids:
            rows.append(dist(ctx.client_hists[i].astype(float)))
            if packed_map is None:
                models.append(ctx.client_models[i])
            distortion.append(float(dmap.get(i, 0.0)))
        alpha_rows = np.stack(rows)
        alpha_g = dist(ctx.global_hist.astype(float))
        active = np.ones(len(rows), dtype=bool)
        if self.use_module2:
            with _phase(ctx, "phase.weight_solve"):
                beta = fedauto_discounted_weights(
                    alpha_rows, alpha_g, np.zeros(len(rows)),
                    np.asarray(distortion), server_row=0,
                    discount_b=_resolve_fidelity_discount(
                        self.fidelity_discount, ctx))
        else:
            beta = fedauto_simple_average_weights(active, 0, comp_model is not None)
        if getattr(ctx, "telemetry", None):
            out = [beta_row(beta[0], role="server")]
            k = 1
            if comp_model is not None:
                out.append(beta_row(beta[1], role="comp"))
                k = 2
            codecs = ctx.codecs or {}
            for j, i in enumerate(ids):
                out.append(beta_row(beta[k + j], client=i, staleness=0,
                                    rung=codecs.get(i),
                                    distortion=float(dmap.get(i, 0.0))))
            _record_betas(ctx, out)
        if packed_map is not None:
            n_dense = len(models)            # server (+ compensatory)
            return _stream_accumulate(
                ctx, dense=list(zip(beta[:n_dense], models)),
                packed=[(beta[n_dense + j], packed_map[i])
                        for j, i in enumerate(ids)])
        return _accumulate(ctx, models, beta)


# ---------------------------------------------------------------------------
# asynchronous strategy family (driven by repro.fl.server.AsyncRoundLoop)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Arrival:
    """One client upload as it lands at the asynchronous server."""
    client: int
    origin_round: int                     # round whose global seeded the update
    staleness: int                        # rnd − origin_round (0 = fresh)
    arrival_s: float                      # absolute simulated landing time
    model: Any                            # w_i^{origin,E}
    delta: Any = None                     # w_i^{origin,E} − w̄^{origin}
    codec: Optional[str] = None           # rung this upload traveled under
    upload_nbytes: Optional[float] = None  # bytes this upload cost on-wire
    distortion: float = 0.0               # ‖carry−decoded‖/‖carry‖ at encode
    packed: Any = None                    # streaming mode: the wire
    #                                       PackedUpdate (model/delta None —
    #                                       decode(payload) IS the
    #                                       origin-relative delta)


@dataclasses.dataclass
class AsyncRoundContext:
    """What the async server knows when it aggregates at round ``rnd``."""
    rnd: int
    now_s: float                          # simulated clock at the round's end
    global_params: Any
    server_model: Any                     # w_s^{r,E} (always staleness 0)
    arrivals: list                        # List[Arrival], landing-time order
    p: np.ndarray
    client_hists: np.ndarray
    server_hist: np.ndarray
    global_hist: np.ndarray
    runner: Any = None
    codec: Optional[str] = None           # decodable wire codec shared by all
    #                                       uploads (None for adaptive runs)
    upload_nbytes: Optional[float] = None  # bytes-on-wire per client upload
    #                                       (None for adaptive runs)
    # per-client wire metadata of the aggregated arrivals, keyed by client id
    # (latest arrival per client; per-arrival values live on each Arrival):
    codecs: Optional[Dict[int, str]] = None
    upload_bytes: Optional[Dict[int, float]] = None
    distortions: Optional[Dict[int, float]] = None
    telemetry: Any = None                 # run telemetry hub (repro.obs);
    #                                       None/falsy = not recording


class AsyncStrategy(Strategy):
    """Aggregates a stream of (possibly stale) arrivals instead of a
    synchronized cohort.  Under ``server_mode="sync"`` the round's connected
    cohort is presented as staleness-0 arrivals, so async strategies remain
    runnable everywhere.  ``wants_delta`` tells the async loop to snapshot
    ``w_i − w̄^{origin}`` at dispatch time — a stale arrival's delta cannot
    be reconstructed later, once the global has moved on."""
    is_async = True
    wants_delta = False

    def aggregate_async(self, ctx: AsyncRoundContext):
        raise NotImplementedError

    def aggregate(self, ctx: RoundContext):
        codecs = ctx.codecs or {}
        nbytes = ctx.upload_bytes or {}
        dists = ctx.distortions or {}
        packed_map = getattr(ctx, "packed", None)
        if packed_map is not None:
            # streaming bridge: arrivals carry the wire payloads; no model
            # or dispatch-time delta is ever materialized
            arrivals = [Arrival(client=i, origin_round=ctx.rnd, staleness=0,
                                arrival_s=float(ctx.rnd), model=None,
                                packed=pu, codec=codecs.get(i),
                                upload_nbytes=nbytes.get(i),
                                distortion=float(dists.get(i, 0.0)))
                        for i, pu in sorted(packed_map.items())]
        else:
            arrivals = [Arrival(client=i, origin_round=ctx.rnd, staleness=0,
                                arrival_s=float(ctx.rnd), model=m,
                                delta=delta_pytree(m, ctx.global_params),
                                codec=codecs.get(i),
                                upload_nbytes=nbytes.get(i),
                                distortion=float(dists.get(i, 0.0)))
                        for i, m in sorted(ctx.client_models.items())]
        actx = AsyncRoundContext(
            rnd=ctx.rnd, now_s=float(ctx.rnd),
            global_params=ctx.global_params, server_model=ctx.server_model,
            arrivals=arrivals, p=ctx.p, client_hists=ctx.client_hists,
            server_hist=ctx.server_hist, global_hist=ctx.global_hist,
            runner=ctx.runner, codec=ctx.codec,
            upload_nbytes=ctx.upload_nbytes, codecs=ctx.codecs,
            upload_bytes=ctx.upload_bytes, distortions=ctx.distortions,
            telemetry=ctx.telemetry)
        return self.aggregate_async(actx)


def _staleness_discount(staleness: int, a: float) -> float:
    """Polynomial discount of FedAsync: (1+s)^{-a}; 1 when fresh."""
    return float((1.0 + max(int(staleness), 0)) ** -a)


class FedAsync(AsyncStrategy):
    """FedAsync-style sequential mixing: each arrival is folded into the
    global model in landing order with rate γ0·(1+s)^{-a}; the server's own
    update is a staleness-0 arrival applied last each round."""
    name = "fedasync"
    streaming = True

    def __init__(self, gamma0: float = 0.6, discount_a: float = 0.5,
                 gamma_server: float = 0.3):
        self.gamma0 = gamma0
        self.discount_a = discount_a
        self.gamma_server = gamma_server

    @staticmethod
    def _mix(global_params, model, gamma: float):
        return jax.tree.map(
            lambda g, w: ((1.0 - gamma) * g.astype(jnp.float32) +
                          gamma * w.astype(jnp.float32)).astype(g.dtype),
            global_params, model)

    def aggregate_async(self, ctx: AsyncRoundContext):
        gammas = [self.gamma0 * _staleness_discount(a.staleness,
                                                    self.discount_a)
                  for a in ctx.arrivals]
        if getattr(ctx, "telemetry", None):
            rows = [beta_row(g, client=a.client, origin_round=a.origin_round,
                             staleness=a.staleness, rung=a.codec,
                             distortion=a.distortion)
                    for g, a in zip(gammas, ctx.arrivals)]
            rows.append(beta_row(self.gamma_server, role="server"))
            _record_betas(ctx, rows)
        if ctx.arrivals and all(a.packed is not None for a in ctx.arrivals):
            # Streaming: the sequential mixing is linear in the models, so
            # unroll it —  w_out = c0·w̄ + Σ_j c_j·model_j + γ_s·w_s with
            # c_j = (1−γ_s)·γ_j·∏_{k>j}(1−γ_k) — and evaluate the Σ over
            # model_j = origin_global_j + decode(payload_j) in one
            # accumulator pass instead of |arrivals| pytree mixes.
            coefs = [0.0] * len(gammas)
            suffix = 1.0 - self.gamma_server
            for j in range(len(gammas) - 1, -1, -1):
                coefs[j] = gammas[j] * suffix
                suffix *= 1.0 - gammas[j]
            return _stream_accumulate(
                ctx, dense=[(suffix, ctx.global_params),
                            (self.gamma_server, ctx.server_model)],
                packed=[(c, a.packed)
                        for c, a in zip(coefs, ctx.arrivals)])
        w = ctx.global_params
        for gamma, arr in zip(gammas, ctx.arrivals):
            w = self._mix(w, arr.model, gamma)
        return self._mix(w, ctx.server_model, self.gamma_server)


class FedBuff(AsyncStrategy):
    """FedBuff-style buffered-K aggregation: client deltas accumulate (with
    staleness discounts) and are applied as one averaged server step only
    once K of them have landed; the server's own delta is applied every
    round so training never stalls on an empty buffer."""
    name = "fedbuff"
    wants_delta = True
    streaming = True              # a held payload's decode IS the
    #                               origin-relative delta: streaming mode
    #                               needs no dispatch-time snapshot at all

    def __init__(self, buffer_k: int = 4, eta: float = 1.0,
                 discount_a: float = 0.5):
        self.buffer_k = buffer_k
        self.eta = eta
        self.discount_a = discount_a

    def init_state(self, runner) -> None:
        self._held: list = []     # (delta|None, disc, meta, packed|None)

    def aggregate_async(self, ctx: AsyncRoundContext):
        for arr in ctx.arrivals:
            # dispatch-time snapshot (w_i − w̄^{origin}); in streaming mode
            # the packed payload replaces it — decode(payload) is exactly
            # that delta, so nothing is materialized at dispatch either
            delta = (None if arr.packed is not None
                     else arr.delta if arr.delta is not None
                     else delta_pytree(arr.model, ctx.global_params))
            self._held.append((
                delta, _staleness_discount(arr.staleness, self.discount_a),
                dict(client=arr.client, origin_round=arr.origin_round,
                     staleness=arr.staleness, rung=arr.codec,
                     distortion=arr.distortion), arr.packed))
        server_delta = delta_pytree(ctx.server_model, ctx.global_params)
        flush = len(self._held) >= self.buffer_k
        denom = 1 + (len(self._held) if flush else 0)
        dense = [(1.0 / denom, server_delta)]
        packed = []
        if flush:
            for d, disc, _meta, pu in self._held:
                if pu is not None:
                    packed.append((disc / denom, pu))
                else:
                    dense.append((disc / denom, d))
        if getattr(ctx, "telemetry", None):
            # each delta's applied step weight: η · disc / denom
            rows = [beta_row(self.eta / denom, role="server")]
            if flush:
                rows.extend(beta_row(self.eta * disc / denom, **meta)
                            for _d, disc, meta, _pu in self._held)
            _record_betas(ctx, rows)
        if flush:
            self._held = []
        if packed:
            step = _stream_delta_sum(ctx, dense, packed)
        else:
            step = _accumulate(ctx, [t for _w, t in dense],
                               np.asarray([w for w, _t in dense]))
        return jax.tree.map(
            lambda g, d: (g.astype(jnp.float32) +
                          self.eta * d.astype(jnp.float32)).astype(g.dtype),
            ctx.global_params, step)


class FedAutoAsync(AsyncStrategy):
    """FedAuto under staleness: Module 1 compensatory training over the
    classes the *arrived* cohort misses, then Module 2's QP (Eq. 8 with the
    Eq. 9 server pin) on the arrivals' α-rows with each β discounted by
    (1+s)^{-a} · (1−d)^{b} (``fedauto_discounted_weights``): staleness ×
    the upload's measured compression distortion.  With every arrival fresh
    and ``fidelity_discount`` at 0 (or every upload lossless) this is
    exactly FedAuto."""
    name = "fedauto_async"
    streaming = True

    def __init__(self, use_module1: bool = True, discount_a: float = 0.5,
                 fidelity_discount: Optional[float] = None):
        self.use_module1 = use_module1
        self.discount_a = discount_a
        self.fidelity_discount = fidelity_discount

    def aggregate_async(self, ctx: AsyncRoundContext):
        runner = ctx.runner
        received = np.zeros(len(ctx.client_hists), dtype=bool)
        for arr in ctx.arrivals:
            received[arr.client] = True
        miss = missing_classes(ctx.client_hists, received)
        comp_model, comp_hist = None, None
        if self.use_module1 and miss.any():
            comp_model, comp_hist = runner.train_compensatory(miss, ctx.rnd)

        def dist(h):
            tot = h.sum()
            return h / tot if tot > 0 else np.full_like(h, 1.0 / len(h),
                                                        dtype=float)

        rows = [dist(ctx.server_hist.astype(float))]
        models = [ctx.server_model]
        staleness = [0]
        distortion = [0.0]
        if comp_model is not None:
            rows.append(dist(comp_hist.astype(float)))
            models.append(comp_model)
            staleness.append(0)
            distortion.append(0.0)
        # client-index order (not landing order): the QP is a batch solve, and
        # this makes the fresh-cohort case bit-identical to synchronous FedAuto
        sorted_arrs = sorted(ctx.arrivals, key=lambda a: (a.client,
                                                          a.origin_round))
        streaming = bool(sorted_arrs) and all(a.packed is not None
                                              for a in sorted_arrs)
        for arr in sorted_arrs:
            rows.append(dist(ctx.client_hists[arr.client].astype(float)))
            if not streaming:
                models.append(arr.model)
            staleness.append(arr.staleness)
            distortion.append(float(arr.distortion))
        alpha_rows = np.stack(rows)
        alpha_g = dist(ctx.global_hist.astype(float))
        with _phase(ctx, "phase.weight_solve"):
            beta = fedauto_discounted_weights(
                alpha_rows, alpha_g, np.asarray(staleness),
                np.asarray(distortion), server_row=0,
                discount_a=self.discount_a,
                discount_b=_resolve_fidelity_discount(self.fidelity_discount,
                                                      ctx))
        if getattr(ctx, "telemetry", None):
            out = [beta_row(beta[0], role="server")]
            k = 1
            if comp_model is not None:
                out.append(beta_row(beta[1], role="comp"))
                k = 2
            for j, arr in enumerate(sorted_arrs):
                out.append(beta_row(beta[k + j], client=arr.client,
                                    origin_round=arr.origin_round,
                                    staleness=arr.staleness, rung=arr.codec,
                                    distortion=arr.distortion))
            _record_betas(ctx, out)
        if streaming:
            n_dense = len(models)            # server (+ compensatory)
            return _stream_accumulate(
                ctx, dense=list(zip(beta[:n_dense], models)),
                packed=[(beta[n_dense + j], arr.packed)
                        for j, arr in enumerate(sorted_arrs)])
        return _accumulate(ctx, models, beta)


class CentralizedPublic(Strategy):
    """Server-only training on the public dataset (no client knowledge)."""
    name = "centralized_public"

    def aggregate(self, ctx: RoundContext):
        _record_betas(ctx, [beta_row(1.0, role="server")])
        return ctx.server_model


STRATEGIES = {
    "fedavg": FedAvg,
    "fedprox": FedProx,
    "scaffold": Scaffold,
    "fedlaw": FedLAW,
    "tf_aggregation": TFAggregation,
    "fedawe": FedAWE,
    "fedex_lora": FedExLoRA,
    "fedauto": FedAuto,
    "centralized_public": CentralizedPublic,
    "fedasync": FedAsync,
    "fedbuff": FedBuff,
    "fedauto_async": FedAutoAsync,
}
