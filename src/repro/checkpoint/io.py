"""msgpack pytree checkpointing (no orbax/flax offline).

Format: {"tree": nested lists/dicts with leaf descriptors, "blobs": raw
bytes}. Dtypes/shapes round-trip exactly; jax arrays come back as numpy
(callers re-device them). Atomic via temp-file rename.
"""
from __future__ import annotations

import os
import tempfile
from typing import Any

import jax
import msgpack
import numpy as np

_LEAF = "__leaf__"


def _pack(tree: Any, blobs: list):
    if isinstance(tree, dict):
        return {k: _pack(v, blobs) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        t = [_pack(v, blobs) for v in tree]
        return {"__tuple__": t} if isinstance(tree, tuple) else t
    if hasattr(tree, "shape"):
        arr = np.asarray(tree)
        blobs.append(arr.tobytes())
        return {_LEAF: len(blobs) - 1, "dtype": str(arr.dtype),
                "shape": list(arr.shape)}
    return {"__scalar__": tree}


def _unpack(node: Any, blobs: list):
    if isinstance(node, dict):
        if _LEAF in node:
            arr = np.frombuffer(blobs[node[_LEAF]], dtype=node["dtype"])
            return arr.reshape(node["shape"]).copy()
        if "__scalar__" in node:
            return node["__scalar__"]
        if "__tuple__" in node:
            return tuple(_unpack(v, blobs) for v in node["__tuple__"])
        return {k: _unpack(v, blobs) for k, v in node.items()}
    if isinstance(node, list):
        return [_unpack(v, blobs) for v in node]
    return node


def save(path: str, tree: Any) -> None:
    tree = jax.tree.map(lambda x: np.asarray(x), tree)
    blobs: list = []
    packed = _pack(tree, blobs)
    payload = msgpack.packb({"tree": packed, "blobs": blobs}, use_bin_type=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)) or ".")
    with os.fdopen(fd, "wb") as f:
        f.write(payload)
    os.replace(tmp, path)


def load(path: str) -> Any:
    with open(path, "rb") as f:
        obj = msgpack.unpackb(f.read(), raw=False)
    return _unpack(obj["tree"], obj["blobs"])
