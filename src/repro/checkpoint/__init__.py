from repro.checkpoint.io import load, save  # noqa: F401
