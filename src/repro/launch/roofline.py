"""Roofline-term extraction from compiled dry-run artifacts (EXPERIMENTS.md
§Roofline).

Convention: jax's ``compiled.cost_analysis()`` reports the SPMD-partitioned
per-device module, so all three terms below are per-chip seconds:

    compute    = HLO_FLOPs_per_chip / 197e12          (v5e bf16 peak)
    memory     = HLO_bytes_per_chip / 819e9           (HBM BW)
    collective = collective_bytes_per_chip / 50e9     (per-link ICI BW,
                  1-link-serialized conservative model)

collective_bytes is parsed from the optimized HLO text: the summed result
sizes of every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute op (fusion never renames collectives, so text parsing is
stable).
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes / s / chip
ICI_BW = 50e9                # bytes / s / link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "u4": 1,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes per collective kind from optimized HLO text."""
    out: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        kind = m.group(2)
        out[kind] = out.get(kind, 0) + _shape_bytes(m.group(1))
    return out


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes: int) -> Dict[str, float]:
    t_c = flops / PEAK_FLOPS
    t_m = bytes_accessed / HBM_BW
    t_x = coll_bytes / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "dominant": dom}


def model_flops(cfg, shape_info: dict) -> float:
    """MODEL_FLOPS: 6·N_active·tokens for training, 2·N_active·tokens for a
    decode/prefill step."""
    n = cfg.active_param_count()
    B, S = shape_info["global_batch"], shape_info["seq_len"]
    if shape_info["kind"] in ("train", "fft_round"):
        return 6.0 * n * B * S
    if shape_info["kind"] == "prefill":
        return 2.0 * n * B * S
    return 2.0 * n * B          # decode: one token per sequence


def analytic_roofline(cfg, shape_info: dict, *, n_devices: int,
                      batch_shards: int, model_shards: int,
                      fsdp: bool = False) -> dict:
    """Napkin-math three-term roofline per device (DESIGN.md §6).

    Motivation: XLA:CPU ``cost_analysis`` counts while-loop (lax.scan)
    bodies ONCE, not ×trip-count, so HLO numbers under-report scanned layer
    stacks by ~L. The analytic model is exact enough for bottleneck
    identification and is what the §Perf loop optimizes; the HLO numbers
    remain in the table as structure-sensitive cross-checks.

    Model (bf16 = 2 bytes, fp32 master math folded into the constants):
      compute  = MODEL_FLOPS/device ÷ peak  (+ ~1/3 remat re-forward when
                 training, matching per-layer jax.checkpoint)
      memory   = params traffic + activation traffic + KV-cache traffic
      collective = TP output all-reduces (2/layer fwd [+2 bwd]) on
                 (tokens_dev × d_model) + DP/FSDP gradient reduce-scatter +
                 all-gather when training.
    """
    B, S = shape_info["global_batch"], shape_info["seq_len"]
    kind = shape_info["kind"]
    n_active = cfg.active_param_count()
    n_total = cfg.param_count()
    L = cfg.num_layers + (cfg.num_encoder_layers if cfg.encoder_decoder else 0)
    d = cfg.d_model
    bytes_p = 2.0

    tokens = B * S if kind in ("train", "prefill", "fft_round") else B
    tokens_dev = tokens / batch_shards
    params_dev = n_total * bytes_p / (model_shards * (batch_shards if fsdp else 1))

    if kind in ("train", "fft_round"):
        flops_dev = 6.0 * n_active * tokens / n_devices * (8.0 / 6.0)  # remat
        # params: fwd read + remat re-read + bwd read + grad write + update
        mem = 5.0 * params_dev
        # activations: ~6 (tokens_dev·d) tensors per layer r/w with remat
        mem += 6.0 * L * tokens_dev * d * bytes_p
        coll = 4.0 * L * tokens_dev * d * bytes_p          # TP psums fwd+bwd
        if fsdp:
            coll += 4.0 * params_dev * batch_shards        # AG + RS per step
        elif batch_shards > 1:
            coll += 2.0 * params_dev                       # DP grad all-reduce
    elif kind == "prefill":
        flops_dev = 2.0 * n_active * tokens / n_devices
        mem = params_dev + 4.0 * L * tokens_dev * d * bytes_p
        coll = 2.0 * L * tokens_dev * d * bytes_p
        if fsdp:
            coll += params_dev * batch_shards
    else:  # decode: one token, full cache read
        flops_dev = 2.0 * n_active * tokens / n_devices
        cache_len = min(S, cfg.sliding_window) if cfg.sliding_window else S
        if cfg.mla:
            kv_bytes = cache_len * (cfg.mla_kv_lora_rank + cfg.mla_rope_head_dim)
        elif cfg.block_pattern is not None:
            # recurrent states: O(1) per layer
            kv_bytes = (cfg.ssm_expand * d * cfg.ssm_state_size)
        else:
            kv_bytes = cache_len * 2 * cfg.num_kv_heads * cfg.resolved_head_dim
        mem = params_dev + B / batch_shards * L * kv_bytes * bytes_p
        coll = 2.0 * L * tokens_dev * d * bytes_p + \
            tokens_dev * cfg.vocab_size * bytes_p / model_shards
        if fsdp:
            coll += params_dev * batch_shards

    t_c = flops_dev / PEAK_FLOPS
    t_m = mem / HBM_BW
    t_x = coll / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    bound = max(t_c, t_m, t_x)
    return {"a_compute_s": t_c, "a_memory_s": t_m, "a_collective_s": t_x,
            "a_dominant": dom, "a_step_s": bound,
            "a_mfu_bound": t_c / bound if bound else 0.0}
