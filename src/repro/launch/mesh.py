"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi_pod adds a leading 2-pod axis.

    The dry-run forces 512 host devices; the single-pod mesh uses the first
    256, so both meshes build in one process.
    """
    import math
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (dryrun.py does this automatically)")
    return jax.make_mesh(shape, axes, devices=devices)


def make_host_mesh():
    """Single-device mesh for CPU smoke runs."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"
