"""Sharding rules: logical-axis rules for activations and path-based
PartitionSpecs for every parameter in the zoo (DESIGN.md §5).

Layout summary (single-pod ('data','model'); multi-pod adds 'pod'):
  batch/tokens            -> ('pod','data')
  attention heads, FFN hidden, vocab, MoE experts -> 'model'
  large archs (≥ fsdp_threshold params) additionally shard the non-'model'
  weight dimension over 'data' (FSDP); XLA inserts the per-layer gathers.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

FSDP_THRESHOLD = 8e9        # params; above this, weights also shard over 'data'


def logical_rules(mesh, cfg: Optional[ModelConfig] = None) -> Dict[str, object]:
    batch = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    msize = dict(mesh.shape)["model"]
    rules = {
        "batch": batch if len(batch) > 1 else batch[0],
        "seq": None,
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        # decode-cache layout: follows the cache (replicated when kv heads
        # don't divide the tensor axis) — see models/attention.gqa_decode
        "kv_cache_heads": None,
    }
    if cfg is not None and cfg.num_kv_heads % msize == 0:
        rules["kv_cache_heads"] = "model"
    # NOTE: vocab stays 'model' even when vocab_size % msize != 0 — GSPMD
    # handles uneven sharding with padding; forcing replication regressed
    # seamless train_4k 1.7× (measured).
    return rules


def _spec_for(path: str, ndim: int, cfg: ModelConfig, fsdp: Optional[str]):
    """PartitionSpec for one (unstacked) param. path: '/'-joined key names."""
    leaf = path.rsplit("/", 1)[-1]

    def pick():
        # ---- embeddings / lm head: shard vocab over model
        if "embed" in path or "lm_head" in path:
            return P("model", fsdp)
        # ---- MoE
        if "/moe/" in path or path.startswith("moe/"):
            if "router" in path:
                return P(None, None)
            if "shared" in path:
                if leaf == "b":
                    return P("model") if "w_up" in path or "w_gate" in path else P(None)
                if "w_down" in path:
                    return P("model", fsdp)
                return P(fsdp, "model")
            if cfg.num_experts % 16 == 0:
                if "w_down" in path:
                    return P("model", None, fsdp)   # (E, f, d): experts sharded
                return P("model", fsdp, None)       # (E, d, f)
            # virtual-expert layout (§Perf B iter 2): E < model size — shard
            # the expert FFN hidden dim instead, matching the shard_map
            # reshape so weights never travel.
            if "w_down" in path:
                return P(None, "model", fsdp)       # (E, f, d)
            return P(None, fsdp, "model")           # (E, d, f)
        # ---- MLA attention
        if cfg.mla and "/attn/" in path:
            if "q_up" in path or "kv_up" in path:
                return P(None, "model")
            if "q_down" in path or "kv_down" in path:
                return P(fsdp, None)
            if leaf == "w" and "wo" in path:
                return P("model", fsdp)
            return P(None)
        # ---- GQA attention / cross attention
        if "/attn/" in path or "/cross/" in path:
            if leaf == "w":
                if "wo" in path:
                    return P("model", fsdp)
                return P(fsdp, "model")              # wq/wk/wv
            if leaf == "b":
                return P(None) if "wo" in path else P("model")
            return P(None)                            # q_norm/k_norm scales
        # ---- dense FFN
        if "/ffn/" in path or path.startswith("ffn/"):
            if leaf == "w":
                return P("model", fsdp) if "w_down" in path else P(fsdp, "model")
            if leaf == "b":
                return P(None) if "w_down" in path else P("model")
            return P(None)
        # ---- Mamba2 / xLSTM (small models: replicate or fsdp only)
        if "/mamba/" in path or "/mlstm/" in path or "/slstm/" in path:
            if leaf == "w" and ndim == 2:
                return P(fsdp, None)
            return P(None)
        # ---- norms, scalars, everything else
        return P(*([None] * min(ndim, 1)))

    spec = pick()
    # pad/truncate to ndim
    parts = list(spec) + [None] * ndim
    return P(*parts[:ndim])


def param_pspecs(params, cfg: ModelConfig, mesh) -> object:
    """Mirror `params` with PartitionSpecs. Detects scanned stacks (paths under
    layers/ or enc_layers/) and prepends a None axis for the layer dim."""
    fsdp = "data" if cfg.param_count() >= FSDP_THRESHOLD and "data" in mesh.axis_names else None

    def one(key_path, leaf):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in key_path)
        scanned = path.startswith("layers/") or path.startswith("enc_layers/")
        ndim = leaf.ndim - (1 if scanned else 0)
        spec = _spec_for(path, ndim, cfg, fsdp)
        if scanned:
            spec = P(*([None] + list(spec)))
        # sanity: never shard an axis that does not divide
        parts = []
        for dim, ax in zip(leaf.shape, list(spec) + [None] * leaf.ndim):
            if ax is None:
                parts.append(None)
                continue
            size = np.prod([mesh.shape[a] for a in (ax if isinstance(ax, tuple) else (ax,))])
            parts.append(ax if dim % int(size) == 0 else None)
        return P(*parts)

    return jax.tree_util.tree_map_with_path(one, params)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Input specs per (arch × input shape): ShapeDtypeStructs + PartitionSpecs
# ---------------------------------------------------------------------------
INPUT_SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
    # extra (not part of the assigned 40): one full FFT round — K parallel
    # clients on the data axis + β-weighted aggregation collective (Eq. 7)
    "fft_round_4k": dict(seq_len=4096, global_batch=256, kind="fft_round",
                         clients=16, client_batch=16),
}

# archs whose attention is not sub-quadratic-capable -> skip long_500k
LONG_CONTEXT_OK = {
    "llava-next-mistral-7b", "starcoder2-7b", "mixtral-8x22b",
    "xlstm-125m", "zamba2-1.2b",
}


def batch_pspec(mesh) -> P:
    batch = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return P(batch if len(batch) > 1 else batch[0])


def input_specs(cfg: ModelConfig, shape_name: str, mesh):
    """Returns (batch_dict_of_ShapeDtypeStruct, pspecs_dict) for train/prefill;
    decode shapes are handled by the dry-run via init_decode_state."""
    sh = INPUT_SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    bspec = batch_pspec(mesh)
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    pspecs: Dict[str, P] = {}

    def add(name, shape, dtype, spec):
        specs[name] = jax.ShapeDtypeStruct(shape, dtype)
        pspecs[name] = spec

    b0 = list(bspec)[0]
    if cfg.vision_frontend:
        n_img = cfg.num_image_tokens
        s_txt = S - n_img
        add("tokens", (B, s_txt), jnp.int32, P(b0, None))
        add("image_embeds", (B, n_img, cfg.d_model), jnp.bfloat16, P(b0, None, None))
        add("labels", (B, S), jnp.int32, P(b0, None))
    elif cfg.encoder_decoder:
        s_enc = min(S, 4096)
        add("tokens", (B, S), jnp.int32, P(b0, None))
        add("encoder_embeds", (B, s_enc, cfg.d_model), jnp.bfloat16, P(b0, None, None))
        add("labels", (B, S), jnp.int32, P(b0, None))
    else:
        add("tokens", (B, S), jnp.int32, P(b0, None))
        add("labels", (B, S), jnp.int32, P(b0, None))
    return specs, pspecs
