"""End-to-end training driver (deliverable b): train an assigned-architecture
model on synthetic token streams — e.g. the ~125M xlstm:

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
        --steps 300 --batch 8 --seq 256 --smoke-scale=false

On CPU this uses the single-device mesh; on a TPU cluster the same code runs
under make_production_mesh with the sharding rules from launch.sharding.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save
from repro.configs import get_config, get_smoke_config
from repro.data.tokens import batches_from_stream, make_bigram_stream
from repro.models import transformer as T
from repro.optim import adamw_init, adamw_update, warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke-scale", default="false")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    smoke = args.smoke_scale.lower() in ("1", "true", "yes")
    cfg = get_smoke_config(args.arch) if smoke else get_config(args.arch)
    n_params_est = cfg.param_count()
    print(f"arch={cfg.name} params≈{n_params_est / 1e6:.1f}M "
          f"steps={args.steps} batch={args.batch} seq={args.seq}")

    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    opt_state = adamw_init(params)
    sched = warmup_cosine(args.lr, warmup=20, total=args.steps)

    stream = make_bigram_stream(500_000, cfg.vocab_size, domain=0,
                                n_domains=1, seed=0)
    batches = batches_from_stream(stream, args.batch, args.seq, seed=0)

    @jax.jit
    def train_step(params, opt_state, toks, labels, lr):
        def loss_fn(p):
            loss, m = T.forward(p, cfg, {"tokens": toks, "labels": labels},
                                q_chunk=min(args.seq, 2048), loss_chunk=256)
            return loss, m

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state = adamw_update(params, grads, opt_state, lr)
        return params, opt_state, loss, metrics

    t0 = time.time()
    losses = []
    for step in range(1, args.steps + 1):
        toks, labels = next(batches)
        params, opt_state, loss, _ = train_step(
            params, opt_state, jnp.asarray(toks), jnp.asarray(labels),
            sched(step))
        losses.append(float(loss))
        if step % args.log_every == 0 or step == 1:
            tps = args.batch * args.seq * step / (time.time() - t0)
            print(f"step {step:5d} loss={losses[-1]:.4f} "
                  f"({np.mean(losses[-10:]):.4f} avg10) tok/s={tps:,.0f}")
    print(f"loss: first={losses[0]:.4f} last10={np.mean(losses[-10:]):.4f} "
          f"wall={time.time() - t0:.1f}s")
    assert np.mean(losses[-10:]) < losses[0], "training did not reduce loss"
    if args.checkpoint:
        save(args.checkpoint, {"params": params, "step": args.steps})
        print(f"checkpoint -> {args.checkpoint}")


if __name__ == "__main__":
    main()
