"""Batched serving driver: prefill a prompt batch, then decode with the
ring-buffer KV cache (SWA archs) / SSM state (recurrent archs).

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-1.2b \
        --smoke-scale=true --batch 4 --prompt-len 64 --decode-steps 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--smoke-scale", default="true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    smoke = args.smoke_scale.lower() in ("1", "true", "yes")
    cfg = get_smoke_config(args.arch) if smoke else get_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    B = args.batch

    enc = None
    if cfg.encoder_decoder:
        enc = jax.random.normal(key, (B, args.prompt_len, cfg.d_model),
                                jnp.dtype(cfg.dtype))
    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (B, args.prompt_len), 0, cfg.vocab_size)
    state = T.init_decode_state(params, cfg, B, args.cache_len,
                                encoder_embeds=enc)

    decode = jax.jit(lambda p, s, t: T.decode_step(p, cfg, s, t))

    # prefill by teacher-forcing the prompt through decode (exactly the KV
    # path that serves; a chunked prefill kernel is the TPU fast path)
    t0 = time.time()
    for t in range(args.prompt_len):
        logits, state = decode(params, state, prompts[:, t:t + 1])
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, -1)[:, None]
    out = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(args.decode_steps):
        logits, state = decode(params, state, tok)
        if args.temperature > 0:
            key, k2 = jax.random.split(key)
            tok = jax.random.categorical(k2, logits / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits, -1)[:, None]
        out.append(np.asarray(tok))
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    toks_s = B * args.decode_steps / t_decode
    print(f"arch={cfg.name} B={B} prefill({args.prompt_len} tok)="
          f"{t_prefill:.2f}s decode={args.decode_steps} steps "
          f"{t_decode:.2f}s -> {toks_s:,.1f} tok/s")
    print("sample:", np.concatenate(out, 1)[0][:16].tolist())


if __name__ == "__main__":
    main()
