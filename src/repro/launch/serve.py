"""Batched serving driver: prefill a prompt batch, then decode with the
ring-buffer KV cache (SWA archs) / SSM state (recurrent archs).

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-1.2b \
        --smoke-scale=true --batch 4 --prompt-len 64 --decode-steps 32

``--mode broadcast`` instead exercises the FL downlink side: the
``PagedBroadcastCache`` below encodes the global model ONCE per
(round, downlink rung) into fixed-size pages and serves every client on
that rung from the cache — the paged-KV serving idiom applied to the
federated broadcast, where re-encoding per client would dominate a
large cohort's round time.

    PYTHONPATH=src python -m repro.launch.serve --mode broadcast \
        --clients 256 --rungs int8,qsgd:4,sign1 --rounds 3
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import transformer as T
from repro.obs.telemetry import NULL_TELEMETRY


# --------------------------------------------------------------------------
# Paged broadcast cache (FL downlink serving)
# --------------------------------------------------------------------------

#: default page size — small enough that a sign1 broadcast still spans
#: several pages, large enough that page bookkeeping is negligible
PAGE_BYTES = 1 << 16


def _pack_pages(payload, page_bytes: int) -> List[np.ndarray]:
    """Flatten a codec payload's wire arrays into fixed-size uint8 pages
    (the last page may be short).  Pages are immutable and shared by
    reference across every client served from them."""
    blob = b"".join(np.asarray(v).tobytes()
                    for el in payload.leaves for v in el.data.values())
    if not blob:
        return [np.zeros(0, np.uint8)]
    return [np.frombuffer(blob[o:o + page_bytes], np.uint8)
            for o in range(0, len(blob), page_bytes)]


class PagedBroadcastCache:
    """Encode-once, serve-many downlink cache keyed ``(round, rung)``.

    The first client of a round on a given rung pays the encode
    (``encode_fn``); its payload is split into fixed-size pages and every
    later client on that rung is served the same page list by reference —
    no copy, no re-encode.  Old rounds are evicted wholesale (all pages of
    a key at once) once they fall ``keep_rounds`` behind the newest round
    seen, so resident pages stay O(#rungs · keep_rounds), independent of
    cohort size.
    """

    def __init__(self, *, page_bytes: int = PAGE_BYTES, keep_rounds: int = 2,
                 telemetry=NULL_TELEMETRY):
        if page_bytes <= 0:
            raise ValueError(f"page_bytes must be > 0, got {page_bytes}")
        if keep_rounds < 1:
            raise ValueError(f"keep_rounds must be >= 1, got {keep_rounds}")
        self.page_bytes = int(page_bytes)
        self.keep_rounds = int(keep_rounds)
        self.telemetry = telemetry
        # (round, rung) -> (payload, pages); insertion-ordered
        self._entries: Dict[Tuple[int, str], Tuple[Any, List[np.ndarray]]] \
            = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.bytes_served = 0.0
        self.peak_pages = 0

    @property
    def n_pages(self) -> int:
        return sum(len(pages) for _, pages in self._entries.values())

    def serve(self, rnd: int, rung: str, encode_fn) -> List[np.ndarray]:
        """Pages of the ``(rnd, rung)`` broadcast; encodes on first use."""
        key = (int(rnd), str(rung))
        ent = self._entries.get(key)
        if ent is None:
            self.misses += 1
            payload = encode_fn()
            ent = (payload, _pack_pages(payload, self.page_bytes))
            self._entries[key] = ent
            self._evict(int(rnd))
            self.peak_pages = max(self.peak_pages, self.n_pages)
            if self.telemetry:
                self.telemetry.counter("broadcast.cache_miss")
        else:
            self.hits += 1
            if self.telemetry:
                self.telemetry.counter("broadcast.cache_hit")
        self.bytes_served += float(sum(p.nbytes for p in ent[1]))
        return ent[1]

    def payload_for(self, rnd: int, rung: str):
        """The cached codec payload backing a served key (what a client
        decodes), or None when the key was never encoded or was evicted."""
        ent = self._entries.get((int(rnd), str(rung)))
        return ent[0] if ent is not None else None

    def _evict(self, current_rnd: int) -> None:
        horizon = current_rnd - self.keep_rounds
        for key in [k for k in self._entries if k[0] <= horizon]:
            del self._entries[key]
            self.evictions += 1

    @property
    def stats(self) -> Dict[str, float]:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "resident_pages": self.n_pages,
                "peak_pages": self.peak_pages,
                "bytes_served": self.bytes_served}


def broadcast_main(args) -> None:
    """Demo/benchmark of the paged broadcast cache: a mixed-rung cohort is
    served the global model each round; encodes happen once per (round,
    rung), everyone else hits pages."""
    from repro.fl.comm import make_codec
    cfg = get_smoke_config(args.arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tree = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    rungs = [r.strip() for r in args.rungs.split(",") if r.strip()]
    codecs = {r: make_codec(r) for r in rungs}
    rng = np.random.default_rng(0)
    client_rung = [rungs[i] for i in rng.integers(0, len(rungs),
                                                  args.clients)]
    cache = PagedBroadcastCache(page_bytes=args.page_bytes)
    for rnd in range(1, args.rounds + 1):
        t0 = time.time()
        m0 = cache.misses
        for c in range(args.clients):
            rung = client_rung[c]
            cache.serve(rnd, rung, lambda rung=rung:
                        codecs[rung].encode(tree))
        dt = time.time() - t0
        print(f"round {rnd}: served {args.clients} clients, "
              f"{cache.misses - m0} encodes, "
              f"{cache.n_pages} resident pages, {dt:.3f}s")
    s = cache.stats
    total = s["hits"] + s["misses"]
    print(f"cache: {s['hits']:.0f}/{total:.0f} hits "
          f"({100 * s['hits'] / max(total, 1):.1f}%), "
          f"{s['misses']:.0f} encodes, {s['evictions']:.0f} evictions, "
          f"peak {s['peak_pages']:.0f} pages, "
          f"{s['bytes_served'] / 1e6:.1f} MB served")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="decode",
                    choices=("decode", "broadcast"))
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--smoke-scale", default="true")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--rungs", default="int8,qsgd:4,sign1")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--page-bytes", type=int, default=PAGE_BYTES)
    args = ap.parse_args()

    if args.mode == "broadcast":
        broadcast_main(args)
        return

    smoke = args.smoke_scale.lower() in ("1", "true", "yes")
    cfg = get_smoke_config(args.arch) if smoke else get_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    B = args.batch

    enc = None
    if cfg.encoder_decoder:
        enc = jax.random.normal(key, (B, args.prompt_len, cfg.d_model),
                                jnp.dtype(cfg.dtype))
    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (B, args.prompt_len), 0, cfg.vocab_size)
    state = T.init_decode_state(params, cfg, B, args.cache_len,
                                encoder_embeds=enc)

    decode = jax.jit(lambda p, s, t: T.decode_step(p, cfg, s, t))

    # prefill by teacher-forcing the prompt through decode (exactly the KV
    # path that serves; a chunked prefill kernel is the TPU fast path)
    t0 = time.time()
    for t in range(args.prompt_len):
        logits, state = decode(params, state, prompts[:, t:t + 1])
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, -1)[:, None]
    out = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(args.decode_steps):
        logits, state = decode(params, state, tok)
        if args.temperature > 0:
            key, k2 = jax.random.split(key)
            tok = jax.random.categorical(k2, logits / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits, -1)[:, None]
        out.append(np.asarray(tok))
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    toks_s = B * args.decode_steps / t_decode
    print(f"arch={cfg.name} B={B} prefill({args.prompt_len} tok)="
          f"{t_prefill:.2f}s decode={args.decode_steps} steps "
          f"{t_decode:.2f}s -> {toks_s:,.1f} tok/s")
    print("sample:", np.concatenate(out, 1)[0][:16].tolist())


if __name__ == "__main__":
    main()
