"""Multi-pod dry-run (deliverable e): prove every (architecture × input
shape × mesh) lowers AND compiles on the production meshes, and extract the
memory/cost/collective numbers the roofline analysis consumes.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results.json
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (device count locks on
# first init). Everything below is ordinary code.

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.configs.base import ATTN, MAMBA2, MLSTM, SLSTM, SHARED_ATTN, ModelConfig
from repro.launch import roofline as rl
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.launch.sharding import (INPUT_SHAPES, LONG_CONTEXT_OK, input_specs,
                                   logical_rules, param_pspecs)
from repro.fl.parallel import make_fft_round_step
from repro.models import dist
from repro.models import transformer as T
from repro.models.layers import set_logical_rules

LR = 1e-3


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, q_chunk: int):
    def train_step(params, batch):
        def loss_fn(p):
            loss, _ = T.forward(p, cfg, batch, q_chunk=q_chunk, loss_chunk=512)
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - LR * g.astype(jnp.float32))
            .astype(p.dtype), params, grads)
        return loss, new_params

    return train_step


def make_prefill_step(cfg: ModelConfig, q_chunk: int):
    def prefill_step(params, batch):
        h, _ = T.hidden_states(params, cfg, batch, q_chunk=q_chunk)
        w = (params["embed"]["embedding"].T if cfg.tie_embeddings
             else params["lm_head"]["embedding"].T)
        return (h[:, -1] @ w).astype(jnp.float32)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, state, tokens):
        return T.decode_step(params, cfg, state, tokens)

    return serve_step


# ---------------------------------------------------------------------------
# decode-state partition specs (mirrors transformer.init_decode_state)
# ---------------------------------------------------------------------------
def _maybe(mesh, ax, dim: int):
    if ax is None:
        return None
    size = 1
    for a in (ax if isinstance(ax, tuple) else (ax,)):
        size *= mesh.shape[a]
    return ax if dim % size == 0 else None


def decode_state_pspecs(cfg: ModelConfig, mesh, batch: int, cache_len: int):
    from repro.models.attention import KVCache
    from repro.models.ssm import MambaCache
    from repro.models.xlstm import MLSTMCache, SLSTMCache

    baxes = batch_axes(mesh)
    b = _maybe(mesh, baxes if len(baxes) > 1 else baxes[0], batch)
    H = cfg.ssm_num_heads or cfg.num_heads
    d_in = cfg.ssm_expand * cfg.d_model

    def kv(scanned: bool):
        ms = dict(mesh.shape)["model"]
        if cfg.mla:
            k = P(b, None, None)
            v = P(b, None, None)
        elif cfg.num_kv_heads % ms != 0 and cache_len % ms == 0:
            # seq-sharded cache (distributed flash decode — §Perf A)
            k = P(b, "model", None, None)
            v = P(b, "model", None, None)
        else:
            kvh = _maybe(mesh, "model", cfg.num_kv_heads)
            k = P(b, None, kvh, None)
            v = P(b, None, kvh, None)
        if scanned:
            k = P(*([None] + list(k)))
            v = P(*([None] + list(v)))
        return KVCache(k=k, v=v, length=P(None) if scanned else P())

    def block_spec(kind: str):
        if kind in (ATTN, SHARED_ATTN):
            return kv(False)
        if kind == MAMBA2:
            return MambaCache(h=P(b, _maybe(mesh, "model", H), None, None),
                              conv=P(b, None, _maybe(mesh, "model", d_in)),
                              length=P())
        if kind == MLSTM:
            return MLSTMCache(C=P(b, _maybe(mesh, "model", H), None, None),
                              n=P(b, _maybe(mesh, "model", H), None),
                              m=P(b, _maybe(mesh, "model", H)), length=P())
        if kind == SLSTM:
            return SLSTMCache(c=P(b, None), n=P(b, None), h=P(b, None),
                              m=P(b, None), length=P())
        raise ValueError(kind)

    state: Dict[str, object] = {}
    if cfg.block_pattern is None:
        state["layers"] = kv(True)
        for i in range(cfg.first_k_dense):
            state[f"dense_layer_{i}"] = kv(False)
    else:
        state["blocks"] = {str(i): block_spec(k)
                           for i, k in enumerate(cfg.layer_kinds())}
    if cfg.encoder_decoder:
        state["enc_out"] = P(b, None, None)
    return state


# ---------------------------------------------------------------------------
# one dry-run
# ---------------------------------------------------------------------------
def cache_len_for(cfg: ModelConfig, seq_len: int) -> int:
    return min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len


def run_one(arch: str, shape_name: str, multi_pod: bool,
            verbose: bool = True, mesh_override=None) -> Dict:
    cfg = get_config(arch)
    sh = INPUT_SHAPES[shape_name]
    mesh_name = mesh_override or ("multi" if multi_pod else "single")
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_OK:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": "full-attention arch (DESIGN.md §4)"}
    t0 = time.time()
    if mesh_override:
        # exploration mesh, e.g. "64x4" -> (data=64, model=4); §Perf D
        d_, m_ = (int(v) for v in mesh_override.split("x"))
        mesh = jax.make_mesh((d_, m_), ("data", "model"),
                             devices=jax.devices()[:d_ * m_])
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    set_logical_rules(logical_rules(mesh, cfg))
    dist.set_mesh_context(dist.MeshContext(mesh=mesh, batch_axes=batch_axes(mesh),
                                           model_axis="model"))
    try:
        params_shape = jax.eval_shape(
            lambda k: T.init_params(k, cfg), jax.random.PRNGKey(0))
        pspecs = param_pspecs(params_shape, cfg, mesh)
        pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                              is_leaf=lambda x: isinstance(x, P))
        B, S = sh["global_batch"], sh["seq_len"]
        q_chunk = 2048 if S > 4096 else 4096

        with dist.set_mesh(mesh):
            if sh["kind"] == "fft_round":
                K, b = sh["clients"], sh["client_batch"]
                step = make_fft_round_step(cfg, lr=LR, q_chunk=q_chunk)
                dax = "data"
                tok = jax.ShapeDtypeStruct((K, b, S), jnp.int32)
                beta = jax.ShapeDtypeStruct((K,), jnp.float32)
                tshard = NamedSharding(mesh, P(dax, None, None))
                jitted = jax.jit(
                    step,
                    in_shardings=(pshard, tshard, tshard,
                                  NamedSharding(mesh, P(None))),
                    out_shardings=(pshard, NamedSharding(mesh, P())))
                lowered = jitted.lower(params_shape, tok, tok, beta)
            elif sh["kind"] in ("train", "prefill"):
                specs, in_pspecs = input_specs(cfg, shape_name, mesh)
                bshard = {k: NamedSharding(mesh, v) for k, v in in_pspecs.items()}
                if sh["kind"] == "train":
                    step = make_train_step(cfg, q_chunk)
                    out_shardings = (NamedSharding(mesh, P()), pshard)
                else:
                    specs.pop("labels"); bshard.pop("labels")
                    step = make_prefill_step(cfg, q_chunk)
                    out_shardings = NamedSharding(
                        mesh, P(list(bshard.values())[0].spec[0],
                                _maybe(mesh, "model", cfg.vocab_size)))
                jitted = jax.jit(step, in_shardings=(pshard, bshard),
                                 out_shardings=out_shardings)
                lowered = jitted.lower(params_shape, specs)
            else:  # decode
                clen = cache_len_for(cfg, S)
                enc_shape = None
                if cfg.encoder_decoder:
                    enc_shape = jax.ShapeDtypeStruct((B, 4096, cfg.d_model),
                                                     jnp.bfloat16)
                state_shape = jax.eval_shape(
                    lambda p: T.init_decode_state(p, cfg, B, clen,
                                                  encoder_embeds=(
                                                      jnp.zeros(enc_shape.shape, enc_shape.dtype)
                                                      if enc_shape else None)),
                    params_shape) if enc_shape is None else jax.eval_shape(
                    lambda p, e: T.init_decode_state(p, cfg, B, clen,
                                                     encoder_embeds=e),
                    params_shape, enc_shape)
                st_pspecs = decode_state_pspecs(cfg, mesh, B, clen)
                st_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), st_pspecs,
                                        is_leaf=lambda x: isinstance(x, P))
                baxes = batch_axes(mesh)
                bax = _maybe(mesh, baxes if len(baxes) > 1 else baxes[0], B)
                tok_shard = NamedSharding(mesh, P(bax, None))
                logits_shard = NamedSharding(mesh, P(bax, _maybe(mesh, "model",
                                                                 cfg.vocab_size)))
                step = make_serve_step(cfg)
                jitted = jax.jit(step, in_shardings=(pshard, st_shard, tok_shard),
                                 out_shardings=(logits_shard, st_shard))
                tok_shape = jax.ShapeDtypeStruct((B, 1), jnp.int32)
                lowered = jitted.lower(params_shape, state_shape, tok_shape)

            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        # jax 0.4.x returns a one-dict list per computation; >=0.5 a dict
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        coll = rl.collective_bytes(hlo)
        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        terms = rl.roofline_terms(flops, bytes_acc, sum(coll.values()))
        mf = rl.model_flops(cfg, sh)
        n_dev = 1
        for v in dict(mesh.shape).values():
            n_dev *= v
        from repro.launch.sharding import FSDP_THRESHOLD
        msh = dict(mesh.shape)["model"]
        bsh = n_dev // msh
        analytic = rl.analytic_roofline(
            cfg, sh, n_devices=n_dev, batch_shards=bsh, model_shards=msh,
            fsdp=cfg.param_count() >= FSDP_THRESHOLD)
        result = {
            "arch": arch, "shape": shape_name,
            "mesh": mesh_name,
            "status": "ok",
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "flops_per_device": flops, "bytes_per_device": bytes_acc,
            "collective_bytes_per_device": sum(coll.values()),
            "collectives": coll,
            "model_flops_total": mf,
            "model_flops_per_device": mf / n_dev,
            "useful_flops_frac": (mf / n_dev) / flops if flops else None,
            **terms,
            **analytic,
            "mem": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            },
        }
        if verbose:
            print(f"[ok] {arch:24s} {shape_name:12s} "
                  f"{'multi' if multi_pod else 'single':6s} "
                  f"compile={t_compile:6.1f}s flops/dev={flops:.3e} "
                  f"dom={terms['dominant']}")
        return result
    except Exception as e:  # noqa: BLE001 — a failed lowering is a result
        if verbose:
            print(f"[FAIL] {arch} {shape_name} {'multi' if multi_pod else 'single'}: {e}")
            traceback.print_exc()
        return {"arch": arch, "shape": shape_name,
                "mesh": mesh_name,
                "status": "fail", "error": f"{type(e).__name__}: {e}"}
    finally:
        dist.set_mesh_context(None)
        set_logical_rules({})


ASSIGNED = [
    "deepseek-v2-236b", "llava-next-mistral-7b", "starcoder2-7b",
    "mixtral-8x22b", "xlstm-125m", "qwen3-1.7b", "codeqwen1.5-7b",
    "zamba2-1.2b", "gemma-7b", "seamless-m4t-large-v2",
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--remesh", default=None,
                    help="exploration mesh 'DxM' (e.g. 64x4) instead of the production meshes")
    args = ap.parse_args()

    archs = ASSIGNED if (args.all or args.arch is None) else [args.arch]
    if args.shape is None:
        # the assigned 4 shapes; fft_round_4k is an extra, run explicitly
        shapes = [s for s, v in INPUT_SHAPES.items() if v["kind"] != "fft_round"]
    else:
        shapes = [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    jsonl = (args.out + ".jsonl") if args.out else None
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in (meshes if not args.remesh else [False]):
                r = run_one(arch, shape, mp, mesh_override=args.remesh)
                results.append(r)
                if jsonl:                      # incremental, crash-safe
                    with open(jsonl, "a") as f:
                        f.write(json.dumps(r) + "\n")
    ok = sum(r["status"] == "ok" for r in results)
    sk = sum(r["status"] == "skipped" for r in results)
    fail = sum(r["status"] == "fail" for r in results)
    print(f"\nDRYRUN SUMMARY: {ok} ok, {sk} skipped, {fail} failed / {len(results)}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    return 0 if fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
