"""Mixtral-8x22B [arXiv:2401.04088].

56L d_model=6144 48H GQA kv=8 d_ff=16384 vocab=32768, 8 experts top-2, SWA.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    arch_type="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    moe_d_ff=16384,
    vocab_size=32768,
    sliding_window=4096,
    moe=True,
    num_experts=8,
    num_experts_per_tok=2,
    ffn_activation="swiglu",
    rope_theta=1000000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b-smoke",
        arch_type="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        moe_d_ff=256,
        vocab_size=512,
        sliding_window=64,
        moe=True,
        num_experts=4,
        num_experts_per_tok=2,
        ffn_activation="swiglu",
    )


register(CONFIG, smoke_config)
