from repro.configs.base import (  # noqa: F401
    ModelConfig,
    get_config,
    get_smoke_config,
    list_archs,
    register,
)
