"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Backbone: 32L d_model=4096 32H GQA kv=8 d_ff=14336 vocab=32000, SWA 4096.
Vision frontend (SigLIP/CLIP + anyres tiling) is a STUB: input_specs supplies
pre-projected patch embeddings (B, num_image_tokens, d_model).
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    arch_type="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    sliding_window=4096,
    ffn_activation="swiglu",
    rope_theta=1000000.0,
    vision_frontend=True,
    num_image_tokens=1152,   # anyres 2x2 tiles + base thumb, pooled stub
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b-smoke",
        arch_type="vlm",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        sliding_window=64,
        ffn_activation="swiglu",
        vision_frontend=True,
        num_image_tokens=16,
    )


register(CONFIG, smoke_config)
