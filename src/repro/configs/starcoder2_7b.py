"""StarCoder2-7B [arXiv:2402.19173].

32L d_model=4608 36H GQA kv=4 d_ff=18432 vocab=49152; RoPE, sliding-window
4096, attention bias, gelu FFN (starcoder2 uses non-gated MLP with bias).
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="starcoder2-7b",
    arch_type="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    sliding_window=4096,
    attn_bias=True,
    ffn_activation="gelu",
    rope_theta=1000000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b-smoke",
        arch_type="dense",
        num_layers=2,
        d_model=144,
        num_heads=6,   # head_dim 24; kv=2 divides 6
        num_kv_heads=2,
        d_ff=288,
        vocab_size=512,
        sliding_window=64,
        attn_bias=True,
        ffn_activation="gelu",
    )


register(CONFIG, smoke_config)
