"""SeamlessM4T-large-v2 (text/unit decoder + speech encoder) [arXiv:2308.11596].

Enc-dec backbone: 24 encoder layers + 24 decoder layers, d_model=1024, 16H
kv=16, d_ff=8192, vocab=256206. The speech frontend (mel filterbank + conformer
feature extractor) is a STUB: input_specs supplies frame embeddings
(B, S_enc, d_model). Decoder has self- and cross-attention.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    arch_type="audio",
    num_layers=24,           # decoder layers
    num_encoder_layers=24,
    encoder_decoder=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    encoder_frontend_dim=1024,
    ffn_activation="gelu",
    attn_bias=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2-smoke",
        arch_type="audio",
        num_layers=2,
        num_encoder_layers=2,
        encoder_decoder=True,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        encoder_frontend_dim=128,
        ffn_activation="gelu",
        attn_bias=True,
    )


register(CONFIG, smoke_config)
