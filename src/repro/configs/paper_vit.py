"""The paper's own large-scale model: ViT-Base/16 (86M) fine-tuned with LoRA
rank 8 on the QKV projection (Appendix III-C, Table 10).

Represented in the zoo as a dense decoder-free encoder config; the actual
vision models used by the FL experiments live in repro.models.vision.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="paper-vit-b16",
    arch_type="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=1000,     # classification head width upper bound
    ffn_activation="gelu",
    attn_bias=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="paper-vit-b16-smoke",
        arch_type="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=100,
        ffn_activation="gelu",
        attn_bias=True,
    )


register(CONFIG, smoke_config)
