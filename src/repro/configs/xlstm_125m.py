"""xLSTM-125M [arXiv:2405.04517].

12L d_model=768, 4 heads, vocab=50304 (GPT-NeoX rounding). No FFN (d_ff=0):
sLSTM and mLSTM blocks carry their own up/down projections. We use the
paper's 1:1 alternating sLSTM/mLSTM pattern.
"""
from repro.configs.base import ModelConfig, MLSTM, SLSTM, register

_PATTERN = tuple(MLSTM if i % 2 == 0 else SLSTM for i in range(12))

CONFIG = ModelConfig(
    name="xlstm-125m",
    arch_type="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=_PATTERN,
    ssm_expand=2,
    ssm_num_heads=4,
    tie_embeddings=True,
    ffn_activation="gelu",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m-smoke",
        arch_type="ssm",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=512,
        block_pattern=(MLSTM, SLSTM),
        ssm_expand=2,
        ssm_num_heads=4,
        tie_embeddings=True,
        ffn_activation="gelu",
    )


register(CONFIG, smoke_config)
