"""Gemma-7B [arXiv:2403.08295].

28L d_model=3072 16H kv=16 (MHA on 7b; MQA is the 2b variant) d_ff=24576,
head_dim=256, GeGLU, vocab=256000, tied embeddings, embedding scaling.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="gemma-7b",
    arch_type="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    d_ff=24576,
    vocab_size=256000,
    head_dim=256,
    ffn_activation="geglu",
    tie_embeddings=True,
    rope_theta=10000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b-smoke",
        arch_type="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        head_dim=48,
        ffn_activation="geglu",
        tie_embeddings=True,
    )


register(CONFIG, smoke_config)
