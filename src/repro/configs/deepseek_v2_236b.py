"""DeepSeek-V2 236B [arXiv:2405.04434].

60L, d_model=5120, 128 heads (MLA: kv_lora=512, q_lora=1536, rope_hd=64,
nope_hd=128, v_hd=128), d_ff(dense)=12288, MoE: 160 routed experts top-6 +
2 shared, expert hidden 1536, first layer dense, vocab 102400.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    d_ff=12288,            # dense-layer FFN hidden (first_k_dense layers)
    moe_d_ff=1536,         # per assigned spec: expert hidden 1536
    vocab_size=102400,
    mla=True,
    mla_kv_lora_rank=512,
    mla_q_lora_rank=1536,
    mla_rope_head_dim=64,
    mla_nope_head_dim=128,
    mla_v_head_dim=128,
    moe=True,
    num_experts=160,
    num_experts_per_tok=6,
    num_shared_experts=2,
    first_k_dense=1,
    ffn_activation="swiglu",
    rope_theta=10000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b-smoke",
        arch_type="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        moe_d_ff=128,
        vocab_size=512,
        mla=True,
        mla_kv_lora_rank=32,
        mla_q_lora_rank=48,
        mla_rope_head_dim=16,
        mla_nope_head_dim=32,
        mla_v_head_dim=32,
        moe=True,
        num_experts=4,
        num_experts_per_tok=2,
        num_shared_experts=1,
        first_k_dense=1,
        ffn_activation="swiglu",
    )


register(CONFIG, smoke_config)
