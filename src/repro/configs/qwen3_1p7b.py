"""Qwen3-1.7B [hf:Qwen/Qwen3-8B family card].

28L d_model=2048 16H GQA kv=8 d_ff=6144 vocab=151936; qk_norm, tied embeds.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    arch_type="dense",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=6144,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    tie_embeddings=True,
    ffn_activation="swiglu",
    rope_theta=1000000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b-smoke",
        arch_type="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        qk_norm=True,
        tie_embeddings=True,
        ffn_activation="swiglu",
    )


register(CONFIG, smoke_config)
