"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B].

32L d_model=4096 32H kv=32 (MHA) d_ff=13440 vocab=92416; qwen1.5 arch:
attention QKV bias, full attention, SwiGLU.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    attn_bias=True,
    ffn_activation="swiglu",
    rope_theta=1000000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b-smoke",
        arch_type="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        attn_bias=True,
        ffn_activation="swiglu",
    )


register(CONFIG, smoke_config)
