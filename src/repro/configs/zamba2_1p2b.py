"""Zamba2-1.2B [arXiv:2411.15242].

38L d_model=2048, Mamba2 backbone (ssm_state=64) with a SHARED-parameter
attention(+MLP) block interleaved every 6 Mamba2 blocks (32H kv=32,
d_ff=8192 inside the shared block). vocab=32000.
"""
from repro.configs.base import ModelConfig, MAMBA2, SHARED_ATTN, register


def _pattern(n: int, every: int):
    kinds = []
    for i in range(n):
        kinds.append(SHARED_ATTN if (i + 1) % every == 0 else MAMBA2)
    return tuple(kinds)


CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    block_pattern=_pattern(38, 6),
    ssm_state_size=64,
    ssm_num_heads=32,
    ssm_expand=2,
    shared_attn_every=6,
    ffn_activation="geglu",
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b-smoke",
        arch_type="hybrid",
        num_layers=3,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        block_pattern=(MAMBA2, MAMBA2, SHARED_ATTN),
        ssm_state_size=16,
        ssm_num_heads=4,
        ssm_expand=2,
        shared_attn_every=3,
        ffn_activation="geglu",
        tie_embeddings=True,
    )


register(CONFIG, smoke_config)
