"""Config system: one dataclass describes every architecture in the zoo.

Each assigned architecture gets a module ``src/repro/configs/<id>.py`` that
exports ``CONFIG`` (the exact published shape, used only by the dry-run via
ShapeDtypeStructs) and ``smoke_config()`` (a reduced same-family variant used
by CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Block kinds understood by repro.models.transformer
ATTN = "attn"            # (GQA / MLA) attention block
MAMBA2 = "mamba2"        # Mamba2 SSM block
SLSTM = "slstm"          # xLSTM sLSTM block
MLSTM = "mlstm"          # xLSTM mLSTM block
SHARED_ATTN = "shared_attn"  # zamba2-style shared-parameter attention block


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // num_heads
    # --- attention variants ---
    rope_theta: float = 10000.0
    qk_norm: bool = False
    attn_bias: bool = False
    sliding_window: Optional[int] = None   # None = full attention
    # MLA (deepseek-v2)
    mla: bool = False
    mla_kv_lora_rank: int = 512
    mla_q_lora_rank: int = 1536
    mla_rope_head_dim: int = 64
    mla_nope_head_dim: int = 128
    mla_v_head_dim: int = 128
    # --- ffn variants ---
    ffn_activation: str = "swiglu"   # swiglu | geglu | gelu
    # --- MoE ---
    moe: bool = False
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                # expert hidden size (if != d_ff)
    first_k_dense: int = 0           # deepseek: first k layers use dense FFN
    router_aux_loss_coef: float = 0.001
    moe_capacity_factor: float = 2.0  # expert-parallel slack (§Perf B3)
    # --- SSM / xLSTM / hybrid ---
    block_pattern: Optional[Tuple[str, ...]] = None  # per-layer kinds; None -> all ATTN
    ssm_state_size: int = 64
    ssm_num_heads: int = 0
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    shared_attn_every: int = 0       # zamba2: shared attn block every k mamba blocks
    # --- enc-dec (audio) ---
    encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_frontend_dim: int = 0    # stub frame-embedding dim (== d_model)
    # --- VLM ---
    vision_frontend: bool = False
    num_image_tokens: int = 0        # anyres stub patch count for train shapes
    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    def layer_kinds(self) -> Tuple[str, ...]:
        if self.block_pattern is not None:
            assert len(self.block_pattern) == self.num_layers
            return self.block_pattern
        return tuple([ATTN] * self.num_layers)

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        total = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d
        enc_layers = self.num_encoder_layers if self.encoder_decoder else 0
        for kind in list(self.layer_kinds()) + [ATTN] * enc_layers:
            if kind in (ATTN, SHARED_ATTN):
                if self.mla:
                    qh = self.mla_nope_head_dim + self.mla_rope_head_dim
                    total += d * self.mla_q_lora_rank + self.mla_q_lora_rank * nq * qh
                    total += d * (self.mla_kv_lora_rank + self.mla_rope_head_dim)
                    total += self.mla_kv_lora_rank * nq * (self.mla_nope_head_dim + self.mla_v_head_dim)
                    total += nq * self.mla_v_head_dim * d
                else:
                    total += d * nq * hd + 2 * d * nkv * hd + nq * hd * d
                total += self._ffn_params()
            elif kind == MAMBA2:
                d_in = self.ssm_expand * d
                total += d * (2 * d_in + 2 * self.ssm_state_size *
                              max(self.ssm_num_heads, 1)) + d_in * d
            elif kind in (SLSTM, MLSTM):
                d_in = self.ssm_expand * d
                total += 4 * d * d_in + d_in * d
        # cross attention for decoder layers
        if self.encoder_decoder:
            total += self.num_layers * (d * nq * hd + 2 * d * nkv * hd + nq * hd * d)
        return total

    def _ffn_params(self) -> int:
        d = self.d_model
        if self.moe:
            eff = self.moe_d_ff or self.d_ff
            n_mats = 3 if self.ffn_activation in ("swiglu", "geglu") else 2
            routed = self.num_experts * n_mats * d * eff
            shared = self.num_shared_experts * n_mats * d * eff
            return routed + shared + d * self.num_experts
        n_mats = 3 if self.ffn_activation in ("swiglu", "geglu") else 2
        return n_mats * d * self.d_ff

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed-active experts)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        eff = self.moe_d_ff or self.d_ff
        n_mats = 3 if self.ffn_activation in ("swiglu", "geglu") else 2
        n_moe_layers = sum(1 for k in self.layer_kinds() if k == ATTN) - self.first_k_dense
        inactive = n_moe_layers * (self.num_experts - self.num_experts_per_tok) * n_mats * d * eff
        return self.param_count() - inactive


_REGISTRY: dict = {}


def register(config: ModelConfig, smoke_fn) -> None:
    _REGISTRY[config.name] = (config, smoke_fn)


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    return _REGISTRY[name][0]


def get_smoke_config(name: str) -> ModelConfig:
    _ensure_loaded()
    return _REGISTRY[name][1]()


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    import importlib
    for mod in (
        "deepseek_v2_236b", "llava_next_mistral_7b", "starcoder2_7b",
        "mixtral_8x22b", "xlstm_125m", "qwen3_1p7b", "codeqwen15_7b",
        "zamba2_1p2b", "gemma_7b", "seamless_m4t_large_v2", "paper_vit",
    ):
        importlib.import_module(f"repro.configs.{mod}")
    _LOADED = True
