"""Health-monitor gate: run a world under sketch telemetry, assert the verdict.

The online detectors (``repro.obs.HealthMonitors``) are only trustworthy if
they fire on known-bad runs AND stay silent on known-good ones.  This
script is that contract as an executable check — CI runs it twice:

    # seeded fault injection: the blackout world must trip alarms
    PYTHONPATH=src python examples/run_health.py --world blackout \\
        --expect alarms --out /tmp/blackout.ndjson

    # committed healthy baseline: the same detectors must stay silent
    PYTHONPATH=src python examples/run_health.py --world bursty_handover \\
        --expect healthy

Two run profiles, selected by ``--expect`` (override with ``--profile``):

* ``baseline`` — the committed healthy-baseline settings (6 clients,
  30 s deadline, default model size; the configuration the
  ``HealthConfig`` thresholds are calibrated to stay silent on for
  ``bursty_handover`` and ``correlated_wifi``);
* ``stress`` — tight 5 s deadline against a 4 MB model, which gives
  fault-injection worlds like ``blackout`` something to break.

Exit code 0 when the verdict matches ``--expect``, 1 when it does not.
``--trace spans.json`` additionally exports and verifies the Chrome trace
(the spans must telescope to the per-round phase gauges).
"""
from __future__ import annotations

import argparse
import sys

from repro.core.strategies import STRATEGIES
from repro.fl.runtime import FFTConfig
from repro.fl.toy import make_toy_runner
from repro.obs import load_report, reconcile, verify_trace

PROFILES = {
    "baseline": dict(n_clients=6, k_selected=4, deadline_s=30.0,
                     model_bytes=None, tau_max=3, buffer_k=2, seed=3),
    "stress": dict(n_clients=8, k_selected=6, deadline_s=5.0,
                   model_bytes=4e6, tau_max=2, buffer_k=3, seed=0),
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--world", default="blackout")
    ap.add_argument("--mode", default="sync",
                    choices=["sync", "async", "buffered"])
    ap.add_argument("--codec", default="adaptive:sign1-fp16")
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--expect", required=True,
                    choices=["healthy", "alarms"])
    ap.add_argument("--profile", default=None,
                    choices=sorted(PROFILES),
                    help="default: baseline for --expect healthy, "
                         "stress for --expect alarms")
    ap.add_argument("--out", default=None, help="NDJSON event-log path")
    ap.add_argument("--trace", default=None,
                    help="also export + verify a Chrome trace here")
    args = ap.parse_args()

    profile = args.profile or ("healthy" == args.expect and "baseline"
                               or "stress")
    prof = PROFILES[profile]
    strategy = "fedauto" if args.mode == "sync" else "fedauto_async"
    cfg = FFTConfig(local_steps=2, batch_size=8, lr=0.05, eval_every=2,
                    failure_mode=f"scenario:{args.world}",
                    server_mode=args.mode, codec=args.codec,
                    telemetry="sketch", telemetry_console=True,
                    telemetry_log=args.out, telemetry_trace=args.trace,
                    **prof)
    runner = make_toy_runner(cfg, n_samples=300, n_classes=4, image_size=8,
                             public_per_class=10, pretrain_steps=0,
                             seed=prof["seed"])
    runner.run(STRATEGIES[strategy](), rounds=args.rounds)

    report = runner.report
    reconcile(report, runner)
    if args.out:
        reloaded = load_report(args.out)
        assert reloaded.health_verdict() == report.health_verdict()
        reconcile(reloaded, runner)
    if args.trace:
        stats = verify_trace(args.trace, report)
        print(f"trace verified: {stats}")

    verdict = report.health_verdict()
    print(f"profile: {profile}  verdict: {verdict}")
    got = "healthy" if verdict["healthy"] else "alarms"
    if got != args.expect:
        print(f"FAIL: expected {args.expect!r}, run was {got!r}",
              file=sys.stderr)
        return 1
    print(f"OK: run is {got!r} as expected")
    return 0


if __name__ == "__main__":
    sys.exit(main())
