"""Compressed uploads through the deadline simulator.

Runs the same scenario world twice — fp32 uploads vs a lossy codec — and
shows the codec converting deadline-cause drops into participants: smaller
payloads finish before the round timeout, so clients the fp32 run lost are
back in the cohort, at (near) identical accuracy thanks to error feedback.

    PYTHONPATH=src python examples/compressed_uploads.py
    PYTHONPATH=src python examples/compressed_uploads.py --codec topk:0.05
"""
from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from repro.core.strategies import STRATEGIES
from repro.fl.runtime import FFTConfig
from repro.fl.toy import make_toy_runner


def run_once(cfg: FFTConfig, rounds: int):
    runner = make_toy_runner(cfg, n_samples=900, public_per_class=10,
                             pretrain_steps=15)
    hist = runner.run(STRATEGIES["fedauto"](), rounds=rounds)
    parts = runner.loop.participants_per_round
    return {
        "acc": hist[-1],
        "participants": float(np.mean(parts)),
        "upload_bytes": runner.upload_bytes,
        "uplink_total": runner.comm.total_uplink_bytes,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--codec", default="int8",
                    help="lossy codec to compare against fp32")
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--world", default="lossy_uplink")
    args = ap.parse_args()

    # model_bytes simulates a paper-scale fp32 payload over the toy CNN; the
    # codec scales it by its exact compression ratio on the real pytree.
    base = FFTConfig(n_clients=8, k_selected=8, local_steps=3, batch_size=16,
                     lr=0.05, seed=0, eval_every=2,
                     failure_mode=f"scenario:{args.world}",
                     deadline_s=5.0, model_bytes=4e6)

    print(f"world={args.world} deadline={base.deadline_s}s "
          f"fp32_payload={base.model_bytes:.0f}B rounds={args.rounds}\n")
    results = {}
    for codec in ["fp32", args.codec]:
        results[codec] = run_once(dataclasses.replace(base, codec=codec),
                                  args.rounds)
        r = results[codec]
        print(f"  {codec:>10}: upload {r['upload_bytes']:>10.0f} B/client  "
              f"mean participants {r['participants']:.2f}/8  "
              f"final acc {r['acc']:.4f}")
    f, c = results["fp32"], results[args.codec]
    print(f"\n{args.codec} cut bytes-on-wire "
          f"{f['upload_bytes'] / max(c['upload_bytes'], 1):.1f}x and "
          f"recovered {c['participants'] - f['participants']:+.2f} "
          f"participants/round (acc {c['acc'] - f['acc']:+.4f}).")


if __name__ == "__main__":
    main()
