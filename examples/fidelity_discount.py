"""Fidelity-aware aggregation: distortion-discounted QP weights.

Every upload that travels through a lossy codec arrives distorted —
``CommState.roundtrip`` measures exactly how much (‖carry − decoded‖ /
‖carry‖, essentially free since both pytrees are in hand) — yet the plain
Eq. 8/9 QP weighs a sign1-coarse reconstruction like a lossless fp32 one.
``fidelity_discount_b`` (or the strategies' ``fidelity_discount`` knob)
multiplies each post-QP β by ``(1 − d)^b`` and redistributes the free mass
on the simplex with the Eq. 9 server pin intact, so a recovering client's
isolated coarse upload counts for what it actually carries.

    PYTHONPATH=src python examples/fidelity_discount.py
    PYTHONPATH=src python examples/fidelity_discount.py --world correlated_wifi
    PYTHONPATH=src python examples/fidelity_discount.py --b 4.0 --codec sign1
"""
from __future__ import annotations

import argparse
import dataclasses

from repro.core.strategies import STRATEGIES
from repro.fl.metrics import accuracy_drawdown, mean_distortion
from repro.fl.runtime import FFTConfig
from repro.fl.toy import make_toy_runner


def run_once(cfg: FFTConfig, rounds: int):
    runner = make_toy_runner(cfg, n_samples=900, public_per_class=10,
                             pretrain_steps=15)
    hist = runner.run(STRATEGIES["fedauto"](), rounds=rounds)
    return {"acc": hist[-1], "hist": hist,
            "drawdown": accuracy_drawdown(hist),
            "mean_distortion": mean_distortion(
                runner.loop.distortion_history)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--codec", default="adaptive:sign1-fp16",
                    help="upload codec (a lossy or adaptive spec distorts)")
    ap.add_argument("--b", type=float, default=0.5,
                    help="fidelity discount exponent (0 disables; keep it "
                         "gentle — large b skews the effective class "
                         "distribution the QP optimized)")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--world", default="diurnal")
    args = ap.parse_args()

    base = FFTConfig(n_clients=8, k_selected=8, local_steps=3, batch_size=16,
                     lr=0.05, seed=0, eval_every=2,
                     failure_mode=f"scenario:{args.world}",
                     deadline_s=5.0, model_bytes=4e6, codec=args.codec)

    print(f"world={args.world} codec={args.codec} rounds={args.rounds}\n")
    results = {}
    for b in (0.0, args.b):
        results[b] = run_once(
            dataclasses.replace(base, fidelity_discount_b=b), args.rounds)
        r = results[b]
        print(f"  fidelity_discount_b={b:>4}: final acc {r['acc']:.4f}  "
              f"max drawdown {r['drawdown']:.4f}  "
              f"mean upload distortion {r['mean_distortion']:.3f}")

    off, on = results[0.0], results[args.b]
    print(f"\n(1-d)^{args.b:g} discounting moved the worst transient "
          f"{off['drawdown']:.4f} -> {on['drawdown']:.4f} at final acc "
          f"{off['acc']:.4f} -> {on['acc']:.4f}.")


if __name__ == "__main__":
    main()
