"""Quickstart: Federated Fine-Tuning with FedAuto on an unreliable
heterogeneous network (the paper's Fig. 1 scenario, CPU-sized).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.strategies import FedAuto, FedAvg
from repro.data.synthetic import fft_split, make_dataset, train_test_split
from repro.fl.partition import partition
from repro.fl.runtime import FFTConfig, FFTRunner
from repro.models.vision import make_model


def main():
    # --- data: public (server) + non-iid private (clients), Fig. 1 style ----
    ds = make_dataset(3000, n_classes=10, image_size=16, channels=1, seed=0)
    train, test = train_test_split(ds, 600, seed=1)
    public, private = fft_split(train, public_per_class=20, seed=0)
    parts, hists = partition("group_classes", private.y, n_clients=20,
                             n_classes=10, classes_per_group=2, seed=0)
    print(f"public={len(public.y)} samples, clients hold "
          f"{[len(p) for p in parts[:4]]}... samples, 2 classes each")

    # --- model + FFT config: 20 clients over wired/WiFi/4G/5G, mixed failures
    init_fn, apply_fn = make_model("cnn", 10, 16, 1)
    cfg = FFTConfig(n_clients=20, k_selected=20, local_steps=5, batch_size=32,
                    lr=0.05, failure_mode="mixed", seed=0, eval_every=5)
    runner = FFTRunner(cfg, init_fn, apply_fn, public, parts, private, test,
                       pretrain_steps=60)
    print(f"server pre-training done: acc={runner.evaluate():.3f}")

    # --- run FedAvg then FedAuto from the same pre-trained model ------------
    g0 = runner.global_params
    log = lambda r, a: print(f"  round {r:3d}  acc={a:.3f}")
    print("FedAvg under mixed failures:")
    runner.rng = np.random.default_rng(42)
    acc_avg = runner.run(FedAvg(), rounds=25, log=log)[-1]

    runner.global_params = g0
    runner.rng = np.random.default_rng(42)
    print("FedAuto (compensatory training + weight optimization):")
    acc_auto = runner.run(FedAuto(), rounds=25, log=log)[-1]
    print(f"\nfinal: FedAvg={acc_avg:.3f}  FedAuto={acc_auto:.3f}")


if __name__ == "__main__":
    main()
