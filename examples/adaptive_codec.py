"""Adaptive per-client codec assignment with a compressed downlink.

Runs the same scenario world under static fp32, a static lossy codec, and
the adaptive controller (``codec="adaptive:<lo>-<hi>"``).  The controller
estimates each client's capacity online — from observed arrivals and
deadline misses only, no oracle — and assigns the richest rung of the
ladder predicted to land before the deadline, per client, per round; the
global broadcast travels compressed too (server-side error feedback).  The
punchline: adaptive recovers the deadline-dropped clients static fp32
loses, at accuracy on par with the best static codec, while fast links
keep their fidelity.

    PYTHONPATH=src python examples/adaptive_codec.py
    PYTHONPATH=src python examples/adaptive_codec.py --world correlated_wifi
    PYTHONPATH=src python examples/adaptive_codec.py --spec adaptive:qsgd:2-fp32
"""
from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from repro.core.strategies import STRATEGIES
from repro.fl.runtime import FFTConfig
from repro.fl.toy import make_toy_runner


def run_once(cfg: FFTConfig, rounds: int):
    runner = make_toy_runner(cfg, n_samples=900, public_per_class=10,
                             pretrain_steps=15)
    hist = runner.run(STRATEGIES["fedauto"](), rounds=rounds)
    return {
        "acc": hist[-1],
        "participants": float(np.mean(runner.loop.participants_per_round)),
        "uplink_MB": runner.comm.total_uplink_bytes / 1e6,
        "downlink_MB": runner.comm.total_downlink_bytes / 1e6,
        "controller": runner.controller,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", default="adaptive:sign1-fp16",
                    help="adaptive codec spec (adaptive:<lo>-<hi>)")
    ap.add_argument("--static", default="int8",
                    help="static lossy codec to compare against")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--world", default="diurnal")
    args = ap.parse_args()

    # model_bytes simulates a paper-scale fp32 payload over the toy CNN; every
    # codec (and each adaptive rung) scales it by its exact compression ratio.
    base = FFTConfig(n_clients=8, k_selected=8, local_steps=3, batch_size=16,
                     lr=0.05, seed=0, eval_every=2,
                     failure_mode=f"scenario:{args.world}",
                     deadline_s=5.0, model_bytes=4e6)

    print(f"world={args.world} deadline={base.deadline_s}s "
          f"fp32_payload={base.model_bytes:.0f}B rounds={args.rounds}\n")
    results = {}
    for codec in ["fp32", args.static, args.spec]:
        results[codec] = run_once(dataclasses.replace(base, codec=codec),
                                  args.rounds)
        r = results[codec]
        print(f"  {codec:>20}: mean participants "
              f"{r['participants']:.2f}/8  final acc {r['acc']:.4f}  "
              f"uplink {r['uplink_MB']:6.2f} MB  "
              f"downlink {r['downlink_MB']:6.2f} MB")

    ctl = results[args.spec]["controller"]
    hist = {k: v for k, v in ctl.rung_histogram().items() if v}
    print(f"\nrung assignments (client-rounds): {hist}")
    print(f"estimated capacities: "
          f"{np.round(ctl.cap_hat / 1e6, 2)} Mbps "
          f"({ctl.n_success} landed / {ctl.n_miss} missed observations)")
    f, a = results["fp32"], results[args.spec]
    print(f"\n{args.spec} recovered "
          f"{a['participants'] - f['participants']:+.2f} participants/round "
          f"over fp32 (acc {a['acc'] - f['acc']:+.4f}) and cut the "
          f"broadcast {f['downlink_MB'] / max(a['downlink_MB'], 1e-9):.1f}x.")


if __name__ == "__main__":
    main()
