"""Run telemetry end to end: instrumented run → NDJSON log → Markdown report.

Turns on the flight recorder (``FFTConfig.telemetry``) for a short
scenario run, writes the schema-versioned NDJSON event log, reloads it,
cross-checks the reloaded report against the run's own accounting
(``repro.obs.reconcile``), and renders the Markdown run report — the same
tables ``python -m benchmarks.report run-report <log.ndjson>`` prints.

    PYTHONPATH=src python examples/telemetry_report.py
    PYTHONPATH=src python examples/telemetry_report.py --mode buffered \\
        --codec adaptive:sign1-fp16 --out /tmp/telemetry.ndjson

``--telemetry sketch`` records the same run through the bounded-memory
sketch sink (PR 8) — byte totals stay bit-equal, distributions become
ε-approximate quantiles; ``--trace spans.json`` additionally exports the
phase timers as Perfetto-loadable Chrome trace-event JSON and verifies the
spans telescope back to the report's phase gauges.
"""
from __future__ import annotations

import argparse

from repro.core.strategies import STRATEGIES
from repro.fl.runtime import FFTConfig
from repro.fl.toy import make_toy_runner
from repro.obs import (load_report, reconcile, render_markdown,
                       verify_trace)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--world", default="bursty_handover")
    ap.add_argument("--mode", default="sync",
                    choices=["sync", "async", "buffered"])
    ap.add_argument("--strategy", default=None,
                    help="default: fedauto (sync) / fedauto_async (async)")
    ap.add_argument("--codec", default="adaptive:sign1-fp16")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--out", default="telemetry.ndjson",
                    help="NDJSON event-log path")
    ap.add_argument("--report-out", default=None,
                    help="also write the Markdown report here")
    ap.add_argument("--telemetry", default="full",
                    choices=["full", "sketch"],
                    help="flight-recorder mode (sketch = bounded memory)")
    ap.add_argument("--trace", default=None,
                    help="also export a Chrome trace-event JSON here")
    args = ap.parse_args()

    strategy = args.strategy or ("fedauto" if args.mode == "sync"
                                 else "fedauto_async")
    cfg = FFTConfig(n_clients=8, k_selected=6, local_steps=2, batch_size=16,
                    failure_mode=f"scenario:{args.world}", deadline_s=5.0,
                    model_bytes=4e6, server_mode=args.mode, tau_max=3,
                    buffer_k=3, codec=args.codec, eval_every=2, seed=0,
                    telemetry=args.telemetry, telemetry_log=args.out,
                    telemetry_console=True, telemetry_trace=args.trace)
    runner = make_toy_runner(cfg, n_samples=600, public_per_class=10,
                             pretrain_steps=15)
    hist = runner.run(STRATEGIES[strategy](), rounds=args.rounds)
    print(f"\naccuracy history: {[round(a, 4) for a in hist]}")

    # the NDJSON log round-trips to the same flight record the run held in
    # memory, and both agree with CommState's byte totals and the loop's
    # participant counts (load_report picks RunReport or SketchReport by
    # the log's recorded telemetry mode)
    reloaded = load_report(args.out)
    nums = reconcile(reloaded, runner)
    assert (reloaded.drop_cause_counts()
            == runner.report.drop_cause_counts())
    print(f"reconciled: {nums}")

    # per-phase profiler (PR 7): where each round's wall time actually
    # went — exclusive timers, so shares sum to 100%
    print("\nphase table (hottest first):")
    for row in reloaded.phase_table():
        print(f"  {row['phase']:<14s} {row['total_s']:8.3f} s total"
              f"  {row['s_per_round'] * 1e3:8.2f} ms/round"
              f"  {row['share'] * 100:5.1f}%")

    if args.trace:
        stats = verify_trace(args.trace, runner.report)
        print(f"\ntrace verified: {stats} → load {args.trace} in "
              f"https://ui.perfetto.dev")

    md = render_markdown([reloaded])
    print("\n" + md)
    if args.report_out:
        with open(args.report_out, "w") as fh:
            fh.write(md + "\n")
        print(f"\nwrote {args.report_out}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
