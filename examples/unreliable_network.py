"""Strategy shoot-out across connection-failure modes (paper Tables 1-2,
reduced): every baseline vs FedAuto under transient / intermittent / mixed
failures with non-iid clients.

    PYTHONPATH=src python examples/unreliable_network.py [--rounds 20]
"""
import argparse

import numpy as np

from repro.core.strategies import STRATEGIES
from repro.data.synthetic import fft_split, make_dataset, train_test_split
from repro.fl.partition import partition
from repro.fl.runtime import FFTConfig, FFTRunner
from repro.models.vision import make_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--strategies", default="fedavg,fedprox,fedawe,fedauto")
    args = ap.parse_args()

    ds = make_dataset(2000, n_classes=4, image_size=8, channels=1, seed=0)
    train, test = train_test_split(ds, 400, seed=1)
    public, private = fft_split(train, public_per_class=15, seed=0)
    parts, _ = partition("group_classes", private.y, 8, 4, classes_per_group=1,
                         group_size=2, seed=0)
    init_fn, apply_fn = make_model("cnn", 4, 8, 1)

    print(f"{'strategy':20s} " + "  ".join(f"{m:>12s}"
          for m in ["transient", "intermittent", "mixed"]))
    for name in args.strategies.split(","):
        accs = []
        for mode in ["transient", "intermittent", "mixed"]:
            cfg = FFTConfig(n_clients=8, k_selected=8, local_steps=3,
                            batch_size=16, lr=0.05, failure_mode=mode,
                            seed=0, eval_every=10 ** 6, model_bytes=0.2e6)
            runner = FFTRunner(cfg, init_fn, apply_fn, public, parts, private,
                               test, pretrain_steps=30)
            runner.rng = np.random.default_rng(7)
            accs.append(runner.run(STRATEGIES[name](), args.rounds)[-1])
        print(f"{name:20s} " + "  ".join(f"{a:12.3f}" for a in accs))


if __name__ == "__main__":
    main()
