"""Asynchronous aggregation server demo: staleness-buffered rounds vs the
synchronous baseline, scored in simulated wall-clock seconds.

Run 1 drives the synchronous server (stragglers discarded at the deadline).
Run 2 drives the asynchronous server on the *same world and seed*: late
uploads are computed anyway, parked in the staleness buffer, and aggregated
(staleness-discounted through FedAuto-Async's QP) in the round their upload
actually lands.  Run 3 replays run 2's recorded trace twice and asserts the
async run is bit-exact — the same per-realization guarantee the synchronous
engine has.

    PYTHONPATH=src python examples/async_server.py \
        [--scenario diurnal] [--rounds 8] [--deadline 3.0] [--tau-max 4]
"""
import argparse
import collections
import os
import tempfile

from repro.core.strategies import STRATEGIES
from repro.fl.runtime import FFTConfig
from repro.fl.scenarios import available_scenarios
from repro.fl.toy import make_server_mode_runners, make_toy_runner


def timeline_str(runner):
    return "  ".join(f"{p.t_s:6.1f}s acc={p.acc:.3f}"
                     for p in runner.timeline)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="diurnal",
                    choices=available_scenarios())
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--deadline", type=float, default=3.0)
    ap.add_argument("--tau-max", type=int, default=4)
    ap.add_argument("--trace", default=None)
    args = ap.parse_args()
    trace = args.trace
    if trace is None:
        fd, trace = tempfile.mkstemp(suffix=".ndjson")
        os.close(fd)

    cfg = FFTConfig(n_clients=8, k_selected=8, local_steps=3, batch_size=16,
                    lr=0.05, seed=0, eval_every=2, model_bytes=0.2e6,
                    failure_mode=f"scenario:{args.scenario}",
                    deadline_s=args.deadline, tau_max=args.tau_max)
    runners = make_server_mode_runners(cfg, modes=("sync", "async"))

    # --- run 1: synchronous baseline ---------------------------------------
    acc_sync = runners["sync"].run(STRATEGIES["fedauto"](), args.rounds)
    print(f"sync   ({args.scenario}, deadline {args.deadline}s): "
          f"{timeline_str(runners['sync'])}")

    # --- run 2: staleness-buffered async server, recorded ------------------
    runners["async"].cfg.trace_record = trace
    acc_async = runners["async"].run(STRATEGIES["fedauto_async"](),
                                     args.rounds)
    loop = runners["async"].loop
    print(f"async  ({args.scenario}, tau_max {args.tau_max}):   "
          f"{timeline_str(runners['async'])}")
    stale = collections.Counter(loop.staleness_applied)
    print(f"  arrivals applied by staleness: "
          f"{dict(sorted(stale.items()))}  "
          f"(evicted={loop.buffer.n_evicted}, "
          f"unreachable={loop.n_unreachable})")
    print(f"  final: sync={acc_sync[-1]:.3f} async={acc_async[-1]:.3f}")

    # --- run 3: bit-exact replay of the async realization ------------------
    rep_cfg = FFTConfig(**{**cfg.__dict__, "server_mode": "async",
                           "trace_record": None, "trace_replay": trace})
    reps = [make_toy_runner(rep_cfg).run(STRATEGIES["fedauto_async"](),
                                         args.rounds) for _ in range(2)]
    assert reps[0] == reps[1] == acc_async, "async replay must be bit-exact"
    print(f"replayed {trace} twice: histories bit-exact with live run")


if __name__ == "__main__":
    main()
