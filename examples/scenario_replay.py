"""Record a network-scenario realization, then replay it bit-exactly.

Run 1 samples a scenario world (default: correlated Wi-Fi outages under an
8 s server deadline) and records every round to an NDJSON trace.  Run 2
replays the trace: identical per-round ``connected`` masks, identical
accuracy curve — the paper's per-realization convergence claim, made
operational.  Replaying also lets two *different* strategies face the exact
same failure realization, which the demo shows for FedAvg vs FedAuto.

    PYTHONPATH=src python examples/scenario_replay.py \
        [--scenario correlated_wifi] [--rounds 10] [--trace /tmp/trace.ndjson]
"""
import argparse
import os
import tempfile

import numpy as np

from repro.core.strategies import STRATEGIES
from repro.fl.runtime import FFTConfig
from repro.fl.scenarios import available_scenarios, load_trace
from repro.fl.toy import make_toy_runner


def build_runner(cfg):
    return make_toy_runner(cfg)


def masks_of(runner, rounds):
    runner.failures.reset()
    return np.stack([runner.failures.draw(r) for r in range(1, rounds + 1)])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="correlated_wifi",
                    choices=available_scenarios())
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--deadline", type=float, default=8.0)
    ap.add_argument("--trace", default=None)
    args = ap.parse_args()
    if args.trace:
        trace = args.trace
    else:
        fd, trace = tempfile.mkstemp(suffix=".ndjson")
        os.close(fd)

    base = dict(n_clients=8, k_selected=8, local_steps=3, batch_size=16,
                lr=0.05, seed=0, eval_every=10 ** 6, model_bytes=0.2e6,
                deadline_s=args.deadline)

    # --- run 1: live scenario, recorded ------------------------------------
    cfg = FFTConfig(failure_mode=f"scenario:{args.scenario}",
                    trace_record=trace, **base)
    runner = build_runner(cfg)
    acc_live = runner.run(STRATEGIES["fedauto"](), args.rounds)
    masks_live = masks_of(runner, args.rounds)
    print(f"recorded {args.rounds} rounds of scenario:{args.scenario} "
          f"-> {trace}")
    header, rounds = load_trace(trace)
    causes = {}
    for rec in rounds.values():
        for c in rec["clients"]:
            causes[c["cause"]] = causes.get(c["cause"], 0) + 1
    print(f"  trace causes: {causes}")

    # --- run 2: bit-exact replay -------------------------------------------
    cfg2 = FFTConfig(failure_mode="replay", trace_replay=trace, **base)
    runner2 = build_runner(cfg2)
    acc_replay = runner2.run(STRATEGIES["fedauto"](), args.rounds)
    masks_replay = masks_of(runner2, args.rounds)
    same_masks = bool((masks_live == masks_replay).all())
    print(f"replay: masks identical={same_masks}  "
          f"accuracy live={acc_live[-1]:.3f} replay={acc_replay[-1]:.3f}")
    assert same_masks and acc_live == acc_replay

    # --- bonus: a different strategy against the SAME realization ----------
    cfg3 = FFTConfig(failure_mode="replay", trace_replay=trace, **base)
    runner3 = build_runner(cfg3)
    acc_avg = runner3.run(STRATEGIES["fedavg"](), args.rounds)
    print(f"same realization, fedavg={acc_avg[-1]:.3f} vs "
          f"fedauto={acc_replay[-1]:.3f}")


if __name__ == "__main__":
    main()
