"""Streaming fused aggregation under the buffered server.

Runs the same buffered-mode scenario twice — ``streaming_agg="off"``
(the materializing control arm: every packed upload decoded to a full
fp32 model before the β-reduce) vs ``"auto"`` (packed uploads fed
straight through the batched decode-and-accumulate kernels via the
``StreamAccumulator``) — and shows three things line up:

* the global params of the two arms agree to float tolerance,
* the uplink-decode attribution gauges flip from all-fallback to
  all-fused, with the peak decoded footprint dropping from O(K) full
  models to O(1) accumulator-sized,
* the run-report phase table shows the aggregate phase shrinking.

    PYTHONPATH=src python examples/streaming_agg.py
    PYTHONPATH=src python examples/streaming_agg.py --rounds 8 --codec qsgd:4
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategies import STRATEGIES
from repro.fl.runtime import FFTConfig
from repro.fl.toy import make_toy_runner


def run_once(streaming_agg: str, args) -> object:
    cfg = FFTConfig(n_clients=8, k_selected=8, local_steps=2, batch_size=16,
                    lr=0.05, seed=0, eval_every=2, tx_delay_s=0.8,
                    failure_mode=f"scenario:{args.world}", deadline_s=5.0,
                    model_bytes=2e6, server_mode="buffered", buffer_k=4,
                    tau_max=3, codec=args.codec,
                    streaming_agg=streaming_agg, telemetry=True)
    runner = make_toy_runner(cfg, n_samples=600, public_per_class=10,
                             pretrain_steps=10)
    hist = runner.run(STRATEGIES["fedbuff"](), rounds=args.rounds)
    return runner, hist


def uplink_gauges(runner) -> dict:
    """Sum the per-round uplink-decode attribution gauges."""
    fused = fallback = 0
    peak = 0.0
    for rec in runner.report.rounds:
        g = rec["gauges"]
        fused += int(g.get("uplink_fused_payloads", 0))
        fallback += int(g.get("uplink_fallback_payloads", 0))
        peak = max(peak, float(g.get("uplink_peak_decoded_bytes", 0.0)))
    return {"fused": fused, "fallback": fallback, "peak_bytes": peak}


def print_phase_table(label: str, runner) -> float:
    """Render the run-report phase table; return the aggregate-phase s."""
    agg_s = 0.0
    print(f"\n  phase table ({label}):")
    print(f"    {'phase':<16} {'total_s':>9} {'s/round':>9} {'share':>7}")
    for row in runner.report.phase_table():
        print(f"    {row['phase']:<16} {row['total_s']:>9.3f} "
              f"{row['s_per_round']:>9.4f} {row['share']:>6.1%}")
        if row["phase"] == "aggregate":
            agg_s = float(row["total_s"])
    return agg_s


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--codec", default="int8")
    ap.add_argument("--world", default="bursty_handover")
    args = ap.parse_args()

    print(f"buffered server (fedbuff, buffer_k=4), codec={args.codec}, "
          f"world={args.world}, rounds={args.rounds}")

    r_mat, _ = run_once("off", args)       # materializing control arm
    r_str, hist = run_once("auto", args)   # streaming fused aggregation

    # both arms must produce the same global model: the streaming path is
    # a reassociation of the same β-weighted sum, not a different update
    diffs = [float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(r_mat.global_params),
        jax.tree.leaves(r_str.global_params))]
    maxerr = max(diffs) if diffs else 0.0
    print(f"\nglobal-params parity: maxerr {maxerr:.2e}")
    assert maxerr < 1e-3, f"streaming diverged from control ({maxerr:.2e})"
    print(f"accuracy history (streaming): {[round(a, 4) for a in hist]}")

    # uplink-decode attribution: the control arm decodes every payload to
    # fp32 (all fallback); the streaming arm fuses every payload
    gm, gs = uplink_gauges(r_mat), uplink_gauges(r_str)
    print(f"\nuplink decode attribution over {args.rounds} rounds:")
    print(f"  materializing: fused={gm['fused']:>3}  "
          f"fallback={gm['fallback']:>3}  "
          f"peak decoded {gm['peak_bytes'] / 1e6:.2f} MB")
    print(f"      streaming: fused={gs['fused']:>3}  "
          f"fallback={gs['fallback']:>3}  "
          f"peak decoded {gs['peak_bytes'] / 1e6:.2f} MB")
    assert gs["fused"] > 0 and gs["fallback"] == 0, gs
    assert gm["fallback"] > 0, gm

    agg_mat = print_phase_table("materializing", r_mat)
    agg_str = print_phase_table("streaming", r_str)
    if agg_mat > 0 and agg_str > 0:
        print(f"\naggregate phase: {agg_mat:.3f}s -> {agg_str:.3f}s "
              f"({agg_mat / agg_str:.2f}x) with peak decoded bytes "
              f"{gm['peak_bytes'] / max(gs['peak_bytes'], 1):.0f}x smaller.")
    print("\nstreaming aggregation OK: identical model, fused decode path, "
          "O(1) peak decoded memory.")


if __name__ == "__main__":
    main()
