"""FedAuto FFT of a transformer LM with LoRA adapters (paper §V-C
generalized to the LLM zoo): clients hold domain-specific token streams,
only rank-r adapters travel, and FedAuto's class-histogram machinery runs on
hashed token buckets (DESIGN.md §4).

    PYTHONPATH=src python examples/fft_lora_llm.py [--rounds 8]
"""
import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.aggregation import aggregate_pytrees, fedauto_weights
from repro.data.tokens import (batches_from_stream, make_bigram_stream,
                               token_class_histogram)
from repro.fl.lora import LoRAConfig, apply_lora, lora_init
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_smoke_config("qwen3-1.7b")
    key = jax.random.PRNGKey(0)
    base = T.init_params(key, cfg)
    lcfg = LoRAConfig(rank=4, alpha=8.0,
                      match=lambda p: p.endswith("wq/w") or p.endswith("wv/w"))
    adapters = lora_init(jax.random.fold_in(key, 1), base, lcfg)
    n_ad = len(jax.tree.leaves(adapters))
    print(f"arch={cfg.name}: {len(jax.tree.leaves(base))} base tensors frozen, "
          f"{n_ad} LoRA tensors trainable")

    # domain-specific client corpora + hashed-bucket histograms (Remark 2)
    N_BUCKETS = 32
    streams = [make_bigram_stream(20_000, cfg.vocab_size, domain=i,
                                  n_domains=args.clients, seed=0)
               for i in range(args.clients)]
    server_stream = np.concatenate(
        [make_bigram_stream(4_000, cfg.vocab_size, domain=i,
                            n_domains=args.clients, seed=1)
         for i in range(args.clients)])
    hists = np.stack([token_class_histogram(s, N_BUCKETS) for s in streams])
    server_hist = token_class_histogram(server_stream, N_BUCKETS)
    global_hist = server_hist + hists.sum(0)

    def loss_fn(ad, toks, labels):
        params = apply_lora(base, ad, lcfg)
        loss, _ = T.forward(params, cfg, {"tokens": toks, "labels": labels},
                            q_chunk=args.seq, loss_chunk=args.seq)
        return loss

    @jax.jit
    def local_update(ad, toks, labels, lr):
        def step(a, _):
            l, g = jax.value_and_grad(loss_fn)(a, toks, labels)
            a = jax.tree.map(lambda p, gg: p - lr * gg, a, g)
            return a, l
        ad, losses = jax.lax.scan(step, ad, None, length=args.local_steps)
        return ad, losses[-1]

    iters = [batches_from_stream(s, 4, args.seq, seed=i)
             for i, s in enumerate(streams)]
    server_iter = batches_from_stream(server_stream, 4, args.seq, seed=99)
    rng = np.random.default_rng(0)

    for r in range(1, args.rounds + 1):
        up = rng.uniform(size=args.clients) > 0.35        # unreliable uplinks
        models, rows = [], []
        toks, labels = next(server_iter)
        server_model, sl = local_update(adapters, jnp.asarray(toks),
                                        jnp.asarray(labels), 1e-2)
        models.append(server_model)
        rows.append(server_hist / server_hist.sum())
        for i in range(args.clients):
            if not up[i]:
                continue
            toks, labels = next(iters[i])
            m, _ = local_update(adapters, jnp.asarray(toks),
                                jnp.asarray(labels), 1e-2)
            models.append(m)
            rows.append(hists[i] / hists[i].sum())
        beta = fedauto_weights(np.stack(rows), global_hist / global_hist.sum(),
                               np.ones(len(rows), bool), 0)
        adapters = aggregate_pytrees(models, beta)
        print(f"round {r}: connected={int(up.sum())}/{args.clients} "
              f"server_loss={float(sl):.3f} beta={np.round(beta, 3).tolist()}")
    print("done — adapters aggregated with FedAuto weights each round")


if __name__ == "__main__":
    main()
