"""Population-scale scenario-engine bench: timing-only rounds to 1M clients.

Rows:

* ``population/10k_<world>_participants`` — exact connected-client totals
  at n=10k over a few rounds (count kind: any shift in the realized
  simulation gates the bench).
* ``population/10k_adaptive_participants`` / ``.../10k_skipped_participants``
  — same accounting with a real adaptive controller pricing rungs against
  the synthetic wire model, straggler skip on.
* ``population/sketch_trace_bytes`` — on-disk size of a v5 sketch trace of
  the 10k adaptive run (count kind: sketch-size regressions gate).
* ``population/engine_equiv_exact`` — 1.0 iff the vectorized engine is
  bit-identical to the heap reference across every registered world at
  small n (exact kind).
* ``population/100k_us_per_round`` and ``population/1m_us_per_round`` —
  wall time per simulated round at 100k and 1M clients (timing kind,
  warn-only).  The 1M row doubles as the "completes a 1M-client round"
  acceptance check.
"""
from __future__ import annotations

import os
import tempfile
import time
from typing import List

import numpy as np

from repro.fl.scenarios import (available_scenarios, make_scenario_model,
                                simulate_population)

COUNT_WORLDS = ["cross_region", "lossy_uplink"]


def _engine_equivalent(n: int = 33, rounds: int = 3) -> bool:
    for name in available_scenarios():
        models = {
            eng: make_scenario_model(name, n, model_bytes=2e5,
                                     deadline_s=10.0, seed=3, engine=eng)
            for eng in ("heap", "vectorized")}
        for r in range(1, rounds + 1):
            ev = {eng: m.draw_events(r) for eng, m in models.items()}
            a, b = ev["heap"], ev["vectorized"]
            if not (np.array_equal(a.up_mask(), b.up_mask())
                    and np.array_equal(a.finish_array(), b.finish_array())
                    and a.cause_list() == b.cause_list()):
                return False
    return True


def _timed(world: str, n: int, rounds: int, **kw) -> float:
    t0 = time.perf_counter()
    simulate_population(world, n, rounds, **kw)
    return (time.perf_counter() - t0) / rounds


def run(quick: bool = True) -> List[str]:
    rows = []

    # exact participant accounting at 10k (gates)
    for world in COUNT_WORLDS:
        t0 = time.perf_counter()
        stats = simulate_population(world, 10_000, 3, seed=0)
        us = (time.perf_counter() - t0) / 3 * 1e6
        total = sum(s.n_connected for s in stats)
        rows.append(f"population/10k_{world}_participants,{us:.0f},{total}")

    # adaptive controller + straggler skip + v5 sketch trace at 10k
    with tempfile.TemporaryDirectory() as td:
        trace = os.path.join(td, "pop10k.ndjson")
        t0 = time.perf_counter()
        stats = simulate_population(
            "lossy_uplink", 10_000, 3, seed=0, k_selected=5_000,
            adaptive="adaptive:sign1-fp16", skip_stragglers=True,
            trace_path=trace, trace_mode="sketch")
        us = (time.perf_counter() - t0) / 3 * 1e6
        rows.append("population/10k_adaptive_participants,"
                    f"{us:.0f},{sum(s.n_connected for s in stats)}")
        rows.append("population/10k_skipped_participants,"
                    f"0,{sum(s.n_skipped for s in stats)}")
        rows.append("population/sketch_trace_bytes,"
                    f"0,{os.path.getsize(trace)}")

    # vectorized vs heap reference, every registered world
    ok = _engine_equivalent()
    rows.append(f"population/engine_equiv_exact,0,{1.0 if ok else 0.0:.4f}")

    # scale timings (warn-only)
    s = _timed("cross_region", 100_000, 3 if quick else 5, seed=0)
    rows.append(f"population/100k_us_per_round,{s * 1e6:.0f},{s:.3f}")
    s = _timed("cross_region", 1_000_000, 1 if quick else 2, seed=0)
    rows.append(f"population/1m_us_per_round,{s * 1e6:.0f},{s:.3f}")
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
