"""Table 5: FedAuto ablations — Module 1 (compensatory training) ×
Module 2 (weight optimization), mixed failures, non-iid."""
import numpy as np

from benchmarks.common import make_problem, timed_run
from repro.core.strategies import FedAuto


def run(quick: bool = True):
    rounds = 30 if quick else 200
    runner = make_problem(non_iid=True, failure_mode="mixed", quick=quick)
    rows = []
    g0 = runner.global_params
    for m1, m2 in [(False, False), (True, False), (False, True), (True, True)]:
        runner.global_params = g0
        runner.rng = np.random.default_rng(123)
        hist, us = timed_run(runner, FedAuto(use_module1=m1, use_module2=m2),
                             rounds)
        rows.append(f"table5/m1={int(m1)}_m2={int(m2)},{us:.0f},{hist[-1]:.4f}")
    runner.global_params = g0
    return rows
