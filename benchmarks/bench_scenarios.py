"""Scenario-registry sweep: FedAuto vs FedAvg / FedProx / TF-Aggregation
across every named network world (beyond the paper's Table 6).

Rows: ``scenario:<name>/<strategy>,us_per_round,final_accuracy`` plus a
``.../participation`` row carrying the realized mean connected fraction, so
the accuracy deltas can be read against how hostile each world actually was.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import make_problem, run_strategies
from repro.fl.scenarios import available_scenarios

STRATS = ["fedavg", "fedprox", "tf_aggregation", "fedauto"]


def run(quick: bool = True) -> List[str]:
    rows = []
    rounds = 8 if quick else 60
    deadline = 8.0 if quick else 20.0
    names = available_scenarios()
    if quick:
        names = ["correlated_wifi", "diurnal", "bursty_handover", "churn",
                 "cross_region"]
    for name in names:
        runner = make_problem(non_iid=True,
                              failure_mode=f"scenario:{name}",
                              quick=quick, deadline_s=deadline, seed=0)
        rows += run_strategies(runner, STRATS, rounds,
                               f"scenario:{name}")
        # realized hostility of this world: the exact model the strategies
        # faced (same channels/seed), re-drawn from its seed
        runner.failures.reset()
        frac = np.mean([runner.failures.draw(r).mean()
                        for r in range(1, rounds + 1)])
        rows.append(f"scenario:{name}/participation,0,{frac:.4f}")
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
