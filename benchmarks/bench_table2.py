"""Table 2: full-parameter FFT, full participation, NON-iid data ×
failure modes — the paper's headline comparison."""
from benchmarks.common import make_problem, run_strategies

QUICK_STRATS = ["centralized_public", "fedavg", "fedprox", "fedawe", "fedauto"]
FULL_STRATS = ["centralized_public", "fedavg", "fedprox", "scaffold",
               "fedlaw", "tf_aggregation", "fedawe", "fedauto"]


def run(quick: bool = True):
    rows = []
    rounds = 30 if quick else 200
    strats = QUICK_STRATS if quick else FULL_STRATS
    for mode in (["mixed"] if quick else ["transient", "intermittent", "mixed"]):
        runner = make_problem(non_iid=True, failure_mode=mode, quick=quick)
        rows += run_strategies(runner, strats, rounds, f"table2/noniid/{mode}")
        ideal = make_problem(non_iid=True, failure_mode="none", quick=quick)
        rows += run_strategies(ideal, ["fedavg"], rounds,
                               f"table2/noniid/{mode}/ideal")
    return rows
