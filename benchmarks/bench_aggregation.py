"""FedAuto server-side overhead: per-round cost of Module 2's QP solve and
of the β-weighted aggregation (Eq. 7) as the participant count / model size
grows — the paper's plug-and-play claim is that this overhead is negligible
next to local training."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import aggregate_pytrees, fedauto_weights


def run(quick: bool = True):
    rows = []
    rng = np.random.default_rng(0)
    # QP solve cost vs participants / classes
    for J, C in [(12, 10), (22, 100)] + ([] if quick else [(52, 1000)]):
        alpha = rng.dirichlet(np.ones(C) * 0.5, size=J)
        ag = rng.dirichlet(np.ones(C))
        active = np.ones(J, bool)
        fedauto_weights(alpha, ag, active, 0)           # compile
        t0 = time.perf_counter()
        for _ in range(5):
            beta = fedauto_weights(alpha, ag, active, 0)
        us = (time.perf_counter() - t0) / 5 * 1e6
        rows.append(f"aggregation/qp_J{J}_C{C},{us:.0f},{float(beta.sum()):.4f}")

    # weighted aggregation cost vs model size
    for P in [int(2e5)] + ([] if quick else [int(1e7)]):
        key = jax.random.PRNGKey(0)
        models = [{"w": jax.random.normal(jax.random.fold_in(key, i), (P,))}
                  for i in range(22)]
        beta = np.full(22, 1 / 22)
        aggregate_pytrees(models, beta)
        t0 = time.perf_counter()
        for _ in range(5):
            out = aggregate_pytrees(models, beta)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / 5 * 1e6
        rows.append(f"aggregation/weighted_sum_P{P},{us:.0f},22")
    return rows
