"""Codec × scenario world × server mode sweep: does compression convert
``deadline``-cause drops into participants?

The deadline simulator prices every upload at the codec's exact byte count,
so a lossy codec's smaller payload finishes earlier and clients that missed
the fp32 deadline recover.  ``model_bytes`` simulates a paper-scale payload
over the toy problem (the codec scales it by its measured compression ratio
on the real trainable pytree).  Rows:

  comm:<world>/<mode>/<codec>,us_per_round,final_accuracy
  comm:<world>/<mode>/<codec>/participants,0,mean per-round participant count
  comm:<world>/<mode>/<codec>/upload_bytes,0,per-client bytes on wire
  comm:<world>/deadline_drop_fp32,0,fraction of up-link rounds lost to the
      deadline at fp32 size (the recovery headroom compression plays for)
  comm:kernel/dequant_fedagg_*,us,fused vs decode-then-aggregate timing

Acceptance (ISSUE 3): on ≥ 2 worlds a lossy codec strictly increases the
mean participant count vs fp32 at the same deadline, with final accuracy
within 1 point.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import make_problem, timed_run
from repro.core.strategies import STRATEGIES
from repro.fl.scenarios.engine import CAUSE_DEADLINE

# Simulated fp32 payload (bytes): paper-scale upload over the toy model.
MODEL_BYTES = 4e6
DEADLINE_S = 5.0

MODES = {"sync": "fedauto", "async": "fedauto_async"}


def _run_one(world: str, mode: str, codec: str, rounds: int, quick: bool):
    runner = make_problem(non_iid=True, failure_mode=f"scenario:{world}",
                          quick=quick, deadline_s=DEADLINE_S, seed=0,
                          server_mode=mode, tau_max=4, buffer_k=4,
                          codec=codec, model_bytes=MODEL_BYTES)
    hist, us_per_round = timed_run(runner, STRATEGIES[MODES[mode]](), rounds)
    parts = runner.loop.participants_per_round
    return (hist[-1], float(np.mean(parts)) if parts else 0.0,
            runner.upload_bytes, us_per_round)


def _deadline_drop_fraction(world: str, rounds: int, quick: bool) -> float:
    """Of the client-rounds whose link was up, how many died to the
    deadline at fp32 size — the headroom compression can recover."""
    m = make_problem(non_iid=True, failure_mode=f"scenario:{world}",
                     quick=quick, deadline_s=DEADLINE_S, seed=0,
                     model_bytes=MODEL_BYTES)
    m.failures.reset()
    up, late = 0, 0
    for r in range(1, rounds + 1):
        for e in m.failures.draw_events(r).events:
            up += int(e.up)
            late += int(e.up and e.cause == CAUSE_DEADLINE)
    return late / max(up, 1)


def _bench_kernel(quick: bool) -> List[str]:
    """Fused dequantize-and-β-accumulate vs decode-then-fedagg."""
    from repro.kernels import ref
    M, P = 22, 100_000 if quick else 1_000_000
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.integers(-127, 128, (M, P)), jnp.int8)
    scales = jnp.asarray(rng.uniform(1e-4, 1e-2, M), jnp.float32)
    betas = jnp.asarray(rng.dirichlet(np.ones(M)), jnp.float32)

    fused = jax.jit(ref.dequant_fedagg)
    unfused = jax.jit(lambda q_, s_, b_: ref.fedagg(
        q_.astype(jnp.float32) * s_[:, None], b_))
    rows = []
    for name, fn in [("fused", fused), ("decode_then_agg", unfused)]:
        fn(q, scales, betas)                        # compile
        t0 = time.perf_counter()
        for _ in range(5):
            out = fn(q, scales, betas)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / 5 * 1e6
        gbps = M * P / (us / 1e6) / 1e9             # int8 payload bytes read
        rows.append(f"comm:kernel/dequant_fedagg_{name},{us:.0f},{gbps:.1f}")
    return rows


def run(quick: bool = True) -> List[str]:
    rows = []
    rounds = 8 if quick else 30
    worlds = (["lossy_uplink", "diurnal"] if quick
              else ["lossy_uplink", "diurnal", "correlated_wifi",
                    "cross_region"])
    codecs = (["fp32", "int8", "topk:0.1"] if quick
              else ["fp32", "fp16", "int8", "qsgd:4", "topk:0.1", "sign1"])
    for world in worlds:
        rows.append(f"comm:{world}/deadline_drop_fp32,0,"
                    f"{_deadline_drop_fraction(world, rounds, quick):.4f}")
        for mode in MODES:
            for codec in codecs:
                final, parts, up_bytes, us = _run_one(world, mode, codec,
                                                      rounds, quick)
                rows.append(f"comm:{world}/{mode}/{codec},{us:.0f},"
                            f"{final:.4f}")
                rows.append(f"comm:{world}/{mode}/{codec}/participants,0,"
                            f"{parts:.3f}")
                rows.append(f"comm:{world}/{mode}/{codec}/upload_bytes,0,"
                            f"{up_bytes:.0f}")
    rows.extend(_bench_kernel(quick))
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
