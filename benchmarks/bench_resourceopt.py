"""Fig. 5: FedAuto (aggregation-only) vs physical-layer resource allocation
(ResourceOpt-1 joint / ResourceOpt-2 per-standard) under transient failures."""
from benchmarks.common import make_problem, run_strategies


def run(quick: bool = True):
    rounds = 30 if quick else 200
    rows = []
    for label, ropt, strat in [
        ("resourceopt1", "joint", "fedavg"),
        ("resourceopt2", "per_standard", "fedavg"),
        ("fedauto_no_ropt", None, "fedauto"),
    ]:
        runner = make_problem(non_iid=True, failure_mode="transient",
                              quick=quick, resource_opt=ropt)
        rows += run_strategies(runner, [strat], rounds, f"fig5/{label}")
    return rows
