"""Adaptive per-client codec assignment vs the static codecs it subsumes.

The adaptive controller (``codec="adaptive:<lo>-<hi>"``) probes each client
at the richest rung, backs off on observed deadline misses, and climbs back
as uploads land — so on worlds where static fp32 loses whole cohorts to the
deadline it should recover them like a small static codec does, while
spending extra bytes only on clients whose links can afford them (and
compressing the downlink broadcast too).  Rows:

  adaptive:<world>/<mode>/<codec>,us_per_round,final_accuracy
  adaptive:<world>/<mode>/<codec>/participants,0,mean per-round participants
  adaptive:<world>/<mode>/<codec>/uplink_MB,0,total simulated uplink MB
  adaptive:<world>/<mode>/rungs,0,rung assignment histogram (name:count|...)
  adaptive:<world>/<mode>/replay_bit_exact,0,1 if the recorded v3 trace
      replays to the identical accuracy history (0 = regression)

Acceptance (ISSUE 4): on ≥ 2 worlds, in sync AND buffered modes,
``adaptive:sign1-fp16`` achieves strictly higher mean participants than
static fp32 at final accuracy within 1 point of the best static codec.

Every run is telemetry-instrumented (``repro.obs``): headline numbers come
from the run's ``RunReport`` and are cross-checked against the comm/loop
accounting via ``reconcile``.  For the full per-round picture (drop-cause
breakdown, β-mass tables) run with ``telemetry_log=`` and render the log
with ``python -m benchmarks.report run-report <log.ndjson>``.
"""
from __future__ import annotations

import os
import tempfile
from typing import List

from benchmarks.common import (BenchResult, make_problem, report_phases,
                               timed_run)
from repro.core.strategies import STRATEGIES
from repro.obs import reconcile

# Same simulated paper-scale payload and deadline as bench_comm, so the
# static rows are directly comparable across the two benchmarks.
MODEL_BYTES = 4e6
DEADLINE_S = 5.0

MODES = {"sync": "fedauto", "buffered": "fedauto_async"}
ADAPTIVE = "adaptive:sign1-fp16"


def _run_one(world: str, mode: str, codec: str, rounds: int, quick: bool,
             trace_record=None, trace_replay=None):
    runner = make_problem(non_iid=True, failure_mode=f"scenario:{world}",
                          quick=quick, deadline_s=DEADLINE_S, seed=0,
                          server_mode=mode, tau_max=4, buffer_k=4,
                          codec=codec, model_bytes=MODEL_BYTES,
                          trace_record=trace_record,
                          trace_replay=trace_replay, telemetry=True)
    hist, us_per_round = timed_run(runner, STRATEGIES[MODES[mode]](), rounds)
    # headline numbers from the telemetry flight record, cross-checked
    # against the run's own accounting
    reconcile(runner.report, runner)
    return runner, hist, runner.report.mean_participants(), us_per_round


def run(quick: bool = True) -> List[str]:
    rows = []
    # 30 rounds so finals are past the early transient (and, on diurnal,
    # past the first trough); shorter runs make the ±1 pt accuracy match
    # a coin flip on the toy problem
    rounds = 30 if quick else 40
    worlds = (["diurnal", "correlated_wifi"] if quick
              else ["diurnal", "correlated_wifi", "cross_region",
                    "bursty_handover"])
    statics = ["fp32", "int8"] if quick else ["fp32", "fp16", "int8", "sign1"]
    for world in worlds:
        for mode in MODES:
            for codec in statics + [ADAPTIVE]:
                trace = None
                if codec == ADAPTIVE:
                    trace = os.path.join(tempfile.mkdtemp(),
                                         f"{world}_{mode}.ndjson")
                runner, hist, parts, us = _run_one(
                    world, mode, codec, rounds, quick, trace_record=trace)
                # headline row carries the run's per-phase profiler seconds
                # into the JSON baseline
                rows.append(BenchResult(
                    name=f"adaptive:{world}/{mode}/{codec}", us_per_call=us,
                    derived=f"{hist[-1]:.4f}", value=float(f"{hist[-1]:.4f}"),
                    kind="accuracy", phases=report_phases(runner)))
                rows.append(f"adaptive:{world}/{mode}/{codec}/participants,"
                            f"0,{parts:.3f}")
                rows.append(f"adaptive:{world}/{mode}/{codec}/uplink_MB,0,"
                            f"{runner.report.total_upload_bytes() / 1e6:.2f}")
                if codec == ADAPTIVE:
                    hist_r = _run_one(world, mode, codec, rounds, quick,
                                      trace_replay=trace)[1]
                    rows.append(f"adaptive:{world}/{mode}/replay_bit_exact,"
                                f"0,{int(hist_r == hist)}")
                    rungs = "|".join(
                        f"{k}:{v}" for k, v in
                        runner.controller.rung_histogram().items() if v)
                    rows.append(f"adaptive:{world}/{mode}/rungs,0,{rungs}")
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
