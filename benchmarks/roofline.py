"""§Roofline report: reads the dry-run JSON (produced by
``python -m repro.launch.dryrun --all --out benchmarks/dryrun_results.json``)
and prints the per-(arch × shape × mesh) roofline table.

The compute term uses max(HLO_FLOPs, analytic MODEL_FLOPS/device): XLA's
cost analysis undercounts ``ragged_dot`` (MoE grouped matmuls), so the
analytic bound keeps MoE archs honest.
"""
import json
import os

from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "dryrun_optimized.json")
if not os.path.exists(DEFAULT_PATH):  # fall back to the baseline table
    DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "dryrun_results.json")


def run(quick: bool = True, path: str = DEFAULT_PATH):
    if not os.path.exists(path):
        return [f"roofline/skipped,0,no {path} (run repro.launch.dryrun --all)"]
    with open(path) as f:
        results = json.load(f)
    rows = []
    for r in results:
        if r.get("status") != "ok":
            continue
        flops = max(r["flops_per_device"], r.get("model_flops_per_device", 0.0))
        t_c = flops / PEAK_FLOPS
        t_m = r["bytes_per_device"] / HBM_BW
        t_x = r["collective_bytes_per_device"] / ICI_BW
        dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
                  key=lambda kv: kv[1])[0]
        total = max(t_c, t_m, t_x)
        frac = r.get("useful_flops_frac")
        rows.append(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
            f"{total * 1e6:.0f},"
            f"dom={dom};tc={t_c:.4f};tm={t_m:.4f};tx={t_x:.4f};"
            f"useful={frac if frac is None else round(frac, 3)}")
    return rows
