"""Table 4: partial-parameter fine-tuning (LoRA on the ViT attention
projections), mixed failures, non-iid."""
from benchmarks.common import make_problem, run_strategies


def run(quick: bool = True):
    rounds = 20 if quick else 150
    strats = (["fedavg", "fedex_lora", "fedauto"] if quick else
              ["centralized_public", "fedavg", "fedprox", "scaffold",
               "fedlaw", "fedawe", "fedex_lora", "fedauto"])
    runner = make_problem(non_iid=True, failure_mode="mixed", quick=quick,
                          model="vit")
    return run_strategies(runner, strats, rounds, "table4/lora")
