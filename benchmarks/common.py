"""Shared benchmark scaffolding.

Every paper table/figure gets a module with ``run(quick: bool) -> rows``
where each row is either a ``name,us_per_call,derived`` CSV string or a
``BenchResult``.  ``us_per_call`` is wall time per FFT round (or per kernel
call); ``derived`` is the table's metric (accuracy, participants, …).
Quick mode shrinks the problem so ``python -m benchmarks.run`` finishes on
CPU; ``--full`` approaches the paper's setting.

Besides the CSV stream, the harness persists every bench's results as a
schema-versioned ``BENCH_<name>.json`` (``BENCH_SCHEMA``/``BENCH_VERSION``)
carrying per-metric kinds, per-phase profiler seconds, and an environment
fingerprint — the machine-readable baselines ``benchmarks.report diff``
compares across runs.
"""
from __future__ import annotations

import dataclasses
import datetime
import json
import os
import platform
import subprocess
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.strategies import STRATEGIES
from repro.data.synthetic import fft_split, make_dataset, train_test_split
from repro.fl.lora import LoRAConfig
from repro.fl.partition import partition
from repro.fl.runtime import FFTConfig, FFTRunner
from repro.models.vision import make_model


def make_problem(*, non_iid: bool, failure_mode: str, quick: bool,
                 model: str = "cnn", k_selected: Optional[int] = None,
                 resource_opt: Optional[str] = None, seed: int = 0,
                 deadline_s: Optional[float] = None,
                 trace_record: Optional[str] = None,
                 trace_replay: Optional[str] = None,
                 server_mode: str = "sync", tau_max: int = 5,
                 buffer_k: int = 4, eval_every: Optional[int] = None,
                 codec: str = "fp32", downlink_codec: Optional[str] = None,
                 model_bytes: Optional[float] = -1.0,
                 telemetry: bool = False,
                 telemetry_log: Optional[str] = None):
    n_clients = 8 if quick else 20
    n_classes = 4 if quick else 10
    img = 8 if quick else 16
    n_samples = 1500 if quick else 6000
    ds = make_dataset(n_samples, n_classes=n_classes, image_size=img,
                      channels=1, noise=0.8, seed=seed)
    train, test = train_test_split(ds, n_samples // 5, seed=seed + 1)
    pub, priv = fft_split(train, public_per_class=10 if quick else 30,
                          seed=seed)
    mode = "group_classes" if non_iid else "iid"
    cpg = 1 if quick else 2
    parts, _ = partition(mode, priv.y, n_clients, n_classes,
                         classes_per_group=cpg,
                         group_size=2 if quick else 4, seed=seed)
    lora_cfg = None
    if model == "vit":
        lora_cfg = LoRAConfig(rank=8, match=lambda p: "qkv/w" in p)
    init_fn, apply_fn = make_model(model, n_classes, img, 1)
    cfg = FFTConfig(
        n_clients=n_clients,
        k_selected=k_selected or n_clients,
        local_steps=3 if quick else 5,
        batch_size=16 if quick else 32,
        lr=0.05 if model == "cnn" else 0.02,
        failure_mode=failure_mode,
        resource_opt=resource_opt,
        seed=seed,
        eval_every=eval_every if eval_every is not None else 10 ** 6,
        # -1 keeps the historical benchmark sizes; None derives from the
        # trainable pytree (the FFTConfig default); a float overrides.
        model_bytes=(0.2e6 if quick else 0.86e6) if model_bytes == -1.0
        else model_bytes,
        trace_record=trace_record,
        trace_replay=trace_replay,
        server_mode=server_mode,
        tau_max=tau_max,
        buffer_k=buffer_k,
        codec=codec,
        downlink_codec=downlink_codec,
        telemetry=telemetry,
        telemetry_log=telemetry_log,
    )
    if deadline_s is not None:
        cfg.deadline_s = deadline_s
    runner = FFTRunner(cfg, init_fn, apply_fn, pub, parts, priv, test,
                       lora_cfg=lora_cfg, pretrain_steps=30 if quick else 100)
    return runner


def timed_run(runner, strategy, rounds: int):
    """One timed ``runner.run``: ``(history, us_per_round)``, measured with
    the monotonic clock (``time.perf_counter`` — wall-clock jumps from NTP
    adjustments can't corrupt a bench number)."""
    t0 = time.perf_counter()
    hist = runner.run(strategy, rounds=rounds)
    return hist, (time.perf_counter() - t0) / rounds * 1e6


def report_phases(runner) -> Optional[Dict[str, float]]:
    """Per-phase profiler seconds of the runner's last instrumented run
    (``RunReport.phase_seconds``), or None when telemetry was off."""
    rep = getattr(runner, "report", None)
    if rep is None:
        return None
    phases = rep.phase_seconds()
    return ({k: round(float(v), 6) for k, v in phases.items()}
            if phases else None)


def run_strategies(runner, names: List[str], rounds: int,
                   label: str, strategy_kwargs: Optional[Dict] = None) -> List[str]:
    rows = []
    g0 = runner.global_params
    kw = strategy_kwargs or {}
    for name in names:
        runner.global_params = g0
        runner.rng = np.random.default_rng(123)
        strat = STRATEGIES[name](**kw.get(name, {}))
        hist, us_per_round = timed_run(runner, strat, rounds)
        # telemetry-instrumented runs read the headline number from the
        # flight record (identical to hist[-1] by construction — the
        # eval_acc gauge is the same evaluate() call)
        final = hist[-1]
        if getattr(runner, "report", None) is not None:
            acc = runner.report.final_accuracy()
            if acc is not None:
                final = acc
        rows.append(f"{label}/{name},{us_per_round:.0f},{final:.4f}")
    runner.global_params = g0
    return rows


# ---------------------------------------------------------------------------
# structured bench results — the machine-readable baselines
# ---------------------------------------------------------------------------
BENCH_SCHEMA = "fft-bench"
BENCH_VERSION = 1

# metric kinds and how ``benchmarks.report diff`` compares them:
#   accuracy  regression iff new < old − atol (improvements pass)
#   count     regression iff |new − old| > atol (deterministic accounting —
#             participants, simulated MB — where *any* shift means the run
#             changed behavior)
#   exact     must match bit-for-bit (replay/bit-exactness indicator rows)
#   timing    relative band with a noise floor; warn-only by default
#   info      non-numeric payloads (rung histograms, error rows) — never
#             gate, mismatches are surfaced as notes
BENCH_KINDS = ("accuracy", "count", "exact", "timing", "info")


@dataclasses.dataclass
class BenchResult:
    """One bench metric: the CSV row, typed."""
    name: str
    us_per_call: float
    derived: str                          # raw derived column (CSV payload)
    value: Optional[float] = None         # numeric derived, when parsable
    kind: str = "accuracy"
    phases: Optional[Dict[str, float]] = None   # profiler seconds
    #                                             (``report_phases``)

    def __post_init__(self):
        if self.kind not in BENCH_KINDS:
            raise ValueError(f"unknown metric kind {self.kind!r} "
                             f"(known: {BENCH_KINDS})")

    def csv_row(self) -> str:
        return f"{self.name},{self.us_per_call:.0f},{self.derived}"

    @staticmethod
    def classify(name: str, derived: str):
        """``(value, kind)`` heuristics for plain-CSV rows: suffix-tagged
        exactness indicators, deterministic counts, kernel throughputs,
        everything else numeric is an accuracy-band metric."""
        try:
            value = float(derived)
        except ValueError:
            return None, "info"
        base = name.rsplit("/", 1)[-1]
        if base.endswith("_exact"):
            return value, "exact"
        if ("participants" in base
                or base.endswith(("_MB", "_bytes", "_s"))):
            # deterministic simulation accounting: any move is a behavior
            # change, so the symmetric count band gates it
            return value, "count"
        if (name.startswith("kernels/") or "us_per" in base
                or base.startswith("t_to_")):
            # wall/derived times (t_to_* may legitimately be inf): noisy,
            # so only the wide warn-first timing band applies
            return value, "timing"
        return value, "accuracy"

    @classmethod
    def from_csv_row(cls, row: str) -> "BenchResult":
        parts = row.split(",", 2)
        if len(parts) != 3:
            raise ValueError(f"not a name,us_per_call,derived row: {row!r}")
        name, us, derived = parts
        value, kind = cls.classify(name, derived)
        return cls(name=name, us_per_call=float(us), derived=derived,
                   value=value, kind=kind)

    def to_json(self) -> Dict[str, Any]:
        doc: Dict[str, Any] = {"name": self.name,
                               "us_per_call": round(self.us_per_call, 1),
                               "derived": self.derived, "kind": self.kind}
        if self.value is not None:
            doc["value"] = self.value
        if self.phases:
            doc["phases"] = self.phases
        return doc

    @classmethod
    def from_json(cls, doc: Dict[str, Any]) -> "BenchResult":
        return cls(name=doc["name"], us_per_call=float(doc["us_per_call"]),
                   derived=str(doc["derived"]), value=doc.get("value"),
                   kind=doc.get("kind", "accuracy"),
                   phases=doc.get("phases"))


def env_fingerprint(quick: bool) -> Dict[str, Any]:
    """Where these numbers came from: git sha, library versions, host,
    quick/full mode, and a UTC timestamp."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)),
            timeout=10).stdout.strip() or None
    except Exception:
        sha = None
    import jax
    return {"git_sha": sha,
            "jax": jax.__version__,
            "numpy": np.__version__,
            "python": platform.python_version(),
            "platform": platform.platform(),
            "quick": bool(quick),
            "date": datetime.datetime.now(datetime.timezone.utc)
            .strftime("%Y-%m-%dT%H:%M:%SZ")}


def write_bench_json(path: str, bench: str, results: List[BenchResult], *,
                     elapsed_s: float, env: Dict[str, Any]) -> None:
    doc = {"schema": BENCH_SCHEMA, "version": BENCH_VERSION, "bench": bench,
           "env": env, "elapsed_s": round(float(elapsed_s), 3),
           "results": [r.to_json() for r in results]}
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2)
        fh.write("\n")


def load_bench_json(path: str) -> Dict[str, Any]:
    """Load and schema-check one ``BENCH_<name>.json`` document."""
    with open(path) as fh:
        doc = json.load(fh)
    if (doc.get("schema") != BENCH_SCHEMA
            or doc.get("version") != BENCH_VERSION):
        raise ValueError(
            f"{path}: not a {BENCH_SCHEMA} v{BENCH_VERSION} baseline "
            f"(got {doc.get('schema')!r} v{doc.get('version')!r})")
    for key in ("bench", "results"):
        if key not in doc:
            raise ValueError(f"{path}: missing {key!r}")
    return doc
