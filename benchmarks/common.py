"""Shared benchmark scaffolding.

Every paper table/figure gets a module with ``run(quick: bool) -> list of
CSV rows``: ``name,us_per_call,derived``. ``us_per_call`` is wall time per
FFT round (or per kernel call); ``derived`` is the table's metric (accuracy).
Quick mode shrinks the problem so ``python -m benchmarks.run`` finishes on
CPU; ``--full`` approaches the paper's setting.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.strategies import STRATEGIES
from repro.data.synthetic import fft_split, make_dataset, train_test_split
from repro.fl.lora import LoRAConfig
from repro.fl.partition import partition
from repro.fl.runtime import FFTConfig, FFTRunner
from repro.models.vision import make_model


def make_problem(*, non_iid: bool, failure_mode: str, quick: bool,
                 model: str = "cnn", k_selected: Optional[int] = None,
                 resource_opt: Optional[str] = None, seed: int = 0,
                 deadline_s: Optional[float] = None,
                 trace_record: Optional[str] = None,
                 trace_replay: Optional[str] = None,
                 server_mode: str = "sync", tau_max: int = 5,
                 buffer_k: int = 4, eval_every: Optional[int] = None,
                 codec: str = "fp32", downlink_codec: Optional[str] = None,
                 model_bytes: Optional[float] = -1.0,
                 telemetry: bool = False,
                 telemetry_log: Optional[str] = None):
    n_clients = 8 if quick else 20
    n_classes = 4 if quick else 10
    img = 8 if quick else 16
    n_samples = 1500 if quick else 6000
    ds = make_dataset(n_samples, n_classes=n_classes, image_size=img,
                      channels=1, noise=0.8, seed=seed)
    train, test = train_test_split(ds, n_samples // 5, seed=seed + 1)
    pub, priv = fft_split(train, public_per_class=10 if quick else 30,
                          seed=seed)
    mode = "group_classes" if non_iid else "iid"
    cpg = 1 if quick else 2
    parts, _ = partition(mode, priv.y, n_clients, n_classes,
                         classes_per_group=cpg,
                         group_size=2 if quick else 4, seed=seed)
    lora_cfg = None
    if model == "vit":
        lora_cfg = LoRAConfig(rank=8, match=lambda p: "qkv/w" in p)
    init_fn, apply_fn = make_model(model, n_classes, img, 1)
    cfg = FFTConfig(
        n_clients=n_clients,
        k_selected=k_selected or n_clients,
        local_steps=3 if quick else 5,
        batch_size=16 if quick else 32,
        lr=0.05 if model == "cnn" else 0.02,
        failure_mode=failure_mode,
        resource_opt=resource_opt,
        seed=seed,
        eval_every=eval_every if eval_every is not None else 10 ** 6,
        # -1 keeps the historical benchmark sizes; None derives from the
        # trainable pytree (the FFTConfig default); a float overrides.
        model_bytes=(0.2e6 if quick else 0.86e6) if model_bytes == -1.0
        else model_bytes,
        trace_record=trace_record,
        trace_replay=trace_replay,
        server_mode=server_mode,
        tau_max=tau_max,
        buffer_k=buffer_k,
        codec=codec,
        downlink_codec=downlink_codec,
        telemetry=telemetry,
        telemetry_log=telemetry_log,
    )
    if deadline_s is not None:
        cfg.deadline_s = deadline_s
    runner = FFTRunner(cfg, init_fn, apply_fn, pub, parts, priv, test,
                       lora_cfg=lora_cfg, pretrain_steps=30 if quick else 100)
    return runner


def run_strategies(runner, names: List[str], rounds: int,
                   label: str, strategy_kwargs: Optional[Dict] = None) -> List[str]:
    rows = []
    g0 = runner.global_params
    kw = strategy_kwargs or {}
    for name in names:
        runner.global_params = g0
        runner.rng = np.random.default_rng(123)
        strat = STRATEGIES[name](**kw.get(name, {}))
        t0 = time.time()
        hist = runner.run(strat, rounds=rounds)
        dt = time.time() - t0
        us_per_round = dt / rounds * 1e6
        # telemetry-instrumented runs read the headline number from the
        # flight record (identical to hist[-1] by construction — the
        # eval_acc gauge is the same evaluate() call)
        final = hist[-1]
        if getattr(runner, "report", None) is not None:
            acc = runner.report.final_accuracy()
            if acc is not None:
                final = acc
        rows.append(f"{label}/{name},{us_per_round:.0f},{final:.4f}")
    runner.global_params = g0
    return rows
