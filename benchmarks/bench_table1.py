"""Table 1: testing accuracy under full-parameter FFT, full participation,
i.i.d. data × {transient, intermittent, mixed} failures."""
from benchmarks.common import make_problem, run_strategies

QUICK_STRATS = ["fedavg", "fedauto"]
FULL_STRATS = ["centralized_public", "fedavg", "fedprox", "scaffold",
               "fedlaw", "tf_aggregation", "fedawe", "fedauto"]


def run(quick: bool = True):
    rows = []
    rounds = 30 if quick else 200
    strats = QUICK_STRATS if quick else FULL_STRATS
    for mode in (["mixed"] if quick else ["transient", "intermittent", "mixed"]):
        runner = make_problem(non_iid=False, failure_mode=mode, quick=quick)
        rows += run_strategies(runner, strats, rounds, f"table1/iid/{mode}")
        # the FedAvg(Ideal) upper bound: same problem, no failures
        ideal = make_problem(non_iid=False, failure_mode="none", quick=quick)
        rows += run_strategies(ideal, ["fedavg"], rounds,
                               f"table1/iid/{mode}/ideal")
    return rows
