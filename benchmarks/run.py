"""Benchmark harness entrypoint — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV. ``--full`` approaches the paper's
scale; default quick mode finishes on CPU.
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names (e.g. table2,kernels)")
    args = ap.parse_args()

    from benchmarks import (bench_adaptive, bench_aggregation, bench_async,
                            bench_comm, bench_convergence, bench_fidelity,
                            bench_kernels, bench_resourceopt,
                            bench_scenarios, bench_table1, bench_table2,
                            bench_table3, bench_table4, bench_table5,
                            roofline)
    benches = {
        "kernels": bench_kernels,
        "aggregation": bench_aggregation,
        "convergence": bench_convergence,
        "table1": bench_table1,
        "table2": bench_table2,
        "table3": bench_table3,
        "table4": bench_table4,
        "table5": bench_table5,
        "resourceopt": bench_resourceopt,
        "scenarios": bench_scenarios,
        "async": bench_async,
        "comm": bench_comm,
        "adaptive": bench_adaptive,
        "fidelity": bench_fidelity,
        "roofline": roofline,
    }
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    for name, mod in benches.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            rows = mod.run(quick=not args.full)
        except Exception as e:  # noqa: BLE001
            rows = [f"{name}/ERROR,0,{type(e).__name__}:{e}"]
        for row in rows:
            print(row)
        print(f"# {name} took {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
