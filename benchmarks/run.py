"""Benchmark harness entrypoint — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and writes a schema-versioned
``BENCH_<name>.json`` baseline per bench (``--json-dir``, default the
working directory) carrying typed metrics, per-phase profiler seconds, and
an environment fingerprint — the inputs ``benchmarks.report diff`` gates
regressions on.  ``--full`` approaches the paper's scale; default quick
mode finishes on CPU.  Exits nonzero when any bench raises (the failure is
still printed as an ERROR CSV row, but never silently swallowed).
"""
import argparse
import sys
import time


def get_benches():
    from benchmarks import (bench_adaptive, bench_aggregation, bench_async,
                            bench_comm, bench_convergence, bench_fidelity,
                            bench_kernels, bench_population,
                            bench_resourceopt, bench_scenarios,
                            bench_stream, bench_table1, bench_table2,
                            bench_table3, bench_table4, bench_table5,
                            roofline)
    return {
        "kernels": bench_kernels,
        "aggregation": bench_aggregation,
        "stream": bench_stream,
        "convergence": bench_convergence,
        "table1": bench_table1,
        "table2": bench_table2,
        "table3": bench_table3,
        "table4": bench_table4,
        "table5": bench_table5,
        "resourceopt": bench_resourceopt,
        "scenarios": bench_scenarios,
        "population": bench_population,
        "async": bench_async,
        "comm": bench_comm,
        "adaptive": bench_adaptive,
        "fidelity": bench_fidelity,
        "roofline": roofline,
    }


def run_benches(benches, *, quick: bool, json_dir=None, out=print) -> int:
    """Run ``benches`` (name → module), stream CSV rows through ``out``,
    persist per-bench JSON baselines under ``json_dir``, and return the
    process exit code: 0 when every bench completed, 1 when any raised.
    A failing bench still emits an ERROR row (and fails the run) but never
    stops the benches after it."""
    import os

    from benchmarks.common import (BenchResult, env_fingerprint,
                                   write_bench_json)
    failures = []
    out("name,us_per_call,derived")
    for name, mod in benches.items():
        t0 = time.perf_counter()
        try:
            rows = mod.run(quick=quick)
            failed = False
        except Exception as e:  # noqa: BLE001
            rows = [f"{name}/ERROR,0,{type(e).__name__}:{e}"]
            failed = True
            failures.append(name)
        elapsed = time.perf_counter() - t0
        results = [r if isinstance(r, BenchResult)
                   else BenchResult.from_csv_row(r) for r in rows]
        for r in results:
            out(r.csv_row())
        print(f"# {name} took {elapsed:.1f}s", file=sys.stderr)
        if json_dir is not None and not failed:
            write_bench_json(os.path.join(json_dir, f"BENCH_{name}.json"),
                             name, results, elapsed_s=elapsed,
                             env=env_fingerprint(quick))
    if failures:
        print(f"# FAILED: {', '.join(failures)}", file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names (e.g. table2,kernels)")
    ap.add_argument("--json-dir", default=".",
                    help="directory for BENCH_<name>.json baselines "
                         "(default: cwd; 'none' disables)")
    args = ap.parse_args(argv)

    benches = get_benches()
    if args.only:
        only = args.only.split(",")
        unknown = sorted(set(only) - set(benches))
        if unknown:
            print(f"unknown benches: {', '.join(unknown)} "
                  f"(known: {', '.join(benches)})", file=sys.stderr)
            return 2
        benches = {n: benches[n] for n in benches if n in only}
    json_dir = None if args.json_dir == "none" else args.json_dir
    return run_benches(benches, quick=not args.full, json_dir=json_dir)


if __name__ == "__main__":
    sys.exit(main())
