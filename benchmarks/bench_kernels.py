"""Kernel microbenchmarks: Pallas (interpret on CPU — correctness-path
timing only; the compiled TPU path is the target) vs the XLA reference.
On CPU the REFERENCE timing is the meaningful number; interpret-mode Pallas
timing is reported for completeness, not as a perf claim."""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _time(fn, *args, iters=5):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(quick: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)

    # fedagg: 22 participants × 1M params (quick: 100k)
    P = 100_000 if quick else 1_000_000
    stacked = jax.random.normal(key, (22, P), jnp.float32)
    betas = jax.nn.softmax(jax.random.normal(key, (22,)))
    agg_ref = jax.jit(ref.fedagg)
    us = _time(agg_ref, stacked, betas)
    gbps = 22 * P * 4 / (us / 1e6) / 1e9
    rows.append(f"kernels/fedagg_ref_xla,{us:.0f},{gbps:.1f}")

    # dequant_fedagg: same reduction over int8 payloads (repro.fl.comm) —
    # 1 byte/param streamed instead of 4, dequantized in-register
    q = jnp.asarray(jax.random.randint(key, (22, P), -127, 128), jnp.int8)
    scales = jax.random.uniform(key, (22,), jnp.float32, 1e-4, 1e-2)
    dq_ref = jax.jit(ref.dequant_fedagg)
    us = _time(dq_ref, q, scales, betas)
    gbps = 22 * P / (us / 1e6) / 1e9                # int8: 1 B/param read
    rows.append(f"kernels/dequant_fedagg_ref_xla,{us:.0f},{gbps:.1f}")

    # flash attention reference (B=1, S=1024, H=8)
    S = 512 if quick else 2048
    q = jax.random.normal(key, (1, S, 8, 64), jnp.float32)
    k = jax.random.normal(key, (1, S, 2, 64), jnp.float32)
    fa = jax.jit(lambda q_, k_, v_: ref.flash_attention(q_, k_, v_, causal=True))
    us = _time(fa, q, k, k)
    rows.append(f"kernels/attention_ref_xla,{us:.0f},{S}")

    # decode attention reference (B=8, S=8k cache)
    S = 2048 if quick else 8192
    qd = jax.random.normal(key, (8, 1, 8, 64), jnp.float32)
    kd = jax.random.normal(key, (8, S, 2, 64), jnp.float32)
    valid = jnp.ones((S,), bool)
    da = jax.jit(lambda q_, k_, v_, m: ref.decode_attention(q_, k_, v_, m,
                                                            scale=0.125))
    us = _time(da, qd, kd, kd, valid)
    rows.append(f"kernels/decode_attention_ref_xla,{us:.0f},{S}")

    # lora matmul
    T, D, O, R = (256, 512, 512, 8) if quick else (1024, 4096, 4096, 8)
    x = jax.random.normal(key, (T, D), jnp.float32)
    w = jax.random.normal(key, (D, O), jnp.float32)
    a = jax.random.normal(key, (D, R), jnp.float32)
    b = jax.random.normal(key, (R, O), jnp.float32)
    lm = jax.jit(lambda *t: ref.lora_matmul(*t, 2.0))
    us = _time(lm, x, w, a, b)
    rows.append(f"kernels/lora_matmul_ref_xla,{us:.0f},{T * D * O * 2 / (us / 1e6) / 1e9:.1f}")
    return rows
