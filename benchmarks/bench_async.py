"""Sync vs async vs buffered server across the scenario worlds, scored in
*simulated wall-clock seconds*, not rounds.

Under a tight deadline the synchronous server both discards stragglers and
waits out its full timeout for them; the asynchronous server waits the same
wall clock but keeps every upload that lands within ``tau_max`` extra
rounds.  Rows:

  async:<world>/<mode>,us_per_round,final_accuracy
  async:<world>/<mode>/sim_s,0,total simulated seconds
  async:<world>/<mode>/t_to_sync_final,0,first simulated second at which the
      mode's accuracy reached the sync baseline's final accuracy (inf if it
      never did) — the headline sync-vs-async fairness metric

Modes map to strategies: sync -> fedauto, async/buffered -> fedauto_async.
"""
from __future__ import annotations

import math
from typing import List

import numpy as np

from benchmarks.common import make_problem, timed_run
from repro.core.strategies import STRATEGIES

MODES = {"sync": "fedauto", "async": "fedauto_async",
         "buffered": "fedauto_async"}


def _run_mode(world: str, mode: str, strat: str, rounds: int,
              deadline: float, quick: bool):
    runner = make_problem(non_iid=True, failure_mode=f"scenario:{world}",
                          quick=quick, deadline_s=deadline, seed=0,
                          server_mode=mode, tau_max=4, buffer_k=4,
                          eval_every=1)
    hist, us_per_round = timed_run(runner, STRATEGIES[strat](), rounds)
    return runner.timeline, hist[-1], us_per_round


def _time_to(timeline, target: float) -> float:
    for pt in timeline:
        if pt.acc >= target - 1e-9:
            return pt.t_s
    return math.inf


def run(quick: bool = True) -> List[str]:
    rows = []
    rounds = 12 if quick else 40
    deadline = 3.0 if quick else 6.0
    worlds = (["diurnal", "correlated_wifi", "bursty_handover"] if quick
              else ["diurnal", "table6", "bursty_handover", "churn",
                    "correlated_wifi", "cross_region", "lossy_uplink"])
    for world in worlds:
        results = {}
        for mode, strat in MODES.items():
            timeline, final, us = _run_mode(world, mode, strat, rounds,
                                            deadline, quick)
            results[mode] = (timeline, final)
            rows.append(f"async:{world}/{mode},{us:.0f},{final:.4f}")
            rows.append(f"async:{world}/{mode}/sim_s,0,"
                        f"{timeline[-1].t_s:.2f}")
        target = results["sync"][1]
        for mode in MODES:
            t = _time_to(results[mode][0], target)
            rows.append(f"async:{world}/{mode}/t_to_sync_final,0,"
                        f"{t if math.isfinite(t) else 'inf'}")
        # realized staleness pressure of this world under the deadline
        m = make_problem(non_iid=True, failure_mode=f"scenario:{world}",
                         quick=quick, deadline_s=deadline, seed=0)
        m.failures.reset()
        late = np.mean([m.failures.draw_events(r).late_mask().mean()
                        for r in range(1, rounds + 1)])
        rows.append(f"async:{world}/late_fraction,0,{late:.4f}")
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
