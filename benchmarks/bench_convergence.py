"""Fig. 2/3: convergence curves of FFT strategies under mixed failures.
Prints the accuracy trajectory (derived = final acc; curve to stdout)."""
import numpy as np

from benchmarks.common import make_problem, timed_run
from repro.core.strategies import STRATEGIES


def run(quick: bool = True):
    rounds = 40 if quick else 300
    strats = (["centralized_public", "fedavg", "fedauto"] if quick else
              ["centralized_public", "fedavg", "fedprox", "scaffold",
               "fedlaw", "fedawe", "fedauto"])
    runner = make_problem(non_iid=True, failure_mode="mixed", quick=quick)
    runner.cfg.eval_every = max(rounds // 8, 1)
    rows = []
    g0 = runner.global_params
    for name in strats:
        runner.global_params = g0
        runner.rng = np.random.default_rng(123)
        hist, us = timed_run(runner, STRATEGIES[name](), rounds)
        curve = " ".join(f"{a:.3f}" for a in hist)
        print(f"# fig2 curve {name}: {curve}")
        rows.append(f"fig2/{name},{us:.0f},{hist[-1]:.4f}")
    runner.global_params = g0
    return rows
