"""Streaming fused aggregation vs the materializing server path (ISSUE 10).

The materializing server decodes every packed upload to a full fp32 vector
and then β-reduces K decoded pytrees; the streaming server feeds packed
``(payload, β)`` pairs through the batched decode-and-accumulate kernels
into one fp32 accumulator.  This bench measures the server-side cost per
aggregate — decode included on both arms, since streaming fuses it — at
K ∈ {64, 256, 1024} arrivals, plus the O(1)-vs-O(K) peak decoded memory
and the napkin roofline target for the fused pass (memory-bound: K int8
payload reads + one fp32 accumulator write).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.comm import make_codec
from repro.fl.comm.stream import StreamAccumulator
from repro.launch.roofline import roofline_terms


def _bench(fn, repeat=3):
    fn()                                     # compile / warm caches
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeat


def run(quick: bool = True):
    from benchmarks.common import BenchResult
    rows = []
    P = 1 << 16 if quick else 1 << 20
    template = {"w": jnp.zeros((P,), jnp.float32)}
    codec = make_codec("int8")
    rng = np.random.default_rng(0)
    k_max = 1024
    payloads = [codec.encode(
        {"w": jnp.asarray(rng.normal(size=P), jnp.float32)})
        for _ in range(k_max)]

    for K in (64, 256, 1024):
        betas = np.full(K, 1.0 / K, np.float32)

        def materializing(K=K, betas=betas):
            # the old hot path: per-payload decode to fp32, then β-reduce
            out = jnp.zeros((P,), jnp.float32)
            for p, b in zip(payloads[:K], betas):
                out = out + jnp.float32(b) * codec.decode(p)["w"]
            return out

        def streaming(K=K, betas=betas):
            acc = StreamAccumulator(template, batch_k=64)
            for p, b in zip(payloads[:K], betas):
                acc.add(p, b)
            return acc.total()["w"]

        t_mat = _bench(materializing)
        t_str = _bench(streaming)
        # parity guard: a fast-but-wrong aggregate must fail the bench
        err = float(jnp.max(jnp.abs(materializing() - streaming())))
        if err > 1e-4:
            raise AssertionError(
                f"K={K}: streaming aggregate diverges from the "
                f"materializing path (maxerr {err:.3e})")
        rows.append(BenchResult(
            name=f"stream/materializing_K{K}", us_per_call=t_mat * 1e6,
            derived=f"{K / t_mat:.0f}", value=K / t_mat, kind="timing"))
        rows.append(BenchResult(
            name=f"stream/streaming_K{K}", us_per_call=t_str * 1e6,
            derived=f"{K / t_str:.0f}", value=K / t_str, kind="timing"))
        rows.append(BenchResult(
            name=f"stream/speedup_K{K}", us_per_call=t_str * 1e6,
            derived=f"{t_mat / t_str:.2f}", value=t_mat / t_str,
            kind="timing"))

    # peak decoded memory: O(1) streaming accumulator vs O(K) materialized
    acc = StreamAccumulator(template, batch_k=64)
    for p, b in zip(payloads, np.full(k_max, 1.0 / k_max)):
        acc.add(p, b)
    acc.total()
    rows.append(BenchResult(
        name=f"stream/peak_decoded_MB_K{k_max}",
        us_per_call=0.0, derived=f"{acc.peak_decoded_bytes / 1e6:.1f}",
        value=round(acc.peak_decoded_bytes / 1e6, 1), kind="count"))
    rows.append(BenchResult(
        name=f"stream/materialized_MB_K{k_max}",
        us_per_call=0.0, derived=f"{k_max * 4 * P / 1e6:.1f}",
        value=round(k_max * 4 * P / 1e6, 1), kind="count"))

    # roofline target for the fused pass: read K int8 payloads (P bytes
    # each + fp32 scales, negligible), write one fp32 accumulator; one
    # multiply-add per element
    terms = roofline_terms(flops=2.0 * k_max * P,
                           bytes_accessed=k_max * P + 4.0 * P,
                           coll_bytes=0)
    target_s = max(terms["compute_s"], terms["memory_s"])
    rows.append(BenchResult(
        name=f"stream/roofline_target_K{k_max}",
        us_per_call=target_s * 1e6, derived=terms["dominant"],
        kind="info"))
    return rows
