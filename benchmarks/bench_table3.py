"""Table 3: partial participation (K = N/2), mixed failures, non-iid."""
from benchmarks.common import make_problem, run_strategies


def run(quick: bool = True):
    rounds = 30 if quick else 200
    strats = (["fedavg", "fedauto"] if quick else
              ["centralized_public", "fedavg", "fedprox", "scaffold",
               "fedlaw", "tf_aggregation", "fedawe", "fedauto"])
    n = 8 if quick else 20
    runner = make_problem(non_iid=True, failure_mode="mixed", quick=quick,
                          k_selected=n // 2)
    return run_strategies(runner, strats, rounds, "table3/partial")
