"""Fidelity-aware aggregation: distortion-discounted QP weights.

The adaptive controller hands a recovering client a coarse rung (sign1 after
a long outage); without a fidelity discount the Eq. 8/9 QP weighs that
coarse reconstruction exactly like a lossless fp32 upload, and the isolated
one-shot coarse update injects a visible accuracy transient.  This bench
sweeps the adaptive ladder × three discount variants over two scenario
worlds in sync and buffered modes:

  none                no post-QP discount at all (a = 0, b = 0)
  staleness           (1+s)^{-a} only — PR 2's fedauto_async behavior
  staleness_fidelity  (1+s)^{-a} · (1−d)^{b}: d is each upload's measured
                      compression distortion (``CommState.roundtrip``)

Rows:

  fidelity:<world>/<mode>/<variant>,us_per_round,final_accuracy
  fidelity:<world>/<mode>/<variant>/transient,0,max accuracy drawdown after
      warmup (running max − current, worst over the eval curve)
  fidelity:<world>/<mode>/<variant>/mean_distortion,0,mean recorded
      per-upload distortion
  fidelity:<world>/<mode>/replay_bit_exact,0,1 if the recorded v4 trace of
      the staleness_fidelity run replays to the identical accuracy history
  fidelity:<world>/<mode>/distortion_replay_exact,0,1 if the replay
      recomputes every recorded per-client distortion bit-exactly

Acceptance (ISSUE 5): on ≥ 1 world × mode cell, staleness_fidelity shows a
smaller transient than none at final accuracy within 1 point.

Every run is telemetry-instrumented (``repro.obs``): ``mean_distortion``
is read from the run's ``RunReport`` and cross-checked against the
comm/loop accounting via ``reconcile``.  Render a full run report from a
telemetry log with ``python -m benchmarks.report run-report
<log.ndjson>``.
"""
from __future__ import annotations

import os
import tempfile
from typing import List

from benchmarks.common import (BenchResult, make_problem, report_phases,
                               timed_run)
from repro.core.strategies import FedAuto, FedAutoAsync
from repro.fl.metrics import accuracy_drawdown, distortion_replay_matches
from repro.obs import reconcile

# Same simulated paper-scale payload and deadline as bench_comm /
# bench_adaptive, so rows are directly comparable across the benches.
MODEL_BYTES = 4e6
DEADLINE_S = 5.0
LADDER = "adaptive:sign1-fp16"
# Gentle exponent: the QP already optimizes the effective class
# distribution, and an aggressive b (≥ 1) persistently down-weights every
# client parked on a coarse rung — skewing the distribution the QP chose
# and costing final accuracy.  b = 0.5 damps the isolated post-outage
# coarse-upload transient while leaving steady-state weights close to the
# QP's optimum (measured: larger b degrades finals on every world).
DISCOUNT_B = 0.5

# variant -> (discount_a, fidelity_discount b); sync mode has no staleness,
# so its "staleness" row doubles as a sanity check that a alone is inert
VARIANTS = {
    "none": (0.0, 0.0),
    "staleness": (0.5, 0.0),
    "staleness_fidelity": (0.5, DISCOUNT_B),
}


def _strategy(mode: str, a: float, b: float):
    if mode == "sync":
        return FedAuto(fidelity_discount=b)
    return FedAutoAsync(discount_a=a, fidelity_discount=b)


def _run_one(world: str, mode: str, a: float, b: float, rounds: int,
             quick: bool, trace_record=None, trace_replay=None):
    runner = make_problem(non_iid=True, failure_mode=f"scenario:{world}",
                          quick=quick, deadline_s=DEADLINE_S, seed=0,
                          server_mode=mode, tau_max=4, buffer_k=4,
                          codec=LADDER, model_bytes=MODEL_BYTES,
                          eval_every=2, trace_record=trace_record,
                          trace_replay=trace_replay, telemetry=True)
    hist, us_per_round = timed_run(runner, _strategy(mode, a, b), rounds)
    # headline numbers from the telemetry flight record, cross-checked
    # against the run's own accounting
    reconcile(runner.report, runner)
    return runner, hist, us_per_round


def run(quick: bool = True) -> List[str]:
    rows = []
    rounds = 30 if quick else 40
    warmup = 5                       # eval_every=2 → evals past round 10
    worlds = (["diurnal", "correlated_wifi"] if quick
              else ["diurnal", "correlated_wifi", "cross_region",
                    "bursty_handover"])
    for world in worlds:
        for mode in ("sync", "buffered"):
            for variant, (a, b) in VARIANTS.items():
                trace = None
                if variant == "staleness_fidelity":
                    trace = os.path.join(tempfile.mkdtemp(),
                                         f"{world}_{mode}.ndjson")
                runner, hist, us = _run_one(world, mode, a, b, rounds,
                                            quick, trace_record=trace)
                # headline row carries the run's per-phase profiler seconds
                # into the JSON baseline
                rows.append(BenchResult(
                    name=f"fidelity:{world}/{mode}/{variant}",
                    us_per_call=us, derived=f"{hist[-1]:.4f}",
                    value=float(f"{hist[-1]:.4f}"), kind="accuracy",
                    phases=report_phases(runner)))
                rows.append(f"fidelity:{world}/{mode}/{variant}/transient,"
                            f"0,{accuracy_drawdown(hist, warmup):.4f}")
                rows.append(f"fidelity:{world}/{mode}/{variant}"
                            f"/mean_distortion,0,"
                            f"{runner.report.mean_distortion():.4f}")
                if trace is not None:
                    rep, hist_r, _ = _run_one(world, mode, a, b, rounds,
                                              quick, trace_replay=trace)
                    rows.append(f"fidelity:{world}/{mode}/replay_bit_exact,"
                                f"0,{int(hist_r == hist)}")
                    rows.append(f"fidelity:{world}/{mode}"
                                f"/distortion_replay_exact,0,"
                                f"{int(distortion_replay_matches(rep.failures, rep.loop.distortion_history, rounds))}")
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
