"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dry-run JSON.

    PYTHONPATH=src python -m benchmarks.report benchmarks/dryrun_results.json
"""
import json
import sys


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2 ** 30:.2f}"


def render(path: str) -> str:
    rows = json.load(open(path))
    out = []
    out.append("| arch | shape | mesh | status | compile_s | HLO GF/dev | "
               "HLO GB/dev | coll GB/dev | args GiB/dev | tc_ms | tm_ms | "
               "tx_ms | dominant | a_dom | a_bound_ms | a_mfu |")
    out.append("|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"{r['status']}: {r.get('reason', r.get('error', ''))[:60]} |"
                       + " - |" * 12)
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['compile_s']:.0f} | {r['flops_per_device'] / 1e9:.0f} | "
            f"{r['bytes_per_device'] / 1e9:.0f} | "
            f"{r['collective_bytes_per_device'] / 1e9:.2f} | "
            f"{fmt_bytes(r['mem']['argument_bytes'])} | "
            f"{r['compute_s'] * 1e3:.2f} | {r['memory_s'] * 1e3:.2f} | "
            f"{r['collective_s'] * 1e3:.2f} | {r['dominant']} | "
            f"{r.get('a_dominant', '-')} | "
            f"{r.get('a_step_s', 0) * 1e3:.2f} | "
            f"{r.get('a_mfu_bound', 0):.2f} |")
    return "\n".join(out)


if __name__ == "__main__":
    print(render(sys.argv[1]))
