"""Render benchmark tables.

Two modes:

* dry-run roofline (the default, EXPERIMENTS.md §Dry-run / §Roofline):

      PYTHONPATH=src python -m benchmarks.report benchmarks/dryrun_results.json

* run-report — Markdown tables over one or more telemetry NDJSON logs
  (``FFTConfig.telemetry_log``; see ``repro.obs``): per-run summary,
  drop-cause breakdown, bytes-vs-participation, β-mass by staleness/rung:

      PYTHONPATH=src python -m benchmarks.report run-report run1.ndjson ...
"""
import json
import sys

USAGE = (
    "usage: python -m benchmarks.report <dryrun_results.json>\n"
    "       python -m benchmarks.report run-report <telemetry.ndjson> [...]")


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2 ** 30:.2f}"


def render(path: str) -> str:
    with open(path) as fh:
        rows = json.load(fh)
    out = []
    out.append("| arch | shape | mesh | status | compile_s | HLO GF/dev | "
               "HLO GB/dev | coll GB/dev | args GiB/dev | tc_ms | tm_ms | "
               "tx_ms | dominant | a_dom | a_bound_ms | a_mfu |")
    out.append("|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        status = r.get("status", "?")
        if status != "ok":
            out.append(f"| {r.get('arch', '?')} | {r.get('shape', '?')} | "
                       f"{r.get('mesh', '?')} | "
                       f"{status}: {r.get('reason', r.get('error', ''))[:60]} |"
                       + " - |" * 12)
            continue
        out.append(
            f"| {r.get('arch', '?')} | {r.get('shape', '?')} | "
            f"{r.get('mesh', '?')} | ok | "
            f"{r.get('compile_s', 0):.0f} | "
            f"{r.get('flops_per_device', 0) / 1e9:.0f} | "
            f"{r.get('bytes_per_device', 0) / 1e9:.0f} | "
            f"{r.get('collective_bytes_per_device', 0) / 1e9:.2f} | "
            f"{fmt_bytes(r.get('mem', {}).get('argument_bytes'))} | "
            f"{r.get('compute_s', 0) * 1e3:.2f} | "
            f"{r.get('memory_s', 0) * 1e3:.2f} | "
            f"{r.get('collective_s', 0) * 1e3:.2f} | "
            f"{r.get('dominant', '-')} | "
            f"{r.get('a_dominant', '-')} | "
            f"{r.get('a_step_s', 0) * 1e3:.2f} | "
            f"{r.get('a_mfu_bound', 0):.2f} |")
    return "\n".join(out)


def render_run_report(paths) -> str:
    """Markdown run report over telemetry NDJSON logs (``repro.obs``)."""
    from repro.obs import RunReport, render_markdown
    reports = [RunReport.from_ndjson(p) for p in paths]
    return render_markdown(reports)


def main(argv) -> int:
    if len(argv) < 2:
        print(USAGE, file=sys.stderr)
        return 2
    if argv[1] == "run-report":
        if len(argv) < 3:
            print(USAGE, file=sys.stderr)
            return 2
        print(render_run_report(argv[2:]))
        return 0
    print(render(argv[1]))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
