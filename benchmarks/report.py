"""Render benchmark tables and diff bench baselines.

Three modes:

* dry-run roofline (the default, EXPERIMENTS.md §Dry-run / §Roofline):

      PYTHONPATH=src python -m benchmarks.report benchmarks/dryrun_results.json

* run-report — Markdown tables over one or more telemetry NDJSON logs
  (``FFTConfig.telemetry_log``; see ``repro.obs``): per-run summary,
  drop-cause breakdown, bytes-vs-participation, β-mass by staleness/rung,
  distribution quantiles, health verdicts, per-phase profiler timings.
  Full-mode and sketch-mode logs both render (``load_report`` picks the
  report type per file); ``--fail-on-alarm`` exits 1 when any run's health
  verdict carries alarms (the CI fault-injection gate):

      PYTHONPATH=src python -m benchmarks.report run-report [--fail-on-alarm] run1.ndjson ...

* watch — live dashboard over an NDJSON log another process is writing
  (per-record flush + truncated-final-line tolerance make it readable
  mid-run); redraws in place until the run_end record lands.  ``--once``
  renders a single frame and exits (CI smoke):

      PYTHONPATH=src python -m benchmarks.report watch [--interval 2] [--once] run.ndjson

* diff — cross-run regression gate over ``BENCH_<name>.json`` baselines
  (written by ``python -m benchmarks.run``).  Arguments are files or
  directories (a directory expands to its ``BENCH_*.json``); documents are
  paired by their ``bench`` field, first occurrence = baseline, second =
  candidate.  Per-metric tolerance bands by kind: accuracy may not drop
  more than ``ACC_ATOL``; counts (participants, simulated MB) may not move
  more than ``COUNT_ATOL``; ``*_exact`` indicators must match bit-for-bit;
  timings use a relative band with a noise floor and only warn unless
  ``--strict-timing``.  Prints a Markdown table of every flagged metric and
  exits 1 on regression (2 on usage/schema errors):

      PYTHONPATH=src python -m benchmarks.report diff benchmarks/baselines new/
"""
import glob
import json
import os
import sys

USAGE = (
    "usage: python -m benchmarks.report <dryrun_results.json>\n"
    "       python -m benchmarks.report run-report [--fail-on-alarm] "
    "<telemetry.ndjson> [...]\n"
    "       python -m benchmarks.report watch [--interval N] [--once] "
    "<telemetry.ndjson>\n"
    "       python -m benchmarks.report diff [--strict-timing] "
    "<old.json|dir> [...] <new.json|dir> [...]")


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2 ** 30:.2f}"


def render(path: str) -> str:
    with open(path) as fh:
        rows = json.load(fh)
    out = []
    out.append("| arch | shape | mesh | status | compile_s | HLO GF/dev | "
               "HLO GB/dev | coll GB/dev | args GiB/dev | tc_ms | tm_ms | "
               "tx_ms | dominant | a_dom | a_bound_ms | a_mfu |")
    out.append("|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        status = r.get("status", "?")
        if status != "ok":
            out.append(f"| {r.get('arch', '?')} | {r.get('shape', '?')} | "
                       f"{r.get('mesh', '?')} | "
                       f"{status}: {r.get('reason', r.get('error', ''))[:60]} |"
                       + " - |" * 12)
            continue
        out.append(
            f"| {r.get('arch', '?')} | {r.get('shape', '?')} | "
            f"{r.get('mesh', '?')} | ok | "
            f"{r.get('compile_s', 0):.0f} | "
            f"{r.get('flops_per_device', 0) / 1e9:.0f} | "
            f"{r.get('bytes_per_device', 0) / 1e9:.0f} | "
            f"{r.get('collective_bytes_per_device', 0) / 1e9:.2f} | "
            f"{fmt_bytes(r.get('mem', {}).get('argument_bytes'))} | "
            f"{r.get('compute_s', 0) * 1e3:.2f} | "
            f"{r.get('memory_s', 0) * 1e3:.2f} | "
            f"{r.get('collective_s', 0) * 1e3:.2f} | "
            f"{r.get('dominant', '-')} | "
            f"{r.get('a_dominant', '-')} | "
            f"{r.get('a_step_s', 0) * 1e3:.2f} | "
            f"{r.get('a_mfu_bound', 0):.2f} |")
    return "\n".join(out)


def render_run_report(paths) -> str:
    """Markdown run report over telemetry NDJSON logs (``repro.obs``);
    full-mode and sketch-mode logs mix freely."""
    from repro.obs import load_report, render_markdown
    reports = [load_report(p) for p in paths]
    return render_markdown(reports)


def run_report_alarms(paths) -> int:
    """Total health alarms across the logs (for ``--fail-on-alarm``)."""
    from repro.obs import load_report
    total = 0
    for p in paths:
        rep = load_report(p)
        verdict = rep.health_verdict()
        if verdict is not None:
            total += int(verdict.get("n_alarms", 0))
        else:
            total += len(getattr(rep, "health", []) or [])
    return total


# ---------------------------------------------------------------------------
# baseline diffing
# ---------------------------------------------------------------------------
# accuracy on the toy problems is deterministic per machine but can drift a
# couple of points across BLAS/jax builds; the band must stay well under a
# real break (a lost cohort moves finals by 5+ points)
ACC_ATOL = 0.02
# participants / simulated MB are deterministic accounting: any visible
# move means the run changed behavior (0.25 absorbs mean-rounding only)
COUNT_ATOL = 0.25
# shared-CI timing noise is huge; flag only clear blowups, and below the
# floor (interpreter overhead territory) never flag at all
TIMING_RTOL = 0.5
TIMING_FLOOR_US = 200.0

REGRESSION, WARNING, OK = "REGRESSION", "warning", "ok"


def expand_bench_paths(paths):
    """Files pass through; directories expand to their ``BENCH_*.json``."""
    out = []
    for p in paths:
        if os.path.isdir(p):
            found = sorted(glob.glob(os.path.join(p, "BENCH_*.json")))
            if not found:
                raise ValueError(f"{p}: no BENCH_*.json files")
            out.extend(found)
        else:
            out.append(p)
    return out


def pair_baselines(paths):
    """Pair loaded documents by their ``bench`` field: first occurrence is
    the baseline, second the candidate.  Returns ``(pairs, unpaired)`` as
    ``{bench: (old_doc, new_doc)}`` and the benches seen only once."""
    from benchmarks.common import load_bench_json
    seen = {}
    pairs = {}
    for p in paths:
        doc = load_bench_json(p)
        bench = doc["bench"]
        if bench in pairs:
            raise ValueError(
                f"{p}: bench {bench!r} appears more than twice")
        if bench in seen:
            pairs[bench] = (seen.pop(bench), doc)
        else:
            seen[bench] = doc
    return pairs, seen


def _timing_status(old_us, new_us, strict):
    if max(old_us, new_us) < TIMING_FLOOR_US:
        return OK, None
    limit = old_us * (1.0 + TIMING_RTOL) + TIMING_FLOOR_US
    if new_us <= limit:
        return OK, None
    note = f"slower than {1.0 + TIMING_RTOL:.1f}x band"
    return (REGRESSION if strict else WARNING), note


def diff_metric(kind, old, new, *, strict_timing=False):
    """Compare one metric's derived values under its kind's tolerance band:
    ``(status, note)``."""
    if kind == "info":
        if old.derived != new.derived:
            return WARNING, "payload changed"
        return OK, None
    if old.value is None or new.value is None:
        return WARNING, "metric lost its numeric value"
    if kind == "exact":
        if new.value != old.value:
            return REGRESSION, "exactness indicator changed"
        return OK, None
    if kind == "count":
        if abs(new.value - old.value) > COUNT_ATOL:
            return REGRESSION, f"moved more than ±{COUNT_ATOL}"
        return OK, None
    if kind == "timing":
        return _timing_status(old.value, new.value, strict_timing)
    # accuracy: one-sided — improvements pass
    if new.value < old.value - ACC_ATOL:
        return REGRESSION, f"dropped more than {ACC_ATOL}"
    return OK, None


def diff_baselines(paths, *, strict_timing=False):
    """Diff paired baselines; returns ``(markdown, n_regressions)``."""
    from benchmarks.common import BenchResult
    pairs, unpaired = pair_baselines(paths)
    if not pairs:
        raise ValueError("no baseline/candidate pair: every bench appeared "
                         f"only once ({sorted(unpaired) or 'none'})")
    flagged = []         # (bench, metric, kind, old, new, status, note)
    n_reg = 0
    n_metrics = 0
    for bench in sorted(unpaired):
        flagged.append((bench, "(whole bench)", "-", "present", "missing",
                        REGRESSION, "no candidate run to compare"))
        n_reg += 1
    for bench, (old_doc, new_doc) in sorted(pairs.items()):
        old = {r["name"]: BenchResult.from_json(r)
               for r in old_doc["results"]}
        new = {r["name"]: BenchResult.from_json(r)
               for r in new_doc["results"]}
        for name, o in old.items():
            n = new.get(name)
            if n is None:
                flagged.append((bench, name, o.kind, o.derived, "missing",
                                REGRESSION, "metric disappeared"))
                n_reg += 1
                continue
            n_metrics += 1
            status, note = diff_metric(o.kind, o, n,
                                       strict_timing=strict_timing)
            if status != OK:
                flagged.append((bench, name, o.kind, o.derived, n.derived,
                                status, note))
                n_reg += status == REGRESSION
            # every row's us_per_call additionally gets the timing band
            tstat, tnote = _timing_status(o.us_per_call, n.us_per_call,
                                          strict_timing)
            if tstat != OK:
                flagged.append((bench, name, "us_per_call",
                                f"{o.us_per_call:.0f}",
                                f"{n.us_per_call:.0f}", tstat, tnote))
                n_reg += tstat == REGRESSION
        for name in sorted(set(new) - set(old)):
            flagged.append((bench, name, new[name].kind, "-",
                            new[name].derived, WARNING,
                            "new metric, no baseline"))
    lines = ["# Bench baseline diff", "",
             f"{len(pairs)} bench(es), {n_metrics} paired metric(s), "
             f"{n_reg} regression(s), "
             f"{sum(1 for f in flagged if f[5] == WARNING)} warning(s)", ""]
    if flagged:
        lines += ["| bench | metric | kind | old | new | status | note |",
                  "|---|---|---|---|---|---|---|"]
        flagged.sort(key=lambda f: (f[5] != REGRESSION, f[0], f[1]))
        for bench, metric, kind, old_v, new_v, status, note in flagged:
            lines.append(f"| {bench} | {metric} | {kind} | {old_v} | "
                         f"{new_v} | {status} | {note or ''} |")
    else:
        lines.append("No regressions, no warnings.")
    return "\n".join(lines), n_reg


def main(argv) -> int:
    if len(argv) < 2:
        print(USAGE, file=sys.stderr)
        return 2
    if argv[1] == "run-report":
        args = argv[2:]
        fail_on_alarm = "--fail-on-alarm" in args
        args = [a for a in args if a != "--fail-on-alarm"]
        if not args:
            print(USAGE, file=sys.stderr)
            return 2
        print(render_run_report(args))
        if fail_on_alarm:
            n = run_report_alarms(args)
            if n:
                print(f"run-report: {n} health alarm(s)", file=sys.stderr)
                return 1
        return 0
    if argv[1] == "watch":
        args = argv[2:]
        once = "--once" in args
        args = [a for a in args if a != "--once"]
        interval = 2.0
        if "--interval" in args:
            i = args.index("--interval")
            try:
                interval = float(args[i + 1])
            except (IndexError, ValueError):
                print(USAGE, file=sys.stderr)
                return 2
            del args[i:i + 2]
        if len(args) != 1:
            print(USAGE, file=sys.stderr)
            return 2
        from repro.obs import watch
        try:
            watch(args[0], interval=interval, once=once)
        except KeyboardInterrupt:
            pass
        return 0
    if argv[1] == "diff":
        args = argv[2:]
        strict = "--strict-timing" in args
        args = [a for a in args if a != "--strict-timing"]
        if not args:
            print(USAGE, file=sys.stderr)
            return 2
        try:
            report, n_reg = diff_baselines(expand_bench_paths(args),
                                           strict_timing=strict)
        except (ValueError, OSError, json.JSONDecodeError, KeyError) as e:
            print(f"diff: {e}", file=sys.stderr)
            return 2
        print(report)
        return 1 if n_reg else 0
    print(render(argv[1]))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
