"""ISSUE-5: fidelity-aware aggregation + strategy-state/wire-metadata fixes.

Covers the discount pipeline ``fedauto_discounted_weights`` (simplex, Eq. 9
pin, bit-exact reductions to the sync and async solutions, monotonicity in
distortion), the measured-distortion plumbing (``CommState.roundtrip`` →
round loops → ``RoundContext``/``AsyncRoundContext``/``Arrival`` → the
fedauto strategies), trace schema v4 (per-client distortions, replay
cross-check), and the satellite bugfixes: TF-Aggregation cross-run state,
adaptive-run wire metadata in the strategy context, selection-masked rung
histograms, and round-1 compressed-downlink enrollment accounting.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import (fedauto_async_weights,
                                    fedauto_discounted_weights,
                                    fedauto_weights)
from repro.core.strategies import (STRATEGIES, Arrival, AsyncRoundContext,
                                   FedAuto, FedAutoAsync, TFAggregation)
from repro.fl.comm import RUNG_LADDER, CommState, make_codec
from repro.fl.metrics import distortion_replay_matches
from repro.fl.runtime import FFTConfig
from repro.fl.toy import make_toy_runner

BASE = dict(n_clients=6, k_selected=6, local_steps=2, batch_size=8, lr=0.05,
            seed=0, eval_every=2, model_bytes=4e6, deadline_s=5.0)
TOY = dict(n_samples=600, public_per_class=10, pretrain_steps=9)


def _rows(rng, J, C):
    alpha = rng.dirichlet(np.ones(C) * 0.5, size=J)
    p = rng.dirichlet(np.ones(J))
    return alpha, p @ alpha


# ---------------------------------------------------------------------------
# fedauto_discounted_weights: the one post-QP discount pipeline
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_discounted_weights_feasibility_and_pin(seed):
    rng = np.random.default_rng(seed)
    J, C = 4 + seed, 5 + seed
    alpha, alpha_g = _rows(rng, J, C)
    staleness = rng.integers(0, 4, J)
    staleness[0] = 0
    distortion = rng.uniform(0.0, 0.9, J)
    distortion[0] = 0.0
    beta = fedauto_discounted_weights(alpha, alpha_g, staleness, distortion,
                                      server_row=0, discount_b=1.5)
    assert np.all(beta >= -1e-6)
    assert abs(beta.sum() - 1.0) < 1e-4
    # Eq. 9 pin survives both discounts: beta_s = 1/(1+m)
    assert abs(beta[0] - 1.0 / J) < 1e-4


def test_discounted_weights_fresh_lossless_is_sync_bit_exact():
    rng = np.random.default_rng(5)
    alpha, alpha_g = _rows(rng, 6, 8)
    sync = fedauto_weights(alpha, alpha_g, np.ones(6, bool), server_row=0)
    got = fedauto_discounted_weights(alpha, alpha_g, np.zeros(6, int),
                                     np.zeros(6), server_row=0,
                                     discount_b=2.0)
    np.testing.assert_array_equal(sync, got)                 # bit-identical


def test_discounted_weights_stale_lossless_is_async_bit_exact():
    rng = np.random.default_rng(6)
    alpha, alpha_g = _rows(rng, 7, 9)
    staleness = np.array([0, 0, 1, 3, 0, 2, 5])
    want = fedauto_async_weights(alpha, alpha_g, staleness, server_row=0,
                                 discount_a=0.7)
    got = fedauto_discounted_weights(alpha, alpha_g, staleness, np.zeros(7),
                                     server_row=0, discount_a=0.7,
                                     discount_b=2.0)
    np.testing.assert_array_equal(want, got)                 # bit-identical


def test_discounted_weights_b_zero_ignores_distortion():
    rng = np.random.default_rng(7)
    alpha, alpha_g = _rows(rng, 5, 6)
    staleness = np.array([0, 1, 0, 2, 0])
    d = rng.uniform(0.1, 0.9, 5)
    want = fedauto_async_weights(alpha, alpha_g, staleness, server_row=0)
    got = fedauto_discounted_weights(alpha, alpha_g, staleness, d,
                                     server_row=0, discount_b=0.0)
    np.testing.assert_array_equal(want, got)


def test_discounted_weights_monotone_in_distortion():
    """Two participants with the *same* alpha row: the more distorted
    upload must never get more weight, and raising one participant's
    distortion must not raise its own weight."""
    rng = np.random.default_rng(8)
    C = 6
    row = rng.dirichlet(np.ones(C))
    alpha = np.stack([rng.dirichlet(np.ones(C)), row, row])
    alpha_g = np.array([0.3, 0.3, 0.4]) @ alpha
    beta = fedauto_discounted_weights(alpha, alpha_g, np.zeros(3),
                                      np.array([0.0, 0.0, 0.8]),
                                      server_row=0, discount_b=1.0)
    assert beta[2] < beta[1]
    prev = None
    for d in np.linspace(0.0, 1.0, 6):
        b = fedauto_discounted_weights(alpha, alpha_g, np.zeros(3),
                                       np.array([0.0, 0.0, d]),
                                       server_row=0, discount_b=1.0)
        if prev is not None:
            assert b[2] <= prev + 1e-9
        prev = b[2]
    even = fedauto_discounted_weights(alpha, alpha_g, np.zeros(3),
                                      np.array([0.0, 0.5, 0.5]),
                                      server_row=0, discount_b=1.0)
    assert abs(even[1] - even[2]) < 1e-5                     # equal discount


def test_discounted_weights_full_distortion_drops_to_server():
    rng = np.random.default_rng(9)
    alpha, alpha_g = _rows(rng, 4, 5)
    beta = fedauto_discounted_weights(alpha, alpha_g, np.zeros(4),
                                      np.array([0.0, 1.0, 1.0, 1.0]),
                                      server_row=0, discount_b=1.0)
    # every client annihilated: the server keeps the whole budget
    assert beta[0] == pytest.approx(1.0)
    assert np.all(beta[1:] == 0.0)
    # out-of-range distortions are clipped, not amplified
    clipped = fedauto_discounted_weights(alpha, alpha_g, np.zeros(4),
                                         np.array([0.0, 2.5, 1.0, 7.0]),
                                         server_row=0, discount_b=1.0)
    np.testing.assert_array_equal(beta, clipped)


# ---------------------------------------------------------------------------
# distortion plumbing: roundtrip → loops → strategy contexts
# ---------------------------------------------------------------------------
def test_roundtrip_distortion_matches_residual_over_carry():
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.normal(size=(17, 5)), jnp.float32)}
    st = CommState(make_codec("sign1"), tree)
    g = jax.tree.map(jnp.zeros_like, tree)
    model = tree                       # random delta: sign1 genuinely lossy
    _, _, d = st.roundtrip(0, model, g)
    carry = jax.tree.map(
        lambda w, gg: w.astype(jnp.float32) - gg.astype(jnp.float32),
        model, g)                                  # first upload: no residual
    resid = st.residual(0)
    l2 = lambda t: float(jnp.sqrt(sum(jnp.sum(jnp.square(x))
                                      for x in jax.tree.leaves(t))))
    assert d == pytest.approx(l2(resid) / l2(carry))
    assert 0.0 < d < 1.0
    assert st.last_distortions[0] == d


@pytest.mark.parametrize("mode", ["sync", "buffered"])
def test_context_carries_distortions_and_wire_metadata(mode):
    cfg = FFTConfig(codec="sign1", server_mode=mode,
                    failure_mode="scenario:lossy_uplink", **BASE)
    runner = make_toy_runner(cfg, **TOY)
    seen = []
    name = "fedauto" if mode == "sync" else "fedauto_async"

    class Probe(STRATEGIES[name]):
        def aggregate(self, ctx):
            seen.append(ctx)
            return super().aggregate(ctx)

        def aggregate_async(self, ctx):
            seen.append(ctx)
            return super().aggregate_async(ctx)

    runner.run(Probe(), rounds=3)
    with_parts = [c for c in seen if c.distortions]
    assert with_parts, "no round delivered any upload"
    for ctx in with_parts:
        assert ctx.codec == "sign1"                # decodable static codec
        for i, d in ctx.distortions.items():
            assert 0.0 < d <= 1.0                  # sign1 is lossy: measured
            assert ctx.codecs[i] == "sign1"
            assert ctx.upload_bytes[i] == pytest.approx(
                runner.comm.upload_bytes)


def test_adaptive_context_metadata_is_per_round_truth():
    """Satellite: adaptive runs must not report the ``adaptive:…`` spec
    string as ``ctx.codec`` nor the static hi-rung bytes as
    ``ctx.upload_nbytes`` — the per-client assignment is the truth."""
    cfg = FFTConfig(codec="adaptive:sign1-fp16",
                    failure_mode="scenario:diurnal", **BASE)
    runner = make_toy_runner(cfg, **TOY)
    seen = []

    class Probe(STRATEGIES["fedavg"]):
        def aggregate(self, ctx):
            seen.append(ctx)
            return super().aggregate(ctx)

    runner.run(Probe(), rounds=3)
    assert any(c.codecs for c in seen)
    for ctx in seen:
        assert ctx.codec is None                   # no single decodable codec
        assert ctx.upload_nbytes is None           # no single wire size
        for i, cname in ctx.codecs.items():
            assert cname in RUNG_LADDER
            assert ctx.upload_bytes[i] == pytest.approx(
                runner.comm.nbytes_for(cname))
            assert i in ctx.distortions


def test_fidelity_discount_downweights_distorted_upload():
    """End to end through FedAutoAsync: a maximally distorted arrival loses
    weight to its lossless twin once the fidelity discount is on."""
    rng = np.random.default_rng(3)
    tree = lambda s: {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)}
    hists = np.array([[5, 5, 0], [5, 5, 0]])
    mk = lambda b: FedAutoAsync(use_module1=False, fidelity_discount=b)
    arrivals = [
        Arrival(client=0, origin_round=1, staleness=0, arrival_s=0.0,
                model=tree(0), distortion=0.0),
        Arrival(client=1, origin_round=1, staleness=0, arrival_s=0.0,
                model=tree(1), distortion=0.9),
    ]
    captured = {}
    orig = fedauto_discounted_weights

    def capture(*a, **kw):
        beta = orig(*a, **kw)
        captured.setdefault("betas", []).append(beta)
        return beta

    import repro.core.strategies as smod
    smod.fedauto_discounted_weights = capture
    try:
        for b in (0.0, 2.0):
            ctx = AsyncRoundContext(
                rnd=1, now_s=0.0, global_params=tree(2),
                server_model=tree(3), arrivals=list(arrivals),
                p=np.full(3, 1 / 3), client_hists=hists,
                server_hist=np.array([3, 3, 3]),
                global_hist=np.array([13, 13, 3]))
            mk(b).aggregate_async(ctx)
    finally:
        smod.fedauto_discounted_weights = orig
    b0, b2 = captured["betas"]
    # same alpha rows: without the discount the twins weigh equally; with it
    # the distorted one is strictly down-weighted
    assert b0[1] == pytest.approx(b0[2])
    assert b2[2] < b2[1]
    assert abs(b2.sum() - 1.0) < 1e-4


def test_config_fidelity_discount_b_reaches_strategy():
    """``FFTConfig.fidelity_discount_b`` changes training under a lossy
    codec and is bit-exactly inert under a lossless one.  Compared on the
    trained parameters, not the accuracy history — the toy test set is so
    small that a small re-weighting can leave every accuracy bucket
    unchanged."""
    params = {}
    for codec in ("sign1", "fp32"):
        for b in (0.0, 4.0):
            cfg = FFTConfig(codec=codec, fidelity_discount_b=b,
                            failure_mode="scenario:lossy_uplink", **BASE)
            runner = make_toy_runner(cfg, **TOY)
            runner.run(FedAuto(use_module1=False), rounds=3)
            params[codec, b] = jax.tree.leaves(runner.global_params)

    def same(a, b):
        return all(np.array_equal(np.asarray(x), np.asarray(y))
                   for x, y in zip(a, b))

    assert same(params["fp32", 0.0], params["fp32", 4.0])      # lossless: inert
    assert not same(params["sign1", 0.0], params["sign1", 4.0])  # lossy


# ---------------------------------------------------------------------------
# trace schema v4: per-client distortions, same-config replay cross-check
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["sync", "buffered"])
def test_trace_v4_records_and_replays_distortions(tmp_path, mode):
    path = str(tmp_path / "t.ndjson")
    cfg = FFTConfig(codec="adaptive:sign1-fp16", server_mode=mode,
                    failure_mode="scenario:diurnal", trace_record=path,
                    **BASE)
    runner = make_toy_runner(cfg, **TOY)
    live = runner.run(STRATEGIES["fedauto" if mode == "sync"
                                 else "fedauto_async"](), rounds=4)
    live_dist = runner.loop.distortion_history
    lines = [json.loads(l) for l in open(path)]
    assert lines[0]["version"] == 5
    recorded_any = False
    for rec in lines[1:]:
        d = {c["id"]: c["distortion"] for c in rec["clients"]
             if "distortion" in c}
        assert d == pytest.approx(live_dist[rec["round"] - 1])
        recorded_any |= bool(d)
    assert recorded_any

    # same-config replay recomputes the identical distortions bit-exactly
    rep_cfg = FFTConfig(codec="adaptive:sign1-fp16", server_mode=mode,
                        trace_replay=path, **BASE)
    rep_runner = make_toy_runner(rep_cfg, **TOY)
    rep = rep_runner.run(STRATEGIES["fedauto" if mode == "sync"
                                    else "fedauto_async"](), rounds=4)
    assert rep == live
    # bit-exact recomputation of every recorded per-client distortion
    assert distortion_replay_matches(rep_runner.failures,
                                     rep_runner.loop.distortion_history, 4)
    # and the cross-check is not vacuous: perturb one value and it trips
    rep_runner.loop.distortion_history[-1][
        next(iter(rep_runner.loop.distortion_history[-1]), 0)] = 0.123
    assert not distortion_replay_matches(
        rep_runner.failures, rep_runner.loop.distortion_history, 4)


def test_legacy_v3_adaptive_trace_still_replays(tmp_path, monkeypatch):
    """Pre-v4 adaptive traces were recorded with the round-1 broadcast
    priced at the steady-state compressed rate; replaying one must feed the
    controller that same number (not the v4 enrollment ref_bytes), or its
    re-derived rungs would drift from the recording and the loud
    cross-check would wrongly blame the user's configuration."""
    path = str(tmp_path / "t3.ndjson")
    cfg = FFTConfig(codec="adaptive:sign1-fp16",
                    failure_mode="scenario:diurnal", trace_record=path,
                    **BASE)
    runner = make_toy_runner(cfg, **TOY)
    # replicate the pre-v4 recorder: no enrollment repricing anywhere
    monkeypatch.setattr(type(runner.comm), "next_broadcast_nbytes",
                        lambda self: float(self.download_bytes))
    live = runner.run(STRATEGIES["fedavg"](), rounds=3)
    lines = [json.loads(l) for l in open(path)]
    assert lines[1]["clients"][0]["download_bytes"] == pytest.approx(
        runner.comm.download_bytes)              # compressed round 1, as v3
    lines[0]["version"] = 3
    for rec in lines[1:]:
        for c in rec["clients"]:
            c.pop("distortion", None)
    with open(path, "w") as fh:
        for rec in lines:
            fh.write(json.dumps(rec) + "\n")
    monkeypatch.undo()                           # replay runs unpatched
    rerec = str(tmp_path / "rerec.ndjson")
    rep_cfg = FFTConfig(codec="adaptive:sign1-fp16", trace_replay=path,
                        trace_record=rerec, **BASE)
    rep = make_toy_runner(rep_cfg, **TOY).run(STRATEGIES["fedavg"](),
                                              rounds=3)
    assert rep == live
    # a re-recording made during a legacy replay keeps the source's version
    # stamp (its controller trajectory used the legacy enrollment pricing),
    # so replaying the re-recording applies the same shim and stays exact
    assert json.loads(open(rerec).readline())["version"] == 3
    rep2_cfg = FFTConfig(codec="adaptive:sign1-fp16", trace_replay=rerec,
                         **BASE)
    rep2 = make_toy_runner(rep2_cfg, **TOY).run(STRATEGIES["fedavg"](),
                                               rounds=3)
    assert rep2 == live


def test_fidelity_discounted_run_replays_bit_exact(tmp_path):
    path = str(tmp_path / "t.ndjson")
    cfg = FFTConfig(codec="adaptive:sign1-fp16", fidelity_discount_b=1.0,
                    failure_mode="scenario:diurnal", trace_record=path,
                    **BASE)
    live = make_toy_runner(cfg, **TOY).run(STRATEGIES["fedauto"](), rounds=4)
    rep_cfg = FFTConfig(codec="adaptive:sign1-fp16", fidelity_discount_b=1.0,
                        trace_replay=path, **BASE)
    rep = make_toy_runner(rep_cfg, **TOY).run(STRATEGIES["fedauto"](),
                                              rounds=4)
    assert rep == live


# ---------------------------------------------------------------------------
# satellite: stale cross-run strategy state
# ---------------------------------------------------------------------------
def test_tf_aggregation_resets_selection_probs_between_runs():
    strat = TFAggregation()
    strat.s = np.array([1.0, 0.0, 0.0])            # poisoned by a prior run
    strat.init_state(None)
    assert strat.s is None


def test_reused_strategy_instances_reproduce_fresh_runs():
    """One instance run twice must match two fresh instances — no state
    (selection probs, control variates, buffers, extrapolation clocks)
    may leak across runs."""
    for name in ("tf_aggregation", "scaffold", "fedawe", "fedbuff"):
        cfg = FFTConfig(codec="fp32", failure_mode="scenario:lossy_uplink",
                        server_mode=("buffered" if name == "fedbuff"
                                     else "sync"), **BASE)

        def fresh_run(strat):
            runner = make_toy_runner(cfg, **TOY)
            return runner.run(strat, rounds=3)

        reused = STRATEGIES[name]()
        first = fresh_run(reused)
        again = fresh_run(reused)
        control = fresh_run(STRATEGIES[name]())
        assert first == control, name
        assert again == control, name


# ---------------------------------------------------------------------------
# satellite: selection-masked rung histogram + trace rows
# ---------------------------------------------------------------------------
def test_rung_histogram_counts_only_selected_clients(tmp_path):
    path = str(tmp_path / "t.ndjson")
    cfg = dict(BASE)
    cfg["k_selected"] = 3                          # partial participation
    cfg = FFTConfig(codec="adaptive:sign1-fp16",
                    failure_mode="scenario:diurnal", trace_record=path,
                    **cfg)
    runner = make_toy_runner(cfg, **TOY)
    rounds = 4
    runner.run(STRATEGIES["fedavg"](), rounds=rounds)
    hist = runner.controller.rung_histogram()
    assert sum(hist.values()) == rounds * 3        # not rounds * n_clients
    # trace rows carry a rung only for clients the server contacted
    for rec in [json.loads(l) for l in open(path)][1:]:
        for c in rec["clients"]:
            assert ("codec" in c) == c["selected"]


def test_partial_selection_adaptive_replay_bit_exact(tmp_path):
    path = str(tmp_path / "t.ndjson")
    kw = dict(BASE, k_selected=3)
    cfg = FFTConfig(codec="adaptive:sign1-fp16",
                    failure_mode="scenario:diurnal", trace_record=path, **kw)
    live = make_toy_runner(cfg, **TOY).run(STRATEGIES["fedavg"](), rounds=4)
    rep_cfg = FFTConfig(codec="adaptive:sign1-fp16", trace_replay=path, **kw)
    rep = make_toy_runner(rep_cfg, **TOY).run(STRATEGIES["fedavg"](),
                                              rounds=4)
    assert rep == live


# ---------------------------------------------------------------------------
# satellite: round-1 compressed-downlink enrollment accounting
# ---------------------------------------------------------------------------
def test_enrollment_broadcast_charged_at_ref_bytes_end_to_end():
    cfg = FFTConfig(codec="fp32", downlink_codec="int8",
                    failure_mode="scenario:lossy_uplink", **BASE)
    runner = make_toy_runner(cfg, **TOY)
    rounds = 3
    runner.run(STRATEGIES["fedavg"](), rounds=rounds)
    comm = runner.comm
    assert comm.total_downlink_bytes == pytest.approx(
        comm.ref_bytes + (rounds - 1) * comm.download_bytes)
    assert comm.download_bytes < comm.ref_bytes


def test_downlink_repricing_keeps_compressed_upload_pricing():
    """Regression: the per-round downlink repricing of a static run with a
    downlink codec must restate the upload size — ``set_payload_bytes``
    resets any direction passed as None to the full model_bytes default,
    which would silently erase the upload codec's deadline benefit."""
    cfg = FFTConfig(codec="int8", downlink_codec="int8",
                    failure_mode="scenario:lossy_uplink", **BASE)
    runner = make_toy_runner(cfg, **TOY)
    runner.run(STRATEGIES["fedavg"](), rounds=2)
    sim = runner.failures.sim
    assert sim.upload_bytes is not None
    np.testing.assert_allclose(sim.upload_bytes, runner.comm.upload_bytes)
    np.testing.assert_allclose(sim.download_bytes, runner.comm.download_bytes)


def test_round1_assignment_and_trace_record_enrollment_bytes(tmp_path):
    """The controller's round-1 assignment (what ``observe`` divides by)
    and the trace both carry the enrollment transfer's actual ref_bytes,
    matching how the simulator priced that round's downlink."""
    path = str(tmp_path / "t.ndjson")
    cfg = FFTConfig(codec="adaptive:sign1-fp16",
                    failure_mode="scenario:lossy_uplink", trace_record=path,
                    **BASE)
    runner = make_toy_runner(cfg, **TOY)
    runner.run(STRATEGIES["fedavg"](), rounds=2)
    comm = runner.comm
    assert runner.controller.assignments[1].download_bytes == pytest.approx(
        comm.ref_bytes)
    assert runner.controller.assignments[2].download_bytes == pytest.approx(
        comm.download_bytes)
    lines = [json.loads(l) for l in open(path)]
    for rec in lines[1:]:
        want = comm.ref_bytes if rec["round"] == 1 else comm.download_bytes
        for c in rec["clients"]:
            assert c["download_bytes"] == pytest.approx(want)
