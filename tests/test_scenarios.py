"""Scenario subsystem tests: event engine semantics, registry validity,
deadline monotonicity, NDJSON trace schema, and the headline guarantee —
record → replay reproduces identical ``connected`` masks and accuracy."""
import json
import math

import numpy as np
import pytest

from repro.fl.scenarios import (CAUSE_DEADLINE, CAUSE_OK, DeadlineSimulator,
                                LinkState, ReplayFailureModel, TraceRecorder,
                                available_scenarios, load_trace,
                                make_scenario, make_scenario_model)

N = 12
ROUNDS = 100


# ---------------------------------------------------------------------------
# event engine
# ---------------------------------------------------------------------------
def test_engine_wired_always_meets_generous_deadline():
    sim = DeadlineSimulator(2, model_bytes=1e6, deadline_s=1e6,
                            compute_s=0.1, seed=0)
    links = [LinkState(math.inf), LinkState(math.inf)]
    ev = sim.simulate_round(1, links)
    assert ev.connected_mask().all()
    for e in ev.events:
        assert e.t_upload_s == 0.0 and e.cause == CAUSE_OK


def test_engine_slow_link_misses_deadline_with_cause():
    sim = DeadlineSimulator(2, model_bytes=1e6, deadline_s=5.0,
                            compute_s=0.0, hetero_sigma=0.0,
                            jitter_sigma=0.0, seed=0)
    # 8e6 bits over 100 Mbps -> 0.09 s total; over 0.1 Mbps -> 80 s upload.
    ev = sim.simulate_round(1, [LinkState(100e6), LinkState(0.1e6)])
    np.testing.assert_array_equal(ev.connected_mask(), [True, False])
    assert ev.events[1].cause == CAUSE_DEADLINE
    assert ev.events[1].up                       # link up, just too slow
    assert ev.events[0].finish_s <= 5.0
    # the server waited out the full deadline for the straggler
    assert ev.duration_s == 5.0


def test_engine_down_link_reports_refined_cause():
    sim = DeadlineSimulator(1, model_bytes=1e6, deadline_s=10.0, seed=0)
    ev = sim.simulate_round(1, [LinkState(0.0, up=False, cause="ap_outage")])
    assert not ev.connected_mask().any()
    assert ev.events[0].cause == "ap_outage"
    assert math.isinf(ev.events[0].finish_s)


def test_engine_server_wait_respects_selection():
    sim = DeadlineSimulator(2, model_bytes=1e6, deadline_s=30.0,
                            compute_s=0.0, hetero_sigma=0.0,
                            jitter_sigma=0.0, seed=0)
    ev = sim.simulate_round(1, [LinkState(100e6), LinkState(0.01e6)])
    assert ev.duration_s == 30.0                 # full cohort: straggler
    sel = np.array([True, False])
    assert ev.server_wait(sel) == ev.events[0].finish_s
    # an empty cohort still waits out the round timeout — a server whose
    # selection came up empty does not advance its clock for free
    assert ev.server_wait(np.array([False, False])) == 30.0


def test_engine_round_duration_bounded_by_deadline():
    sim = DeadlineSimulator(3, model_bytes=1e6, deadline_s=7.0,
                            compute_s=1.0, seed=1)
    ev = sim.simulate_round(1, [LinkState(5e6) for _ in range(3)])
    assert 0.0 < ev.duration_s <= 7.0


# ---------------------------------------------------------------------------
# registry worlds
# ---------------------------------------------------------------------------
def test_registry_has_required_worlds():
    names = available_scenarios()
    assert len(names) >= 4
    for required in ["correlated_wifi", "diurnal", "bursty_handover",
                     "churn", "table6"]:
        assert required in names


@pytest.mark.parametrize("name", available_scenarios())
def test_scenario_draws_valid_masks_100_rounds(name):
    m = make_scenario_model(name, N, model_bytes=0.2e6, deadline_s=8.0,
                            seed=0)
    masks = np.stack([m.draw(r) for r in range(1, ROUNDS + 1)])
    assert masks.shape == (ROUNDS, N) and masks.dtype == bool
    assert masks.any()                           # never a dead world
    ev = m.draw_events(ROUNDS)
    assert len(ev.events) == N
    for e in ev.events:
        assert e.capacity_bps >= 0.0
        assert e.connected == (e.up and e.met_deadline)


def test_repeated_draw_returns_cached_realization():
    """draw(r) for a past round must replay the recorded realization, not
    re-advance the scenario's Markov state."""
    m = make_scenario_model("bursty_handover", N, model_bytes=0.2e6,
                            deadline_s=8.0, seed=4)
    first = [m.draw(r).copy() for r in range(1, 11)]
    for r in [3, 7, 1, 10]:
        np.testing.assert_array_equal(m.draw(r), first[r - 1])


@pytest.mark.parametrize("name", available_scenarios())
def test_scenario_reset_reproduces_realization(name):
    m = make_scenario_model(name, N, model_bytes=0.2e6, deadline_s=8.0,
                            seed=5)
    a = np.stack([m.draw(r) for r in range(1, 31)])
    m.reset()
    b = np.stack([m.draw(r) for r in range(1, 31)])
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("name", available_scenarios())
def test_participation_monotone_in_deadline(name):
    """Tightening the server deadline can only drop participants: the same
    realization's durations don't depend on the cutoff."""
    totals = []
    for deadline in [0.5, 2.0, 8.0, 40.0, 1e6]:
        m = make_scenario_model(name, N, model_bytes=0.2e6,
                                deadline_s=deadline, seed=3)
        totals.append(sum(int(m.draw(r).sum()) for r in range(1, 41)))
    assert totals == sorted(totals)
    # with effectively no deadline, only hard link outages remain
    m = make_scenario_model(name, N, model_bytes=0.2e6, deadline_s=1e6,
                            seed=3)
    ev = m.draw_events(1)
    assert ev.deadline_mask()[ev.up_mask()].all()


def test_correlated_wifi_outages_are_grouped():
    scen = make_scenario("correlated_wifi", 12, seed=2, n_aps=3,
                         p_fail=0.3, p_recover=0.3)
    grouped = 0
    for r in range(200):
        links = scen.sample_round(r)
        down = np.array([not l.up for l in links])
        for ap in range(3):
            members = down[np.arange(12) % 3 == ap]
            assert members.all() or not members.any()   # AP drops all or none
            grouped += members.all()
    assert grouped > 0                                  # outages do happen


def test_unknown_scenario_raises():
    with pytest.raises(ValueError, match="unknown scenario"):
        make_scenario("no_such_world", 4)


# ---------------------------------------------------------------------------
# trace schema + replay
# ---------------------------------------------------------------------------
def test_trace_ndjson_schema(tmp_path):
    path = str(tmp_path / "t.ndjson")
    m = make_scenario_model("cross_region", 6, model_bytes=0.2e6,
                            deadline_s=8.0, seed=0)
    with TraceRecorder(path, {"scenario": "scenario:cross_region",
                              "n_clients": 6, "deadline_s": 8.0,
                              "model_bytes": 0.2e6, "seed": 0}) as rec:
        for r in range(1, 6):
            ev = m.draw_events(r)
            sel = np.ones(6, dtype=bool)
            rec.write_round(r, sel, sel & ev.connected_mask(), ev)

    lines = [json.loads(l) for l in open(path)]
    assert lines[0]["record"] == "header" and lines[0]["version"] == 5
    assert lines[0]["n_clients"] == 6
    assert len(lines) == 6
    for rec_ in lines[1:]:
        assert rec_["record"] == "round"
        assert len(rec_["clients"]) == 6
        for c in rec_["clients"]:
            assert {"id", "capacity_bps", "up", "duration_s", "selected",
                    "met_deadline", "connected", "cause"} <= set(c)

    header, rounds = load_trace(path)
    assert sorted(rounds) == [1, 2, 3, 4, 5]


def test_replay_reproduces_masks_bit_exactly(tmp_path):
    path = str(tmp_path / "t.ndjson")
    m = make_scenario_model("bursty_handover", N, model_bytes=0.2e6,
                            deadline_s=6.0, seed=9)
    masks = []
    with TraceRecorder(path, {"scenario": "scenario:bursty_handover",
                              "n_clients": N}) as rec:
        for r in range(1, 41):
            ev = m.draw_events(r)
            sel = np.ones(N, dtype=bool)
            rec.write_round(r, sel, ev.connected_mask(), ev)
            masks.append(ev.connected_mask())
    replay = ReplayFailureModel(path, n_clients=N)
    for r in range(1, 41):
        np.testing.assert_array_equal(replay.draw(r), masks[r - 1])
    with pytest.raises(ValueError, match="no round"):
        replay.draw(99)


def test_replay_rejects_wrong_client_count(tmp_path):
    path = str(tmp_path / "t.ndjson")
    m = make_scenario_model("churn", 4, model_bytes=0.2e6, deadline_s=8.0,
                            seed=0)
    with TraceRecorder(path, {"n_clients": 4}) as rec:
        ev = m.draw_events(1)
        rec.write_round(1, np.ones(4, bool), ev.connected_mask(), ev)
    with pytest.raises(ValueError, match="clients"):
        ReplayFailureModel(path, n_clients=7)


# ---------------------------------------------------------------------------
# end-to-end: FFTRunner on a scenario, record -> replay -> identical history
# ---------------------------------------------------------------------------
def _tiny_runner(cfg):
    from repro.fl.toy import make_toy_runner
    return make_toy_runner(cfg, n_samples=600, public_per_class=10,
                           pretrain_steps=9)


@pytest.mark.parametrize("strategy", ["fedavg", "fedauto"])
def test_runner_scenario_record_then_replay(tmp_path, strategy):
    from repro.core.strategies import STRATEGIES
    from repro.fl.runtime import FFTConfig
    from repro.fl.scenarios.engine import ScenarioFailureModel

    path = str(tmp_path / "realization.ndjson")
    base = dict(n_clients=6, k_selected=6, local_steps=2, batch_size=8,
                lr=0.05, seed=0, eval_every=2, model_bytes=0.2e6,
                deadline_s=6.0)

    cfg = FFTConfig(failure_mode="scenario:correlated_wifi",
                    trace_record=path, **base)
    runner = _tiny_runner(cfg)
    assert isinstance(runner.failures, ScenarioFailureModel)
    hist = runner.run(STRATEGIES[strategy](), rounds=4)
    runner.failures.reset()
    masks = np.stack([runner.failures.draw(r) for r in range(1, 5)])

    cfg2 = FFTConfig(failure_mode="scenario:correlated_wifi",
                     trace_replay=path, **base)
    runner2 = _tiny_runner(cfg2)
    assert isinstance(runner2.failures, ReplayFailureModel)
    hist2 = runner2.run(STRATEGIES[strategy](), rounds=4)
    masks2 = np.stack([runner2.failures.draw(r) for r in range(1, 5)])

    np.testing.assert_array_equal(masks, masks2)   # identical realization
    assert hist == hist2                           # identical accuracy curve


def test_table6_scenario_uses_runner_channels():
    """ResourceOpt (and any other channel intervention) must reach the
    scenario world, not a freshly rebuilt topology."""
    from repro.fl.runtime import FFTConfig
    cfg = FFTConfig(n_clients=6, k_selected=6, local_steps=1, batch_size=8,
                    lr=0.05, seed=0, eval_every=10 ** 6, model_bytes=0.2e6,
                    failure_mode="scenario:table6", resource_opt="joint")
    runner = _tiny_runner(cfg)
    assert runner.failures.scenario.channels is runner.channels


def test_runner_legacy_modes_unchanged(tmp_path):
    """Legacy failure modes still run through the new loop (met_deadline all
    True) and their realization is recordable/replayable too."""
    from repro.core.strategies import STRATEGIES
    from repro.fl.runtime import FFTConfig

    path = str(tmp_path / "legacy.ndjson")
    base = dict(n_clients=6, k_selected=6, local_steps=2, batch_size=8,
                lr=0.05, seed=0, eval_every=2, model_bytes=0.2e6)
    runner = _tiny_runner(FFTConfig(failure_mode="intermittent",
                                    trace_record=path, **base))
    hist = runner.run(STRATEGIES["fedavg"](), rounds=4)
    runner2 = _tiny_runner(FFTConfig(failure_mode="intermittent",
                                     trace_replay=path, **base))
    hist2 = runner2.run(STRATEGIES["fedavg"](), rounds=4)
    assert hist == hist2
    # the recorded up bits are the model's true draw (not inferred from
    # connected|selected), so replay under a different selection is honest
    from repro.fl.failures import IntermittentFailures
    fresh = IntermittentFailures(6, duration_max=10, seed=0)
    replay = ReplayFailureModel(path, n_clients=6)
    for r in range(1, 5):
        np.testing.assert_array_equal(replay.draw(r), fresh.draw(r))
