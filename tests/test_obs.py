"""ISSUE-6: run telemetry subsystem (repro.obs).

Acceptance criteria, asserted across server modes × codec families on real
instrumented runs:

* outcome closure — every client in every round has exactly one terminal
  outcome, and per-cause counts sum to ``n_clients × rounds``;
* byte reconciliation — telemetry totals equal
  ``CommState.total_uplink_bytes`` / ``total_downlink_bytes``;
* β rows match the weights the strategy actually applied;
* the NDJSON event log round-trips to the same flight record;
* the disabled-telemetry path leaves accuracy histories bit-identical.

Plus unit coverage of the hub's invariants (duplicate-outcome rejection,
resolution upgrades, counters/timers) and the renderer/reconcile helpers.
"""
import copy
import math

import numpy as np
import pytest

from repro.core.strategies import STRATEGIES
from repro.core.weights_qp import heuristic_weights
from repro.fl.runtime import FFTConfig
from repro.fl.toy import make_toy_runner
from repro.obs import (AGGREGATED, BUFFERED, EVICTED, LINK_DOWN,
                       MISSED_DEADLINE, NOT_SELECTED, NULL_TELEMETRY,
                       OUTCOMES, TELEMETRY_VERSION, ConsoleSink, NdjsonSink,
                       ReconcileError, RunReport, Telemetry, beta_row,
                       reconcile, render_markdown)

BASE = dict(n_clients=6, k_selected=4, local_steps=2, batch_size=8, lr=0.05,
            seed=3, eval_every=2, deadline_s=30.0, tau_max=3, buffer_k=2,
            failure_mode="scenario:bursty_handover")
TOY = dict(n_samples=300, n_classes=4, image_size=8, public_per_class=10,
           pretrain_steps=0, seed=3)
ROUNDS = 5

# (server_mode, codec, strategy): sync/async/buffered × static/adaptive
COMBOS = [
    ("sync", "fp32", "fedavg"),
    ("sync", "qsgd:4", "fedauto"),
    ("sync", "adaptive:sign1-fp16", "fedauto"),
    ("async", "fp32", "fedasync"),
    ("async", "adaptive:sign1-fp16", "fedauto_async"),
    ("buffered", "qsgd:4", "fedbuff"),
    ("buffered", "adaptive:sign1-fp16", "fedauto_async"),
]


def _run(mode, codec, strat, tmp_path=None, telemetry=True, rounds=ROUNDS,
         **over):
    cfg_kw = dict(BASE, server_mode=mode, codec=codec, telemetry=telemetry,
                  **over)
    if tmp_path is not None:
        slug = codec.replace(":", "_").replace("-", "_")
        cfg_kw["telemetry_log"] = str(
            tmp_path / f"{mode}_{strat}_{slug}.ndjson")
    cfg = FFTConfig(**cfg_kw)
    runner = make_toy_runner(cfg, **TOY)
    hist = runner.run(STRATEGIES[strat](), rounds=rounds)
    return runner, hist


@pytest.fixture(scope="module")
def runs(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("tel")
    out = {}
    for mode, codec, strat in COMBOS:
        out[(mode, codec, strat)] = _run(mode, codec, strat, tmp_path=tmp)
    return out


# ---------------------------------------------------------------------------
# acceptance: outcome closure + byte reconciliation + NDJSON round-trip
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("combo", COMBOS, ids=lambda c: "/".join(c))
def test_outcome_closure_and_reconcile(runs, combo):
    runner, _hist = runs[combo]
    rep = runner.report
    assert rep is not None and rep.n_rounds == ROUNDS
    counts = rep.drop_cause_counts()
    assert set(counts) == set(OUTCOMES)
    assert sum(counts.values()) == BASE["n_clients"] * ROUNDS
    # exactly one terminal outcome per (round, client)
    assert len(rep.final_outcomes()) == BASE["n_clients"] * ROUNDS
    nums = reconcile(rep, runner)          # raises ReconcileError on drift
    assert nums["uplink_bytes"] == pytest.approx(
        runner.comm.total_uplink_bytes)
    assert nums["downlink_bytes"] == pytest.approx(
        runner.comm.total_downlink_bytes)


@pytest.mark.parametrize("combo", COMBOS, ids=lambda c: "/".join(c))
def test_ndjson_roundtrip(runs, combo):
    runner, _hist = runs[combo]
    rep2 = RunReport.from_ndjson(runner.cfg.telemetry_log)
    reconcile(rep2, runner)
    assert rep2.drop_cause_counts() == runner.report.drop_cause_counts()
    assert rep2.participants_per_round() == \
        runner.report.participants_per_round()
    assert rep2.total_upload_bytes() == pytest.approx(
        runner.report.total_upload_bytes())
    c1, c2 = runner.report.accuracy_curve(), rep2.accuracy_curve()
    assert [r for r, _ in c2] == [r for r, _ in c1]
    assert [a for _, a in c2] == pytest.approx([a for _, a in c1])
    assert len(rep2.beta_rows()) == len(runner.report.beta_rows())


def test_disabled_path_bit_identical():
    for mode, codec, strat in [("sync", "qsgd:4", "fedauto"),
                               ("buffered", "adaptive:sign1-fp16",
                                "fedauto_async")]:
        _, h_on = _run(mode, codec, strat, telemetry=True)
        runner_off, h_off = _run(mode, codec, strat, telemetry=False)
        assert h_off == h_on
        assert runner_off.report is None
        assert runner_off.telemetry is NULL_TELEMETRY


# ---------------------------------------------------------------------------
# acceptance: β rows match the strategy's actually-applied weights
# ---------------------------------------------------------------------------
def test_beta_rows_match_fedavg_weights(runs):
    runner, _ = runs[("sync", "fp32", "fedavg")]
    rep = runner.report
    outcomes = rep.final_outcomes()
    full = runner.k_selected >= runner.n_clients
    for rnd_rec in rep.rounds:
        r = rnd_rec["round"]
        connected = np.array([
            outcomes[(r, i)]["outcome"] == AGGREGATED
            for i in range(runner.n_clients)])
        beta = heuristic_weights(runner.p,
                                 np.concatenate([[True], connected]),
                                 server_idx=0, full_participation=full)
        rows = rnd_rec["betas"]
        by_client = {row["client"]: row["beta"] for row in rows
                     if row["role"] == "client"}
        assert set(by_client) == set(np.where(connected)[0])
        for i, b in by_client.items():
            assert b == pytest.approx(float(beta[i + 1]))
        server = [row["beta"] for row in rows if row["role"] == "server"]
        assert server == [pytest.approx(float(beta[0]))]


@pytest.mark.parametrize("combo", [("sync", "qsgd:4", "fedauto"),
                                   ("buffered", "adaptive:sign1-fp16",
                                    "fedauto_async")],
                         ids=lambda c: "/".join(c))
def test_beta_rows_simplex_and_cohort(runs, combo):
    """FedAuto's QP weights live on the simplex; the recorded client rows
    must be exactly the aggregated cohort of each aggregation step."""
    runner, _ = runs[combo]
    rep = runner.report
    outcomes = rep.final_outcomes()
    for rnd_rec in rep.rounds:
        rows = rnd_rec["betas"]
        if not rows:                        # deferred buffered round
            assert rnd_rec["gauges"]["participants"] == 0
            continue
        assert sum(row["beta"] for row in rows) == pytest.approx(1.0)
        assert all(row["beta"] >= -1e-12 for row in rows)
        recorded = {(row.get("origin_round", rnd_rec["round"]),
                     row["client"])
                    for row in rows if row["role"] == "client"}
        aggregated = {
            (r, c) for (r, c), rec in outcomes.items()
            if rec["outcome"] == AGGREGATED
            and rec.get("applied_round", r) == rnd_rec["round"]}
        assert recorded == aggregated


def test_aggregated_betas_carry_rung_and_distortion(runs):
    runner, _ = runs[("sync", "adaptive:sign1-fp16", "fedauto")]
    client_rows = [row for row in runner.report.beta_rows()
                   if row.get("role") == "client"]
    assert client_rows
    for row in client_rows:
        assert row["rung"] in runner.controller.rungs
        assert 0.0 <= row["distortion"] <= 1.0


# ---------------------------------------------------------------------------
# full outcome vocabulary on a harsh world (stragglers + evictions)
# ---------------------------------------------------------------------------
def test_async_vocabulary_and_resolutions(tmp_path):
    runner, hist = _run("buffered", "fp32", "fedauto_async",
                        tmp_path=tmp_path, telemetry=True, rounds=8,
                        failure_mode="scenario:cross_region",
                        deadline_s=6.0, model_bytes=8e6, k_selected=5,
                        seed=7, tau_max=2, buffer_k=3)
    rep = runner.report
    reconcile(rep, runner)
    counts = rep.drop_cause_counts()
    assert counts[EVICTED] > 0             # unreachable stragglers
    assert rep.resolutions                 # late arrivals resolved
    # every resolution upgraded a record that was provisionally buffered
    raw = {(r["round"], c): rec["outcome"]
           for r in rep.rounds for c, rec in r["clients"].items()}
    for res in rep.resolutions:
        assert raw[(res["origin_round"], res["client"])] == BUFFERED
        assert res["outcome"] in (AGGREGATED, EVICTED)
    # unresolved buffered records are still in flight at run end
    final = rep.final_outcomes()
    in_flight = [k for k, rec in final.items()
                 if rec["outcome"] == BUFFERED]
    assert len(in_flight) == len(runner.loop.buffer)
    # ndjson round-trip preserves the resolutions
    rep2 = RunReport.from_ndjson(runner.cfg.telemetry_log)
    assert rep2.drop_cause_counts() == counts
    assert len(rep2.resolutions) == len(rep.resolutions)


# ---------------------------------------------------------------------------
# hub unit semantics
# ---------------------------------------------------------------------------
def test_hub_one_outcome_per_round_client():
    tel = Telemetry()
    tel.start_run({})
    tel.begin_round(1)
    tel.client_outcome(1, 0, AGGREGATED)
    with pytest.raises(ValueError, match="exactly one terminal outcome"):
        tel.client_outcome(1, 0, NOT_SELECTED)
    with pytest.raises(ValueError, match="unknown outcome"):
        tel.client_outcome(1, 1, "vanished")
    with pytest.raises(ValueError, match="begin_round"):
        tel.begin_round(2)
    with pytest.raises(ValueError, match="staged"):
        tel.client_outcome(7, 1, AGGREGATED)
    with pytest.raises(ValueError, match="resolution outcome"):
        tel.resolve(1, 0, NOT_SELECTED)


def test_hub_counters_timers_and_null():
    tel = Telemetry()
    tel.counter("x")
    tel.counter("x", 2.5)
    assert tel.counters["x"] == 3.5
    with tel.timer("t"):
        pass
    assert tel.timers_s["t"] >= 0.0
    assert not NULL_TELEMETRY and bool(tel)
    # the null hub accepts the whole protocol as no-ops
    NULL_TELEMETRY.begin_round(1)
    NULL_TELEMETRY.client_outcome(1, 0, "anything")
    with NULL_TELEMETRY.timer("t"):
        pass
    NULL_TELEMETRY.end_round(1)
    NULL_TELEMETRY.end_run()


def test_report_resolution_upgrade_and_guards():
    rep = RunReport()
    tel = Telemetry(sinks=[rep])
    tel.start_run({"n_clients": 2})
    tel.begin_round(1)
    tel.client_outcome(1, 0, BUFFERED)
    tel.client_outcome(1, 1, NOT_SELECTED)
    tel.end_round(1)
    tel.begin_round(2)
    tel.client_outcome(2, 0, NOT_SELECTED)
    tel.client_outcome(2, 1, NOT_SELECTED)
    tel.resolve(1, 0, AGGREGATED, staleness=1, applied_round=2)
    tel.end_round(2)
    tel.end_run()
    final = rep.final_outcomes()
    assert final[(1, 0)]["outcome"] == AGGREGATED
    assert final[(1, 0)]["staleness"] == 1
    # a resolution against a non-buffered record is rejected
    bad = copy.deepcopy(rep)
    bad.resolutions.append({"origin_round": 1, "client": 1,
                            "outcome": EVICTED})
    with pytest.raises(ValueError, match="not 'buffered'"):
        bad.final_outcomes()
    bad2 = copy.deepcopy(rep)
    bad2.resolutions.append({"origin_round": 9, "client": 0,
                             "outcome": EVICTED})
    with pytest.raises(ValueError, match="unknown record"):
        bad2.final_outcomes()


def test_reconcile_flags_drift(runs):
    runner, _ = runs[("sync", "qsgd:4", "fedauto")]
    rep = copy.deepcopy(runner.report)
    # tamper with one upload's byte count -> byte reconciliation must fail
    for r in rep.rounds:
        for rec in r["clients"].values():
            if rec.get("upload_bytes"):
                rec["upload_bytes"] += 1e6
                break
        else:
            continue
        break
    with pytest.raises(ReconcileError, match="uplink"):
        reconcile(rep, runner)
    # drop one client record -> outcome closure must fail
    rep2 = copy.deepcopy(runner.report)
    clients = rep2.rounds[0]["clients"]
    clients.pop(next(iter(clients)))
    with pytest.raises(ReconcileError, match="outcome counts"):
        reconcile(rep2, runner)


def test_ndjson_nonfinite_roundtrip(tmp_path):
    path = str(tmp_path / "nf.ndjson")
    rep = RunReport()
    tel = Telemetry(sinks=[rep, NdjsonSink(path)])
    tel.start_run({"n_clients": 1})
    tel.begin_round(1)
    tel.client_outcome(1, 0, MISSED_DEADLINE, detail="never_lands",
                       finish_s=math.inf)
    tel.gauge(1, "nan_gauge", math.nan)
    tel.end_round(1)
    tel.end_run()
    rep2 = RunReport.from_ndjson(path)
    rec = rep2.final_outcomes()[(1, 0)]
    assert rec["finish_s"] == math.inf
    assert math.isnan(rep2.rounds[0]["gauges"]["nan_gauge"])


def test_ndjson_rejects_foreign_schema(tmp_path):
    path = tmp_path / "bad.ndjson"
    path.write_text('{"record": "run_start", "schema": "other", '
                    '"version": 1, "meta": {}}\n')
    with pytest.raises(ValueError, match="not a fft-telemetry"):
        RunReport.from_ndjson(str(path))


# ---------------------------------------------------------------------------
# renderer + console sink
# ---------------------------------------------------------------------------
def test_render_markdown_tables(runs):
    reports, labels = [], []
    for combo in COMBOS[:3]:
        reports.append(runs[combo][0].report)
        labels.append("/".join(combo))
    md = render_markdown(reports, labels)
    assert "## Drop-cause breakdown" in md
    assert "## Bytes vs participation" in md
    assert "## β-mass by staleness" in md and "## β-mass by rung" in md
    for lab in labels:
        assert lab in md
    for outcome in OUTCOMES:
        assert outcome in md
    # drop-cause rows sum to n_clients × rounds in the table too
    assert f"| {BASE['n_clients'] * ROUNDS} |" in md


def test_beta_mass_and_rung_histogram(runs):
    runner, _ = runs[("buffered", "adaptive:sign1-fp16", "fedauto_async")]
    rep = runner.report
    mass = rep.beta_mass_by("staleness")
    assert mass and sum(mass.values()) == pytest.approx(1.0)
    assert "server" in mass                 # non-client rows group by role
    hist = rep.rung_histogram()
    assert sum(hist.values()) > 0
    assert set(hist) <= set(runner.controller.rungs)


def test_console_sink_line(runs, capsys):
    runner, _ = runs[("sync", "fp32", "fedavg")]
    sink = ConsoleSink()
    sink.on_round(runner.report.rounds[-1])
    out = capsys.readouterr().out
    assert out.startswith("[obs] r=")
    assert "agg=" in out and "wait=" in out


# ---------------------------------------------------------------------------
# PR 7: per-phase profiler — exclusive timers, round gauges, NDJSON v2
# ---------------------------------------------------------------------------
PHASE_CORE = {"phase.uplink", "phase.local_update", "phase.aggregate",
              "phase.network_draw"}


@pytest.mark.parametrize("combo", COMBOS, ids=lambda c: "/".join(c))
def test_phase_gauges_bounded_by_round_wall(runs, combo):
    """Phases are exclusive timers: per round they can never claim more
    than the measured wall time, and over the run they should cover the
    bulk of it (the ``(untimed)`` remainder is loop bookkeeping)."""
    rep = runs[combo][0].report
    claimed_total, wall_total = 0.0, 0.0
    for r in rep.rounds:
        wall = r["gauges"]["round_wall_s"]
        claimed = sum(v for k, v in r["gauges"].items()
                      if k.startswith("phase."))
        assert 0.0 < claimed <= wall + 1e-6
        claimed_total += claimed
        wall_total += wall
    assert wall_total == pytest.approx(rep.total_wall_s())
    assert claimed_total / wall_total > 0.5


@pytest.mark.parametrize("combo", COMBOS, ids=lambda c: "/".join(c))
def test_phase_timer_vocabulary(runs, combo):
    mode, codec, _ = combo
    runner, _hist = runs[combo]
    timers = runner.report.summary["timers_s"]
    phases = {k for k in timers if k.startswith("phase.")}
    assert PHASE_CORE <= phases
    assert "phase.eval" in phases           # eval_every=2, rounds=5
    if codec.startswith("adaptive:"):
        assert "phase.controller" in phases
    if mode == "buffered":
        assert "phase.buffer" in phases
    # all timers are positive and (being accumulators) finite
    for name in phases:
        assert 0.0 < timers[name] < 1e4


def test_phase_timers_exclusive_nesting():
    """A nested timer pauses its parent: the two buckets partition the
    elapsed time instead of double-counting the inner span."""
    import time as _time
    tel = Telemetry()
    t0 = _time.perf_counter()
    with tel.timer("phase.outer"):
        _time.sleep(0.02)
        with tel.timer("phase.inner"):
            _time.sleep(0.04)
        _time.sleep(0.01)
    elapsed = _time.perf_counter() - t0
    outer, inner = tel.timers_s["phase.outer"], tel.timers_s["phase.inner"]
    assert inner >= 0.04
    assert outer >= 0.03
    assert outer + inner <= elapsed + 1e-6
    # timers accumulate monotonically across reuse
    with tel.timer("phase.outer"):
        pass
    assert tel.timers_s["phase.outer"] >= outer


def test_phase_table_untimed_closes_gap(runs):
    rep = runs[("sync", "qsgd:4", "fedauto")][0].report
    table = rep.phase_table()
    assert table and table[-1]["phase"] == "(untimed)"
    named = table[:-1]
    # hottest-first ordering over the named phases
    assert [p["total_s"] for p in named] == \
        sorted((p["total_s"] for p in named), reverse=True)
    # untimed row closes the accounting: totals and shares both telescope
    assert sum(p["total_s"] for p in table) == \
        pytest.approx(rep.total_wall_s())
    assert sum(p["share"] for p in table) == pytest.approx(1.0)
    for p in table:
        assert p["s_per_round"] == pytest.approx(p["total_s"] / rep.n_rounds)
    # phase_seconds keys are the bare names feeding the table
    assert {p["phase"] for p in named} == set(rep.phase_seconds())


def test_phase_seconds_single_round_slice(runs):
    rep = runs[("sync", "qsgd:4", "fedauto")][0].report
    whole = rep.phase_seconds()
    per_round = [rep.phase_seconds(r["round"]) for r in rep.rounds]
    for name, total in whole.items():
        assert sum(pr.get(name, 0.0) for pr in per_round) == \
            pytest.approx(total)


def test_ndjson_v2_roundtrips_phase_gauges(runs):
    runner, _ = runs[("buffered", "adaptive:sign1-fp16", "fedauto_async")]
    rep2 = RunReport.from_ndjson(runner.cfg.telemetry_log)
    assert rep2.total_wall_s() == pytest.approx(
        runner.report.total_wall_s())
    want, got = runner.report.phase_seconds(), rep2.phase_seconds()
    assert set(got) == set(want)
    for name in want:
        assert got[name] == pytest.approx(want[name])
    assert rep2.phase_table()
    reconcile(rep2, runner)                 # telescoping holds post-load


def test_ndjson_v1_log_still_loads(runs, tmp_path):
    """A pre-profiler v1 log (no phase gauges) must keep loading under the
    v2 reader, with the phase views degrading to empty."""
    import json as _json
    src = runs[("sync", "fp32", "fedavg")][0].cfg.telemetry_log
    dst = tmp_path / "v1.ndjson"
    lines = []
    for line in open(src):
        doc = _json.loads(line)
        if doc.get("record") == "health":
            continue                        # health records postdate v1
        if doc.get("record") == "run_start":
            assert doc["version"] == TELEMETRY_VERSION
            doc["version"] = 1
        if doc.get("record") == "round":
            doc["gauges"] = {k: v for k, v in doc["gauges"].items()
                             if not k.startswith("phase.")
                             and k != "round_wall_s"}
        lines.append(_json.dumps(doc))
    dst.write_text("\n".join(lines) + "\n")
    rep = RunReport.from_ndjson(str(dst))
    assert rep.n_rounds == ROUNDS
    assert rep.phase_seconds() == {}
    assert rep.phase_table() == []
    assert rep.total_wall_s() == 0.0
    assert rep.drop_cause_counts() == \
        runs[("sync", "fp32", "fedavg")][0].report.drop_cause_counts()


def test_reconcile_flags_tampered_phase_gauges(runs):
    runner, _ = runs[("sync", "qsgd:4", "fedauto")]
    # inflating one round's phase gauge breaks the telescoping check
    rep = copy.deepcopy(runner.report)
    gauges = rep.rounds[0]["gauges"]
    name = next(k for k in gauges if k.startswith("phase."))
    gauges[name] += 10.0
    with pytest.raises(ReconcileError, match="gauges sum"):
        reconcile(rep, runner)
    # phases claiming more than the measured wall break the budget check
    rep2 = copy.deepcopy(runner.report)
    rep2.summary["timers_s"] = {}           # silence the telescoping check
    rep2.rounds[0]["gauges"]["round_wall_s"] = 1e-9
    with pytest.raises(ReconcileError, match="round wall"):
        reconcile(rep2, runner)


def test_run_report_renders_phase_section(runs):
    runner, _ = runs[("sync", "qsgd:4", "fedauto")]
    md = render_markdown([runner.report], ["qsgd"])
    assert "## Phase timings" in md
    assert "phase_table" not in md          # bare names, not repr noise
    assert "(untimed)" in md
    for name in runner.report.phase_seconds():
        assert name in md


def test_beta_row_builder():
    row = beta_row(0.25, client=3, origin_round=2, staleness=1,
                   rung="qsgd:4", distortion=0.1)
    assert row == {"role": "client", "beta": 0.25, "client": 3,
                   "origin_round": 2, "staleness": 1, "rung": "qsgd:4",
                   "distortion": 0.1}
    assert beta_row(0.5, role="server") == {"role": "server", "beta": 0.5}
