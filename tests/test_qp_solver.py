"""Deterministic tests for the Module-2 QP solver — the system's central
invariant: β is feasible and (near-)optimal for Eq. (8).  The hypothesis
sweeps over random problems live in ``tests/test_hypothesis_properties.py``
so this module always collects."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.weights_qp import (chi2_effective, heuristic_weights,
                                   solve_weights, solve_weights_oracle)


def _random_problem(rng, J, C):
    alpha = rng.dirichlet(np.ones(C) * 0.5, size=J)
    p = rng.dirichlet(np.ones(J))
    alpha_g = p @ alpha
    return alpha, alpha_g


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_solver_feasibility(seed):
    rng = np.random.default_rng(seed)
    J, C = 3 + seed, 5 + 2 * seed
    alpha, alpha_g = _random_problem(rng, J, C)
    mask = np.ones(J, dtype=bool)
    mask[rng.choice(J, J // 2, replace=False)] = False
    mask[0] = True                      # server always present
    beta = np.asarray(solve_weights(jnp.asarray(alpha), jnp.asarray(alpha_g),
                                    jnp.asarray(mask)))
    assert np.all(beta >= -1e-6)
    assert abs(beta.sum() - 1.0) < 1e-4
    assert np.all(beta[~mask] <= 1e-6)          # Eq. (10c)
    uni = np.where(mask, 1.0 / mask.sum(), 0.0)
    f_beta = float(chi2_effective(jnp.asarray(beta), jnp.asarray(alpha),
                                  jnp.asarray(alpha_g)))
    f_uni = float(chi2_effective(jnp.asarray(uni), jnp.asarray(alpha),
                                 jnp.asarray(alpha_g)))
    assert f_beta <= f_uni + 1e-5


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_solver_matches_float64_oracle(seed):
    rng = np.random.default_rng(seed)
    J, C = 8, 10
    alpha, alpha_g = _random_problem(rng, J, C)
    mask = np.ones(J, dtype=bool)
    mask[rng.choice(J, 2, replace=False)] = False
    mask[0] = True
    got = np.asarray(solve_weights(jnp.asarray(alpha), jnp.asarray(alpha_g),
                                   jnp.asarray(mask), fixed_idx=0,
                                   fixed_val=jnp.float32(0.25)))
    want = solve_weights_oracle(alpha, alpha_g, mask, fixed_idx=0,
                                fixed_val=0.25, iters=20_000)
    f = lambda b: float(chi2_effective(jnp.asarray(b), jnp.asarray(alpha),
                                       jnp.asarray(alpha_g)))
    assert abs(got[0] - 0.25) < 1e-5
    assert f(got) <= f(want) + 1e-4          # same optimum value


def test_exact_recovery_when_global_in_hull():
    """If α_g = Σ p_j α_j with p on the simplex, optimum reaches χ² = 0."""
    rng = np.random.default_rng(7)
    J, C = 6, 8
    alpha = rng.dirichlet(np.ones(C), size=J)
    p = rng.dirichlet(np.ones(J))
    alpha_g = p @ alpha
    mask = np.ones(J, dtype=bool)
    beta = solve_weights(jnp.asarray(alpha), jnp.asarray(alpha_g),
                         jnp.asarray(mask))
    assert float(chi2_effective(beta, jnp.asarray(alpha),
                                jnp.asarray(alpha_g))) < 1e-6


def test_heuristic_weights_footnote2():
    p = np.array([0.2, 0.2, 0.2, 0.2, 0.2])
    mask = np.array([True, True, False, True, False])
    full = heuristic_weights(p, mask, server_idx=0, full_participation=True)
    assert abs(full.sum() - 1.0) < 1e-9
    assert full[2] == 0 and full[4] == 0
    part = heuristic_weights(p, mask, server_idx=0, full_participation=False)
    assert abs(part[0] - 0.2) < 1e-9
    assert abs(part[1] - (1 - 0.2) / 2) < 1e-9
