"""Communication codec subsystem (repro.fl.comm) + bytes-on-wire threading.

Covers the ISSUE-3 checklist: registry parsing, round-trip exactness of the
lossless codecs, quantizer error bounds, error-feedback residual
contraction, byte accounting through the deadline simulator (compression
converting deadline drops into participants), sync-vs-async equivalence at
infinite deadline under every codec, the fused Pallas dequantize-and-
β-accumulate kernel vs the fp32 path, and the v2 trace schema.
"""
import dataclasses
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import aggregate_pytrees
from repro.core.strategies import STRATEGIES
from repro.fl.comm import (CommState, aggregate_quantized, fp32_nbytes,
                           is_quantized, make_codec)
from repro.fl.runtime import FFTConfig
from repro.fl.scenarios.engine import (CAUSE_DEADLINE, DeadlineSimulator,
                                       LinkState)
from repro.fl.toy import make_toy_runner

ALL_SPECS = ["fp32", "fp16", "int8", "qsgd:4", "topk:0.25", "sign1"]


def _tree(seed=0, shapes=((13, 7), (7,), (3, 5, 2))):
    rng = np.random.default_rng(seed)
    return {f"l{i}": jnp.asarray(rng.normal(size=s), jnp.float32)
            for i, s in enumerate(shapes)}


def _maxdiff(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("spec", ALL_SPECS)
def test_registry_builds_every_spec(spec):
    c = make_codec(spec)
    assert c.name == spec or spec in ("topk:0.25",)  # topk normalizes float
    p = c.encode(_tree())
    assert p.nbytes == c.nbytes(_tree())


@pytest.mark.parametrize("spec", ["fp99", "qsgd:", "qsgd:0", "qsgd:9",
                                  "qsgd:x", "topk:0", "topk:1.5", "topk:x",
                                  "huff:2"])
def test_registry_rejects_bad_specs(spec):
    with pytest.raises(ValueError):
        make_codec(spec)


def test_byte_counts_are_value_independent_and_exact():
    t = _tree()
    n = sum(l.size for l in jax.tree.leaves(t))
    leaves = len(jax.tree.leaves(t))
    assert make_codec("fp32").nbytes(t) == 4 * n == fp32_nbytes(t)
    assert make_codec("fp16").nbytes(t) == 2 * n
    assert make_codec("int8").nbytes(t) == n + 4 * leaves
    assert make_codec("qsgd:4").nbytes(t) == sum(
        math.ceil(4 * l.size / 8) + 4 for l in jax.tree.leaves(t))
    assert make_codec("sign1").nbytes(t) == sum(
        math.ceil(l.size / 8) + 4 for l in jax.tree.leaves(t))
    assert make_codec("topk:0.25").nbytes(t) == sum(
        8 * max(1, math.ceil(0.25 * l.size)) for l in jax.tree.leaves(t))
    # value-independence: zeros cost the same as noise
    zeros = jax.tree.map(jnp.zeros_like, t)
    for spec in ALL_SPECS:
        assert make_codec(spec).encode(zeros).nbytes == \
            make_codec(spec).encode(t).nbytes


# ---------------------------------------------------------------------------
# round-trip exactness (lossless family) and quantizer error bounds
# ---------------------------------------------------------------------------
def test_fp32_round_trip_exact():
    c = make_codec("fp32")
    t = _tree()
    assert _maxdiff(c.decode(c.encode(t)), t) == 0.0


def test_fp16_round_trip_exact_on_fp16_values():
    c = make_codec("fp16")
    t = jax.tree.map(lambda l: l.astype(jnp.float16).astype(jnp.float32),
                     _tree())
    assert _maxdiff(c.decode(c.encode(t)), t) == 0.0


def test_lora_only_round_trip_exact_and_guards():
    c = make_codec("lora_only")
    adapters = {"blk/qkv/w": {"a": jnp.ones((8, 4)), "b": jnp.zeros((4, 8))}}

    class _L:  # minimal lora_cfg stand-in
        rank = 4

    c.validate_template(adapters, lora_cfg=_L())
    assert _maxdiff(c.decode(c.encode(adapters)), adapters) == 0.0
    with pytest.raises(ValueError, match="lora"):
        c.validate_template(adapters, lora_cfg=None)      # not a LoRA run
    with pytest.raises(ValueError, match="adapter"):
        c.validate_template({"w": jnp.ones((8, 8))}, lora_cfg=_L())


def test_int8_error_bound():
    c = make_codec("int8")
    t = _tree(3)
    dec = c.decode(c.encode(t))
    for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(dec)):
        scale = float(jnp.max(jnp.abs(x))) / 127.0
        assert float(jnp.max(jnp.abs(x - y))) <= scale / 2 + 1e-7


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_qsgd_error_bound_tightens_with_bits(bits):
    c = make_codec(f"qsgd:{bits}")
    t = _tree(4)
    dec = c.decode(c.encode(t))
    levels = (1 << (bits - 1)) - 1
    for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(dec)):
        scale = float(jnp.max(jnp.abs(x))) / levels
        assert float(jnp.max(jnp.abs(x - y))) <= scale / 2 + 1e-7


def test_topk_keeps_exactly_the_largest_coordinates():
    c = make_codec("topk:0.25")
    t = {"w": jnp.asarray(np.random.default_rng(5).normal(size=(10, 8)),
                          jnp.float32)}
    dec = c.decode(c.encode(t))["w"].reshape(-1)
    flat = np.asarray(t["w"]).reshape(-1)
    k = math.ceil(0.25 * flat.size)
    top = np.argsort(-np.abs(flat))[:k]
    np.testing.assert_allclose(dec[top], flat[top], rtol=0)   # kept exactly
    mask = np.ones(flat.size, bool)
    mask[top] = False
    assert np.all(np.asarray(dec)[mask] == 0.0)               # rest zeroed


def test_sign1_is_one_bit_with_l1_scale():
    c = make_codec("sign1")
    t = {"w": jnp.asarray([[1.5, -0.5, 2.0, -1.0]], jnp.float32)}
    dec = np.asarray(c.decode(c.encode(t))["w"])
    scale = np.mean(np.abs(np.asarray(t["w"])))
    np.testing.assert_allclose(np.abs(dec), scale, rtol=1e-6)
    assert np.all(np.sign(dec) == np.sign(np.asarray(t["w"])))


# ---------------------------------------------------------------------------
# error feedback: residual stays bounded, cumulative decoded mass tracks the
# cumulative true delta (the EF contraction that keeps biased codecs honest)
# ---------------------------------------------------------------------------
def _l2(tree):
    return float(sum(jnp.sum(jnp.square(l))
                     for l in jax.tree.leaves(tree))) ** 0.5


@pytest.mark.parametrize("spec", ["fp16", "int8", "qsgd:4", "topk:0.25",
                                  "sign1"])
def test_compressor_is_a_contraction(spec):
    """Every lossy codec is a δ-contraction: ‖x − C(x)‖ < ‖x‖ — the property
    the EF convergence theory needs from the compressor itself."""
    c = make_codec(spec)
    x = _tree(11)
    err = jax.tree.map(jnp.subtract, x, c.decode(c.encode(x)))
    assert _l2(err) < _l2(x) * (1.0 - 1e-4)


@pytest.mark.parametrize("spec", ["int8", "qsgd:4", "topk:0.25", "sign1"])
def test_error_feedback_residual_contraction(spec):
    """EF invariants under a constant true update delta:

    1. conservation: Σ_t decoded_t + e_T = T·delta exactly — the wire never
       loses mass, it only delays it;
    2. the residual respects the contraction bound ‖e_t‖ ≤ γ/(1−γ)·‖delta‖
       where γ is the codec's worst observed per-step contraction factor
       (< 1 by the test above), so the mean decoded update converges to
       delta at rate O(‖e‖/T).
    """
    st = CommState(make_codec(spec), _tree())
    g = jax.tree.map(jnp.zeros_like, _tree())      # global stays at 0
    delta = _tree(7)                               # constant true update
    model = jax.tree.map(lambda gg, d: gg + d, g, delta)
    T = 30
    acc = jax.tree.map(jnp.zeros_like, g)
    gamma = 0.0
    for _ in range(T):
        prev = st.residual(0)
        carry = delta if prev is None else jax.tree.map(jnp.add, delta, prev)
        recon, _, _ = st.roundtrip(0, model, g)
        acc = jax.tree.map(lambda a, r: a + r, acc, recon)
        gamma = max(gamma, _l2(st.residual(0)) / max(_l2(carry), 1e-12))
    assert gamma < 1.0 - 1e-4                      # contraction every step
    bound = gamma / (1.0 - gamma) * _l2(delta)
    assert _l2(st.residual(0)) <= bound * (1.0 + 1e-3)
    # conservation: acc + e_T == T·delta, leaf-wise
    total = jax.tree.map(lambda a, e: a + e, acc, st.residual(0))
    want = jax.tree.map(lambda d: T * d, delta)
    assert _maxdiff(total, want) <= 1e-3


def test_lossless_codecs_keep_no_residual():
    for spec in ["fp32", "lora_only"]:
        codec = make_codec(spec)
        tmpl = ({"p/x": {"a": jnp.ones((4, 2)), "b": jnp.zeros((2, 4))}}
                if spec == "lora_only" else _tree())

        class _L:
            rank = 2

        st = CommState(codec, tmpl, lora_cfg=_L() if spec == "lora_only"
                       else None)
        model = jax.tree.map(lambda l: l + 1.0, tmpl)
        recon, payload, dist = st.roundtrip(0, model, tmpl)
        assert _maxdiff(recon, model) == 0.0
        assert st.residual(0) is None
        assert dist == 0.0                         # lossless: exactly zero
        assert payload.nbytes == codec.nbytes(tmpl)


# ---------------------------------------------------------------------------
# bytes-on-wire through the deadline simulator
# ---------------------------------------------------------------------------
def test_simulator_prices_per_client_per_direction_bytes():
    sim = DeadlineSimulator(2, model_bytes=1e6, deadline_s=1e9,
                            compute_s=0.0, jitter_sigma=0.0, seed=0)
    links = [LinkState(8e6, downlink_ratio=8.0),
             LinkState(8e6, downlink_ratio=8.0)]
    base = sim.simulate_round(1, links)
    # default: both directions priced at model_bytes
    assert base.events[0].t_upload_s == pytest.approx(1.0)
    assert base.events[0].t_download_s == pytest.approx(1.0 / 8.0)
    # per-client uploads: client 1 compressed 4x; downloads stay full-size
    sim.set_payload_bytes(upload_bytes=np.array([1e6, 0.25e6]),
                          download_bytes=1e6)
    ev = sim.simulate_round(2, links)
    assert ev.events[0].t_upload_s == pytest.approx(1.0)
    assert ev.events[1].t_upload_s == pytest.approx(0.25)
    assert ev.events[1].t_download_s == pytest.approx(1.0 / 8.0)


def test_compression_converts_deadline_drops_into_participants():
    """The acceptance mechanism in miniature: a link where fp32 misses the
    deadline but a 4x-smaller int8 payload lands."""
    mk = lambda up: DeadlineSimulator(1, model_bytes=4e6, deadline_s=5.0,
                                      compute_s=1.0, hetero_sigma=0.0,
                                      jitter_sigma=0.0, seed=0)
    links = [LinkState(8e6)]                       # fp32: 4s up + 0.5s down
    slow = mk(None)
    ev = slow.simulate_round(1, links)
    assert not ev.events[0].met_deadline
    assert ev.events[0].cause == CAUSE_DEADLINE
    fast = mk(None)
    fast.set_payload_bytes(upload_bytes=1e6)       # int8-sized: 1s up
    ev = fast.simulate_round(1, links)
    assert ev.events[0].met_deadline


BASE = dict(n_clients=6, k_selected=6, local_steps=2, batch_size=8, lr=0.05,
            seed=0, eval_every=2, model_bytes=4e6, deadline_s=5.0)
TOY = dict(n_samples=600, public_per_class=10, pretrain_steps=9)


def test_runner_derives_model_bytes_from_trainable_pytree():
    cfg = FFTConfig(**{**BASE, "model_bytes": None})
    runner = make_toy_runner(cfg, **TOY)
    assert runner.model_bytes == fp32_nbytes(runner.global_params)
    assert runner.upload_bytes == runner.model_bytes          # fp32 codec
    # explicit override wins, codec ratio still applies
    cfg8 = FFTConfig(codec="int8", **BASE)
    runner8 = make_toy_runner(cfg8, **TOY)
    assert runner8.model_bytes == 4e6
    assert runner8.upload_bytes == pytest.approx(
        4e6 * runner8.comm.compression_ratio)
    assert runner8.comm.compression_ratio < 0.26


def test_lora_runs_upload_adapter_sized_payloads():
    """Satellite: LoRA runs must not simulate full-model upload times."""
    from benchmarks.common import make_problem
    r = make_problem(non_iid=False, failure_mode="none", quick=True,
                     model="vit", model_bytes=None)
    # trainable pytree is the adapter dict -> derived bytes are adapter bytes
    assert r.model_bytes == fp32_nbytes(r.global_params)
    full = fp32_nbytes(r.base_params)
    assert r.model_bytes < 0.5 * full


@pytest.mark.parametrize("codec", ALL_SPECS)
def test_lossy_codec_recovers_participants_end_to_end(codec):
    """Every smaller-than-fp32 codec weakly increases the per-round
    participant count under deadline pressure; int8 strictly."""
    runners = {}
    for name in ["fp32", codec]:
        cfg = FFTConfig(codec=name,
                        failure_mode="scenario:lossy_uplink", **BASE)
        r = make_toy_runner(cfg, **TOY)
        r.run(STRATEGIES["fedavg"](), rounds=3)
        runners[name] = np.mean(r.loop.participants_per_round)
    assert runners[codec] >= runners["fp32"]
    if codec == "int8":
        assert runners[codec] > runners["fp32"]


@pytest.mark.parametrize("codec", ["fp32", "fp16", "int8", "qsgd:4",
                                   "topk:0.25", "sign1"])
def test_sync_async_equivalent_under_infinite_deadline_per_codec(codec):
    """With no deadline pressure the async server degenerates to the sync
    one under *every* codec — compression must not break the equivalence
    (deterministic codecs + per-client EF residuals)."""
    hist = {}
    for mode in ["sync", "async"]:
        cfg = FFTConfig(codec=codec, failure_mode="scenario:correlated_wifi",
                        server_mode=mode,
                        **{**BASE, "deadline_s": 1e9})
        hist[mode] = make_toy_runner(cfg, **TOY).run(
            STRATEGIES["fedavg"](), rounds=3)
    assert hist["sync"] == hist["async"]


def test_codec_works_under_buffered_mode_and_legacy_failures():
    cfg = FFTConfig(codec="int8", failure_mode="mixed",
                    server_mode="buffered", tau_max=3, buffer_k=2, **BASE)
    r = make_toy_runner(cfg, **TOY)
    hist = r.run(STRATEGIES["fedbuff"](buffer_k=1), rounds=3)
    assert len(hist) == 2 and all(0.0 <= a <= 1.0 for a in hist)


# ---------------------------------------------------------------------------
# trace schema v2
# ---------------------------------------------------------------------------
def test_trace_records_codec_and_payload_bytes(tmp_path):
    path = str(tmp_path / "c.ndjson")
    cfg = FFTConfig(codec="int8", failure_mode="scenario:diurnal",
                    trace_record=path, **BASE)
    runner = make_toy_runner(cfg, **TOY)
    runner.run(STRATEGIES["fedavg"](), rounds=2)
    lines = [json.loads(l) for l in open(path)]
    hdr = lines[0]
    assert hdr["version"] == 5
    assert hdr["codec"] == "int8"
    assert hdr["downlink_codec"] == "fp32"
    assert hdr["upload_bytes"] == pytest.approx(runner.upload_bytes)
    for rec in lines[1:]:
        for c in rec["clients"]:
            assert c["payload_bytes"] == pytest.approx(runner.upload_bytes)


def test_compressed_record_replay_bit_exact(tmp_path):
    path = str(tmp_path / "c.ndjson")
    rec_cfg = FFTConfig(codec="int8", failure_mode="scenario:diurnal",
                        trace_record=path, **BASE)
    live = make_toy_runner(rec_cfg, **TOY).run(STRATEGIES["fedavg"](),
                                               rounds=3)
    rep_cfg = FFTConfig(codec="int8", trace_replay=path, **BASE)
    rep1 = make_toy_runner(rep_cfg, **TOY).run(STRATEGIES["fedavg"](),
                                               rounds=3)
    rep2 = make_toy_runner(rep_cfg, **TOY).run(STRATEGIES["fedavg"](),
                                               rounds=3)
    assert rep1 == rep2 == live


def test_replay_with_mismatched_codec_fails_loudly(tmp_path):
    path = str(tmp_path / "c.ndjson")
    rec_cfg = FFTConfig(codec="int8", failure_mode="scenario:diurnal",
                        trace_record=path, **BASE)
    make_toy_runner(rec_cfg, **TOY).run(STRATEGIES["fedavg"](), rounds=2)
    with pytest.raises(ValueError, match="codec"):
        make_toy_runner(FFTConfig(codec="topk:0.25", trace_replay=path,
                                  **BASE), **TOY)


def test_replay_with_mismatched_model_bytes_fails_loudly(tmp_path):
    """Same codec but a different wire size also invalidates the recorded
    timings — the guard checks bytes, not just the codec name."""
    path = str(tmp_path / "c.ndjson")
    rec_cfg = FFTConfig(codec="int8", failure_mode="scenario:diurnal",
                        trace_record=path, **BASE)        # model_bytes=4e6
    make_toy_runner(rec_cfg, **TOY).run(STRATEGIES["fedavg"](), rounds=2)
    derived = dict(BASE)
    derived["model_bytes"] = None                         # derive -> ~121 kB
    with pytest.raises(ValueError, match="model_bytes"):
        make_toy_runner(FFTConfig(codec="int8", trace_replay=path,
                                  **derived), **TOY)


def test_v1_trace_still_loads_as_fp32(tmp_path):
    """Version-1 traces predate codecs: they load, replay under fp32, and
    refuse any other codec."""
    from repro.fl.scenarios.trace import ReplayFailureModel
    path = str(tmp_path / "v1.ndjson")
    with open(path, "w") as fh:
        fh.write(json.dumps({"record": "header", "version": 1,
                             "scenario": "x", "n_clients": 2}) + "\n")
        fh.write(json.dumps({
            "record": "round", "round": 1, "deadline_s": 5.0,
            "duration_s": 1.0,
            "clients": [{"id": 0, "up": True, "duration_s": 1.0,
                         "selected": True, "met_deadline": True,
                         "connected": True, "cause": "ok"},
                        {"id": 1, "up": False, "duration_s": None,
                         "selected": True, "met_deadline": False,
                         "connected": False, "cause": "outage"}]}) + "\n")
    m = ReplayFailureModel(path)
    assert m.codec == "fp32"
    assert m.payload_bytes(1) is None
    np.testing.assert_array_equal(m.draw(1), [True, False])


def test_unsupported_trace_version_rejected(tmp_path):
    from repro.fl.scenarios.trace import load_trace
    path = str(tmp_path / "v9.ndjson")
    with open(path, "w") as fh:
        fh.write(json.dumps({"record": "header", "version": 9}) + "\n")
    with pytest.raises(ValueError, match="version"):
        load_trace(path)


# ---------------------------------------------------------------------------
# fused Pallas dequantize-and-β-accumulate kernel
# ---------------------------------------------------------------------------
def _quant_inputs(M=5, P=3000, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.integers(-127, 128, (M, P)), jnp.int8)
    scales = jnp.asarray(rng.uniform(1e-4, 1e-2, M), jnp.float32)
    betas = jnp.asarray(rng.dirichlet(np.ones(M)), jnp.float32)
    return q, scales, betas


def test_dequant_fedagg_ref_matches_fp32_path():
    from repro.kernels import ref
    q, scales, betas = _quant_inputs()
    fused = ref.dequant_fedagg(q, scales, betas)
    fp32 = ref.fedagg(q.astype(jnp.float32) * scales[:, None], betas)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(fp32),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("M,P", [(1, 100), (5, 3000), (22, 70000)])
def test_dequant_fedagg_pallas_matches_ref(M, P):
    """Acceptance: the Pallas kernel (interpret mode on CPU) matches the
    reference path to fp32 tolerance, including padded/ragged P."""
    from repro.kernels import ref
    from repro.kernels.dequant_agg import dequant_fedagg
    q, scales, betas = _quant_inputs(M, P, seed=M)
    out = dequant_fedagg(q, scales, betas, interpret=True, block=256)
    expect = ref.dequant_fedagg(q, scales, betas)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-6)


def test_dequant_fedagg_ops_dispatch():
    from repro.kernels import ops, ref
    q, scales, betas = _quant_inputs(3, 512, seed=9)
    mode0 = ops.get_mode()
    try:
        ops.set_mode("off")
        off = ops.dequant_fedagg(q, scales, betas)
        ops.set_mode("interpret")
        interp = ops.dequant_fedagg(q, scales, betas)
    finally:
        ops.set_mode(mode0)
    np.testing.assert_allclose(np.asarray(off), np.asarray(interp),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(off),
                               np.asarray(ref.dequant_fedagg(q, scales,
                                                             betas)),
                               rtol=1e-6)


def test_fused_payload_aggregation_matches_decode_then_aggregate():
    c = make_codec("int8")
    trees = [_tree(seed=i) for i in range(4)]
    payloads = [c.encode(t) for t in trees]
    assert all(is_quantized(p) for p in payloads)
    betas = np.random.default_rng(1).dirichlet(np.ones(4))
    fused = aggregate_quantized(payloads, betas)
    unfused = aggregate_pytrees([c.decode(p) for p in payloads], betas)
    assert _maxdiff(fused, unfused) <= 1e-6
    with pytest.raises(ValueError, match="int8-family"):
        aggregate_quantized([make_codec("fp32").encode(trees[0])], [1.0])


def test_strategy_context_carries_codec_metadata():
    cfg = FFTConfig(codec="int8", failure_mode="scenario:lossy_uplink",
                    **BASE)
    runner = make_toy_runner(cfg, **TOY)
    seen = {}

    class Probe(STRATEGIES["fedavg"]):
        def aggregate(self, ctx):
            seen["codec"] = ctx.codec
            seen["upload_nbytes"] = ctx.upload_nbytes
            return super().aggregate(ctx)

    runner.run(Probe(), rounds=1)
    assert seen["codec"] == "int8"
    assert seen["upload_nbytes"] == pytest.approx(runner.upload_bytes)
