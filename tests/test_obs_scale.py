"""Population-scale telemetry (PR 8): sketch sinks, health monitors,
Chrome-trace export, dashboard, and the crash-durability satellites.

Deterministic variants of the sketch-accuracy properties live here (the
hypothesis sweeps are in ``test_hypothesis_properties.py``); the heavy
claims are structural: sketch-mode totals bit-equal to full mode on the
same seeded run, resident telemetry state O(rounds + K) at 50k clients,
trace spans telescoping to the phase gauges, and health monitors firing on
the seeded blackout world while staying silent on the healthy baselines.
"""
import io
import json
import math
import warnings
from bisect import bisect_left, bisect_right
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.strategies import STRATEGIES
from repro.fl.runtime import FFTConfig
from repro.fl.toy import make_toy_runner
from repro.obs import (AGGREGATED, EVICTED, LINK_DOWN, NOT_SELECTED,
                       ChromeTraceError, ExactSum, GKQuantiles,
                       HealthConfig, HealthMonitors, NdjsonSink, Reservoir,
                       RunReport, SketchReport, SketchState, Telemetry,
                       beta_row, load_report, reconcile, render_dashboard,
                       render_markdown, verify_trace, watch)

BASE = dict(n_clients=6, k_selected=4, local_steps=2, batch_size=8, lr=0.05,
            seed=3, eval_every=2, deadline_s=30.0, tau_max=3, buffer_k=2,
            failure_mode="scenario:bursty_handover")
TOY = dict(n_samples=300, n_classes=4, image_size=8, public_per_class=10,
           pretrain_steps=0, seed=3)
ROUNDS = 5


@pytest.fixture(scope="module")
def mode_runs(tmp_path_factory):
    """The same seeded buffered-adaptive run recorded twice: once in full
    mode (with NDJSON log and Chrome trace), once in sketch mode."""
    tmp = tmp_path_factory.mktemp("obs_scale")
    out = {}
    for mode in ("full", "sketch"):
        cfg = FFTConfig(**BASE, server_mode="buffered",
                        codec="adaptive:sign1-fp16", telemetry=mode,
                        telemetry_log=str(tmp / f"{mode}.ndjson"),
                        telemetry_trace=(str(tmp / "trace.json")
                                         if mode == "full" else None))
        runner = make_toy_runner(cfg, **TOY)
        hist = runner.run(STRATEGIES["fedauto_async"](), rounds=ROUNDS)
        out[mode] = (runner, hist)
    return out


# ---------------------------------------------------------------------------
# sketch primitives (deterministic sweeps; hypothesis versions elsewhere)
# ---------------------------------------------------------------------------
def test_exactsum_bit_equal_to_fsum():
    rng = np.random.default_rng(0)
    for trial in range(20):
        # mixed magnitudes where naive summation visibly loses bits
        vals = list(np.exp(rng.normal(10.0, 8.0, 500)))
        rng.shuffle(vals)
        acc = ExactSum()
        for v in vals:
            acc.add(v)
        assert acc.value() == math.fsum(vals)
        # order independence: a different fold order, same bits
        acc2 = ExactSum()
        for v in reversed(vals):
            acc2.add(v)
        assert acc2.value() == acc.value()
        # serialization round-trip preserves exactness
        assert ExactSum(acc.to_json()).value() == acc.value()


def _check_rank_error(values, eps):
    gk = GKQuantiles(eps)
    for v in values:
        gk.add(v)
    srt = sorted(values)
    n = len(srt)
    for q in (0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99):
        got = gk.query(q)
        target = max(1, math.ceil(q * n))
        lo = bisect_left(srt, got) + 1        # 1-based rank range of `got`
        hi = bisect_right(srt, got)
        slack = eps * n + 1
        assert lo - slack <= target <= hi + slack, \
            f"q={q}: value {got} has ranks [{lo},{hi}] vs target {target}"
    return gk


def test_gk_rank_error_bound_deterministic():
    rng = np.random.default_rng(1)
    for dist in (rng.normal(0, 1, 5000), rng.exponential(1.0, 5000),
                 rng.integers(0, 10, 5000).astype(float),
                 np.sort(rng.uniform(0, 1, 5000))):
        gk = _check_rank_error(list(dist), eps=0.01)
        # size is sketch-like, not list-like
        assert len(gk.entries) < len(dist) / 4
        # serialization round-trips queries exactly
        gk2 = GKQuantiles.from_json(json.loads(json.dumps(gk.to_json())))
        assert all(gk2.query(q) == gk.query(q)
                   for q in (0.1, 0.5, 0.9, 0.99))


def test_reservoir_seeded_and_bounded():
    r1 = Reservoir(16, seed=7)
    r2 = Reservoir(16, seed=7)
    for i in range(1000):
        r1.offer({"i": i})
        r2.offer({"i": i})
    assert len(r1.rows) == 16 and r1.n == 1000
    assert r1.rows == r2.rows          # same seed → same sample
    r3 = Reservoir(16, seed=8)
    for i in range(1000):
        r3.offer({"i": i})
    assert r3.rows != r1.rows          # different seed → different sample


# ---------------------------------------------------------------------------
# sketch mode vs full mode on the same seeded run
# ---------------------------------------------------------------------------
def test_sketch_run_matches_full_bit_for_bit(mode_runs):
    full, hist_full = mode_runs["full"]
    sk, hist_sk = mode_runs["sketch"]
    # telemetry is observational in either mode: identical training
    assert hist_full == hist_sk
    # additive accounting is bit-equal, not approximately equal
    assert (sk.report.total_upload_bytes()
            == full.report.total_upload_bytes())
    assert (sk.report.total_download_bytes()
            == full.report.total_download_bytes())
    assert sk.report.drop_cause_counts() == full.report.drop_cause_counts()
    assert sk.report.rung_histogram() == full.report.rung_histogram()
    assert (sk.report.participants_per_round()
            == full.report.participants_per_round())
    # and both reconcile against their run's own accounting
    reconcile(full.report, full)
    reconcile(sk.report, sk)
    # β masses are exact additive group sums in both modes
    for key in ("staleness", "rung", "role"):
        a, b = full.report.beta_mass_by(key), sk.report.beta_mass_by(key)
        assert set(a) == set(b)
        assert all(a[g] == pytest.approx(b[g]) for g in a)
    assert sk.report.mean_distortion() == \
        pytest.approx(full.report.mean_distortion())


def test_sketch_quantiles_within_rank_error_of_full(mode_runs):
    full, _ = mode_runs["full"]
    sk, _ = mode_runs["sketch"]
    finals = full.report.final_outcomes()
    exact = {
        "upload_bytes": sorted(float(r["upload_bytes"])
                               for r in finals.values()
                               if r.get("upload_bytes") is not None),
        "distortion": sorted(float(r["distortion"]) for r in finals.values()
                             if r.get("distortion") is not None),
        "beta": sorted(float(row["beta"])
                       for row in full.report.beta_rows()
                       if row.get("role", "client") == "client")}
    qdocs = sk.report.quantiles(qs=(0.25, 0.5, 0.9))
    eps = sk.report.summary["sketch"]["eps"]
    for metric, srt in exact.items():
        assert srt, f"fixture recorded no {metric} values"
        n = len(srt)
        for q, got in qdocs[metric].items():
            target = max(1, math.ceil(q * n))
            lo = bisect_left(srt, got) + 1
            hi = bisect_right(srt, got)
            slack = eps * n + 1
            assert lo - slack <= target <= hi + slack, \
                f"{metric} q={q}: {got} ranks [{lo},{hi}] vs {target}"


def test_sketch_ndjson_roundtrip(mode_runs):
    sk, _ = mode_runs["sketch"]
    rep = load_report(sk.cfg.telemetry_log)
    assert isinstance(rep, SketchReport)
    assert rep.total_upload_bytes() == sk.report.total_upload_bytes()
    assert rep.drop_cause_counts() == sk.report.drop_cause_counts()
    assert rep.rung_histogram() == sk.report.rung_histogram()
    assert rep.beta_mass_by("staleness").keys() \
        == sk.report.beta_mass_by("staleness").keys()
    assert set(rep.quantiles()) == set(sk.report.quantiles())
    assert len(rep.sample_rows()) == len(sk.report.sample_rows())
    reconcile(rep, sk)                    # reloaded sketch still reconciles
    # full-mode logs resolve to RunReport through the same entry point
    full, _ = mode_runs["full"]
    assert isinstance(load_report(full.cfg.telemetry_log), RunReport)
    # and the renderer produces the same table set from either mode
    md = render_markdown([rep], labels=["sketch"])
    for section in ("## Runs", "## Drop-cause breakdown",
                    "## β-mass by staleness", "## Phase timings",
                    "## Distribution quantiles", "## Health"):
        assert section in md, section


def test_sketch_beta_ess_gauge(mode_runs):
    for mode in ("full", "sketch"):
        runner, _ = mode_runs[mode]
        ess = [r["gauges"]["beta_ess"] for r in runner.report.rounds
               if "beta_ess" in r["gauges"]]
        assert ess, f"{mode}: no beta_ess gauges recorded"
        assert all(1.0 <= e <= BASE["n_clients"] + 1e-9 for e in ess)
    f = {r["round"]: r["gauges"]["beta_ess"] for r in mode_runs["full"][0]
         .report.rounds if "beta_ess" in r["gauges"]}
    s = {r["round"]: r["gauges"]["beta_ess"] for r in mode_runs["sketch"][0]
         .report.rounds if "beta_ess" in r["gauges"]}
    assert f == pytest.approx(s)


def test_rung_churn_gauge_emitted(mode_runs):
    runner, _ = mode_runs["full"]
    churn = {r["round"]: r["gauges"]["rung_churn"]
             for r in runner.report.rounds if "rung_churn" in r["gauges"]}
    # round 1 has no previous assignment; every later round reports churn
    assert set(churn) == set(range(2, ROUNDS + 1))
    assert all(0.0 <= c <= 1.0 for c in churn.values())


# ---------------------------------------------------------------------------
# population scale: 50k simulated clients, O(rounds + K) resident state
# ---------------------------------------------------------------------------
def _feed_population(n_clients, rounds, k=64, seed=0):
    """Drive the hub protocol directly at population scale (no training —
    the telemetry path is the thing under test) and return the sketch
    report plus a stub runner carrying the ground-truth accounting."""
    rep = SketchReport()
    tel = Telemetry(sinks=[rep],
                    sketch=SketchState(n_clients, k=k, seed=seed))
    tel.start_run({"scenario": "synthetic", "n_clients": n_clients,
                   "rounds": rounds})
    rng = np.random.default_rng(seed)
    uploads = []
    participants = []
    downlink = 0.0
    for r in range(1, rounds + 1):
        tel.begin_round(r)
        sel = rng.random(n_clients) < 0.5
        up = rng.random(n_clients) < 0.9
        n_agg = 0
        for i in range(n_clients):
            if not sel[i]:
                tel.client_outcome(r, i, NOT_SELECTED)
            elif not up[i]:
                tel.client_outcome(r, i, LINK_DOWN, detail="outage")
            else:
                ub = float(rng.integers(10_000, 100_000))
                uploads.append(ub)
                tel.client_outcome(r, i, AGGREGATED, rung="qsgd:4",
                                   upload_bytes=ub,
                                   distortion=float(rng.random()))
                n_agg += 1
        betas = rng.dirichlet(np.ones(min(n_agg, 32)))
        tel.betas(r, [beta_row(b, client=j, rung="qsgd:4")
                      for j, b in enumerate(betas)])
        tel.gauge(r, "participants", float(n_agg))
        tel.gauge(r, "downlink_bytes", 1e6)
        downlink += 1e6
        participants.append(n_agg)
        tel.end_round(r)
    tel.end_run()
    runner = SimpleNamespace(
        comm=SimpleNamespace(total_uplink_bytes=math.fsum(uploads),
                             total_downlink_bytes=downlink),
        loop=SimpleNamespace(participants_per_round=participants))
    return rep, runner


def test_population_scale_sketch_smoke():
    small, _ = _feed_population(2_000, rounds=3, seed=5)
    big, runner = _feed_population(50_000, rounds=3, seed=5)
    # exact closure + bit-equal byte totals against the feed's accounting
    nums = reconcile(big, runner)
    assert nums["outcomes_total"] == 50_000 * 3
    assert big.total_upload_bytes() == runner.comm.total_uplink_bytes

    # resident state is O(rounds + K): no per-client rows anywhere,
    # per-round records of constant size (independent of n_clients),
    # reservoir capped at K, sketches at their ε-bound
    for rec in big.rounds:
        assert "clients" not in rec and "betas" not in rec
    est_small, est_big = small.resident_estimate(), big.resident_estimate()
    assert est_big["reservoir_rows"] == 64
    assert est_big["round_record_bytes"] < 16_000
    # 25× the clients must not grow the per-round record (same structure;
    # allow slack for longer digit strings in the counts)
    assert (est_big["round_record_bytes"]
            < est_small["round_record_bytes"] * 2)
    assert est_big["summary_bytes"] < est_small["summary_bytes"] * 4
    for name, doc in big.summary["sketch"]["sketches"].items():
        assert len(doc["entries"]) < 4_000, name

    # the sketches still answer sensible quantiles at this scale
    q = big.quantiles()["upload_bytes"]
    assert 10_000 <= q[0.5] <= 100_000

    # duplicate-outcome enforcement survives the sketch path
    tel = Telemetry(sinks=[SketchReport()], sketch=SketchState(10))
    tel.start_run({"n_clients": 10})
    tel.begin_round(1)
    tel.client_outcome(1, 3, NOT_SELECTED)
    with pytest.raises(ValueError, match="exactly one terminal outcome"):
        tel.client_outcome(1, 3, AGGREGATED)


# ---------------------------------------------------------------------------
# Chrome-trace export
# ---------------------------------------------------------------------------
def test_trace_is_valid_and_telescopes(mode_runs):
    runner, _ = mode_runs["full"]
    path = runner.cfg.telemetry_trace
    doc = json.load(open(path))
    assert isinstance(doc["traceEvents"], list) and doc["traceEvents"]
    assert {e["ph"] for e in doc["traceEvents"]} == {"B", "E"}
    assert all(e["ts"] >= 0 for e in doc["traceEvents"])
    stats = verify_trace(path, runner.report)
    assert stats["rounds_checked"] == ROUNDS
    assert stats["timers_checked"] == len(runner.report.summary["timers_s"])


def test_trace_verification_catches_tampering(mode_runs, tmp_path):
    runner, _ = mode_runs["full"]
    doc = json.load(open(runner.cfg.telemetry_trace))
    phase_ev = next(e for e in doc["traceEvents"]
                    if e["name"].startswith("phase.") and e["ph"] == "E")
    phase_ev["ts"] += 5e6                  # stretch one span by 5 seconds
    bad = tmp_path / "tampered.json"
    bad.write_text(json.dumps(doc))
    with pytest.raises((ChromeTraceError, ValueError)):
        verify_trace(str(bad), runner.report)


# ---------------------------------------------------------------------------
# crash durability (satellite)
# ---------------------------------------------------------------------------
def test_truncated_final_line_tolerated(mode_runs, tmp_path):
    for mode, loader in (("full", RunReport.from_ndjson),
                         ("sketch", SketchReport.from_ndjson)):
        runner, _ = mode_runs[mode]
        lines = open(runner.cfg.telemetry_log).read().splitlines()
        cut = tmp_path / f"killed_{mode}.ndjson"
        # a kill mid-write: the final record is half a JSON object
        cut.write_text("\n".join(lines[:-1]) + "\n"
                       + lines[-1][:len(lines[-1]) // 2])
        with pytest.warns(RuntimeWarning, match="truncated final record"):
            rep = loader(str(cut))
        assert rep.n_rounds == ROUNDS       # run_end was the casualty
        assert rep.drop_cause_counts() == \
            runner.report.drop_cause_counts()
        # load_report dispatches on the surviving prefix too
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            assert type(load_report(str(cut))) is type(runner.report)

    # corruption that is NOT the final line is a damaged log: still raises
    bad = tmp_path / "damaged.ndjson"
    bad.write_text(lines[0] + "\n{half a record\n" + lines[-1] + "\n")
    with pytest.raises(json.JSONDecodeError):
        RunReport.from_ndjson(str(bad))


def test_ndjson_flushes_every_record(tmp_path):
    path = tmp_path / "flush.ndjson"
    sink = NdjsonSink(str(path))
    sink.on_run_start({"n_clients": 2})
    sink.on_round({"round": 1, "clients": {0: {"client": 0,
                                               "outcome": AGGREGATED}},
                   "gauges": {}, "betas": []})
    sink.on_resolution({"origin_round": 1, "client": 0,
                        "outcome": AGGREGATED})
    sink.on_health({"round": 1, "monitor": "empty_cohort",
                    "severity": "alarm", "value": 3, "threshold": 3,
                    "message": "x"})
    # nothing closed or crashed — every record is already on disk
    kinds = [json.loads(ln)["record"]
             for ln in open(path).read().splitlines()]
    assert kinds == ["run_start", "round", "resolution", "health"]


# ---------------------------------------------------------------------------
# final_outcomes caching (satellite)
# ---------------------------------------------------------------------------
def test_final_outcomes_cached_and_invalidated(mode_runs):
    runner, _ = mode_runs["full"]
    import copy
    rep = copy.deepcopy(runner.report)
    first = rep.final_outcomes()
    assert rep.final_outcomes() is first           # cache hit
    counts = rep.drop_cause_counts()
    # a new round record invalidates
    rep.on_round({"round": ROUNDS + 1,
                  "clients": {0: {"client": 0, "outcome": NOT_SELECTED}},
                  "gauges": {}, "betas": []})
    second = rep.final_outcomes()
    assert second is not first
    assert len(second) == len(first) + 1
    # in-place tampering that changes row counts (what the reconcile tamper
    # tests do) is seen by the cache key; pick a non-buffered row so no
    # resolution record is orphaned by the removal
    some_client = next(c for c, row in rep.rounds[0]["clients"].items()
                       if row["outcome"] != "buffered")
    rep.rounds[0]["clients"].pop(some_client)
    third = rep.final_outcomes()
    assert len(third) == len(second) - 1
    # a resolution record also invalidates (fresh copy: resolutions must
    # target a still-buffered record)
    rep2 = copy.deepcopy(runner.report)
    cached = rep2.final_outcomes()
    buffered_key = next((k for k, v in cached.items()
                         if v["outcome"] == "buffered"), None)
    if buffered_key is not None:
        rep2.on_resolution({"origin_round": buffered_key[0],
                            "client": buffered_key[1],
                            "outcome": EVICTED})
        assert rep2.final_outcomes() is not cached
    assert counts == copy.deepcopy(runner.report).drop_cause_counts()


# ---------------------------------------------------------------------------
# health monitors
# ---------------------------------------------------------------------------
def _digest(r, **kw):
    d = dict(round=r, n_clients=10, counts={}, participants=5,
             eval_acc=None, beta_n=0, beta_ess=None, distortion_mean=None,
             gauges={})
    d.update(kw)
    return d


def test_health_monitors_unit():
    cfg = HealthConfig()
    hm = HealthMonitors(cfg)
    recs = []
    # healthy warmup evals, then a crash below the drawdown threshold
    for r, acc in enumerate([0.5, 0.6, 0.62, 0.3], start=1):
        recs += hm.observe_round(_digest(r, eval_acc=acc))
    assert [x["monitor"] for x in recs] == ["acc_drawdown"]
    # staying collapsed does not re-fire (edge-triggered) …
    recs += hm.observe_round(_digest(5, eval_acc=0.3))
    assert len(recs) == 1
    # … but a recovery re-arms the detector
    hm.observe_round(_digest(6, eval_acc=0.62))
    recs += hm.observe_round(_digest(7, eval_acc=0.3))
    assert [x["monitor"] for x in recs] == ["acc_drawdown"] * 2
    for rec in recs:                      # schema'd records
        assert set(rec) == {"round", "monitor", "severity", "value",
                            "threshold", "message"}
        assert rec["severity"] == "alarm"

    hm = HealthMonitors(cfg)
    out = []
    for r in range(1, 5):
        out += hm.observe_round(_digest(r, participants=0,
                                        counts={"evicted": 1}))
    monitors = [x["monitor"] for x in out]
    assert monitors.count("empty_cohort") == 1
    assert monitors.count("eviction_streak") == 1
    assert out[0]["round"] == cfg.empty_streak

    hm = HealthMonitors(cfg)
    out = []
    for r in range(1, 4):
        out += hm.observe_round(_digest(r, beta_n=10, beta_ess=1.0))
    assert [x["monitor"] for x in out] == ["beta_collapse"]

    hm = HealthMonitors(cfg)
    out = []
    for r in range(1, 5):
        out += hm.observe_round(_digest(r, gauges={"rung_churn": 0.8}))
    assert [x["monitor"] for x in out] == ["rung_thrash"]

    hm = HealthMonitors(cfg)
    out = []
    for r, cap in enumerate([1e7, 1.1e7, 0.9e7, 1e7, 1e6], start=1):
        out += hm.observe_round(
            _digest(r, gauges={"cap_hat_mean_bps": cap}))
    assert [x["monitor"] for x in out] == ["cap_drift"]

    hm = HealthMonitors(cfg)
    out = []
    for r, d in enumerate([0.1, 0.11, 0.09, 0.6], start=1):
        out += hm.observe_round(_digest(r, distortion_mean=d))
    assert [x["monitor"] for x in out] == ["distortion_spike"]
    v = hm.verdict()
    assert not v["healthy"] and v["n_alarms"] == 1
    assert v["by_monitor"] == {"distortion_spike": 1}
    assert v["first_alarm_round"] == 4 and v["rounds_seen"] == 4


@pytest.fixture(scope="module")
def blackout_run(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("blackout")
    cfg = FFTConfig(n_clients=8, k_selected=6, local_steps=2, batch_size=8,
                    lr=0.05, seed=0, eval_every=2, deadline_s=5.0,
                    tau_max=2, buffer_k=3, model_bytes=4e6,
                    failure_mode="scenario:blackout", server_mode="sync",
                    codec="adaptive:sign1-fp16", telemetry="sketch",
                    telemetry_console=True,
                    telemetry_log=str(tmp / "blackout.ndjson"))
    runner = make_toy_runner(cfg, n_samples=300, n_classes=4, image_size=8,
                             public_per_class=10, pretrain_steps=0, seed=0)
    runner.run(STRATEGIES["fedauto"](), rounds=12)
    return runner


def test_health_fires_on_blackout(blackout_run):
    rep = blackout_run.report
    v = rep.health_verdict()
    assert v is not None and not v["healthy"]
    # the outage must trip the cohort detector at minimum, and the alarms
    # must postdate the blackout onset (round 6)
    assert "empty_cohort" in v["by_monitor"]
    assert v["first_alarm_round"] > 6
    assert len(rep.health) == v["n_alarms"]
    # alarm records and verdict survive the NDJSON round-trip
    rep2 = load_report(blackout_run.cfg.telemetry_log)
    assert [a["monitor"] for a in rep2.health] \
        == [a["monitor"] for a in rep.health]
    assert rep2.health_verdict() == v
    # … and the reloaded report still reconciles
    reconcile(rep2, blackout_run)


def test_console_sink_surfaces_health(capsys):
    from repro.obs import ConsoleSink
    sink = ConsoleSink()
    sink.on_health({"round": 9, "monitor": "empty_cohort",
                    "severity": "alarm", "value": 3.0, "threshold": 3.0,
                    "message": "3 consecutive rounds aggregated nothing"})
    sink.on_run_end({"health": {"healthy": False, "n_alarms": 1,
                                "by_monitor": {"empty_cohort": 1},
                                "first_alarm_round": 9, "rounds_seen": 12}})
    out = capsys.readouterr().out
    assert "[health] ALARM r=  9 empty_cohort" in out
    assert "verdict: 1 ALARMS [empty_cohort=1] first at r=9" in out
    sink.on_run_end({"health": {"healthy": True, "rounds_seen": 5}})
    assert "verdict: HEALTHY (5 rounds, 0 alarms)" \
        in capsys.readouterr().out


def test_health_silent_on_healthy_baseline(mode_runs):
    for mode in ("full", "sketch"):
        v = mode_runs[mode][0].report.health_verdict()
        assert v == {"healthy": True, "n_alarms": 0, "by_monitor": {},
                     "first_alarm_round": None, "rounds_seen": ROUNDS}


# ---------------------------------------------------------------------------
# dashboard
# ---------------------------------------------------------------------------
def test_dashboard_renders_both_modes(mode_runs, blackout_run):
    for mode in ("full", "sketch"):
        frame = render_dashboard(mode_runs[mode][0].report)
        assert "participants" in frame and "outcomes" in frame
        assert "health        OK (run complete, 0 alarms)" in frame
        assert "acc=" in frame
    frame = render_dashboard(blackout_run.report)
    assert "ALARMS" in frame and "empty_cohort" in frame


def test_watch_once_over_live_and_truncated_logs(mode_runs, tmp_path):
    runner, _ = mode_runs["sketch"]
    buf = io.StringIO()
    watch(runner.cfg.telemetry_log, once=True, stream=buf)
    assert "participants" in buf.getvalue()
    # a mid-run log (no run_end yet, half-written last line) still renders
    lines = open(runner.cfg.telemetry_log).read().splitlines()
    live = tmp_path / "live.ndjson"
    live.write_text("\n".join(lines[:3]) + "\n" + lines[3][:10])
    buf = io.StringIO()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        watch(str(live), once=True, stream=buf)
    assert "participants" in buf.getvalue()
    assert "health        OK" in buf.getvalue()   # no verdict yet: still live


def test_dashboard_sink_paints_per_round(capsys):
    rep = SketchReport()
    from repro.obs import DashboardSink
    tel = Telemetry(sinks=[rep, DashboardSink(rep)],
                    sketch=SketchState(4, k=8))
    tel.start_run({"n_clients": 4, "rounds": 2})
    for r in (1, 2):
        tel.begin_round(r)
        for i in range(4):
            tel.client_outcome(r, i, AGGREGATED, upload_bytes=10.0)
        tel.gauge(r, "participants", 4.0)
        tel.end_round(r)
    tel.end_run()
    out = capsys.readouterr().out
    # one frame per round plus the final frame
    assert out.count("┌") == 3
