import os
import sys

# Tests run single-device (smoke tests must see 1 device, not 512 — the
# dry-run sets its own flags in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
