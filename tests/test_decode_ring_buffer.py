"""Ring-buffer / sliding-window decode semantics: decoding PAST the window
must (a) keep working, (b) match a reference attention limited to the
window, and (c) keep the cache allocation at window size."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.models.attention import gqa_init_cache


def test_cache_allocation_is_window_sized():
    cfg = get_smoke_config("starcoder2-7b")          # sliding_window=64
    cache = gqa_init_cache(cfg, batch=2, seq_len=4096, dtype=jnp.float32)
    assert cache.k.shape[1] == cfg.sliding_window


def test_decode_past_window_matches_windowed_forward():
    cfg = dataclasses.replace(get_smoke_config("starcoder2-7b"),
                              dtype="float32", sliding_window=8, num_layers=2)
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    B, S = 2, 24                                      # 3× past the window
    tokens = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    h, _ = T.hidden_states(params, cfg, batch, q_chunk=8)
    w = params["embed"]["embedding"].T if cfg.tie_embeddings else \
        params["lm_head"]["embedding"].T
    fwd = np.asarray((h @ w).astype(jnp.float32))

    state = T.init_decode_state(params, cfg, B, S)   # ring buffer = window 8
    step = jax.jit(lambda p, s, t: T.decode_step(p, cfg, s, t))
    for t in range(S):
        logits, state = step(params, state, tokens[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(logits), fwd[:, t], rtol=2e-3,
                                   atol=2e-3, err_msg=f"t={t}")


def test_moe_capacity_drop_degrades_gracefully():
    """When capacity is exceeded, dropped (token, expert) pairs lose that
    expert's contribution but never corrupt other tokens."""
    from repro.models.moe import _grouped_ffn, _moe_local, moe_init
    cfg = dataclasses.replace(get_smoke_config("mixtral-8x22b"),
                              dtype="float32")
    key = jax.random.PRNGKey(3)
    p = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (16, cfg.d_model))
    out, _ = _moe_local(p, cfg, x)
    assert bool(jnp.all(jnp.isfinite(out)))
