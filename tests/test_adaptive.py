"""ISSUE-4: deadline-simulator timing bugfixes + adaptive codec assignment.

Covers the three timing regressions (outage-independent compute jitter,
inclusive deadline boundary, empty-cohort server wait), the split of link
realization from timing (per-round repricing), the adaptive controller
(ladder policy, capacity estimation, determinism), the downlink codec path
with server-side error feedback, and trace schema v3 (record/replay of
adaptive runs bit-exactly, v2 compatibility, loud mismatches).
"""
import dataclasses
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.strategies import STRATEGIES
from repro.fl.comm import (CommState, RUNG_LADDER, AdaptiveCommController,
                           is_adaptive_spec, ladder_between, make_codec,
                           parse_adaptive_spec)
from repro.fl.runtime import FFTConfig
from repro.fl.scenarios import make_scenario_model
from repro.fl.scenarios.engine import (CAUSE_DEADLINE, CAUSE_OK,
                                       DeadlineSimulator, LinkState)
from repro.fl.toy import make_toy_runner

N = 8


# ---------------------------------------------------------------------------
# bugfix 1: per-round jitters are drawn vectorized up front, so one client's
# link state can never shift another client's compute time
# ---------------------------------------------------------------------------
def _sim(**kw):
    args = dict(model_bytes=1e6, deadline_s=8.0, compute_s=2.0,
                hetero_sigma=0.3, jitter_sigma=0.2, seed=7)
    args.update(kw)
    n = args.pop("n", N)
    return DeadlineSimulator(n, **args)


def test_jitter_independent_of_other_clients_outages():
    """Flipping one link's ``up`` must leave every other client's
    ``t_compute_s`` unchanged — realizations are common-random-number
    comparable across outage patterns."""
    links_all_up = [LinkState(10e6) for _ in range(N)]
    links_one_down = [LinkState(10e6) for _ in range(N)]
    links_one_down[2] = LinkState(0.0, up=False, cause="outage")

    ev_a = _sim().simulate_round(3, links_all_up)
    ev_b = _sim().simulate_round(3, links_one_down)
    for i in range(N):
        if i == 2:
            assert math.isinf(ev_b.events[i].t_compute_s)
        else:
            assert ev_a.events[i].t_compute_s == ev_b.events[i].t_compute_s


def test_jitter_independent_of_payload_and_simulation_count():
    """Re-simulating the same round (at any payload size) replays identical
    compute times: the jitter stream is keyed by (seed, round), not by how
    often the simulator has run."""
    sim = _sim()
    links = [LinkState(5e6) for _ in range(N)]
    first = sim.simulate_round(1, links)
    again = sim.simulate_round(1, links)
    for a, b in zip(first.events, again.events):
        assert a.t_compute_s == b.t_compute_s
        assert a.finish_s == b.finish_s
    sim.set_payload_bytes(upload_bytes=0.25e6)
    repriced = sim.simulate_round(1, links)
    for a, b in zip(first.events, repriced.events):
        assert a.t_compute_s == b.t_compute_s          # only transfers moved
        assert b.t_upload_s == pytest.approx(a.t_upload_s / 4)


def test_jitter_differs_across_rounds_and_clients():
    sim = _sim()
    links = [LinkState(5e6) for _ in range(N)]
    r1 = sim.simulate_round(1, links)
    r2 = sim.simulate_round(2, links)
    c1 = [e.t_compute_s for e in r1.events]
    c2 = [e.t_compute_s for e in r2.events]
    assert c1 != c2                                    # fresh draw per round
    assert len(set(c1)) > 1                            # and per client


# ---------------------------------------------------------------------------
# bugfix 2: an upload landing at exactly t == deadline_s is delivered
# ---------------------------------------------------------------------------
def _exact_boundary_sim(deadline):
    # capacity 8 Mbps, 1e6 B payload, downlink_ratio 8, zero compute:
    # t_dl = 0.125 s, t_ul = 1.0 s -> finish exactly 1.125 s (binary exact)
    sim = DeadlineSimulator(1, model_bytes=1e6, deadline_s=deadline,
                            compute_s=0.0, hetero_sigma=0.0,
                            jitter_sigma=0.0, seed=0)
    return sim, [LinkState(8e6)]


def test_upload_finishing_exactly_at_deadline_is_delivered():
    sim, links = _exact_boundary_sim(deadline=1.125)
    ev = sim.simulate_round(1, links)
    assert ev.events[0].finish_s == 1.125              # boundary is exact
    assert ev.events[0].met_deadline
    assert ev.events[0].cause == CAUSE_OK
    np.testing.assert_array_equal(ev.connected_mask(), [True])
    np.testing.assert_array_equal(ev.late_mask(), [False])


def test_upload_finishing_after_deadline_is_late():
    sim, links = _exact_boundary_sim(deadline=1.124)
    ev = sim.simulate_round(1, links)
    assert ev.events[0].finish_s == 1.125
    assert not ev.events[0].met_deadline
    assert ev.events[0].cause == CAUSE_DEADLINE
    np.testing.assert_array_equal(ev.connected_mask(), [False])
    np.testing.assert_array_equal(ev.late_mask(), [True])


# ---------------------------------------------------------------------------
# bugfix 3: an empty selected cohort still waits out the round timeout
# ---------------------------------------------------------------------------
def test_server_wait_empty_selection_is_the_deadline():
    sim = _sim(jitter_sigma=0.0, hetero_sigma=0.0)
    ev = sim.simulate_round(1, [LinkState(10e6) for _ in range(N)])
    assert ev.server_wait(np.zeros(N, dtype=bool)) == ev.deadline_s
    # non-empty cohorts keep their semantics
    assert 0.0 < ev.server_wait(np.ones(N, dtype=bool)) <= ev.deadline_s


# ---------------------------------------------------------------------------
# link realization split from timing: per-round repricing
# ---------------------------------------------------------------------------
def test_reprice_round_changes_only_timing_never_the_link_draw():
    m = make_scenario_model("correlated_wifi", N, model_bytes=4e6,
                            deadline_s=5.0, seed=3)
    base = [m.draw_events(r) for r in range(1, 9)]
    m.set_payload_bytes(upload_bytes=0.5e6, download_bytes=0.5e6)
    for r in range(1, 9):
        rp = m.reprice_round(r)
        for e0, e1 in zip(base[r - 1].events, rp.events):
            assert e0.up == e1.up
            assert e0.capacity_bps == e1.capacity_bps
            if not e0.up:
                assert e0.cause == e1.cause            # outage cause frozen
            else:
                assert e1.t_upload_s <= e0.t_upload_s  # fewer bytes: faster
                assert e1.t_download_s <= e0.t_download_s
                assert e1.finish_s <= e0.finish_s
                assert e0.t_compute_s == e1.t_compute_s
        # smaller payloads can only add participants
        assert (rp.connected_mask() | ~base[r - 1].connected_mask()).all()
        # the repriced realization is now the cached one
        np.testing.assert_array_equal(m.draw(r), rp.connected_mask())


def test_set_payload_bytes_applies_to_future_rounds_only():
    m = make_scenario_model("lossy_uplink", N, model_bytes=4e6,
                            deadline_s=5.0, seed=1)
    ev1 = m.draw_events(1)
    m.set_payload_bytes(upload_bytes=0.1e6)
    assert m.draw_events(1) is ev1                     # cached, unrepriced
    ev2 = m.draw_events(2)
    up2 = [e for e in ev2.events if e.up]
    assert up2 and all(e.t_upload_s <= 5.0 for e in up2)


def test_timed_adapter_reprices_without_perturbing_inner_draw():
    from repro.fl.network import build_network
    from repro.fl.server.timeline import TimedFailureAdapter
    from repro.fl.failures import IntermittentFailures
    adapter = TimedFailureAdapter(
        IntermittentFailures(N, duration_max=5, seed=2), build_network(N, seed=2),
        model_bytes=4e6, deadline_s=5.0, seed=2)
    base = [adapter.draw_events(r) for r in range(1, 6)]
    adapter.set_payload_bytes(upload_bytes=0.25e6)
    for r in range(1, 6):
        rp = adapter.reprice_round(r)
        for e0, e1 in zip(base[r - 1].events, rp.events):
            assert e0.up == e1.up
            assert e0.capacity_bps == e1.capacity_bps


def test_timed_adapter_capacities_common_random_numbers():
    """Synthesized capacities are keyed by (seed, round) and drawn for every
    client: a different inner failure pattern at the same seed must not
    shift an up client's capacity (the adapter-level mirror of the
    compute-jitter CRN fix)."""
    from repro.fl.network import build_network
    from repro.fl.server.timeline import TimedFailureAdapter
    from repro.fl.failures import IntermittentFailures, NoFailures
    chans = build_network(N, seed=3)
    a = TimedFailureAdapter(NoFailures(N), chans,
                            model_bytes=4e6, deadline_s=5.0, seed=3)
    flaky = IntermittentFailures(N, duration_max=8, seed=9,
                                 rates=np.full(N, 0.4))
    b = TimedFailureAdapter(flaky, chans, model_bytes=4e6, deadline_s=5.0,
                            seed=3)
    saw_both = False
    for r in range(1, 9):
        ea, eb = a.draw_events(r), b.draw_events(r)
        for x, y in zip(ea.events, eb.events):
            if x.up and y.up:
                assert x.capacity_bps == y.capacity_bps
            else:
                saw_both = True
    assert saw_both                        # the outage patterns did differ


# ---------------------------------------------------------------------------
# adaptive spec parsing + ladder policy
# ---------------------------------------------------------------------------
def test_parse_adaptive_specs():
    assert is_adaptive_spec("adaptive:sign1-fp16")
    assert is_adaptive_spec("adaptive")
    assert not is_adaptive_spec("int8")
    assert parse_adaptive_spec("adaptive:sign1-fp16") == ("sign1", "fp16")
    assert parse_adaptive_spec("adaptive:qsgd:2-int8") == ("qsgd:2", "int8")
    assert parse_adaptive_spec("adaptive") == ("sign1", "fp32")
    assert ladder_between("qsgd:8", "fp16") == ("qsgd:8", "int8", "fp16")


@pytest.mark.parametrize("bad", ["adaptive:", "adaptive:fp16-sign1",
                                 "adaptive:sign1", "adaptive:topk:0.1-fp32",
                                 "adaptive:sign1-fp64"])
def test_parse_adaptive_rejects_bad_specs(bad):
    with pytest.raises(ValueError):
        parse_adaptive_spec(bad)


def _controller(lo="sign1", hi="fp16", **kw):
    tmpl = {"w": jnp.zeros((1000,), jnp.float32)}
    comm = CommState(make_codec(hi), tmpl, model_bytes_override=4e6)
    args = dict(deadline_s=5.0, compute_s=2.0)
    args.update(kw)
    return AdaptiveCommController(N, comm, lo=lo, hi=hi, **args)


def test_ladder_monotone_in_estimated_capacity():
    ctl = _controller()
    caps = np.logspace(2, 12, 60)                      # 100 bps .. 1 Tbps
    idx = [ctl.rung_index_for(c) for c in caps]
    assert idx == sorted(idx)                          # monotone
    assert idx[0] == 0                                 # hopeless -> cheapest
    assert idx[-1] == len(ctl.rungs) - 1               # fast -> richest
    assert max(idx) < len(RUNG_LADDER)                 # never beyond ladder


def test_ladder_never_exceeds_hi_rung():
    ctl = _controller(lo="qsgd:4", hi="int8")
    assert ctl.rung_for(1e15) == "int8"
    assert ctl.rung_for(1.0) == "qsgd:4"
    full = _controller(lo="sign1", hi="fp32")
    assert full.rung_for(1e15) == "fp32"               # fp32 is the ceiling
    # rung bytes are non-decreasing along every ladder slice
    assert (np.diff(full.rung_bytes) >= 0).all()


def test_controller_probes_high_then_backs_off_on_misses():
    ctl = _controller()
    a1 = ctl.assign(1)
    assert all(c == "fp16" for c in a1.codecs)         # optimistic start
    sim = DeadlineSimulator(N, model_bytes=4e6, deadline_s=5.0,
                            compute_s=2.0, hetero_sigma=0.0,
                            jitter_sigma=0.0, seed=0)
    sim.set_payload_bytes(upload_bytes=a1.upload_bytes,
                          download_bytes=a1.download_bytes)
    slow = [LinkState(0.05e6) for _ in range(N)]       # nobody lands at fp16
    ctl.observe(1, sim.simulate_round(1, slow), np.ones(N, dtype=bool))
    a2 = ctl.assign(2)
    idx = [ctl.rungs.index(c) for c in a2.codecs]
    assert all(k < ctl.rungs.index("fp16") for k in idx)
    # keep missing: the controller walks to the cheapest rung and stays
    for r in range(3, 16):
        sim.set_payload_bytes(upload_bytes=ctl.assignments[r - 1].upload_bytes)
        ctl.observe(r - 1, sim.simulate_round(r - 1, slow),
                    np.ones(N, dtype=bool))
        a2 = ctl.assign(r)
    assert all(c == "sign1" for c in a2.codecs)
    assert (ctl.cap_hat >= ctl.cap_min).all()          # floored, can recover


def test_controller_recovers_after_successes():
    ctl = _controller()
    ctl.cap_hat[:] = ctl.cap_min                       # beaten all the way down
    a = ctl.assign(1)
    assert all(c == "sign1" for c in a.codecs)
    sim = DeadlineSimulator(N, model_bytes=4e6, deadline_s=5.0,
                            compute_s=2.0, hetero_sigma=0.0,
                            jitter_sigma=0.0, seed=0)
    fast = [LinkState(50e6) for _ in range(N)]
    for r in range(1, 6):
        sim.set_payload_bytes(upload_bytes=ctl.assignments[r].upload_bytes,
                              download_bytes=ctl.assignments[r].download_bytes)
        ctl.observe(r, sim.simulate_round(r, fast), np.ones(N, dtype=bool))
        a = ctl.assign(r + 1)
    assert all(c == "fp16" for c in a.codecs)          # climbed back to hi


def test_controller_ignores_unselected_clients():
    ctl = _controller()
    ctl.assign(1)
    sim = DeadlineSimulator(N, model_bytes=4e6, deadline_s=5.0, seed=0)
    ev = sim.simulate_round(1, [LinkState(0.01e6) for _ in range(N)])
    sel = np.zeros(N, dtype=bool)
    sel[0] = True
    before = ctl.cap_hat.copy()
    ctl.observe(1, ev, sel)
    assert ctl.cap_hat[0] < before[0]                  # observed miss
    np.testing.assert_array_equal(ctl.cap_hat[1:], before[1:])


def test_controller_is_deterministic():
    def run():
        ctl = _controller()
        sim = DeadlineSimulator(N, model_bytes=4e6, deadline_s=5.0, seed=5)
        world = make_scenario_model("diurnal", N, model_bytes=4e6,
                                    deadline_s=5.0, seed=5)
        out = []
        for r in range(1, 11):
            a = ctl.assign(r)
            world.set_payload_bytes(upload_bytes=a.upload_bytes,
                                    download_bytes=a.download_bytes)
            ev = world.draw_events(r)
            ctl.observe(r, ev, np.ones(N, dtype=bool))
            out.append(tuple(a.codecs))
        return out
    assert run() == run()


# ---------------------------------------------------------------------------
# CommState: per-call codec override + downlink broadcast error feedback
# ---------------------------------------------------------------------------
def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {f"l{i}": jnp.asarray(rng.normal(size=s), jnp.float32)
            for i, s in enumerate([(13, 7), (9,)])}


def _maxdiff(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32) -
                                     y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_roundtrip_codec_override_and_residual_flush():
    st = CommState(make_codec("fp16"), _tree())
    g = jax.tree.map(jnp.zeros_like, _tree())
    model = _tree(3)
    # a lossy rung leaves a residual...
    _, p1, d1 = st.roundtrip(0, model, g, codec=st.codec_named("sign1"))
    assert 0.0 < d1 <= 1.0                             # lossy rung: measured
    assert p1.codec == "sign1"
    assert st.residual(0) is not None
    # ...which a later lossless rung flushes down the wire entirely
    recon, p2, d2 = st.roundtrip(0, model, g, codec=st.codec_named("fp32"))
    assert d2 == 0.0                                   # lossless: exactly 0
    assert p2.codec == "fp32"
    assert st.residual(0) is None
    # cumulative conservation: decoded_1 + decoded_2 == 2 * delta exactly
    # (sign1's error was re-sent by the fp32 upload)
    dec = jax.tree.map(lambda a, b: a.astype(jnp.float32) +
                       b.astype(jnp.float32), recon, _decoded_of(st, p1, g))
    want = jax.tree.map(lambda d: 2.0 * d, model)
    assert _maxdiff(dec, want) <= 1e-5


def _decoded_of(st, payload, g):
    dec = st.codec_named(payload.codec).decode(payload)
    return jax.tree.map(lambda gg, d: gg.astype(jnp.float32) + d, g, dec)


def test_nbytes_for_scales_with_model_bytes_override():
    st = CommState(make_codec("fp32"), _tree(), model_bytes_override=8e6)
    assert st.nbytes_for("fp32") == pytest.approx(8e6)
    assert st.nbytes_for("fp16") == pytest.approx(4e6)
    # tiny test tree: the 4 B per-leaf scale keeps sign1 above 1/32 exactly
    assert st.nbytes_for("sign1") < 0.06 * 8e6
    st2 = CommState(make_codec("fp32"), _tree())
    assert st2.nbytes_for("fp32") == st2.fp32_nbytes


def test_broadcast_identity_without_downlink_codec():
    st = CommState(make_codec("fp32"), _tree())
    g = _tree(1)
    out, nbytes = st.broadcast(g)
    assert out is g
    assert nbytes == st.download_bytes == st.ref_bytes


def test_broadcast_downlink_error_feedback_tracks_global():
    """The decoded replica must follow the true global with bounded lag:
    server-side EF re-sends what each broadcast dropped."""
    st = CommState(make_codec("fp32"), _tree(),
                   downlink_codec=make_codec("qsgd:4"))
    rng = np.random.default_rng(0)
    g = jax.tree.map(jnp.zeros_like, _tree())
    out, nbytes = st.broadcast(g)                      # replica initialized
    # enrollment ships the full model: charged at ref_bytes, not the
    # compressed per-round rate
    assert nbytes == st.ref_bytes > st.download_bytes
    drift = []
    for t in range(12):
        g = jax.tree.map(
            lambda x: x + jnp.asarray(rng.normal(0, 0.1, x.shape),
                                      jnp.float32), g)
        out, _ = st.broadcast(g)
        drift.append(_maxdiff(out, g))
    # bounded (EF) and small relative to the accumulated motion
    assert max(drift[3:]) <= max(drift[:3]) * 3 + 1e-3
    assert drift[-1] < 0.1


def test_broadcast_total_downlink_accounting():
    st = CommState(make_codec("fp32"), _tree(),
                   downlink_codec=make_codec("fp16"))
    g = _tree(2)
    for _ in range(3):
        st.broadcast(g)
    # round 1 is the enrollment transfer (full model at ref_bytes); only
    # the subsequent broadcasts travel at the compressed rate
    assert st.total_downlink_bytes == pytest.approx(
        st.ref_bytes + 2 * st.download_bytes)


# ---------------------------------------------------------------------------
# end-to-end: adaptive runs, downlink pricing, trace v3, replay
# ---------------------------------------------------------------------------
BASE = dict(n_clients=6, k_selected=6, local_steps=2, batch_size=8, lr=0.05,
            seed=0, eval_every=2, model_bytes=4e6, deadline_s=5.0)
TOY = dict(n_samples=600, public_per_class=10, pretrain_steps=9)


def test_adaptive_run_recovers_participants_over_fp32():
    parts = {}
    for codec in ["fp32", "adaptive:sign1-fp16"]:
        cfg = FFTConfig(codec=codec, failure_mode="scenario:diurnal", **BASE)
        r = make_toy_runner(cfg, **TOY)
        r.run(STRATEGIES["fedavg"](), rounds=4)
        parts[codec] = float(np.mean(r.loop.participants_per_round))
    assert parts["adaptive:sign1-fp16"] > parts["fp32"]


def test_adaptive_runner_wiring():
    cfg = FFTConfig(codec="adaptive:sign1-fp16",
                    failure_mode="scenario:lossy_uplink", **BASE)
    r = make_toy_runner(cfg, **TOY)
    assert r.controller is not None
    assert r.downlink_codec_resolved == "fp16"         # defaults to hi rung
    assert r.download_bytes == pytest.approx(2e6)      # fp16 of 4e6 override
    assert r.upload_bytes == pytest.approx(2e6)        # hi-rung ceiling
    # static runs keep the uncompressed broadcast
    r2 = make_toy_runner(FFTConfig(codec="int8",
                                   failure_mode="scenario:lossy_uplink",
                                   **BASE), **TOY)
    assert r2.controller is None
    assert r2.download_bytes == pytest.approx(4e6)


def test_adaptive_needs_timing_wraps_legacy_modes():
    from repro.fl.server.timeline import TimedFailureAdapter
    cfg = FFTConfig(codec="adaptive:sign1-fp16", failure_mode="mixed", **BASE)
    r = make_toy_runner(cfg, **TOY)
    assert isinstance(r.failures, TimedFailureAdapter)
    hist = r.run(STRATEGIES["fedavg"](), rounds=3)
    assert len(hist) == 2


def test_downlink_codec_prices_download_bytes():
    cfg = FFTConfig(codec="int8", downlink_codec="int8",
                    failure_mode="scenario:lossy_uplink", **BASE)
    r = make_toy_runner(cfg, **TOY)
    assert r.download_bytes == pytest.approx(r.upload_bytes)
    ev = r.failures.draw_events(1)
    up = [e for e in ev.events if e.up]
    # downloads priced at the compressed size: 4x faster than fp32 would be
    cfg_fp = FFTConfig(codec="int8", failure_mode="scenario:lossy_uplink",
                       **BASE)
    r_fp = make_toy_runner(cfg_fp, **TOY)
    ev_fp = r_fp.failures.draw_events(1)
    for e_c, e_f in zip(up, [e for e in ev_fp.events if e.up]):
        assert e_c.t_download_s == pytest.approx(
            e_f.t_download_s * r.download_bytes / r_fp.download_bytes)


@pytest.mark.parametrize("mode", ["sync", "buffered"])
def test_adaptive_record_replay_bit_exact(tmp_path, mode):
    path = str(tmp_path / "a.ndjson")
    rec_cfg = FFTConfig(codec="adaptive:sign1-fp16", server_mode=mode,
                        failure_mode="scenario:diurnal", trace_record=path,
                        **BASE)
    live = make_toy_runner(rec_cfg, **TOY).run(STRATEGIES["fedavg"](),
                                               rounds=4)
    rep_cfg = FFTConfig(codec="adaptive:sign1-fp16", server_mode=mode,
                        trace_replay=path, **BASE)
    rep = make_toy_runner(rep_cfg, **TOY).run(STRATEGIES["fedavg"](),
                                              rounds=4)
    assert rep == live


def test_v3_trace_schema_records_per_client_codec_and_bytes(tmp_path):
    path = str(tmp_path / "a.ndjson")
    cfg = FFTConfig(codec="adaptive:sign1-fp16",
                    failure_mode="scenario:diurnal", trace_record=path,
                    **BASE)
    runner = make_toy_runner(cfg, **TOY)
    runner.run(STRATEGIES["fedavg"](), rounds=3)
    lines = [json.loads(l) for l in open(path)]
    hdr = lines[0]
    assert hdr["version"] == 5
    assert hdr["codec"] == "adaptive:sign1-fp16"
    assert hdr["upload_bytes"] is None                 # no single size
    assert hdr["downlink_codec"] == "fp16"
    assert hdr["download_bytes"] == pytest.approx(2e6)
    rungs = set()
    for rec in lines[1:]:
        # round 1's broadcast is the full-model enrollment transfer
        # (ref_bytes); later rounds travel at the compressed fp16 rate
        want_dl = 4e6 if rec["round"] == 1 else 2e6
        for c in rec["clients"]:
            assert c["codec"] in RUNG_LADDER
            assert c["download_bytes"] == pytest.approx(want_dl)
            assert c["payload_bytes"] <= 2e6 + 1e-6    # never above hi rung
            rungs.add(c["codec"])
    # the recorded assignments match what the controller decided
    for rnd, a in runner.controller.assignments.items():
        rec = lines[rnd]
        assert [c["codec"] for c in rec["clients"]] == a.codecs


def test_adaptive_replay_with_different_spec_fails_loudly(tmp_path):
    path = str(tmp_path / "a.ndjson")
    cfg = FFTConfig(codec="adaptive:sign1-fp16",
                    failure_mode="scenario:diurnal", trace_record=path,
                    **BASE)
    make_toy_runner(cfg, **TOY).run(STRATEGIES["fedavg"](), rounds=2)
    with pytest.raises(ValueError, match="codec"):
        make_toy_runner(FFTConfig(codec="adaptive:sign1-fp32",
                                  trace_replay=path, **BASE), **TOY)
    with pytest.raises(ValueError, match="downlink"):
        make_toy_runner(FFTConfig(codec="adaptive:sign1-fp16",
                                  downlink_codec="int8",
                                  trace_replay=path, **BASE), **TOY)


def test_adaptive_replay_detects_rung_drift_at_equal_bytes(tmp_path):
    """qsgd:8 and int8 are byte-tied (1 B/param + 4 B/leaf) but decode
    differently — rewriting the recorded rungs must trip the replay check
    even though every byte vector still matches."""
    path = str(tmp_path / "a.ndjson")
    cfg = FFTConfig(codec="adaptive:sign1-fp16",
                    failure_mode="scenario:diurnal", trace_record=path,
                    **BASE)
    make_toy_runner(cfg, **TOY).run(STRATEGIES["fedavg"](), rounds=3)
    lines = [json.loads(l) for l in open(path)]
    drifted = False
    for rec in lines[1:]:
        for c in rec["clients"]:
            if c["codec"] == "int8":
                c["codec"] = "qsgd:8"
                drifted = True
    if not drifted:                                    # force one anyway
        lines[1]["clients"][0]["codec"] = "qsgd:8"
    with open(path, "w") as fh:
        for rec in lines:
            fh.write(json.dumps(rec) + "\n")
    rep_cfg = FFTConfig(codec="adaptive:sign1-fp16", trace_replay=path,
                        **BASE)
    with pytest.raises(ValueError, match="rungs"):
        make_toy_runner(rep_cfg, **TOY).run(STRATEGIES["fedavg"](), rounds=3)


def test_v2_trace_still_loads_and_replays_as_static(tmp_path):
    """A hand-written v2 trace (pre-adaptive schema) must load, expose no
    per-client codecs, and replay bit-exactly under its recorded codec."""
    from repro.fl.scenarios.trace import ReplayFailureModel
    path = str(tmp_path / "v2.ndjson")
    rows = [{"id": i, "capacity_bps": 8e6, "up": True, "duration_s": 1.5,
             "t_download_s": 0.1, "t_compute_s": 0.4, "t_upload_s": 1.0,
             "payload_bytes": 1e6, "selected": True, "met_deadline": True,
             "connected": True, "cause": "ok"} for i in range(2)]
    with open(path, "w") as fh:
        fh.write(json.dumps({"record": "header", "version": 2,
                             "scenario": "x", "n_clients": 2,
                             "codec": "int8", "model_bytes": 4e6,
                             "upload_bytes": 1e6, "deadline_s": 5.0}) + "\n")
        fh.write(json.dumps({"record": "round", "round": 1,
                             "deadline_s": 5.0, "duration_s": 1.5,
                             "clients": rows}) + "\n")
    m = ReplayFailureModel(path)
    assert m.codec == "int8"
    assert m.codecs(1) is None                         # static recording
    assert m.download_bytes(1) is None                 # predates downlink
    np.testing.assert_array_equal(m.draw(1), [True, True])
    np.testing.assert_array_equal(m.payload_bytes(1), [1e6, 1e6])


def test_adaptive_rejects_replay_of_v2_static_trace(tmp_path):
    """Adaptive replay of a static recording must fail on the codec guard:
    the recorded timings were priced at one static size."""
    path = str(tmp_path / "s.ndjson")
    cfg = FFTConfig(codec="int8", failure_mode="scenario:diurnal",
                    trace_record=path, **BASE)
    make_toy_runner(cfg, **TOY).run(STRATEGIES["fedavg"](), rounds=2)
    with pytest.raises(ValueError, match="codec"):
        make_toy_runner(FFTConfig(codec="adaptive:sign1-fp16",
                                  trace_replay=path, **BASE), **TOY)
