"""Model-zoo correctness beyond smoke: decode≡prefill consistency, SWA
semantics, MoE routing exactness, MLA absorbed decode, SSD chunking."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.models.moe import _moe_local, moe_init


def _fp32(cfg):
    return dataclasses.replace(cfg, dtype="float32")


def _decode_vs_forward(arch, S=24, B=2, tol=2e-3):
    """Feeding tokens one by one through decode must reproduce the training
    forward's next-token logits at every position."""
    cfg = _fp32(get_smoke_config(arch))
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    tokens = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.encoder_decoder:
        enc = jax.random.normal(key, (B, 8, cfg.d_model), jnp.float32)
        batch["encoder_embeds"] = enc
    h, _ = T.hidden_states(params, cfg, batch, q_chunk=8)
    w = (params["embed"]["embedding"].T if cfg.tie_embeddings
         else params["lm_head"]["embedding"].T)
    fwd_logits = np.asarray((h @ w).astype(jnp.float32))

    state = T.init_decode_state(
        params, cfg, B, S,
        encoder_embeds=batch.get("encoder_embeds"))
    step = jax.jit(lambda p, s, t: T.decode_step(p, cfg, s, t))
    for t in range(S):
        logits, state = step(params, state, tokens[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(logits), fwd_logits[:, t],
                                   rtol=tol, atol=tol, err_msg=f"{arch} t={t}")


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "starcoder2-7b", "gemma-7b",
                                  "codeqwen1.5-7b"])
def test_decode_matches_forward_dense(arch):
    _decode_vs_forward(arch)


def test_decode_matches_forward_mla():
    _decode_vs_forward("deepseek-v2-236b", tol=5e-3)


def test_decode_matches_forward_moe():
    _decode_vs_forward("mixtral-8x22b", tol=5e-3)


@pytest.mark.parametrize("arch", ["xlstm-125m", "zamba2-1.2b"])
def test_decode_matches_forward_recurrent(arch):
    _decode_vs_forward(arch, tol=5e-3)


def test_decode_matches_forward_encdec():
    _decode_vs_forward("seamless-m4t-large-v2", tol=2e-3)


def test_sliding_window_equals_full_when_window_large():
    cfg = _fp32(get_smoke_config("starcoder2-7b"))
    big = dataclasses.replace(cfg, sliding_window=4096)
    none = dataclasses.replace(cfg, sliding_window=None)
    key = jax.random.PRNGKey(2)
    params = T.init_params(key, big)
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size)}
    batch["labels"] = batch["tokens"]
    h1, _ = T.hidden_states(params, big, batch, q_chunk=16)
    h2, _ = T.hidden_states(params, none, batch, q_chunk=16)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-5,
                               atol=1e-5)


def test_sliding_window_blocks_long_range():
    """With window=4 the output at position t must not depend on tokens
    earlier than t-3."""
    cfg = dataclasses.replace(_fp32(get_smoke_config("starcoder2-7b")),
                              sliding_window=4, num_layers=1)
    key = jax.random.PRNGKey(3)
    params = T.init_params(key, cfg)
    t1 = jax.random.randint(key, (1, 16), 0, cfg.vocab_size)
    t2 = t1.at[0, 0].set((t1[0, 0] + 7) % cfg.vocab_size)   # perturb far past
    h1, _ = T.hidden_states(params, cfg, {"tokens": t1, "labels": t1}, q_chunk=8)
    h2, _ = T.hidden_states(params, cfg, {"tokens": t2, "labels": t2}, q_chunk=8)
    np.testing.assert_allclose(np.asarray(h1[:, 8:]), np.asarray(h2[:, 8:]),
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(h1[:, 0]), np.asarray(h2[:, 0]))


def test_moe_local_matches_dense_oracle():
    """Sort+ragged_dot MoE == explicit per-expert masked einsum."""
    cfg = _fp32(get_smoke_config("mixtral-8x22b"))
    key = jax.random.PRNGKey(4)
    p = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (32, cfg.d_model))
    got, aux = _moe_local(p, cfg, x)

    # oracle: run every expert densely, combine with the same gates
    logits = x @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    top_p, eids = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    gates = top_p / top_p.sum(-1, keepdims=True)
    want = jnp.zeros_like(x)
    for e in range(cfg.num_experts):
        g = jax.nn.silu(x @ p["w_gate"][e]) * (x @ p["w_up"][e])
        y_e = g @ p["w_down"][e]
        for k in range(cfg.num_experts_per_tok):
            sel = (eids[:, k] == e).astype(x.dtype) * gates[:, k]
            want = want + y_e * sel[:, None]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4,
                               atol=2e-4)


def test_q_chunking_invariance():
    cfg = _fp32(get_smoke_config("qwen3-1.7b"))
    key = jax.random.PRNGKey(5)
    params = T.init_params(key, cfg)
    batch = {"tokens": jax.random.randint(key, (2, 64), 0, cfg.vocab_size)}
    batch["labels"] = batch["tokens"]
    h1, _ = T.hidden_states(params, cfg, batch, q_chunk=64)
    h2, _ = T.hidden_states(params, cfg, batch, q_chunk=16)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-5,
                               atol=1e-5)


def test_loss_chunking_invariance():
    from repro.models.loss import chunked_cross_entropy, full_cross_entropy
    key = jax.random.PRNGKey(6)
    B, S, d, V = 2, 32, 16, 50
    h = jax.random.normal(key, (B, S, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (d, V))
    labels = jax.random.randint(jax.random.fold_in(key, 2), (B, S), -1, V)
    l1, _ = chunked_cross_entropy(h, w, labels, chunk=8)
    l2 = full_cross_entropy(h @ w, labels)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    g1 = jax.grad(lambda hh: chunked_cross_entropy(hh, w, labels, chunk=8)[0])(h)
    g2 = jax.grad(lambda hh: full_cross_entropy(hh @ w, labels))(h)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4,
                               atol=1e-6)
