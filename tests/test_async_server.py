"""Asynchronous-server subsystem tests (``repro.fl.server``).

Covers: staleness-buffer invariants (no double apply, staleness <= tau_max,
eviction, churn draining), FedAuto-Async weight properties mirroring
``test_qp_solver``, sync/async equivalence when the deadline is infinite,
bit-exact record -> replay of an async run, and legacy failure modes gaining
synthesized arrival timelines."""
import json
import math

import numpy as np
import pytest

from repro.core.aggregation import fedauto_async_weights, fedauto_weights
from repro.core.strategies import STRATEGIES
from repro.fl.runtime import FFTConfig
from repro.fl.server import (PendingUpdate, StalenessBuffer,
                             TimedFailureAdapter, make_round_loop)
from repro.fl.scenarios.trace import _num, _unnum


# ---------------------------------------------------------------------------
# StalenessBuffer invariants
# ---------------------------------------------------------------------------
def _upd(client, origin, arrival):
    return PendingUpdate(client=client, origin_round=origin,
                         arrival_s=arrival, model=f"m{client}_{origin}")


def test_buffer_no_update_applied_twice():
    buf = StalenessBuffer(tau_max=3)
    buf.push(_upd(0, 1, 5.0))
    with pytest.raises(ValueError, match="twice"):
        buf.push(_upd(0, 1, 6.0))
    got = buf.collect(now_s=10.0, current_round=2)
    assert [e.client for e in got] == [0]
    assert buf.collect(now_s=100.0, current_round=3) == []   # gone for good


def test_buffer_collect_orders_by_arrival_and_respects_now():
    buf = StalenessBuffer(tau_max=5)
    buf.push(_upd(2, 1, 9.0))
    buf.push(_upd(1, 1, 4.0))
    buf.push(_upd(3, 1, 30.0))                               # lands later
    got = buf.collect(now_s=10.0, current_round=2)
    assert [e.client for e in got] == [1, 2]
    assert len(buf) == 1                                     # 3 still in flight
    assert buf.collect(now_s=31.0, current_round=3)[0].client == 3


def test_buffer_staleness_bounded_by_tau_max():
    buf = StalenessBuffer(tau_max=2)
    buf.push(_upd(0, 1, 1.0))
    buf.push(_upd(1, 1, 2.0))
    # round 5: staleness 4 > tau_max -> evicted, never applied
    got = buf.collect(now_s=100.0, current_round=5)
    assert got == [] and len(buf) == 0
    assert buf.n_evicted == 2
    buf.push(_upd(2, 5, 3.0))
    got = buf.collect(now_s=100.0, current_round=7)
    assert [e.staleness(7) for e in got] == [2]              # == tau_max: kept


def test_buffer_evict_and_ready_count():
    buf = StalenessBuffer(tau_max=2)
    buf.push(_upd(0, 1, 1.0))
    buf.push(_upd(1, 3, 2.0))
    buf.push(_upd(2, 3, 99.0))
    # landed & fresh: only (1, origin 3) — client 0 is beyond tau_max,
    # client 2 is still in flight
    assert buf.ready_count(now_s=10.0, current_round=4) == 1
    assert buf.evict(current_round=4) == 1                   # origin 1 too old
    assert sorted(e.client for e in buf.pending()) == [1, 2]


def test_buffer_drained_on_churn():
    buf = StalenessBuffer(tau_max=4)
    for origin in [1, 2, 3]:
        buf.push(_upd(7, origin, 10.0 * origin))
    buf.push(_upd(3, 2, 5.0))
    assert buf.drop_client(7) == 3
    assert [e.client for e in buf.pending()] == [3]


def test_buffer_rejects_negative_tau():
    with pytest.raises(ValueError, match="tau_max"):
        StalenessBuffer(tau_max=-1)


# ---------------------------------------------------------------------------
# FedAuto-Async weights: simplex / pin / discount (mirrors test_qp_solver)
# ---------------------------------------------------------------------------
def _rows(rng, J, C):
    alpha = rng.dirichlet(np.ones(C) * 0.5, size=J)
    p = rng.dirichlet(np.ones(J))
    return alpha, p @ alpha


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_fedauto_async_weights_feasibility_and_pin(seed):
    rng = np.random.default_rng(seed)
    J, C = 4 + seed, 5 + seed
    alpha, alpha_g = _rows(rng, J, C)
    staleness = rng.integers(0, 4, J)
    staleness[0] = 0
    beta = fedauto_async_weights(alpha, alpha_g, staleness, server_row=0)
    assert np.all(beta >= -1e-6)
    assert abs(beta.sum() - 1.0) < 1e-4
    # Eq. 9 pin survives the staleness discount: beta_s = 1/(1+m)
    assert abs(beta[0] - 1.0 / J) < 1e-4


def test_fedauto_async_weights_fresh_equals_sync():
    rng = np.random.default_rng(5)
    alpha, alpha_g = _rows(rng, 6, 8)
    sync = fedauto_weights(alpha, alpha_g, np.ones(6, bool), server_row=0)
    fresh = fedauto_async_weights(alpha, alpha_g, np.zeros(6, int),
                                  server_row=0)
    np.testing.assert_array_equal(sync, fresh)               # bit-identical


def test_fedauto_async_weights_discount_is_monotone():
    """Two participants with the *same* alpha row and different staleness:
    the staler one must never get more weight."""
    rng = np.random.default_rng(8)
    C = 6
    row = rng.dirichlet(np.ones(C))
    alpha = np.stack([rng.dirichlet(np.ones(C)), row, row])
    alpha_g = np.array([0.3, 0.3, 0.4]) @ alpha
    beta = fedauto_async_weights(alpha, alpha_g, np.array([0, 0, 3]),
                                 server_row=0)
    assert beta[2] < beta[1]
    even = fedauto_async_weights(alpha, alpha_g, np.array([0, 2, 2]),
                                 server_row=0)
    assert abs(even[1] - even[2]) < 1e-5                     # equal discount


# ---------------------------------------------------------------------------
# server loops on the toy problem
# ---------------------------------------------------------------------------
BASE = dict(n_clients=6, k_selected=6, local_steps=2, batch_size=8, lr=0.05,
            seed=0, eval_every=2, model_bytes=0.2e6)


def _tiny(cfg):
    from repro.fl.toy import make_toy_runner
    return make_toy_runner(cfg, n_samples=600, public_per_class=10,
                           pretrain_steps=9)


@pytest.mark.parametrize("sync_name,async_name",
                         [("fedavg", "fedavg"),
                          ("fedauto", "fedauto_async")])
def test_sync_async_equivalent_under_infinite_deadline(sync_name, async_name):
    """With no deadline pressure nothing is ever late, so the async server
    degenerates to the synchronous one — identical accuracy histories."""
    hist = {}
    for mode, name in [("sync", sync_name), ("async", async_name)]:
        cfg = FFTConfig(failure_mode="scenario:correlated_wifi",
                        deadline_s=1e9, server_mode=mode, **BASE)
        hist[mode] = _tiny(cfg).run(STRATEGIES[name](), rounds=3)
    assert hist["sync"] == hist["async"]


def test_async_applies_stale_updates_under_tight_deadline():
    cfg = FFTConfig(failure_mode="scenario:diurnal", deadline_s=2.0,
                    server_mode="async", tau_max=4, **BASE)
    runner = _tiny(cfg)
    runner.run(STRATEGIES["fedauto_async"](), rounds=6)
    applied = runner.loop.staleness_applied
    assert applied and max(applied) > 0                      # real staleness
    assert max(applied) <= cfg.tau_max                       # bounded by it
    # every pending upload left in the buffer is still within its horizon
    for e in runner.loop.buffer.pending():
        assert e.staleness(6) <= cfg.tau_max
    # wall-clock timeline is populated and strictly advancing
    ts = [t.t_s for t in runner.timeline]
    assert ts == sorted(ts) and ts[0] > 0.0


def test_async_record_then_replay_bit_exact(tmp_path):
    """Acceptance: an async run replayed from its recorded trace is
    bit-exact — across live vs replay AND across two replays."""
    path = str(tmp_path / "async.ndjson")
    cfg = FFTConfig(failure_mode="scenario:diurnal", deadline_s=2.0,
                    server_mode="async", tau_max=4, trace_record=path, **BASE)
    live = _tiny(cfg).run(STRATEGIES["fedauto_async"](), rounds=4)
    rep_cfg = FFTConfig(failure_mode="scenario:diurnal", deadline_s=2.0,
                        server_mode="async", tau_max=4, trace_replay=path,
                        **BASE)
    rep1 = _tiny(rep_cfg).run(STRATEGIES["fedauto_async"](), rounds=4)
    rep2 = _tiny(rep_cfg).run(STRATEGIES["fedauto_async"](), rounds=4)
    assert rep1 == rep2 == live


def test_buffered_mode_defers_until_k_arrivals():
    cfg = FFTConfig(failure_mode="scenario:diurnal", deadline_s=2.0,
                    server_mode="buffered", tau_max=4, buffer_k=4, **BASE)
    runner = _tiny(cfg)
    hist = runner.run(STRATEGIES["fedbuff"](buffer_k=1), rounds=6)
    assert len(hist) == 3
    # deferred rounds still advance the simulated clock
    assert runner.timeline[-1].t_s > 0.0


@pytest.mark.parametrize("failure_mode",
                         ["none", "transient", "intermittent", "mixed"])
def test_async_works_with_legacy_failure_modes(failure_mode):
    """Non-scenario modes synthesize arrival timelines via
    TimedFailureAdapter, so server_mode='async' works for every mode."""
    cfg = FFTConfig(failure_mode=failure_mode, deadline_s=6.0,
                    server_mode="async", tau_max=3, **BASE)
    runner = _tiny(cfg)
    assert isinstance(runner.failures, TimedFailureAdapter)
    hist = runner.run(STRATEGIES["fedasync"](), rounds=3)
    assert len(hist) == 2 and all(0.0 <= a <= 1.0 for a in hist)
    ev = runner.failures.draw_events(1)
    assert len(ev.events) == cfg.n_clients
    # adapter caches: repeated draws replay the realization
    np.testing.assert_array_equal(runner.failures.draw(2),
                                  runner.failures.draw(2))


def test_async_rejects_timing_less_trace(tmp_path):
    """A trace recorded from a legacy boolean mode has no arrival times
    (duration_s null -> finish_s inf); replaying it async must fail loudly
    instead of silently training on server data alone."""
    path = str(tmp_path / "legacy.ndjson")
    rec_cfg = FFTConfig(failure_mode="intermittent", server_mode="sync",
                        trace_record=path, **BASE)
    _tiny(rec_cfg).run(STRATEGIES["fedavg"](), rounds=2)
    rep_cfg = FFTConfig(failure_mode="intermittent", server_mode="async",
                        trace_replay=path, **BASE)
    with pytest.raises(RuntimeError, match="timing"):
        _tiny(rep_cfg).run(STRATEGIES["fedasync"](), rounds=2)


def test_buffered_deferral_does_not_age_fresh_updates():
    """Staleness that discounts an update is *global-model version* lag:
    rounds the buffered server skipped (no aggregation) don't count."""
    cfg = FFTConfig(failure_mode="scenario:diurnal", deadline_s=2.0,
                    server_mode="buffered", tau_max=4, buffer_k=6, **BASE)
    runner = _tiny(cfg)
    runner.run(STRATEGIES["fedauto_async"](), rounds=6)
    loop = runner.loop
    # aggregation steps happened at most once per round, some rounds deferred
    assert loop.version <= 6
    for s in loop.staleness_applied:
        assert 0 <= s <= loop.version


def test_legacy_sync_mode_keeps_boolean_models_unwrapped():
    cfg = FFTConfig(failure_mode="mixed", server_mode="sync", **BASE)
    runner = _tiny(cfg)
    assert not isinstance(runner.failures, TimedFailureAdapter)


def test_async_strategy_runs_under_sync_server():
    """AsyncStrategy.aggregate adapts the cohort to staleness-0 arrivals."""
    cfg = FFTConfig(failure_mode="scenario:correlated_wifi", deadline_s=8.0,
                    server_mode="sync", **BASE)
    hist = _tiny(cfg).run(STRATEGIES["fedasync"](), rounds=3)
    assert len(hist) == 2


def test_unknown_server_mode_rejected():
    with pytest.raises(ValueError, match="server_mode"):
        _tiny(FFTConfig(server_mode="warp", **BASE))
    with pytest.raises(ValueError, match="server_mode"):
        make_round_loop("warp", None, None)


# ---------------------------------------------------------------------------
# trace float encoding: lossless inf/nan round-trip (deterministic version;
# the hypothesis sweep lives in test_hypothesis_properties.py)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("x", [0.0, -1.5, 3.25e9, math.inf, -math.inf])
def test_num_unnum_round_trip(x):
    encoded = json.loads(json.dumps(_num(x)))
    assert _unnum(encoded) == x


def test_num_unnum_nan_and_none():
    assert math.isnan(_unnum(json.loads(json.dumps(_num(math.nan)))))
    assert _unnum(_num(None)) is None


def test_trace_round_trips_phase_times(tmp_path):
    """Per-phase times (download/compute/upload) and landing instants of
    *late* uploads survive record -> load -> draw_events."""
    from repro.fl.scenarios import (ReplayFailureModel, TraceRecorder,
                                    make_scenario_model)
    path = str(tmp_path / "t.ndjson")
    m = make_scenario_model("diurnal", 8, model_bytes=0.2e6, deadline_s=2.0,
                            seed=0)
    sel = np.ones(8, dtype=bool)
    with TraceRecorder(path, {"scenario": "scenario:diurnal",
                              "n_clients": 8, "deadline_s": 2.0}) as rec:
        for r in range(1, 6):
            ev = m.draw_events(r)
            rec.write_round(r, sel, ev.connected_mask(), ev)
    replay = ReplayFailureModel(path, n_clients=8)
    m.reset()
    for r in range(1, 6):
        want, got = m.draw_events(r), replay.draw_events(r)
        for we, ge in zip(want.events, got.events):
            assert ge.finish_s == we.finish_s                # incl. inf, late
            assert ge.t_download_s == we.t_download_s
            assert ge.t_compute_s == we.t_compute_s
            assert ge.t_upload_s == we.t_upload_s
        np.testing.assert_array_equal(want.late_mask(), got.late_mask())
