"""Streaming fused aggregation (ISSUE 10).

Covers: batched decode-and-accumulate kernel parity with the per-payload
decode + β-weighted-sum reference (bit-exact under ``ref`` dispatch, tight
tolerance under Pallas interpret) across the full rung ladder, mixed-rung
cohorts, K=1 and empty cohorts; ``StreamAccumulator`` semantics (batch
flushing, O(1) peak decoded memory, fallback attribution);
``CommState.encode_upload``/``decode_upload`` vs ``roundtrip``; streaming
vs materializing round loops producing matching global params across a
world × server-mode sweep; and the paged broadcast cache.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.strategies import (STRATEGIES, FedAsync, FedAvg, FedBuff,
                                   Scaffold)
from repro.fl.comm import CommState, make_codec
from repro.fl.comm.codecs import EncodedLeaf, Payload
from repro.fl.comm.stream import (FUSED_FAMILIES, PackedUpdate,
                                  StreamAccumulator, payload_family,
                                  weighted_model_sum, weighted_tree_sum)
from repro.fl.runtime import FFTConfig
from repro.fl.toy import make_toy_runner
from repro.kernels import ops as kops
from repro.launch.serve import PagedBroadcastCache

LADDER = ["sign1", "qsgd:2", "qsgd:4", "qsgd:8", "int8", "fp16", "fp32",
          "topk:0.25"]


def _tree(seed=0, shapes=((33, 5), (17,), (4, 9))):
    rng = np.random.default_rng(seed)
    return {f"l{i}": jnp.asarray(rng.normal(size=s), jnp.float32)
            for i, s in enumerate(shapes)}


def _payloads(spec, k, seed=0):
    codec = make_codec(spec)
    return codec, [codec.encode(_tree(seed + 10 * m)) for m in range(k)]


def _betas(k, seed=0):
    rng = np.random.default_rng(seed + 99)
    w = rng.uniform(0.1, 1.0, k)
    return (w / w.sum()).astype(np.float32)


def _fold_reference(codec, payloads, betas, template):
    """Per-payload decode + sequential β-weighted sum — the unfused oracle
    the streaming accumulator must match."""
    out = jax.tree.map(lambda l: jnp.zeros(l.shape, jnp.float32), template)
    for p, b in zip(payloads, betas):
        d = codec.decode(p)
        out = jax.tree.map(lambda o, x, b=b: o + jnp.float32(b) * x, out, d)
    return out


def _stack_reference(codec, payloads, betas, template):
    """Per-payload decode, stack, einsum — bit-identical to the batched
    ``ref`` kernels for the quant/float families."""
    decoded = [codec.decode(p) for p in payloads]
    b = jnp.asarray(betas, jnp.float32)
    return jax.tree.map(
        lambda *ls: jnp.einsum(
            "mp,m->p", jnp.stack([l.reshape(-1) for l in ls]), b
        ).reshape(ls[0].shape), *decoded)


def _maxdiff(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.fixture
def ref_mode():
    kops.set_mode("off")
    yield
    kops.set_mode("off")


# ---------------------------------------------------------------------------
# payload_family
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("spec", LADDER)
def test_every_ladder_rung_has_a_fused_family(spec):
    codec, (p,) = _payloads(spec, 1)
    fam = payload_family(p)
    assert fam is not None
    assert fam in FUSED_FAMILIES or fam.startswith("topk:")


def test_foreign_payload_layout_has_no_family():
    codec, (p,) = _payloads("fp32", 1)
    el0 = p.leaves[0]
    weird = dataclasses.replace(el0, data={**el0.data, "extra": 0})
    p2 = Payload(codec=p.codec, leaves=[weird] + p.leaves[1:],
                 treedef=p.treedef, nbytes=p.nbytes)
    assert payload_family(p2) is None


# ---------------------------------------------------------------------------
# kernel parity: batched == per-payload decode + β-weighted sum
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("spec", LADDER)
@pytest.mark.parametrize("k", [1, 5])
def test_batched_ref_kernel_bitexact(ref_mode, spec, k):
    """The batched ``ref`` kernels are bit-identical to the unfused oracle
    on every rung: einsum over stacked per-payload decodes (quant/float
    families) or the sequential scatter fold (topk)."""
    from repro.kernels import ref
    codec, payloads = _payloads(spec, k)
    betas = jnp.asarray(_betas(k))
    template = _tree()
    leaves = jax.tree.leaves(template)
    want = (_fold_reference(codec, payloads, betas, template)
            if spec.startswith("topk")
            else _stack_reference(codec, payloads, betas, template))
    for li, (leaf, w) in enumerate(zip(leaves, jax.tree.leaves(want))):
        els = [p.leaves[li] for p in payloads]
        keys = set(els[0].data)
        if keys == {"q", "scale"}:
            got = ref.dequant_fedagg(
                jnp.stack([e.data["q"].reshape(-1) for e in els]),
                jnp.stack([jnp.asarray(e.data["scale"], jnp.float32)
                           for e in els]), betas)
        elif keys == {"v"}:
            got = ref.float_fedagg(
                jnp.stack([e.data["v"].reshape(-1) for e in els]), betas)
        else:
            got = ref.topk_fedagg(
                jnp.stack([e.data["idx"] for e in els]),
                jnp.stack([e.data["val"] for e in els]), betas,
                int(np.prod(leaf.shape)))
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(w).reshape(-1))


@pytest.mark.parametrize("spec", LADDER)
@pytest.mark.parametrize("k", [1, 5])
def test_stream_accumulator_matches_decode_reference(ref_mode, spec, k):
    """The accumulator (jitted flush) against the eager unfused oracle —
    tight tolerance; XLA fusion may reassociate the reduction."""
    codec, payloads = _payloads(spec, k)
    betas = _betas(k)
    acc = StreamAccumulator(_tree(), batch_k=64)
    for p, b in zip(payloads, betas):
        acc.add(p, b)
    got = acc.total()
    want = _fold_reference(codec, payloads, betas, _tree())
    assert _maxdiff(got, want) < 1e-6
    assert acc.n_fused == k and acc.n_fallback == 0


@pytest.mark.parametrize("spec", ["int8", "qsgd:4", "fp16"])
def test_stream_interpret_matches_reference(spec):
    """Pallas kernels (interpret mode on CPU) against the same oracle."""
    kops.set_mode("interpret")
    try:
        codec, payloads = _payloads(spec, 6)
        betas = _betas(6)
        acc = StreamAccumulator(_tree(), batch_k=64)
        for p, b in zip(payloads, betas):
            acc.add(p, b)
        got = acc.total()
    finally:
        kops.set_mode("off")
    want = _fold_reference(codec, payloads, betas, _tree())
    assert _maxdiff(got, want) < 1e-5


def test_mixed_rung_cohort(ref_mode):
    """One payload per rung, all feeding the same accumulator."""
    template = _tree()
    payloads = [(make_codec(s), make_codec(s).encode(_tree(7 * i + 1)))
                for i, s in enumerate(LADDER)]
    betas = _betas(len(payloads))
    acc = StreamAccumulator(template, batch_k=64)
    for (codec, p), b in zip(payloads, betas):
        acc.add(p, b)
    got = acc.total()
    want = jax.tree.map(lambda l: jnp.zeros(l.shape, jnp.float32), template)
    for (codec, p), b in zip(payloads, betas):
        d = codec.decode(p)
        want = jax.tree.map(lambda o, x, b=b: o + jnp.float32(b) * x, want, d)
    assert _maxdiff(got, want) < 1e-5
    assert acc.n_fused == len(payloads)


def test_empty_cohort_is_exact_zeros(ref_mode):
    acc = StreamAccumulator(_tree())
    out = acc.total()
    for l in jax.tree.leaves(out):
        np.testing.assert_array_equal(np.asarray(l), 0.0)
    assert acc.stats["added"] == 0


def test_fallback_payload_still_accumulates(ref_mode):
    """A payload with no batched kernel decodes alone into the accumulator
    and is counted in the fallback attribution."""
    codec, payloads = _payloads("fp32", 3)
    betas = _betas(3)
    # disguise one payload's layout so the family probe fails; the fp32
    # decode ignores the extra key, so the value path is unchanged
    el0 = payloads[1].leaves[0]
    payloads[1] = Payload(
        codec=payloads[1].codec,
        leaves=[dataclasses.replace(el0, data={**el0.data, "x": 0})]
               + payloads[1].leaves[1:],
        treedef=payloads[1].treedef, nbytes=payloads[1].nbytes)
    acc = StreamAccumulator(_tree(), batch_k=64)
    for p, b in zip(payloads, betas):
        acc.add(p, b)
    got = acc.total()
    want = _fold_reference(codec, payloads, betas, _tree())
    assert _maxdiff(got, want) < 1e-6
    assert acc.n_fallback == 1 and acc.n_fused == 2


# ---------------------------------------------------------------------------
# O(1) peak decoded memory
# ---------------------------------------------------------------------------
def test_peak_decoded_bytes_independent_of_k(ref_mode):
    template = _tree()
    acc_bytes = sum(4 * int(np.prod(l.shape))
                    for l in jax.tree.leaves(template))

    def peak(k):
        codec, payloads = _payloads("int8", k)
        acc = StreamAccumulator(template, batch_k=8)
        for p, b in zip(payloads, _betas(k)):
            acc.add(p, b)
        acc.total()
        return acc.peak_decoded_bytes, acc.n_flushes

    p8, f8 = peak(8)
    p64, f64 = peak(64)
    assert p8 == p64                       # O(1) in K
    assert p64 <= 2 * acc_bytes            # accumulator + one partial leaf
    assert f64 >= 8                        # batches actually flushed


# ---------------------------------------------------------------------------
# weighted sums
# ---------------------------------------------------------------------------
def test_weighted_tree_sum(ref_mode):
    trees = [_tree(i) for i in range(3)]
    w = [0.2, 0.5, 0.3]
    got = weighted_tree_sum(trees, w)
    want = jax.tree.map(
        lambda a, b, c: 0.2 * a + 0.5 * b + 0.3 * c, *trees)
    assert _maxdiff(got, want) < 1e-6


def test_weighted_model_sum_groups_origins(ref_mode):
    """Σ β(origin + decode) with two distinct shared origin pytrees must
    match the materialized per-model sum."""
    template = _tree()
    origin_a, origin_b = _tree(100), _tree(200)
    codec = make_codec("int8")
    packed = []
    for m in range(6):
        origin = origin_a if m < 4 else origin_b
        p = codec.encode(_tree(m + 1))
        packed.append(PackedUpdate(client=m, payload=p, origin_global=origin,
                                   codec="int8", nbytes=p.nbytes,
                                   distortion=0.0))
    betas = _betas(6)
    extra = _tree(300)
    got = weighted_model_sum(list(zip(betas, packed)),
                             dense_terms=[(0.25, extra)], template=template)
    want = jax.tree.map(lambda l: 0.25 * l.astype(jnp.float32), extra)
    for b, pu in zip(betas, packed):
        model = jax.tree.map(
            lambda g, d: g.astype(jnp.float32) + d,
            pu.origin_global, codec.decode(pu.payload))
        want = jax.tree.map(lambda o, x, b=b: o + jnp.float32(b) * x,
                            want, model)
    assert _maxdiff(got, want) < 1e-5


def test_weighted_model_sum_empty_cohort(ref_mode):
    template = _tree()
    anchor = _tree(5)
    got = weighted_model_sum([], dense_terms=[(1.0, anchor)],
                             template=template)
    assert _maxdiff(got, anchor) < 1e-6


# ---------------------------------------------------------------------------
# CommState encode/decode split
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("spec", ["int8", "qsgd:4", "fp16", "sign1"])
def test_encode_decode_split_matches_roundtrip(spec):
    """encode_upload + decode_upload reproduces roundtrip bit-exactly,
    including the error-feedback residual evolution across two uploads."""
    template = _tree()
    comm_a = CommState(make_codec(spec), template, n_clients=2)
    comm_b = CommState(make_codec(spec), template, n_clients=2)
    g = _tree(1)
    for step in range(2):
        m = _tree(50 + step)
        recon_a, _p, dist_a = comm_a.roundtrip(0, m, g)
        payload, dist_b = comm_b.encode_upload(0, m, g)
        recon_b = comm_b.decode_upload(payload, g)
        assert dist_a == dist_b
        assert _maxdiff(recon_a, recon_b) == 0.0
    assert comm_a.total_uplink_bytes == comm_b.total_uplink_bytes


# ---------------------------------------------------------------------------
# streaming vs materializing round loops (world × server-mode sweep)
# ---------------------------------------------------------------------------
def _paired_run(server_mode, strategy_fn, codec, rounds=2):
    def run(streaming):
        cfg = FFTConfig(n_clients=8, k_selected=8, local_steps=2,
                        batch_size=16, lr=0.05, failure_mode="mixed",
                        seed=3, eval_every=100, model_bytes=0.2e6,
                        tx_delay_s=0.8, server_mode=server_mode,
                        codec=codec, streaming_agg=streaming,
                        telemetry=True)
        r = make_toy_runner(cfg, n_samples=600, pretrain_steps=5)
        r.run(strategy_fn(), rounds=rounds)
        return r
    return run("auto"), run("off")


@pytest.mark.parametrize("server_mode,strategy_fn,codec", [
    ("sync", FedAvg, "int8"),
    ("async", FedAsync, "qsgd:4"),
    ("buffered", FedBuff, "sign1"),
])
def test_streaming_matches_materializing_global_params(server_mode,
                                                       strategy_fn, codec):
    r_stream, r_mat = _paired_run(server_mode, strategy_fn, codec)
    assert _maxdiff(r_stream.global_params, r_mat.global_params) < 1e-3

    # uplink_decode attribution: the streaming run fused its payloads, the
    # materializing control arm reported its decoded-model count
    def gauges(r):
        return [rec["gauges"] for rec in r.report.rounds]
    assert any(g.get("uplink_fused_payloads", 0) > 0 for g in gauges(r_stream))
    assert all(g.get("uplink_fallback_payloads", 0) == 0
               for g in gauges(r_stream) if "uplink_fallback_payloads" in g)
    mat = [g for g in gauges(r_mat) if "uplink_fallback_payloads" in g]
    assert mat and any(g["uplink_fallback_payloads"] > 0 for g in mat)
    # O(1) vs O(K): the streaming peak never exceeds the materializing one
    peaks_s = [g["uplink_peak_decoded_bytes"] for g in gauges(r_stream)
               if "uplink_peak_decoded_bytes" in g]
    peaks_m = [g["uplink_peak_decoded_bytes"] for g in mat
               if g["uplink_fallback_payloads"] > 1]
    if peaks_s and peaks_m:
        assert max(peaks_s) <= max(peaks_m)


def test_materializing_strategy_ignores_streaming_flag():
    """A strategy without streaming support (Scaffold) runs the documented
    materializing fallback even under streaming_agg='auto'."""
    cfg = FFTConfig(n_clients=8, k_selected=8, local_steps=2, batch_size=16,
                    lr=0.05, failure_mode="none", seed=3, eval_every=100,
                    model_bytes=0.2e6, streaming_agg="auto", telemetry=True)
    r = make_toy_runner(cfg, n_samples=600, pretrain_steps=5)
    hist = r.run(Scaffold(), rounds=2)
    assert 0.0 <= hist[-1] <= 1.0
    gauges = [rec["gauges"] for rec in r.report.rounds]
    assert any(g.get("uplink_fallback_payloads", 0) > 0 for g in gauges)


def test_streaming_agg_knob_validated():
    with pytest.raises(ValueError, match="streaming_agg"):
        cfg = FFTConfig(n_clients=4, k_selected=4, streaming_agg="sideways")
        make_toy_runner(cfg, n_samples=200, pretrain_steps=0)


# ---------------------------------------------------------------------------
# paged broadcast cache
# ---------------------------------------------------------------------------
def test_paged_cache_encodes_once_per_round_and_rung():
    codec = make_codec("int8")
    tree = _tree()
    calls = []

    def enc():
        calls.append(1)
        return codec.encode(tree)

    cache = PagedBroadcastCache(page_bytes=64, keep_rounds=2)
    for _client in range(5):
        pages = cache.serve(1, "int8", enc)
    assert len(calls) == 1
    assert cache.hits == 4 and cache.misses == 1
    # pages reassemble to the exact wire bytes of the payload
    payload = cache.payload_for(1, "int8")
    blob = b"".join(np.asarray(v).tobytes()
                    for el in payload.leaves for v in el.data.values())
    assert b"".join(p.tobytes() for p in pages) == blob
    assert all(p.nbytes <= 64 for p in pages)


def test_paged_cache_evicts_old_rounds():
    codec = make_codec("sign1")
    tree = _tree()
    cache = PagedBroadcastCache(page_bytes=256, keep_rounds=2)
    for rnd in range(1, 5):
        cache.serve(rnd, "sign1", lambda: codec.encode(tree))
    assert cache.evictions == 2
    assert cache.payload_for(1, "sign1") is None
    assert cache.payload_for(4, "sign1") is not None
    assert cache.peak_pages >= cache.n_pages


def test_paged_cache_rejects_bad_config():
    with pytest.raises(ValueError):
        PagedBroadcastCache(page_bytes=0)
    with pytest.raises(ValueError):
        PagedBroadcastCache(keep_rounds=0)
