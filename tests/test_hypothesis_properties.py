"""All hypothesis property tests, gated behind ``pytest.importorskip`` so
the rest of the suite collects and runs on environments without hypothesis
(install it via ``pip install -r requirements-dev.txt``).

Moved here from test_fl_system / test_qp_solver / test_kernels, which keep
deterministic variants of the same invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.aggregation import (fedauto_async_weights,  # noqa: E402
                                    fedauto_discounted_weights,
                                    fedauto_weights)
from repro.core.weights_qp import (chi2_effective, project_simplex,  # noqa: E402
                                   solve_weights)
from repro.fl.comm import (AdaptiveCommController, CommState,  # noqa: E402
                           RUNG_LADDER, make_codec)
from repro.fl.partition import partition  # noqa: E402
from repro.fl.scenarios.engine import (DeadlineSimulator,  # noqa: E402
                                       LinkState)
from repro.fl.scenarios.trace import _num, _unnum  # noqa: E402
from repro.kernels.dequant_agg import dequant_fedagg  # noqa: E402
from repro.kernels.fedagg import fedagg  # noqa: E402


# ---------------------------------------------------------------------------
# partitioner invariants (from test_fl_system)
# ---------------------------------------------------------------------------
@given(st.integers(0, 1000), st.sampled_from(["iid", "group_classes",
                                              "dirichlet"]))
@settings(max_examples=20, deadline=None)
def test_partition_invariants(seed, mode):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, 400).astype(np.int64)
    parts, hists = partition(mode, labels, 20, 10, classes_per_group=2,
                             seed=seed)
    assert len(parts) == 20
    all_idx = np.concatenate([p for p in parts if len(p)])
    assert len(np.unique(all_idx)) == len(all_idx)        # no duplicates
    assert hists.sum() == len(all_idx)
    for p_, h in zip(parts, hists):
        if len(p_):
            np.testing.assert_array_equal(
                np.bincount(labels[p_], minlength=10), h)
    if mode == "group_classes":
        for i, h in enumerate(hists):                     # ≤2 classes each
            assert (h > 0).sum() <= 2
    if mode == "iid":
        assert len(all_idx) == 400                        # covers everything


# ---------------------------------------------------------------------------
# QP solver invariants (from test_qp_solver)
# ---------------------------------------------------------------------------
def _random_problem(rng, J, C):
    alpha = rng.dirichlet(np.ones(C) * 0.5, size=J)
    p = rng.dirichlet(np.ones(J))
    alpha_g = p @ alpha
    return alpha, alpha_g


@st.composite
def qp_problems(draw):
    seed = draw(st.integers(0, 2 ** 31 - 1))
    J = draw(st.integers(2, 12))
    C = draw(st.integers(2, 20))
    n_active = draw(st.integers(1, J))
    rng = np.random.default_rng(seed)
    alpha, alpha_g = _random_problem(rng, J, C)
    mask = np.zeros(J, dtype=bool)
    mask[rng.choice(J, n_active, replace=False)] = True
    mask[0] = True                      # server always present
    return alpha, alpha_g, mask


@given(qp_problems())
@settings(max_examples=25, deadline=None)
def test_solver_feasibility(problem):
    alpha, alpha_g, mask = problem
    beta = np.asarray(solve_weights(jnp.asarray(alpha), jnp.asarray(alpha_g),
                                    jnp.asarray(mask)))
    assert np.all(beta >= -1e-6)
    assert abs(beta.sum() - 1.0) < 1e-4
    assert np.all(beta[~mask] <= 1e-6)          # Eq. (10c)


@given(qp_problems())
@settings(max_examples=15, deadline=None)
def test_solver_no_worse_than_uniform(problem):
    alpha, alpha_g, mask = problem
    beta = np.asarray(solve_weights(jnp.asarray(alpha), jnp.asarray(alpha_g),
                                    jnp.asarray(mask)))
    uni = np.where(mask, 1.0 / mask.sum(), 0.0)
    f_beta = float(chi2_effective(jnp.asarray(beta), jnp.asarray(alpha),
                                  jnp.asarray(alpha_g)))
    f_uni = float(chi2_effective(jnp.asarray(uni), jnp.asarray(alpha),
                                 jnp.asarray(alpha_g)))
    assert f_beta <= f_uni + 1e-5


@st.composite
def discount_problems(draw):
    seed = draw(st.integers(0, 2 ** 31 - 1))
    J = draw(st.integers(2, 10))
    C = draw(st.integers(2, 12))
    b = draw(st.floats(0.0, 4.0))
    rng = np.random.default_rng(seed)
    alpha, alpha_g = _random_problem(rng, J, C)
    staleness = rng.integers(0, 5, J).astype(float)
    staleness[0] = 0.0
    distortion = rng.uniform(0.0, 1.0, J)
    distortion[0] = 0.0
    return alpha, alpha_g, staleness, distortion, b


@given(discount_problems())
@settings(max_examples=25, deadline=None)
def test_discounted_weights_simplex_and_pin_property(problem):
    """Eq. 8/9 invariants survive the staleness × fidelity discount: β on
    the simplex, server pin β_s = 1/(1+m) intact."""
    alpha, alpha_g, staleness, distortion, b = problem
    beta = fedauto_discounted_weights(alpha, alpha_g, staleness, distortion,
                                      server_row=0, discount_b=b)
    assert np.all(beta >= -1e-6)
    assert abs(beta.sum() - 1.0) < 1e-4
    assert abs(beta[0] - 1.0 / len(alpha)) < 1e-4


@given(discount_problems())
@settings(max_examples=25, deadline=None)
def test_discounted_weights_zero_distortion_reductions(problem):
    """At zero distortion the pipeline is bit-exact with the staleness-only
    solution, and additionally with the sync QP when everything is fresh."""
    alpha, alpha_g, staleness, _, b = problem
    zeros = np.zeros(len(alpha))
    got = fedauto_discounted_weights(alpha, alpha_g, staleness, zeros,
                                     server_row=0, discount_b=b)
    want = fedauto_async_weights(alpha, alpha_g, staleness, server_row=0)
    np.testing.assert_array_equal(got, want)
    fresh = fedauto_discounted_weights(alpha, alpha_g, zeros, zeros,
                                       server_row=0, discount_b=b)
    sync = fedauto_weights(alpha, alpha_g, np.ones(len(alpha), bool),
                           server_row=0)
    np.testing.assert_array_equal(fresh, sync)


@given(discount_problems(), st.integers(1, 9), st.floats(0.0, 1.0))
@settings(max_examples=25, deadline=None)
def test_discounted_weights_monotone_in_distortion_property(problem, j, bump):
    """Raising one participant's distortion (all else equal) must never
    raise its own weight."""
    alpha, alpha_g, staleness, distortion, b = problem
    j = j % len(alpha)
    if j == 0:
        j = len(alpha) - 1
    lo = fedauto_discounted_weights(alpha, alpha_g, staleness, distortion,
                                    server_row=0, discount_b=b)
    worse = distortion.copy()
    worse[j] = min(worse[j] + bump * (1.0 - worse[j]), 1.0)
    hi = fedauto_discounted_weights(alpha, alpha_g, staleness, worse,
                                    server_row=0, discount_b=b)
    assert hi[j] <= lo[j] + 1e-9
    assert abs(hi[0] - lo[0]) < 1e-9               # pin untouched


@given(st.integers(0, 10_000), st.integers(2, 16))
@settings(max_examples=30, deadline=None)
def test_simplex_projection_properties(seed, n):
    rng = np.random.default_rng(seed)
    v = rng.normal(0, 3, n)
    mask = rng.uniform(size=n) > 0.3
    if not mask.any():
        mask[0] = True
    total = float(rng.uniform(0.1, 2.0))
    x = np.asarray(project_simplex(jnp.asarray(v, jnp.float32),
                                   jnp.asarray(mask), jnp.float32(total)))
    assert np.all(x >= -1e-6)
    assert abs(x.sum() - total) < 1e-4
    assert np.all(x[~mask] == 0)


# ---------------------------------------------------------------------------
# trace float encoding: lossless JSON round-trip incl. inf/-inf/nan, so an
# async run's recorded arrival times replay bit-exactly
# ---------------------------------------------------------------------------
@given(st.one_of(st.none(),
                 st.floats(allow_nan=True, allow_infinity=True)))
@settings(max_examples=200, deadline=None)
def test_trace_num_unnum_round_trip(x):
    import json
    got = _unnum(json.loads(json.dumps(_num(x))))
    if x is None:
        assert got is None
    elif np.isnan(x):
        assert np.isnan(got)
    else:
        assert got == x


# ---------------------------------------------------------------------------
# communication codecs (repro.fl.comm): byte counts are value-independent
# and exactly nbytes(template); quantizers respect their error bounds; every
# lossy codec is a contraction (the EF convergence prerequisite)
# ---------------------------------------------------------------------------
CODEC_SPECS = ["fp32", "fp16", "int8", "qsgd:2", "qsgd:4", "qsgd:8",
               "topk:0.1", "topk:0.5", "sign1"]


@given(st.integers(0, 10_000), st.sampled_from(CODEC_SPECS),
       st.integers(2, 40), st.integers(2, 40))
@settings(max_examples=40, deadline=None)
def test_codec_nbytes_value_independent_and_exact(seed, spec, d0, d1):
    rng = np.random.default_rng(seed)
    codec = make_codec(spec)
    tree = {"w": jnp.asarray(rng.normal(0, 10, (d0, d1)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(d1,)), jnp.float32)}
    payload = codec.encode(tree)
    assert payload.nbytes == codec.nbytes(tree)
    zeros = {k: jnp.zeros_like(v) for k, v in tree.items()}
    assert codec.encode(zeros).nbytes == payload.nbytes
    if not spec.startswith("topk"):
        # topk pays 8 B per kept entry (index + value), which can exceed
        # 4 B/param on tiny leaves or f = 0.5; the dense codecs only exceed
        # fp32 on 1-element leaves (the 4 B per-leaf scale dominates), which
        # the d0,d1 >= 2 draw excludes
        assert payload.nbytes <= make_codec("fp32").nbytes(tree)


@given(st.integers(0, 10_000), st.integers(2, 8), st.integers(1, 400))
@settings(max_examples=25, deadline=None)
def test_quantizer_error_bound_property(seed, bits, n):
    rng = np.random.default_rng(seed)
    codec = make_codec(f"qsgd:{bits}")
    x = {"w": jnp.asarray(rng.normal(0, 5, (n,)), jnp.float32)}
    dec = codec.decode(codec.encode(x))["w"]
    levels = (1 << (bits - 1)) - 1
    half_step = float(jnp.max(jnp.abs(x["w"]))) / levels / 2
    assert float(jnp.max(jnp.abs(dec - x["w"]))) <= half_step + 1e-6


@given(st.integers(0, 10_000),
       st.sampled_from(["fp16", "int8", "qsgd:4", "topk:0.25", "sign1"]),
       st.integers(2, 200))
@settings(max_examples=30, deadline=None)
def test_lossy_codec_contraction_property(seed, spec, n):
    rng = np.random.default_rng(seed)
    codec = make_codec(spec)
    x = {"w": jnp.asarray(rng.normal(0, 3, (n,)), jnp.float32)}
    if float(jnp.sum(jnp.abs(x["w"]))) < 1e-3:
        return
    dec = codec.decode(codec.encode(x))["w"]
    err = float(jnp.sum(jnp.square(dec - x["w"]))) ** 0.5
    norm = float(jnp.sum(jnp.square(x["w"]))) ** 0.5
    assert err < norm * (1.0 - 1e-6) + 1e-6


# ---------------------------------------------------------------------------
# per-round repricing (ISSUE 4): re-simulating the same link realization at
# different payload bytes moves only the transfer timings, monotonically in
# bytes — never the link draw itself
# ---------------------------------------------------------------------------
@given(st.integers(0, 10_000), st.integers(1, 10),
       st.floats(0.01, 1.0), st.floats(1.0, 100.0))
@settings(max_examples=30, deadline=None)
def test_repricing_is_monotone_in_bytes_and_preserves_links(seed, n, frac,
                                                            scale):
    rng = np.random.default_rng(seed)
    sim = DeadlineSimulator(n, model_bytes=4e6, deadline_s=float(
        rng.uniform(0.5, 20.0)), compute_s=float(rng.uniform(0.0, 3.0)),
        seed=seed)
    links = [LinkState(float(rng.uniform(0.05e6, 50e6 * scale)),
                       up=bool(rng.uniform() > 0.3),
                       cause="outage" if rng.uniform() > 0.5 else "ok")
             for _ in range(n)]
    big = sim.simulate_round(2, links)
    sim.set_payload_bytes(upload_bytes=4e6 * frac, download_bytes=4e6 * frac)
    small = sim.simulate_round(2, links)
    for e_big, e_small in zip(big.events, small.events):
        assert e_big.up == e_small.up
        assert e_big.capacity_bps == e_small.capacity_bps
        if not e_big.up:
            assert e_big.cause == e_small.cause          # link draw frozen
            continue
        assert e_small.t_upload_s <= e_big.t_upload_s
        assert e_small.t_download_s <= e_big.t_download_s
        assert e_small.finish_s <= e_big.finish_s
        assert e_small.t_compute_s == e_big.t_compute_s  # jitter keyed (seed, rnd)
        # met_deadline monotone: fewer bytes can only add participants
        assert e_small.met_deadline or not e_big.met_deadline


# ---------------------------------------------------------------------------
# adaptive controller (ISSUE 4): the rung policy is monotone in estimated
# capacity and never assigns beyond the ladder ceiling (fp32)
# ---------------------------------------------------------------------------
@given(st.integers(0, 10_000), st.integers(0, len(RUNG_LADDER) - 1),
       st.integers(0, len(RUNG_LADDER) - 1))
@settings(max_examples=30, deadline=None)
def test_adaptive_ladder_monotone_property(seed, a, b):
    lo, hi = RUNG_LADDER[min(a, b)], RUNG_LADDER[max(a, b)]
    rng = np.random.default_rng(seed)
    tmpl = {"w": jnp.zeros((int(rng.integers(10, 5000)),), jnp.float32)}
    comm = CommState(make_codec("fp32"), tmpl,
                     model_bytes_override=float(rng.uniform(1e5, 1e8)))
    ctl = AdaptiveCommController(
        4, comm, lo=lo, hi=hi, deadline_s=float(rng.uniform(0.5, 60.0)),
        compute_s=float(rng.uniform(0.0, 3.0)))
    caps = np.sort(rng.uniform(1e2, 1e13, 25))
    idx = [ctl.rung_index_for(c) for c in caps]
    assert idx == sorted(idx)                            # monotone in capacity
    assert all(0 <= k < len(ctl.rungs) for k in idx)
    assert ctl.rungs[-1] == hi                           # ceiling respected
    assert (np.diff(ctl.rung_bytes) >= 0).all()          # ladder byte order
    assert ctl.rung_bytes[-1] <= comm.nbytes_for("fp32") + 1e-9


# ---------------------------------------------------------------------------
# fused dequantize-and-β-accumulate kernel == reference on random payloads
# ---------------------------------------------------------------------------
@given(st.integers(0, 10_000), st.integers(1, 8), st.integers(1, 700))
@settings(max_examples=15, deadline=None)
def test_dequant_fedagg_matches_ref_property(seed, m, p):
    from repro.kernels import ref
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.integers(-127, 128, (m, p)), jnp.int8)
    scales = jnp.asarray(rng.uniform(1e-4, 1e-1, m), jnp.float32)
    betas = jnp.asarray(rng.dirichlet(np.ones(m)), jnp.float32)
    out = np.asarray(dequant_fedagg(q, scales, betas, interpret=True,
                                    block=256))
    expect = np.asarray(ref.dequant_fedagg(q, scales, betas))
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# fedagg kernel convexity (from test_kernels)
# ---------------------------------------------------------------------------
@given(st.integers(0, 10_000), st.integers(1, 8), st.integers(1, 700))
@settings(max_examples=15, deadline=None)
def test_fedagg_convex_hull_property(seed, m, p):
    """With β on the simplex, every output coordinate lies within
    [min_m x, max_m x] — aggregation can never extrapolate."""
    rng = np.random.default_rng(seed)
    stacked = jnp.asarray(rng.normal(0, 5, (m, p)).astype(np.float32))
    beta = jnp.asarray(rng.dirichlet(np.ones(m)).astype(np.float32))
    out = np.asarray(fedagg(stacked, beta, interpret=True, block=256))
    lo = np.min(np.asarray(stacked), axis=0) - 1e-4
    hi = np.max(np.asarray(stacked), axis=0) + 1e-4
    assert np.all(out >= lo) and np.all(out <= hi)


# ---------------------------------------------------------------------------
# sketch-mode telemetry (ISSUE 8): GK quantile sketches honor their
# documented rank-error bound and exact summation is order-independent
# (deterministic variants live in test_obs_scale)
# ---------------------------------------------------------------------------
@given(st.integers(0, 10_000),
       st.sampled_from(["normal", "exp", "ints", "sorted", "constant"]),
       st.integers(50, 4000), st.sampled_from([0.01, 0.05]))
@settings(max_examples=25, deadline=None)
def test_gk_quantile_rank_error_property(seed, dist, n, eps):
    """For any stream and quantile q, the sketch's answer has rank within
    ε·n of ⌈q·n⌉ — the bound SKETCH_EPS documents for sketch-mode reports."""
    import math
    from bisect import bisect_left, bisect_right

    from repro.obs import GKQuantiles

    rng = np.random.default_rng(seed)
    vals = {"normal": lambda: rng.normal(0, 1, n),
            "exp": lambda: rng.exponential(1.0, n),
            "ints": lambda: rng.integers(0, 7, n).astype(float),
            "sorted": lambda: np.sort(rng.uniform(0, 1, n)),
            "constant": lambda: np.full(n, 3.25)}[dist]()
    gk = GKQuantiles(eps)
    for v in vals:
        gk.add(float(v))
    srt = sorted(float(v) for v in vals)
    for q in (0.05, 0.25, 0.5, 0.75, 0.95, 0.99):
        got = gk.query(q)
        target = max(1, math.ceil(q * n))
        lo = bisect_left(srt, got) + 1
        hi = bisect_right(srt, got)
        slack = eps * n + 1
        assert lo - slack <= target <= hi + slack


@given(st.integers(0, 10_000), st.integers(1, 300))
@settings(max_examples=40, deadline=None)
def test_exact_sum_order_independent_property(seed, n):
    """Shewchuk accumulation is bit-equal to math.fsum over the same
    multiset regardless of fold order — the property that makes sketch-mode
    byte totals reconcile bit-for-bit against full mode."""
    import math

    from repro.obs import ExactSum

    rng = np.random.default_rng(seed)
    vals = list(np.exp(rng.normal(0.0, 12.0, n)) *
                rng.choice([-1.0, 1.0], n))
    want = math.fsum(vals)
    fwd, rev = ExactSum(), ExactSum()
    for v in vals:
        fwd.add(v)
    for v in reversed(vals):
        rev.add(v)
    assert fwd.value() == want == rev.value()


# ---------------------------------------------------------------------------
# population-scale engine invariants (PR 9; deterministic variants in
# tests/test_population.py)
# ---------------------------------------------------------------------------
@given(st.integers(0, 10_000),
       st.floats(min_value=0.05, max_value=8.0),
       st.floats(min_value=1.01, max_value=8.0))
@settings(max_examples=25, deadline=None)
def test_arrival_times_monotone_in_payload_both_engines(seed, mb, factor):
    """Growing the payload can never make any client's arrival earlier —
    on fixed links with the deadline out of the way, the realized finish
    times are elementwise monotone in payload bytes, identically under the
    heap and vectorized engines (which must also agree bit-for-bit)."""
    n = 12
    rng = np.random.default_rng(seed)
    links = [LinkState(float(c)) for c in
             np.exp(rng.normal(14.0, 2.0, n))]          # ~1e4..1e8 bps
    fins = {}
    for eng in ("heap", "vectorized"):
        fin = []
        for bytes_ in (mb * 1e6, mb * factor * 1e6):
            sim = DeadlineSimulator(n, model_bytes=bytes_, deadline_s=1e12,
                                    seed=seed, engine=eng)
            fin.append(sim.simulate_round(1, links).finish_array())
        assert np.all(fin[1] >= fin[0])                 # monotone in payload
        fins[eng] = fin
    for a, b in zip(fins["heap"], fins["vectorized"]):  # engines bit-equal
        assert np.array_equal(a, b)
