"""PR 7: machine-readable bench baselines + cross-run regression diffing.

Unit coverage of the ``BENCH_<name>.json`` schema (``benchmarks.common``)
and the ``benchmarks.report diff`` gate: kind classification, JSON
round-trip, tolerance bands per metric kind, pairing/expansion semantics,
and the CLI exit codes (0 clean / 1 regression / 2 usage or schema error).
No FFT runs — everything here works on synthetic baselines, so the file
stays fast enough for tier-1.
"""
import copy
import json

import pytest

from benchmarks.common import (BENCH_SCHEMA, BENCH_VERSION, BenchResult,
                               env_fingerprint, load_bench_json,
                               write_bench_json)
from benchmarks.report import (ACC_ATOL, COUNT_ATOL, OK, REGRESSION,
                               TIMING_FLOOR_US, TIMING_RTOL, WARNING,
                               diff_baselines, diff_metric,
                               expand_bench_paths, main, pair_baselines)
from benchmarks.run import run_benches


# ---------------------------------------------------------------------------
# BenchResult: kind classification and (de)serialization
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name,derived,value,kind", [
    ("adaptive:diurnal/sync/fp32", "0.8125", 0.8125, "accuracy"),
    ("adaptive:diurnal/sync/replay_bit_exact", "1", 1.0, "exact"),
    ("fidelity:diurnal/sync/none/mean_distortion", "0.0312", 0.0312,
     "accuracy"),
    ("adaptive:diurnal/sync/fp32/participants", "5.250", 5.25, "count"),
    ("adaptive:diurnal/sync/fp32/uplink_MB", "88.00", 88.0, "count"),
    ("comm:lossy/sync/fp32/upload_bytes", "4000000", 4e6, "count"),
    ("kernels/fedagg_ref_xla", "14.6", 14.6, "timing"),
    ("async:staleness/t_to_sync_final", "inf", float("inf"), "timing"),
    ("table2/us_per_round_total_s", "1.5", 1.5, "count"),
    ("adaptive:diurnal/sync/rungs", "sign1:3|fp16:2", None, "info"),
])
def test_classify(name, derived, value, kind):
    got_value, got_kind = BenchResult.classify(name, derived)
    assert got_kind == kind
    assert got_value == value


def test_from_csv_row_and_back():
    r = BenchResult.from_csv_row("fig2/fedavg,1234,0.7500")
    assert (r.name, r.us_per_call, r.derived) == ("fig2/fedavg", 1234.0,
                                                  "0.7500")
    assert (r.value, r.kind) == (0.75, "accuracy")
    assert r.csv_row() == "fig2/fedavg,1234,0.7500"
    # derived may itself contain commas (info payloads)
    r2 = BenchResult.from_csv_row("x/ERROR,0,ValueError:a,b")
    assert r2.derived == "ValueError:a,b" and r2.kind == "info"
    with pytest.raises(ValueError, match="not a name"):
        BenchResult.from_csv_row("just-one-field")
    with pytest.raises(ValueError, match="unknown metric kind"):
        BenchResult(name="x", us_per_call=0, derived="0", kind="vibes")


def test_json_roundtrip_preserves_phases():
    r = BenchResult(name="fidelity:a/sync/none", us_per_call=5000.0,
                    derived="0.8000", value=0.8, kind="accuracy",
                    phases={"uplink": 0.12, "local_update": 0.5})
    r2 = BenchResult.from_json(r.to_json())
    assert r2 == r


def _write(tmp_path, fname, bench, results, mutate=None):
    path = str(tmp_path / fname)
    write_bench_json(path, bench, results, elapsed_s=1.0,
                     env={"quick": True})
    if mutate:
        doc = json.load(open(path))
        mutate(doc)
        json.dump(doc, open(path, "w"))
    return path


def test_write_load_schema_gate(tmp_path):
    res = [BenchResult.from_csv_row("a/x,100,0.5")]
    path = _write(tmp_path, "BENCH_a.json", "a", res)
    doc = load_bench_json(path)
    assert (doc["schema"], doc["version"]) == (BENCH_SCHEMA, BENCH_VERSION)
    assert doc["bench"] == "a" and len(doc["results"]) == 1
    bad = _write(tmp_path, "BENCH_bad.json", "a", res,
                 mutate=lambda d: d.update(version=99))
    with pytest.raises(ValueError, match="not a fft-bench"):
        load_bench_json(bad)
    worse = _write(tmp_path, "BENCH_worse.json", "a", res,
                   mutate=lambda d: d.pop("results"))
    with pytest.raises(ValueError, match="missing 'results'"):
        load_bench_json(worse)


def test_env_fingerprint_fields():
    env = env_fingerprint(quick=True)
    for key in ("git_sha", "jax", "numpy", "python", "quick", "date"):
        assert key in env
    assert env["quick"] is True
    assert env["date"].endswith("Z")


# ---------------------------------------------------------------------------
# diff_metric: one band per kind
# ---------------------------------------------------------------------------
def _res(value, kind="accuracy", us=1000.0):
    return BenchResult(name="m", us_per_call=us, derived=str(value),
                       value=None if kind == "info" else float(value),
                       kind=kind)


def test_accuracy_band_is_one_sided():
    old = _res(0.80)
    assert diff_metric("accuracy", old, _res(0.80 - ACC_ATOL / 2))[0] == OK
    assert diff_metric("accuracy", old, _res(0.95))[0] == OK   # improvement
    status, note = diff_metric("accuracy", old, _res(0.80 - 2 * ACC_ATOL))
    assert status == REGRESSION and str(ACC_ATOL) in note


def test_count_band_is_symmetric():
    old = _res(5.0, "count")
    assert diff_metric("count", old, _res(5.0 + COUNT_ATOL / 2, "count"))[0] \
        == OK
    for moved in (5.0 + 2 * COUNT_ATOL, 5.0 - 2 * COUNT_ATOL):
        assert diff_metric("count", old, _res(moved, "count"))[0] \
            == REGRESSION


def test_exact_band_is_bit_for_bit():
    old = _res(1, "exact")
    assert diff_metric("exact", old, _res(1, "exact"))[0] == OK
    assert diff_metric("exact", old, _res(0, "exact"))[0] == REGRESSION


def test_timing_band_floor_and_strictness():
    lo = TIMING_FLOOR_US / 2
    # below the noise floor (both sides) nothing is flagged — interpreter
    # jitter territory, a 90% "blowup" of 100us means nothing
    assert diff_metric("timing", _res(lo, "timing"),
                       _res(lo * 1.9, "timing"))[0] == OK
    old = _res(10_000, "timing")
    slow = _res(10_000 * (1 + TIMING_RTOL) + TIMING_FLOOR_US + 1, "timing")
    assert diff_metric("timing", old, slow)[0] == WARNING
    assert diff_metric("timing", old, slow, strict_timing=True)[0] \
        == REGRESSION
    # inf -> inf passes (t_to_* metrics may legitimately never converge)
    inf = _res(float("inf"), "timing")
    assert diff_metric("timing", inf, inf)[0] == OK


def test_info_band_only_warns():
    old, new = _res("a|b", "info"), _res("a|c", "info")
    assert diff_metric("info", old, old)[0] == OK
    assert diff_metric("info", old, new) == (WARNING, "payload changed")


# ---------------------------------------------------------------------------
# diff_baselines: pairing, missing metrics, table, exit codes
# ---------------------------------------------------------------------------
ROWS = ["a/acc,1000,0.8000", "a/acc/participants,0,5.000",
        "a/replay_bit_exact,0,1", "kernels/k0,900,14.6"]


def _baseline_pair(tmp_path, perturb=None):
    res = [BenchResult.from_csv_row(r) for r in ROWS]
    old = _write(tmp_path, "old_BENCH_a.json", "a", res)
    new_res = copy.deepcopy(res)
    if perturb:
        perturb(new_res)
    new = _write(tmp_path, "new_BENCH_a.json", "a", new_res)
    return [old, new]


def test_diff_identical_is_clean(tmp_path):
    md, n_reg = diff_baselines(_baseline_pair(tmp_path))
    assert n_reg == 0
    assert "No regressions, no warnings." in md


def test_diff_flags_accuracy_regression(tmp_path):
    def perturb(res):
        res[0].value, res[0].derived = 0.70, "0.7000"
    md, n_reg = diff_baselines(_baseline_pair(tmp_path, perturb))
    assert n_reg == 1
    assert "| a | a/acc | accuracy | 0.8000 | 0.7000 | REGRESSION |" in md


def test_diff_flags_missing_metric_and_new_metric(tmp_path):
    def perturb(res):
        res.pop(1)                          # participants disappears
        res.append(BenchResult.from_csv_row("a/new_metric,0,1.0"))
    md, n_reg = diff_baselines(_baseline_pair(tmp_path, perturb))
    assert n_reg == 1
    assert "metric disappeared" in md and "a/acc/participants" in md
    assert "new metric, no baseline" in md and "a/new_metric" in md


def test_diff_flags_exact_flip_and_count_move(tmp_path):
    def perturb(res):
        res[1].value, res[1].derived = 6.0, "6.000"     # count move
        res[2].value, res[2].derived = 0.0, "0"         # exact flip
    md, n_reg = diff_baselines(_baseline_pair(tmp_path, perturb))
    assert n_reg == 2
    assert "exactness indicator changed" in md
    assert f"moved more than ±{COUNT_ATOL}" in md


def test_diff_timing_warns_unless_strict(tmp_path):
    def perturb(res):
        res[3].us_per_call *= 4
        res[3].value *= 4
        res[3].derived = str(res[3].value)
    paths = _baseline_pair(tmp_path, perturb)
    md, n_reg = diff_baselines(paths)
    assert n_reg == 0 and "warning" in md
    md, n_reg = diff_baselines(paths, strict_timing=True)
    assert n_reg >= 1 and "REGRESSION" in md


def test_diff_us_per_call_checked_on_every_row(tmp_path):
    def perturb(res):
        res[0].us_per_call = 100_000        # headline metric got 100x slower
    md, n_reg = diff_baselines(_baseline_pair(tmp_path, perturb),
                               strict_timing=True)
    assert n_reg == 1 and "us_per_call" in md


def test_unpaired_bench_is_a_regression(tmp_path):
    paths = _baseline_pair(tmp_path)
    lone = _write(tmp_path, "BENCH_lonely.json", "lonely",
                  [BenchResult.from_csv_row("l/x,0,1.0")])
    md, n_reg = diff_baselines(paths + [lone])
    assert n_reg == 1
    assert "(whole bench)" in md and "no candidate run to compare" in md


def test_pairing_rejects_third_occurrence(tmp_path):
    paths = _baseline_pair(tmp_path)
    third = _write(tmp_path, "BENCH_third.json", "a",
                   [BenchResult.from_csv_row("a/acc,0,0.8")])
    with pytest.raises(ValueError, match="more than twice"):
        pair_baselines(paths + [third])
    with pytest.raises(ValueError, match="appeared only once"):
        diff_baselines(paths[:1])


def test_expand_bench_paths(tmp_path):
    old_dir, new_dir = tmp_path / "old", tmp_path / "new"
    old_dir.mkdir(), new_dir.mkdir()
    res = [BenchResult.from_csv_row("a/x,0,1.0")]
    _write(old_dir, "BENCH_a.json", "a", res)
    _write(new_dir, "BENCH_a.json", "a", res)
    paths = expand_bench_paths([str(old_dir), str(new_dir)])
    assert [p.split("/")[-2] for p in paths] == ["old", "new"]
    md, n_reg = diff_baselines(paths)
    assert n_reg == 0
    empty = tmp_path / "empty"
    empty.mkdir()
    with pytest.raises(ValueError, match="no BENCH"):
        expand_bench_paths([str(empty)])


# ---------------------------------------------------------------------------
# CLI exit codes (benchmarks.report main / benchmarks.run run_benches)
# ---------------------------------------------------------------------------
def test_report_main_exit_codes(tmp_path, capsys):
    paths = _baseline_pair(
        tmp_path, lambda res: setattr(res[0], "derived", "0.5000")
        or setattr(res[0], "value", 0.5))
    clean = tmp_path / "c"
    clean.mkdir()
    assert main(["report", "diff"] + _baseline_pair(clean)) == 0
    assert main(["report", "diff"] + paths) == 1
    out = capsys.readouterr().out
    assert "| REGRESSION |" in out and "a/acc" in out
    # usage / schema errors exit 2 without a traceback
    assert main(["report", "diff"]) == 2
    assert main(["report"]) == 2
    bogus = tmp_path / "BENCH_bogus.json"
    bogus.write_text('{"schema": "other", "version": 1}\n')
    assert main(["report", "diff", str(bogus), str(bogus)]) == 2
    err = capsys.readouterr().err
    assert "not a fft-bench" in err


class _FakeBench:
    def __init__(self, rows=None, exc=None):
        self._rows, self._exc = rows or [], exc

    def run(self, quick=True):
        if self._exc:
            raise self._exc
        return self._rows


def test_run_benches_tracks_failures(tmp_path, capsys):
    benches = {"good": _FakeBench(["g/x,100,0.9"]),
               "bad": _FakeBench(exc=RuntimeError("boom")),
               "alsogood": _FakeBench(["h/y,50,1.0"])}
    rc = run_benches(benches, quick=True, json_dir=str(tmp_path))
    assert rc == 1
    out, err = capsys.readouterr()
    # the failing bench emits an ERROR row but never stops later benches
    assert "bad/ERROR,0,RuntimeError:boom" in out
    assert "h/y,50,1.0" in out
    assert "# FAILED: bad" in err
    # JSON baselines exist for the successes only
    assert (tmp_path / "BENCH_good.json").exists()
    assert (tmp_path / "BENCH_alsogood.json").exists()
    assert not (tmp_path / "BENCH_bad.json").exists()
    doc = load_bench_json(str(tmp_path / "BENCH_good.json"))
    assert doc["results"][0]["name"] == "g/x"
    assert run_benches({"good": benches["good"]}, quick=True,
                       json_dir=None) == 0
