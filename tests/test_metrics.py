"""Unit tests for ``repro.fl.metrics`` (shared run-level metrics)."""
import math

import numpy as np
import pytest

from repro.fl.metrics import (accuracy_drawdown, distortion_replay_matches,
                              mean_distortion)


# ---------------------------------------------------------------------------
# accuracy_drawdown
# ---------------------------------------------------------------------------
def test_drawdown_basic():
    # running max 0.5 → dip to 0.1 is a 0.4 drawdown
    assert accuracy_drawdown([0.5, 0.1, 0.6, 0.55]) == pytest.approx(0.4)
    # monotone curve never draws down
    assert accuracy_drawdown([0.1, 0.2, 0.3]) == 0.0
    assert accuracy_drawdown([]) == 0.0
    assert accuracy_drawdown([0.7]) == 0.0


def test_drawdown_warmup_skips_early_dips_but_max_still_warms():
    hist = [0.5, 0.1, 0.6, 0.55]
    # warmup=2 ignores the early dip; the worst counted drawdown is the
    # final 0.6 → 0.55 dip
    assert accuracy_drawdown(hist, warmup=2) == pytest.approx(0.05)
    # the running max warms up over the skipped prefix: a curve that never
    # re-reaches its early peak still counts the gap after warmup
    assert accuracy_drawdown([0.9, 0.2, 0.3], warmup=2) == pytest.approx(0.6)
    # warmup past the end of the curve counts nothing
    assert accuracy_drawdown(hist, warmup=10) == 0.0


# ---------------------------------------------------------------------------
# mean_distortion
# ---------------------------------------------------------------------------
def test_mean_distortion_empty():
    assert mean_distortion([]) == 0.0
    # rounds with no uploads contribute nothing (and don't divide by zero)
    assert mean_distortion([{}, {}]) == 0.0


def test_mean_distortion_averages_per_upload():
    hist = [{0: 0.1, 1: 0.3}, {}, {2: 0.2}]
    assert mean_distortion(hist) == pytest.approx((0.1 + 0.3 + 0.2) / 3)


# ---------------------------------------------------------------------------
# distortion_replay_matches
# ---------------------------------------------------------------------------
class _FakeReplay:
    """Stub of ReplayFailureModel: round → recorded distortion array."""

    def __init__(self, per_round):
        self._per_round = per_round

    def distortions(self, rnd):
        return self._per_round.get(rnd)


def test_replay_matches_exact_and_nan_means_absent():
    rec = {1: np.array([0.1, math.nan, 0.3]),
           2: np.array([math.nan, 0.0, math.nan])}
    live = [{0: 0.1, 2: 0.3}, {1: 0.0}]
    assert distortion_replay_matches(_FakeReplay(rec), live, 2)


def test_replay_mismatch_value():
    rec = {1: np.array([0.1, math.nan])}
    assert not distortion_replay_matches(
        _FakeReplay(rec), [{0: 0.1 + 1e-9}], 1)


def test_replay_nan_but_live_uploaded():
    # the trace says client 1 uploaded nothing, the live run has it
    rec = {1: np.array([0.1, math.nan])}
    assert not distortion_replay_matches(
        _FakeReplay(rec), [{0: 0.1, 1: 0.2}], 1)


def test_replay_value_but_live_absent():
    rec = {1: np.array([0.1, 0.2])}
    assert not distortion_replay_matches(_FakeReplay(rec), [{0: 0.1}], 1)


def test_replay_absent_round_record():
    # a round with no trace record matches only an upload-free live round
    assert distortion_replay_matches(_FakeReplay({}), [{}], 1)
    assert not distortion_replay_matches(_FakeReplay({}), [{0: 0.5}], 1)
