"""Launch-layer units: HLO collective parser, roofline terms, sharding-rule
divisibility (via AbstractMesh — no 512-device init in the test process)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.roofline import (collective_bytes, model_flops,
                                   roofline_terms)
from repro.launch.sharding import INPUT_SHAPES, LONG_CONTEXT_OK, param_pspecs


HLO_SNIPPET = """
ENTRY %main {
  %ag = bf16[16,4096,512]{2,1,0} all-gather(%p0), replica_groups={...}
  %ar = f32[1024]{0} all-reduce(%x), to_apply=%add
  %t = (bf16[8,128]{1,0}, bf16[8,128]{1,0}) all-to-all(%a, %b)
  %rs = f32[2048]{0} reduce-scatter(%y), dimensions={0}
  %cp = bf16[64,64]{1,0} collective-permute(%z)
  %ags = bf16[32,32]{1,0} all-gather-start(%q)
  %dot = f32[128,128]{1,0} dot(%l, %r)
}
"""


def test_collective_parser_counts_all_kinds():
    out = collective_bytes(HLO_SNIPPET)
    assert out["all-gather"] == 16 * 4096 * 512 * 2 + 32 * 32 * 2
    assert out["all-reduce"] == 1024 * 4
    assert out["all-to-all"] == 2 * 8 * 128 * 2
    assert out["reduce-scatter"] == 2048 * 4
    assert out["collective-permute"] == 64 * 64 * 2
    assert "dot" not in out


def test_roofline_terms_dominance():
    t = roofline_terms(flops=197e12, bytes_accessed=1e9, coll_bytes=0)
    assert t["dominant"] == "compute" and abs(t["compute_s"] - 1.0) < 1e-9
    t = roofline_terms(flops=1e12, bytes_accessed=819e9, coll_bytes=0)
    assert t["dominant"] == "memory"
    t = roofline_terms(flops=0, bytes_accessed=0, coll_bytes=50e9)
    assert t["dominant"] == "collective"


def test_model_flops_conventions():
    cfg = get_config("qwen3-1.7b")
    n = cfg.active_param_count()
    tr = model_flops(cfg, INPUT_SHAPES["train_4k"])
    assert tr == 6.0 * n * 256 * 4096
    de = model_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert de == 2.0 * n * 128


def test_moe_active_params_smaller_than_total():
    cfg = get_config("deepseek-v2-236b")
    assert cfg.active_param_count() < 0.2 * cfg.param_count()
    dense = get_config("gemma-7b")
    assert dense.active_param_count() == dense.param_count()


def _abstract_mesh(shape, names):
    """AbstractMesh across JAX versions: >=0.5 takes (shape, names); 0.4.x
    takes a tuple of (name, size) pairs."""
    try:
        return jax.sharding.AbstractMesh(shape, names)
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(names, shape)))


@pytest.mark.parametrize("arch", ["deepseek-v2-236b", "mixtral-8x22b",
                                  "qwen3-1.7b", "zamba2-1.2b",
                                  "seamless-m4t-large-v2"])
@pytest.mark.parametrize("multi", [False, True])
def test_param_pspecs_divisible(arch, multi):
    """Every sharded param axis must divide by the mesh axis size — this is
    the invariant that makes all 70 dry-run lowerings legal."""
    from repro.models import transformer as T
    cfg = get_config(arch)
    shape = (2, 16, 16) if multi else (16, 16)
    names = ("pod", "data", "model") if multi else ("data", "model")
    mesh = _abstract_mesh(shape, names)
    params = jax.eval_shape(lambda k: T.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    specs = param_pspecs(params, cfg, mesh)

    def check(leaf, spec):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                continue
            size = int(np.prod([dict(mesh.shape)[a] for a in
                                (ax if isinstance(ax, tuple) else (ax,))]))
            assert dim % size == 0, (arch, leaf.shape, spec)

    jax.tree.map(check, params, specs,
                 is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    # at least the embeddings and attention weights actually shard
    n_sharded = sum(any(ax is not None for ax in tuple(s))
                    for s in jax.tree.leaves(
                        specs, is_leaf=lambda x: isinstance(
                            x, jax.sharding.PartitionSpec)))
    assert n_sharded >= 3


def test_long_context_gate_matches_design():
    assert "gemma-7b" not in LONG_CONTEXT_OK          # full attention
    assert "xlstm-125m" in LONG_CONTEXT_OK            # recurrent
    assert "mixtral-8x22b" in LONG_CONTEXT_OK         # SWA
    assert "deepseek-v2-236b" not in LONG_CONTEXT_OK  # MLA is still full attn
